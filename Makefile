# Canonical entry points for verification and benchmarks.
#
#   make test             tier-1 test suite (the CI / verify command)
#   make test-api         just the unified-API tests (fast)
#   make lint             dead-import lint (pyflakes when installed, AST fallback)
#   make bench-smoke      smoke benchmark subset (fig4_scaling, transform_fused,
#                         fit_fused at quick sizes) + BENCH_*.json artifact check
#   make bench-transform  fused-vs-legacy transform benchmark (BENCH_transform.json)
#   make bench-fit        fused fit-path benchmark (BENCH_fit.json)
#   make bench            full quick benchmark sweep
#   make dev-deps         install dev-only deps (pytest, hypothesis, pyflakes)

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-api lint bench bench-smoke bench-transform bench-fit dev-deps

test:
	$(PYTHON) -m pytest -x -q

test-api:
	$(PYTHON) -m pytest -q tests/test_api.py

lint:
	$(PYTHON) tools/lint.py src/repro benchmarks tools

bench-smoke:
	$(PYTHON) -m benchmarks.run --only fig4_scaling,transform_fused,fit_fused
	$(PYTHON) -m benchmarks.check_artifacts fit transform scaling

bench-transform:
	$(PYTHON) -m benchmarks.run --only transform_fused

bench-fit:
	$(PYTHON) -m benchmarks.run --only fit_fused

bench:
	$(PYTHON) -m benchmarks.run

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt
