# Canonical entry points for verification and benchmarks.
#
#   make test             tier-1 test suite (the CI / verify command)
#   make test-api         just the unified-API tests (fast)
#   make lint             dead-import lint (pyflakes when installed, AST fallback)
#   make ci               lint + tier-1 tests + chaos-smoke + bench-smoke
#                         artifact checks + bench-gate
#                         (what .github/workflows/ci.yml runs)
#   make bench-smoke      smoke benchmark subset (fig4_scaling, transform_fused,
#                         fit_fused, serve_engine, multiclass_batched at quick
#                         sizes) + BENCH_*.json artifact check
#   make bench-transform  fused-vs-legacy transform benchmark (BENCH_transform.json)
#   make bench-fit        fused fit-path benchmark (BENCH_fit.json)
#   make bench-serve      batched serving engine benchmark (BENCH_serve.json)
#   make bench-multiclass sequential-vs-class-batched multi-class fit benchmark
#                         (BENCH_multiclass.json)
#   make bench-streaming  out-of-core streaming fit benchmark (BENCH_streaming.json)
#   make bench-online     incremental update + continuous serving loop benchmark
#                         (BENCH_online.json)
#   make bench-resilience integrity overhead + crash-recovery benchmark
#                         (BENCH_resilience.json)
#   make bench-obs        observability overhead + sketch-fidelity benchmark
#                         (BENCH_obs.json)
#   make bench-gate       perf-regression gate: newest results/history.jsonl
#                         record vs the rolling baseline of earlier records
#   make obs-smoke        continuous loop with obs export (results/obs/trace.json,
#                         metrics.jsonl) + post-hoc obs_report render
#   make chaos-smoke      fault-injection harness (repro.launch.chaos_vi --fast):
#                         kill/resume, corrupt state, degraded activation,
#                         transient faults, poison isolation, torn shards
#   make serve-smoke      in-process CPU run of the serving CLI (repro.launch.serve_vi)
#   make continuous-smoke in-process CPU run of the ingest->refit->activate loop
#                         (repro.launch.continuous_vi)
#   make bench            full quick benchmark sweep
#   make clean            remove compiled bytecode and pytest caches
#   make dev-deps         install dev-only deps (pytest, hypothesis, pyflakes)

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-api lint ci bench bench-smoke bench-transform bench-fit \
        bench-serve bench-multiclass bench-streaming bench-online \
        bench-resilience bench-obs bench-gate chaos-smoke serve-smoke \
        continuous-smoke obs-smoke clean dev-deps

test:
	$(PYTHON) -m pytest -x -q

test-api:
	$(PYTHON) -m pytest -q tests/test_api.py

lint:
	$(PYTHON) tools/lint.py src/repro benchmarks tools

ci: lint test chaos-smoke bench-smoke bench-gate

bench-smoke:
	$(PYTHON) -m benchmarks.run --only fig4_scaling,transform_fused,fit_fused,serve_engine,multiclass_batched,streaming_oavi,online_oavi,resilience_chaos,obs_overhead
	$(PYTHON) -m benchmarks.check_artifacts fit transform scaling serve multiclass streaming online resilience obs

bench-transform:
	$(PYTHON) -m benchmarks.run --only transform_fused

bench-fit:
	$(PYTHON) -m benchmarks.run --only fit_fused

bench-serve:
	$(PYTHON) -m benchmarks.run --only serve_engine

bench-multiclass:
	$(PYTHON) -m benchmarks.run --only multiclass_batched

bench-streaming:
	$(PYTHON) -m benchmarks.run --only streaming_oavi

bench-online:
	$(PYTHON) -m benchmarks.run --only online_oavi

bench-resilience:
	$(PYTHON) -m benchmarks.run --only resilience_chaos

bench-obs:
	$(PYTHON) -m benchmarks.run --only obs_overhead

bench-gate:
	$(PYTHON) -m benchmarks.history --gate

obs-smoke:
	$(PYTHON) -m repro.launch.continuous_vi --base-rows 2048 --increments 2 \
		--increment-rows 1024 --shard-rows 1024 --chunk-rows 512 \
		--min-update-rows 1024 --obs-dir results/obs
	$(PYTHON) -m repro.launch.obs_report --obs-dir results/obs

chaos-smoke:
	$(PYTHON) -m repro.launch.chaos_vi --fast

continuous-smoke:
	$(PYTHON) -m repro.launch.continuous_vi --base-rows 4096 --increments 4 \
		--increment-rows 1024 --shard-rows 1024 --chunk-rows 512

serve-smoke:
	$(PYTHON) -m repro.launch.serve_vi --fit-m 1500 --requests 96 --mean-rows 64 \
		--concurrency 8 --min-bucket 32 --max-bucket 4096

bench:
	$(PYTHON) -m benchmarks.run

clean:
	find . -name __pycache__ -type d -prune -exec rm -rf {} +
	rm -rf .pytest_cache

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt
