# Canonical entry points for verification and benchmarks.
#
#   make test             tier-1 test suite (the CI / verify command)
#   make test-api         just the unified-API tests (fast)
#   make bench-transform  fused-vs-legacy transform benchmark (BENCH_*.json)
#   make bench            full quick benchmark sweep
#   make dev-deps         install dev-only deps (pytest, hypothesis)

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-api bench bench-transform dev-deps

test:
	$(PYTHON) -m pytest -x -q

test-api:
	$(PYTHON) -m pytest -q tests/test_api.py

bench-transform:
	$(PYTHON) -m benchmarks.run --only transform_fused

bench:
	$(PYTHON) -m benchmarks.run

dev-deps:
	$(PYTHON) -m pip install -r requirements-dev.txt
