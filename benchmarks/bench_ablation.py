"""Beyond-paper ablation: the vanishing parameter psi as the scale dial.

Theorem 4.3 ties psi to the generator budget (|G|+|O| <= C(D+n, D) with
D = ceil(-log psi / log 4)); this ablation sweeps psi on the Appendix-C
synthetic and reports the realized |G|+|O|, termination degree, training
time, and downstream test error — the practical trade-off surface a user
of the framework navigates (smaller psi: more/higher-degree generators,
slower, until overfitting to noise).
"""

from __future__ import annotations

import time

from repro.core import terms
from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier
from repro.data.synthetic import appendix_c, train_test_split

from .common import Reporter


def run(rep: Reporter, quick: bool = True):
    m = 4000 if quick else 40000
    X, y = appendix_c(m=m, seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, 0.4, seed=0)
    psis = [0.1, 0.02, 0.005, 0.001] if quick else [0.1, 0.05, 0.02, 0.01, 0.005, 0.002, 0.001]
    for psi in psis:
        clf = VanishingIdealClassifier(PipelineConfig(
            method="cgavi-ihb", psi=psi, oavi_kw={"cap_terms": 128}))
        t0 = time.perf_counter()
        clf.fit(Xtr, ytr)
        t_fit = time.perf_counter() - t0
        err = 100.0 * (1.0 - clf.score(Xte, yte))
        max_deg = max(
            (max((sum(g.term) for g in mdl.generators), default=0)
             for mdl in clf.models), default=0)
        rep.add("ablation_psi", psi=psi,
                bound_per_class=terms.theorem_4_3_size_bound(psi, X.shape[1]),
                G_plus_O=clf.stats["G_plus_O"],
                max_degree=max_deg,
                t_fit_s=round(t_fit, 2),
                err_test_pct=round(err, 2))
