"""Figure 1: Theorem 4.3 bound on |G|+|O| vs psi and n; empirical validation.

Left panel: the bound C(D+n, D), D = ceil(-log psi / log 4), over a psi grid
for several n.  Right panel: empirical |G|+|O| from CGAVI on random data in
[0,1]^n (10k samples) vs the bound — the paper finds the empirical count
slightly below the bound; we assert containment.
"""

from __future__ import annotations


from repro.core import oavi, terms
from repro.core.oavi import OAVIConfig
from repro.core.oracles import OracleConfig
from repro.data.synthetic import random_cube

from .common import Reporter


def run(rep: Reporter, quick: bool = True):
    # -- left: the bound surface
    for n in [1, 2, 3, 5, 10]:
        for psi in [0.2, 0.1, 0.05, 0.01, 0.005, 0.001]:
            rep.add("fig1_bound", n=n, psi=psi,
                    D=terms.theorem_4_3_degree_bound(psi),
                    bound=terms.theorem_4_3_size_bound(psi, n))

    # -- right: empirical vs bound on random data
    m = 2000 if quick else 10000
    ns = [1, 2, 3, 4] if quick else [1, 2, 3, 4, 5, 6]
    psi = 0.005
    for n in ns:
        X = random_cube(m, n, seed=0)
        model = oavi.fit(
            X,
            OAVIConfig(psi=psi, engine="oracle", ihb=True,
                       solver=OracleConfig(name="cg"), cap_terms=128),
        )
        bound = terms.theorem_4_3_size_bound(psi, n)
        emp = model.num_G + model.num_O
        assert emp <= bound, (emp, bound)
        rep.add("fig1_empirical", n=n, psi=psi, m=m,
                G_plus_O=emp, bound=bound, n4=n**4,
                time_s=round(model.stats["time_total"], 2))
