"""Fit-path benchmark: kernel-fused degree step vs the pre-PR jnp path.

Measures, at quick and ``--full`` scales:

* **fit wall clock + per-degree breakdown** — the fused path
  (:func:`repro.core.oavi.fit`: ``kernels.ops.gram_update`` dispatch, slimmed
  IHB state, pow2 capacity buckets with device-side regrowth and the global
  jitted-step cache) against a self-contained *legacy* reimplementation of
  the pre-PR degree step (inline jnp Gram matmuls over the full fixed
  ``Lcap=256`` buffer, all three IHB factors updated per candidate, numpy
  round-trip regrowth).  Both paths are warmed first so compile time is
  excluded; the outputs are asserted bit-exact (same O, same generators,
  same coefficients, same MSEs).
* **steady-state recompiles** — a second fused fit must report
  ``stats["recompiles"] == 0``.
* **wavefront term evaluation** — ``evaluate_terms`` (degree-wavefront) vs
  the sequential ``fori_loop`` on a fitted model with ``|O| >= 100`` at
  q=10k rows (the serving-latency win used by ``api.feature_transform``).

Emits ``results/BENCH_fit.json`` (``bench.v1`` schema).

    PYTHONPATH=src python -m benchmarks.run --only fit_fused
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ihb as ihb_mod
from repro.core import oavi, terms as terms_mod
from repro.kernels import ops as kernel_ops
from repro.core.oavi import (
    Generator,
    OAVIConfig,
    _LoopState,
    _SOLVER_FNS,
    _append_columns,
    evaluate_terms_sequential,
    make_wavefront_evaluator,
)
from repro.core.ordering import pearson_order
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import appendix_c, random_cube

from .common import Reporter, timeit, write_bench_json

LEGACY_CAP_TERMS = 256  # the pre-PR default initial (and usually only) Lcap


# ---------------------------------------------------------------------------
# Pre-PR reference: inline jnp Gram matmuls + full 3-factor IHB state
# ---------------------------------------------------------------------------


def _make_legacy_degree_step(cfg: OAVIConfig):
    """The pre-PR degree step, verbatim semantics: ``QL = A^T B`` / ``C =
    B^T B`` as inline jnp matmuls over the full capacity buffer, closed-form
    warm start always computed, and every candidate append updating AtA, N
    *and* R (the full :class:`IHBState`)."""
    solver = _SOLVER_FNS[cfg.solver.name]
    use_chol = cfg.inverse_engine == "chol"

    def degree_step(A, X, state, ell0, parents, vars_, valid, m_total):
        dtype = A.dtype
        Lcap = A.shape[1]
        K = parents.shape[0]
        psi = jnp.asarray(cfg.psi, dtype)
        inv_m = jnp.asarray(1.0 / m_total, dtype)
        one = jnp.asarray(1.0, dtype)

        P = jnp.take(A, parents, axis=1)
        B = P * jnp.take(X, vars_, axis=1)
        # same canonical GRAM_BLOCK-row blocked reduction as the fused step
        # (kernels.ops.gram_accumulate): like the mse0 normalization below,
        # the bit-exactness assert compares the fusion work, not the O(m)
        # Gram summation order (the pre-PR code used one un-blocked matmul)
        QL_raw, C_raw = kernel_ops.gram_accumulate(A, X, parents, vars_)
        QL = QL_raw * inv_m
        C = C_raw * inv_m

        def body(a, st):
            q = QL[:, a]
            appended_before = (jnp.arange(K) < a) & (~st.accepted) & (st.slots < Lcap) & valid
            safe_slots = jnp.where(appended_before, st.slots, 0)
            q = q.at[safe_slots].add(jnp.where(appended_before, C[:, a], 0.0), mode="drop")
            btb = C[a, a]

            mask = jnp.arange(Lcap) < st.ell
            if use_chol:
                y0 = ihb_mod.closed_form_cholesky(st.ihb, q)
            else:
                y0 = ihb_mod.closed_form_inverse(st.ihb, q)
            y0 = jnp.where(mask, y0, 0.0)
            # same vmap-bit-stable reduction as the fused step (oavi.py): the
            # bit-exactness assert compares the fusion work, not the reduction
            mse0 = btb + jnp.sum(q * y0)

            if cfg.engine == "fast":
                y, mse_final, it = y0, mse0, jnp.asarray(0, jnp.int32)
                ihb_live = st.ihb_live
            else:
                feasible = jnp.sum(jnp.abs(y0)) <= (cfg.solver.tau - 1.0)
                use_warm = st.ihb_live & feasible if cfg.ihb else jnp.asarray(False)
                ihb_live = st.ihb_live & (feasible | jnp.asarray(not cfg.ihb))
                warm = jnp.where(use_warm, y0, 0.0)
                res = solver(st.ihb.AtA, q, btb, one, mask, psi, cfg.solver, warm)
                y, mse_final, it = res.y, res.f, res.iters

            accept = (mse_final <= psi) & valid[a]
            do_append = (~accept) & valid[a]

            def appended(st_in):
                new_ihb = ihb_mod.append_column(st_in.ihb, q, btb, st_in.ell)
                return st_in._replace(
                    ihb=new_ihb, ell=st_in.ell + 1, slots=st_in.slots.at[a].set(st_in.ell)
                )

            st = jax.lax.cond(do_append, appended, lambda s: s, st)
            return st._replace(
                ihb_live=ihb_live,
                accepted=st.accepted.at[a].set(accept),
                coeffs=st.coeffs.at[a].set(jnp.where(accept, y, 0.0)),
                mses=st.mses.at[a].set(mse_final),
                iters=st.iters.at[a].set(it),
            )

        st0 = _LoopState(
            ihb=state,
            ell=ell0,
            ihb_live=jnp.asarray(True),
            accepted=jnp.zeros((K,), bool),
            slots=jnp.full((K,), Lcap, jnp.int32),
            coeffs=jnp.zeros((K, Lcap), dtype),
            mses=jnp.zeros((K,), dtype),
            iters=jnp.zeros((K,), jnp.int32),
        )
        st = jax.lax.fori_loop(0, K, body, st0)
        appended = (~st.accepted) & valid & (st.slots < Lcap)
        A = _append_columns(A, B, st.slots, appended)
        return A, st

    return degree_step


_LEGACY_STEPS = {}  # cfg -> jitted legacy step (so repeat timing excludes compile)


def legacy_fit(X, config: OAVIConfig):
    """The pre-PR fit loop: fixed ``Lcap = 256`` full buffer from the start,
    full IHB state (all factors), numpy round-trip capacity regrowth."""
    dtype = config.jax_dtype()
    X = np.asarray(X)
    m, n = X.shape
    perm = None
    if config.ordering in ("pearson", "reverse_pearson"):
        perm = pearson_order(X, reverse=(config.ordering == "reverse_pearson"))
        X = X[:, perm]
    Xd = jnp.asarray(X, dtype)
    book = terms_mod.TermBook(n=n)
    generators: List[Generator] = []

    Lcap = LEGACY_CAP_TERMS
    A = jnp.zeros((m, Lcap), dtype).at[:, 0].set(1.0)
    state = ihb_mod.init_state(Lcap, jnp.asarray(1.0, dtype), dtype)
    ell = 1
    if config not in _LEGACY_STEPS:
        _LEGACY_STEPS[config] = jax.jit(_make_legacy_degree_step(config))
    degree_step = _LEGACY_STEPS[config]
    degree_times = []

    d = 0
    while True:
        d += 1
        if d > config.max_degree:
            break
        border = book.border(d)
        if not border:
            break
        K = len(border)
        while ell + K > Lcap:  # numpy round-trip regrowth (pre-PR behaviour)
            Lcap *= 2
            A = jnp.asarray(np.pad(np.asarray(A), ((0, 0), (0, Lcap - A.shape[1]))))
            AtA = np.asarray(state.AtA)
            AtAn = np.zeros((Lcap, Lcap), AtA.dtype)
            AtAn[: AtA.shape[0], : AtA.shape[1]] = AtA
            N = np.asarray(state.N)
            Nn = np.eye(Lcap, dtype=N.dtype)
            Nn[: N.shape[0], : N.shape[1]] = N
            R = np.asarray(state.R)
            Rn = np.eye(Lcap, dtype=R.dtype)
            Rn[: R.shape[0], : R.shape[1]] = R
            state = ihb_mod.IHBState(
                AtA=jnp.asarray(AtAn), N=jnp.asarray(Nn), R=jnp.asarray(Rn)
            )

        Kcap = max(config.cap_border, 1 << max(K - 1, 1).bit_length())
        parents = np.zeros((Kcap,), np.int32)
        vars_ = np.zeros((Kcap,), np.int32)
        valid = np.zeros((Kcap,), bool)
        for i, (term, parent, j) in enumerate(border):
            parents[i] = book.index[parent]
            vars_[i] = j
            valid[i] = True

        t0 = time.perf_counter()
        A, st = degree_step(
            A, Xd, state, jnp.asarray(ell, jnp.int32), jnp.asarray(parents),
            jnp.asarray(vars_), jnp.asarray(valid), jnp.asarray(float(m), dtype),
        )
        state = st.ihb
        accepted = np.asarray(st.accepted)
        mses = np.asarray(st.mses)
        coeffs = np.asarray(st.coeffs)
        degree_times.append(time.perf_counter() - t0)

        for i, (term, parent, j) in enumerate(border):
            if accepted[i]:
                generators.append(
                    Generator(
                        term=term, parent_idx=book.index[parent], var=j,
                        coeffs=coeffs[i, : len(book)].copy(), mse=float(mses[i]),
                    )
                )
            else:
                book.append(term, parent, j)
        ell = len(book)

    model = oavi.OAVIModel(
        n=n, psi=config.psi, book=book, generators=generators,
        feature_perm=perm, stats={"degree_times": degree_times}, dtype=config.dtype,
    )
    return model


def _assert_bit_exact(fused: oavi.OAVIModel, legacy: oavi.OAVIModel):
    assert fused.book.terms == legacy.book.terms, "term books differ"
    assert [g.term for g in fused.generators] == [g.term for g in legacy.generators]
    for gf, gl in zip(fused.generators, legacy.generators):
        assert np.array_equal(gf.coeffs, gl.coeffs), f"coeffs differ for {gf.term}"
        assert gf.mse == gl.mse, f"mse differs for {gf.term}: {gf.mse} vs {gl.mse}"


def _assert_same_model(fused: oavi.OAVIModel, legacy: oavi.OAVIModel) -> float:
    """Structure must match exactly; coefficients may carry the fp rounding
    of the tighter Lcap bucket (different XLA matmul shapes).  Returns the
    max abs coefficient difference."""
    assert fused.book.terms == legacy.book.terms, "term books differ"
    assert [g.term for g in fused.generators] == [g.term for g in legacy.generators]
    max_diff = 0.0
    for gf, gl in zip(fused.generators, legacy.generators):
        if len(gf.coeffs):
            max_diff = max(max_diff, float(np.abs(gf.coeffs - gl.coeffs).max()))
        max_diff = max(max_diff, abs(gf.mse - gl.mse))
    assert max_diff < 1e-4, f"tight-bucket fp drift too large: {max_diff}"
    return max_diff


# ---------------------------------------------------------------------------


def run(rep: Reporter, quick: bool = True):
    sizes = [20_000, 100_000] if quick else [100_000, 500_000, 2_000_000]
    psi = 0.005
    cfg = OAVIConfig(psi=psi, engine="fast")
    # same capacity bucket as the legacy path: isolates the kernel-fused
    # degree step + slimmed IHB state, which must be *bit*-exact
    cfg_matched = OAVIConfig(psi=psi, engine="fast", cap_terms=LEGACY_CAP_TERMS)
    rows = []

    for m in sizes:
        X, _ = appendix_c(m=m, seed=0)
        X = MinMaxScaler(dtype="float32").fit_transform(X)

        # warm both paths (compile excluded from the timed runs), and use the
        # warm-up outputs for the correctness checks
        fused0 = oavi.fit(X, cfg)
        legacy0 = legacy_fit(X, cfg)
        _assert_bit_exact(oavi.fit(X, cfg_matched), legacy0)
        max_diff = _assert_same_model(fused0, legacy0)

        t_fused = timeit(lambda: oavi.fit(X, cfg), repeat=3)
        t_legacy = timeit(lambda: legacy_fit(X, cfg), repeat=3)
        fused1 = oavi.fit(X, cfg)
        legacy1 = legacy_fit(X, cfg)
        step_fused = sum(fused1.stats["degree_times"])
        step_legacy = sum(legacy1.stats["degree_times"])

        row = {
            "section": "fit",
            "m": m,
            "n": X.shape[1],
            "num_O": fused0.num_O,
            "num_G": fused0.num_G,
            "t_fit_fused_s": round(t_fused, 4),
            "t_fit_legacy_s": round(t_legacy, 4),
            "fit_speedup": round(t_legacy / max(t_fused, 1e-9), 2),
            "t_step_fused_s": round(step_fused, 4),
            "t_step_legacy_s": round(step_legacy, 4),
            "step_speedup": round(step_legacy / max(step_fused, 1e-9), 2),
            "degree_times_fused": [round(t, 4) for t in fused1.stats["degree_times"]],
            "degree_times_legacy": [round(t, 4) for t in legacy1.stats["degree_times"]],
            "recompiles_warm": fused1.stats["recompiles"],
            "bit_exact_matched_cap": True,
            "max_coeff_diff_tight_bucket": max_diff,
            # measured memory (satellite: peak_bytes where the allocator
            # reports it — TPU/GPU — live-array accounting elsewhere)
            "peak_bytes": fused1.stats.get("peak_bytes"),
            "live_bytes_peak": fused1.stats.get("live_bytes_peak"),
        }
        rows.append(row)
        rep.add("fit_fused", **{k: v for k, v in row.items() if not k.startswith("degree_times")})
        assert fused1.stats["recompiles"] == 0, "steady-state fit recompiled"

    # ---- wavefront term evaluation on a wide fitted model (|O| >= 100) ----
    Xw = random_cube(m=2000, n=7, seed=0)
    wide = oavi.fit(Xw, OAVIConfig(psi=1e-5, engine="fast", max_degree=3))
    parents, vars_ = wide.term_arrays()
    q = 10_000
    Z = jnp.asarray(random_cube(m=q, n=7, seed=1))
    pj, vj = jnp.asarray(parents), jnp.asarray(vars_)
    wavefront = make_wavefront_evaluator(parents, vars_)
    sequential = jax.jit(evaluate_terms_sequential)
    np.testing.assert_array_equal(
        np.asarray(wavefront(Z)), np.asarray(sequential(Z, pj, vj))
    )
    t_wave = timeit(lambda: jax.block_until_ready(wavefront(Z)), repeat=5)
    t_seq = timeit(lambda: jax.block_until_ready(sequential(Z, pj, vj)), repeat=5)
    row = {
        "section": "transform_wavefront",
        "q": q,
        "num_O": wide.num_O,
        "max_degree": int(max(terms_mod.degree(t) for t in wide.book.terms)),
        "t_wavefront_s": round(t_wave, 5),
        "t_sequential_s": round(t_seq, 5),
        "speedup": round(t_seq / max(t_wave, 1e-9), 2),
        "bit_exact": True,
    }
    rows.append(row)
    rep.add("fit_fused", **row)

    write_bench_json(
        "fit",
        rows,
        meta={"psi": psi, "engine": "fast", "legacy_cap_terms": LEGACY_CAP_TERMS,
              "quick": quick, "backend": jax.default_backend()},
    )
