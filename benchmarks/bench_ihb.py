"""Figure 3: IHB / WIHB accelerations.

Compares BPCGAVI (no IHB), BPCGAVI-WIHB, and CGAVI-IHB training times for
varying m — the paper's ordering is CGAVI-IHB < BPCGAVI-WIHB < BPCGAVI.
We also report total solver iterations, the mechanism behind the speed-up
(IHB warm starts make oracle calls ~1-iteration).
"""

from __future__ import annotations

from repro.core import oavi
from repro.core.oavi import OAVIConfig
from repro.core.oracles import OracleConfig
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import appendix_c, uci_like

from .common import Reporter, timeit

VARIANTS = {
    "bpcgavi": dict(engine="oracle", ihb=False, wihb=False, solver="bpcg"),
    "bpcgavi-wihb": dict(engine="oracle", ihb=True, wihb=True, solver="bpcg"),
    "cgavi-ihb": dict(engine="oracle", ihb=True, wihb=False, solver="cg"),
}


def run(rep: Reporter, quick: bool = True):
    datasets = ["bank", "synthetic"] if quick else ["bank", "htru", "skin", "synthetic"]
    sizes = [500, 2000] if quick else [1000, 4000, 16000, 64000, 256000]
    psi = 0.005
    for name in datasets:
        for m in sizes:
            if name == "synthetic":
                X, _ = appendix_c(m=m, seed=0)
            else:
                X, _ = uci_like(name, seed=0)
                X = X[:m]
            if X.shape[0] < m:
                continue
            X = MinMaxScaler().fit_transform(X)
            row = {"dataset": name, "m": m}
            for vname, kv in VARIANTS.items():
                cfg = OAVIConfig(
                    psi=psi, engine=kv["engine"], ihb=kv["ihb"], wihb=kv["wihb"],
                    solver=OracleConfig(name=kv["solver"], max_iter=2000),
                    cap_terms=64,
                )
                model = oavi.fit(X, cfg)  # warmup
                row[f"t_{vname}"] = round(timeit(lambda: oavi.fit(X, cfg)), 3)
                row[f"iters_{vname}"] = sum(model.stats["solver_iters"])
            rep.add("fig3_ihb", **row)
