"""Multi-class fit benchmark: sequential per-class OAVI vs the class-batched
(vmapped) path.

Measures, at k in {4, 8, 16} classes on synthetic planted-variety data:

* **equal class sizes** (pow2 rows, no padding) — end-to-end multi-class
  generator-fit wall clock, sequential loop of :func:`repro.core.oavi.fit`
  vs :func:`repro.core.class_batch.fit_classes`.  The batched result is
  asserted **bit-exact** against the sequential fits (no row padding, so
  matched capacity holds automatically), and the k=8 row must show the
  >= 2x speedup the class-batched path is for.
* **lognormal-skewed class sizes** — the realistic regime: classes are
  grouped into shared row buckets by :func:`repro.api.fit_classes`
  (cross-bucket merges bounded ~2x padding; stragglers folded into their
  cheapest warm bucket, never sequential); speedup plus padding overhead
  and the group count are reported.  Structure (terms, accepted
  generators) is asserted identical to the sequential fits.
* **bpcg oracle engine** (equal sizes) — the paper's BPCG+IHB config through
  the masked fixed-schedule solver path: batched asserted bit-exact against
  the sequential while_loop-ref fits, >= 2x at k=8, 0 warm recompiles
  (the schedule-escalation trajectory is deterministic, so a warm refit
  replays it from the cache).
* **warm-refit recompiles** — a second batched multi-class fit must report
  0 recompiles (shared global degree-step cache).

Emits ``results/BENCH_multiclass.json`` (``bench.v1`` schema).

    PYTHONPATH=src python -m benchmarks.run --only multiclass_batched
"""

from __future__ import annotations

import os

import jax
import numpy as np

from repro import api
from repro.core import class_batch, oavi
from repro.core.oavi import OAVIConfig
from repro.core.oracles import OracleConfig
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import lognormal_sizes, multiclass_planted

from .common import Reporter, timeit, write_bench_json

PSI = 0.005
N_FEATURES = 4


def _per_class(X, y):
    classes = np.unique(y)
    return [X[y == c] for c in classes]


def _assert_bit_exact(seq, bat):
    for s, b in zip(seq, bat):
        assert s.book.terms == b.book.terms, "term books differ"
        assert [g.term for g in s.generators] == [g.term for g in b.generators]
        for gs, gb in zip(s.generators, b.generators):
            assert np.array_equal(gs.coeffs, gb.coeffs), f"coeffs differ {gs.term}"
            assert gs.mse == gb.mse


def _assert_structure(seq, bat):
    for s, b in zip(seq, bat):
        assert s.book.terms == b.book.terms, "term books differ"
        assert [g.term for g in s.generators] == [g.term for g in b.generators]


def run(rep: Reporter, quick: bool = True):
    cfg = OAVIConfig(psi=PSI, engine="fast", cap_terms=64)
    ks = [4, 8, 16]
    # 512 rows/class quick: the dispatch-bound regime the batched path is
    # for (UCI-scale classes), and the widest measured speedup margin
    mean_rows = 512 if quick else 4096
    rows = []

    for k in ks:
        # ---- equal sizes (pow2 -> padding-free -> bit-exact) -------------
        X, y = multiclass_planted([mean_rows] * k, n=N_FEATURES, seed=k)
        X = MinMaxScaler(dtype="float32").fit_transform(X)
        Xcs = _per_class(X, y)

        seq0 = [oavi.fit(Xc, cfg) for Xc in Xcs]  # warm both paths
        bat0 = class_batch.fit_classes(Xcs, cfg)
        _assert_bit_exact(seq0, bat0)

        t_seq = timeit(lambda: [oavi.fit(Xc, cfg) for Xc in Xcs], repeat=5)
        t_bat = timeit(lambda: class_batch.fit_classes(Xcs, cfg), repeat=5)
        warm = class_batch.fit_classes(Xcs, cfg)
        speedup = t_seq / max(t_bat, 1e-9)
        row = {
            "section": "equal_sizes",
            "k": k,
            "rows_per_class": mean_rows,
            "n": N_FEATURES,
            "num_G_total": sum(m.num_G for m in bat0),
            "t_sequential_s": round(t_seq, 4),
            "t_batched_s": round(t_bat, 4),
            "speedup": round(speedup, 2),
            "bit_exact": True,
            "recompiles_warm": warm[0].stats["recompiles"],
        }
        rows.append(row)
        rep.add("multiclass_batched", **row)
        assert warm[0].stats["recompiles"] == 0, "warm batched refit recompiled"
        if k == 8 and speedup < 2.0:
            # wall-clock guard: hard failure locally, soft on constrained
            # CI runners (BENCH_SOFT=1: noisy 2-vCPU machines miss timing
            # targets without anything being wrong with the code)
            msg = f"k=8 equal-size class-batched speedup {speedup:.2f}x < 2x"
            if os.environ.get("BENCH_SOFT"):
                print(f"WARNING: {msg} (BENCH_SOFT set; not failing)")
            else:
                raise AssertionError(msg)

        # ---- bpcg oracle engine (fixed-schedule solvers under vmap) ------
        cfg_bpcg = OAVIConfig(
            psi=PSI,
            engine="oracle",
            solver=OracleConfig(name="bpcg"),
            ihb=True,
            cap_terms=64,
        )
        seq0 = [oavi.fit(Xc, cfg_bpcg) for Xc in Xcs]  # while_loop refs
        bat0 = class_batch.fit_classes(Xcs, cfg_bpcg)  # scheduled solvers
        _assert_bit_exact(seq0, bat0)

        t_seq = timeit(lambda: [oavi.fit(Xc, cfg_bpcg) for Xc in Xcs], repeat=5)
        t_bat = timeit(lambda: class_batch.fit_classes(Xcs, cfg_bpcg), repeat=5)
        warm = class_batch.fit_classes(Xcs, cfg_bpcg)
        speedup = t_seq / max(t_bat, 1e-9)
        row = {
            "section": "bpcg_oracle",
            "k": k,
            "rows_per_class": mean_rows,
            "n": N_FEATURES,
            "num_G_total": sum(m.num_G for m in bat0),
            "t_sequential_s": round(t_seq, 4),
            "t_batched_s": round(t_bat, 4),
            "speedup": round(speedup, 2),
            "bit_exact": True,
            "schedule_len": warm[0].stats["solver_schedule_len"],
            "escalations": warm[0].stats["solver_escalations"],
            "recompiles_warm": warm[0].stats["recompiles"],
        }
        rows.append(row)
        rep.add("multiclass_batched", **row)
        assert warm[0].stats["recompiles"] == 0, "warm bpcg batched refit recompiled"
        if k == 8 and speedup < 2.0:
            msg = f"k=8 bpcg class-batched speedup {speedup:.2f}x < 2x"
            if os.environ.get("BENCH_SOFT"):
                print(f"WARNING: {msg} (BENCH_SOFT set; not failing)")
            else:
                raise AssertionError(msg)

        # ---- lognormal-skewed sizes (bucketed, stragglers folded in) -----
        sizes = lognormal_sizes(k, mean_rows, seed=k)
        Xs, ys = multiclass_planted(sizes, n=N_FEATURES, seed=100 + k)
        Xs = MinMaxScaler(dtype="float32").fit_transform(Xs)
        Xcs = _per_class(Xs, ys)

        seq0 = [oavi.fit(Xc, cfg) for Xc in Xcs]
        bat0 = api.fit_classes(Xcs, "oavi:fast", psi=PSI, cap_terms=64)
        _assert_structure(seq0, bat0)

        t_seq = timeit(lambda: [oavi.fit(Xc, cfg) for Xc in Xcs], repeat=5)
        t_bat = timeit(
            lambda: api.fit_classes(Xcs, "oavi:fast", psi=PSI, cap_terms=64),
            repeat=5,
        )
        agg = api.aggregate_fit_stats(bat0)
        padded_rows = sum(
            m.stats["class_batch"]["m_cap"]
            for m in bat0
            if m.stats.get("class_batch")
        )
        batched_real = sum(
            m.stats["m"] for m in bat0 if m.stats.get("class_batch")
        )
        row = {
            "section": "lognormal_sizes",
            "k": k,
            "sizes": sizes,
            "t_sequential_s": round(t_seq, 4),
            "t_batched_s": round(t_bat, 4),
            "speedup": round(t_seq / max(t_bat, 1e-9), 2),
            "classes_batched": agg["class_batched"],
            "classes_sequential": k - agg["class_batched"],
            "batch_groups": agg["class_batch_groups"],
            "padding_overhead": round(padded_rows / max(batched_real, 1), 3),
            "structure_exact": True,
        }
        rows.append(row)
        rep.add("multiclass_batched", **{k_: v for k_, v in row.items() if k_ != "sizes"})

    write_bench_json(
        "multiclass",
        rows,
        meta={
            "psi": PSI,
            "engine": "fast",
            "mean_rows": mean_rows,
            "quick": quick,
            "backend": jax.default_backend(),
        },
    )
