"""Observability benchmark: what instrumentation costs, and what it gets right.

Backs the obs subsystem's two contracts:

* **overhead** — spans/trace/metrics enabled vs disabled on a *warm* fit and
  a *warm* serving replay must cost **<= 3%** wall-clock (asserted; soft
  under ``BENCH_SOFT=1`` on noisy shared runners), and enabling obs must not
  change a single output bit (asserted hard, both paths: fitted generators
  and served feature blocks).
* **fidelity** — the log-bucket histogram sketch's p50/p90/p99/p999 must
  land within one bucket (relative error ``2^(1/16) - 1`` ~ 4.4%) of
  ``np.percentile`` on lognormal and Pareto (heavy-tail) samples, at a few
  hundred bytes of state instead of storing every sample.

Also reports the cost of draining the trace ring buffer to Chrome-trace
JSON (events, seconds, bytes) — the number that says exporting is safe to do
inline at the end of a run.

Emits ``results/BENCH_obs.json`` (``bench.v1`` schema).

    PYTHONPATH=src python -m benchmarks.run --only obs_overhead
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import numpy as np

from repro import obs
from repro.core import oavi
from repro.core.oavi import OAVIConfig
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import appendix_c
from repro.serving import EngineConfig, TransformEngine

from .common import Reporter, write_bench_json

OVERHEAD_BUDGET = 0.03  # enabled-vs-disabled wall-clock ceiling (fractional)


def _paired_overhead(fn_base, fn_test, repeat: int):
    """Estimate the fractional overhead of ``fn_test`` over ``fn_base``.

    Per-trial wall-clock noise on these workloads is several percent --
    far above the few-microsecond delta this benchmark exists to measure
    -- and almost entirely one-sided (scheduler preemption, allocator
    stalls: trials only ever get *slower*).  The best-of-N time on each
    side is therefore the low-variance estimate of its true floor, and
    the overhead is the ratio of the floors.  Trials alternate order so
    machine drift hits both sides equally, and GC is paused so a
    collection landing inside one window can't masquerade as obs cost.

    Returns ``(best_base, best_test, overhead_frac)``.
    """
    import gc

    best_base = best_test = float("inf")
    gc.collect()
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for i in range(repeat):
            first, second = (fn_base, fn_test) if i % 2 == 0 else (fn_test, fn_base)
            t0 = time.perf_counter()
            first()
            t_first = time.perf_counter() - t0
            t0 = time.perf_counter()
            second()
            t_second = time.perf_counter() - t0
            t_base, t_test = (t_first, t_second) if i % 2 == 0 else (t_second, t_first)
            best_base = min(best_base, t_base)
            best_test = min(best_test, t_test)
    finally:
        if gc_was_enabled:
            gc.enable()
    overhead = best_test / max(best_base, 1e-9) - 1.0
    return best_base, best_test, overhead


def _measured_overhead(fn_base, fn_test, repeat: int):
    """``_paired_overhead`` with two escapes against machine noise.

    When the first estimate lands over budget, re-measure with three times
    the trials before believing it.  If it is *still* over budget, run a
    control: the same estimator on ``fn_base`` vs ``fn_base``, whose true
    overhead is exactly zero — anything it reads is the measurement noise
    floor of this machine right now.  A hard failure is only meaningful
    when that floor sits well under the budget; otherwise the box (small
    VM, steal time, shared runner) cannot resolve a 3% effect at all and
    the caller downgrades to a warning, same as ``BENCH_SOFT``.

    Returns ``(best_base, best_test, overhead_frac, noise_frac)`` where
    ``noise_frac`` is ``None`` unless the control was run.
    """
    t_base, t_test, overhead = _paired_overhead(fn_base, fn_test, repeat)
    if overhead <= OVERHEAD_BUDGET:
        return t_base, t_test, overhead, None
    t_base, t_test, overhead = _paired_overhead(fn_base, fn_test, 3 * repeat)
    if overhead <= OVERHEAD_BUDGET:
        return t_base, t_test, overhead, None
    _, _, control = _paired_overhead(fn_base, fn_base, repeat)
    return t_base, t_test, overhead, abs(control)


def _assert_overhead(overhead, noise, what: str) -> None:
    if noise is not None and noise > OVERHEAD_BUDGET / 2:
        print(
            f"WARNING: obs overhead on {what} measured {overhead:.1%}, but the "
            f"zero-overhead control measured {noise:.1%} — this machine cannot "
            f"resolve the {OVERHEAD_BUDGET:.0%} budget; not failing"
        )
        return
    _soft_assert(
        overhead <= OVERHEAD_BUDGET,
        f"obs overhead on {what} is {overhead:.1%} (> {OVERHEAD_BUDGET:.0%})",
    )


def _soft_assert(ok: bool, msg: str) -> None:
    """Wall-clock guard: hard failure locally, soft on constrained CI
    runners (BENCH_SOFT=1: noisy 2-vCPU machines miss timing targets
    without anything being wrong with the code)."""
    if ok:
        return
    if os.environ.get("BENCH_SOFT"):
        print(f"WARNING: {msg} (BENCH_SOFT set; not failing)")
    else:
        raise AssertionError(msg)


def _assert_bit_exact(a: oavi.OAVIModel, b: oavi.OAVIModel) -> None:
    assert a.book.terms == b.book.terms, "term books differ"
    assert [g.term for g in a.generators] == [g.term for g in b.generators]
    for ga, gb in zip(a.generators, b.generators):
        assert np.array_equal(ga.coeffs, gb.coeffs), f"coeffs differ for {ga.term}"
        assert ga.mse == gb.mse, f"mse differs for {ga.term}"


def _fit_overhead_row(m: int, repeat: int) -> dict:
    X, _ = appendix_c(m=m, seed=0)
    X = MinMaxScaler(dtype="float32").fit_transform(X)
    cfg = OAVIConfig(psi=0.005, engine="fast")

    # warm both states; the warm-up outputs carry the bit-identity assert
    model_on = oavi.fit(X, cfg)
    with obs.disabled():
        model_off = oavi.fit(X, cfg)
    _assert_bit_exact(model_on, model_off)

    def fit_off():
        with obs.disabled():
            oavi.fit(X, cfg)

    t_off, t_on, overhead, noise = _measured_overhead(
        fit_off, lambda: oavi.fit(X, cfg), repeat
    )
    _assert_overhead(overhead, noise, "warm fit")
    row = {
        "section": "fit_overhead",
        "m": m,
        "t_fit_obs_off_s": round(t_off, 4),
        "t_fit_obs_on_s": round(t_on, 4),
        "overhead_frac": round(overhead, 4),
        "bit_identical": True,
    }
    if noise is not None:
        row["noise_frac"] = round(noise, 4)
    return row, model_on


def _serve_overhead_row(model: oavi.OAVIModel, repeat: int):
    eng = TransformEngine([model], config=EngineConfig(min_bucket=64, max_bucket=4096))
    eng.warmup()
    rng = np.random.default_rng(3)
    sizes = [int(s) for s in np.clip(rng.lognormal(np.log(256), 0.9, 128), 1, 4096)]
    pool, _ = appendix_c(m=max(sizes), seed=1)
    pool = MinMaxScaler(dtype="float32").fit_transform(pool)
    payloads = []
    for q in sizes:
        take = rng.integers(0, pool.shape[0] - q + 1)
        payloads.append(pool[take : take + q])

    out_on = eng.transform(payloads[0])
    with obs.disabled():
        out_off = eng.transform(payloads[0])
    assert np.array_equal(out_on, out_off), "served features differ with obs on"

    def replay():
        for p in payloads:
            eng.transform(p)

    def replay_off():
        with obs.disabled():
            replay()

    t_off, t_on, overhead, noise = _measured_overhead(replay_off, replay, repeat)
    _assert_overhead(overhead, noise, "warm serving")
    row = {
        "section": "serve_overhead",
        "requests": len(payloads),
        "rows": int(sum(p.shape[0] for p in payloads)),
        "t_replay_obs_off_s": round(t_off, 4),
        "t_replay_obs_on_s": round(t_on, 4),
        "overhead_frac": round(overhead, 4),
        "bit_identical": True,
    }
    if noise is not None:
        row["noise_frac"] = round(noise, 4)
    return row, eng


def _export_cost_row() -> dict:
    """Drain whatever the overhead sections buffered into Chrome-trace JSON."""
    events = len(obs.trace_events())
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "trace.json")
        t0 = time.perf_counter()
        obs.export_trace(path)
        t_export = time.perf_counter() - t0
        size = os.path.getsize(path)
        with open(path) as f:
            doc_events = obs.validate_chrome_trace(json.load(f))
    assert len(doc_events) == events, "export dropped or invented events"
    return {
        "section": "trace_export",
        "events": events,
        "t_export_s": round(t_export, 4),
        "bytes": size,
        "valid_chrome_trace": True,
    }


def _device_rows(model: oavi.OAVIModel, eng: TransformEngine) -> list:
    """What the device-level flight recorder costs, and what it recorded.

    The fit/serve overhead sections above already price the *whole* obs
    stack (device capture included) against the disabled path; these rows
    break out the two device-specific costs — per-signature HLO cost
    capture and per-boundary memory sampling — and assert the stats
    contract (every fit/serve stats dict carries the device fields).
    """
    from repro.obs import device as obs_device

    # memory-timeline sampling: the per-degree/chunk-boundary price
    mem_stats: dict = {}
    n_samples = 200
    t0 = time.perf_counter()
    for _ in range(n_samples):
        obs_device.sample_memory(mem_stats)
    t_sample = (time.perf_counter() - t0) / n_samples
    cap = obs_device.capture_stats()
    assert "flops_per_degree" in model.stats, "fit stats lost flops_per_degree"
    assert "compile_seconds" in model.stats, "fit stats lost compile_seconds"
    eng_stats = eng.stats
    assert "achieved_gflops" in eng_stats, "engine stats lost achieved_gflops"
    fit_flops = [f for f in model.stats["flops_per_degree"] if f]
    return [
        {
            "section": "device",
            "metric": "memory_sample",
            "calls": n_samples,
            "mean_sample_us": round(t_sample * 1e6, 2),
            "live_bytes_peak": int(mem_stats.get("live_bytes_peak") or 0),
        },
        {
            "section": "device",
            "metric": "cost_capture",
            "captures": int(cap["captures"]),
            "failures": int(cap["failures"]),
            "total_capture_s": round(cap["seconds"], 4),
            "mean_capture_ms": round(
                cap["seconds"] / max(cap["captures"], 1) * 1e3, 3
            ),
        },
        {
            "section": "device",
            "metric": "stats_contract",
            "fit_degrees_with_cost": len(fit_flops),
            "fit_flops_total": float(sum(fit_flops)),
            "fit_compile_seconds": float(model.stats["compile_seconds"]),
            "serve_flops_dispatched": float(eng_stats["flops_dispatched"]),
            "serve_achieved_gflops": float(eng_stats["achieved_gflops"] or 0.0),
        },
    ]


def _sketch_rows() -> list:
    """Sketch quantiles vs np.percentile on lognormal and heavy-tail samples."""
    budget = obs.bucket_relative_error()  # one log-bucket of relative error
    rng = np.random.default_rng(0)
    samples = {
        "lognormal": rng.lognormal(mean=0.0, sigma=1.5, size=200_000),
        "pareto": rng.pareto(a=1.5, size=200_000) + 1.0,
    }
    rows = []
    for name, vals in samples.items():
        h = obs.Histogram()
        h.observe_many(vals)
        worst = 0.0
        per_q = {}
        for q in (50.0, 90.0, 99.0, 99.9):
            exact = float(np.percentile(vals, q))
            approx = h.quantile(q / 100.0)
            rel = abs(approx - exact) / exact
            per_q[f"p{q:g}_rel_err"] = round(rel, 5)
            worst = max(worst, rel)
        assert worst <= budget, (
            f"{name}: sketch quantile off by {worst:.2%} (> one bucket, {budget:.2%})"
        )
        rows.append({
            "section": "sketch_accuracy",
            "distribution": name,
            "samples": int(vals.size),
            "sketch_buckets": h.num_buckets,
            "rel_err_budget": round(budget, 5),
            "worst_rel_err": round(worst, 5),
            **per_q,
        })
    return rows


def run(rep: Reporter, quick: bool = True):
    m = 50_000 if quick else 200_000
    repeat = 7 if quick else 9
    obs.configure(enabled=True, sample_every=1)
    obs.reset()

    fit_row, model = _fit_overhead_row(m, repeat)
    serve_row, eng = _serve_overhead_row(model, repeat)
    export_row = _export_cost_row()
    rows = [fit_row, serve_row, export_row] + _device_rows(model, eng) + _sketch_rows()
    for row in rows:
        rep.add("obs_overhead", **row)

    write_bench_json(
        "obs",
        rows,
        meta={
            "overhead_budget": OVERHEAD_BUDGET,
            "buckets_per_octave": obs.BUCKETS_PER_OCTAVE,
            "quick": quick,
            "backend": jax.default_backend(),
        },
    )


if __name__ == "__main__":
    run(Reporter())
