"""Incremental-OAVI benchmark: update-vs-refit speedup + the serving loop.

What this measures (and asserts):

* **update vs full refit** — after a base fit at m, a 1/16-increment
  :func:`repro.online.update` folds only the new rows into the persisted
  Gram state; wall-clock against a full warm streaming refit on the grown
  source must show **>= 5x** speedup (asserted), with **0 recompiles** warm
  (asserted) and bit-identical generators (asserted at the smallest size).
* **the loop** — ``launch/continuous_vi.py`` run in process under replayed
  arrivals: staleness (arrival -> activation), serve p50/p99 while updates
  are in flight, and 0 bitwise serving mismatches / 0 warm recompiles
  (asserted).

Emits ``results/BENCH_online.json`` (``bench.v1`` schema).

    PYTHONPATH=src python -m benchmarks.run --only online_oavi
"""

from __future__ import annotations

import numpy as np

from repro import online, streaming
from repro.core.oavi import OAVIConfig
from repro.data.synthetic import planted_source
from repro.kernels.ops import GRAM_BLOCK
from repro.streaming import ScaledSource

from .common import Reporter, scaled_planted_source, timeit, write_bench_json

CHUNK_ROWS = 4096
INCREMENT_FRAC = 16  # update folds m/16 new rows
MIN_SPEEDUP = 5.0


def _cfg() -> OAVIConfig:
    return OAVIConfig(psi=0.005, engine="fast", ordering="pearson", cap_terms=64)


def _assert_bit_exact(a, b) -> None:
    assert a.book.terms == b.book.terms, "term books differ"
    assert [g.term for g in a.generators] == [g.term for g in b.generators]
    for ga, gb in zip(a.generators, b.generators):
        assert np.array_equal(ga.coeffs, gb.coeffs), f"coeffs differ for {ga.term}"
        assert ga.mse == gb.mse


def run(rep: Reporter, quick: bool = True):
    cfg = _cfg()
    # m must be large enough that the O(m) refit data work dominates the
    # m-independent per-degree costs both paths share (stats step, dispatch)
    sizes = [65_536, 131_072] if quick else [131_072, 262_144, 524_288]
    rows = []

    for i, m_base in enumerate(sizes):
        m_new = m_base // INCREMENT_FRAC
        m_full = m_base + m_new
        # one source, one frozen scaler: the base view is a strict prefix of
        # the grown view (planted_source is tile-deterministic)
        grown = scaled_planted_source(m_full, chunk_rows=CHUNK_ROWS)
        base = ScaledSource(planted_source(m_base, n=3, seed=0), grown.scaler)

        # warm every cache both paths touch, then time warm-vs-warm
        streaming.fit(grown, cfg, chunk_rows=CHUNK_ROWS)
        model0, state0 = online.fit(base, cfg, chunk_rows=CHUNK_ROWS)
        results = []
        t_update = timeit(
            lambda: results.append(online.update(model0, state0, grown))
        )
        res = results[-1]
        refits = []
        t_refit = timeit(
            lambda: refits.append(streaming.fit(grown, cfg, chunk_rows=CHUNK_ROWS))
        )
        assert res.stats["recompiles"] == 0, "warm update recompiled"
        assert res.stats["replayed_degrees"] == [], (
            "update replayed degrees — the speedup would not be an apples-to-"
            f"apples fold: {res.stats}"
        )
        if i == 0:
            _assert_bit_exact(res.model, refits[-1])
        speedup = t_refit / max(t_update, 1e-9)
        row = {
            "section": "update_vs_refit",
            "m_base": m_base,
            "m_new": m_new,
            "increment_frac": f"1/{INCREMENT_FRAC}",
            "chunk_rows": CHUNK_ROWS,
            "t_update_s": round(t_update, 4),
            "t_full_refit_s": round(t_refit, 4),
            "speedup": round(speedup, 2),
            "folded_degrees": res.stats["folded_degrees"],
            "replayed_degrees": res.stats["replayed_degrees"],
            "recompiles_warm": res.stats["recompiles"],
            "update_chunks": res.stats["chunks"],
            "refit_chunks": refits[-1].stats["streaming"]["num_chunks"],
            "bit_exact_checked": i == 0,
        }
        rows.append(row)
        rep.add("online_oavi", **row)
        assert speedup >= MIN_SPEEDUP, (
            f"update speedup {speedup:.2f}x < {MIN_SPEEDUP}x at m={m_base} "
            f"(update {t_update:.3f}s vs refit {t_refit:.3f}s)"
        )

    # ---- the loop: staleness + serving under in-flight updates -----------
    import tempfile

    from repro.launch import continuous_vi

    loop_args = (
        ["--base-rows", "8192", "--increments", "4", "--increment-rows", "2048",
         "--shard-rows", "2048", "--chunk-rows", "2048", "--min-update-rows",
         "2048"]
        if quick
        else ["--base-rows", "65536", "--increments", "8", "--increment-rows",
              "4096", "--shard-rows", "4096", "--chunk-rows", "4096",
              "--min-update-rows", "4096"]
    )
    with tempfile.TemporaryDirectory(prefix="bench_online_") as workdir:
        report = continuous_vi.main(loop_args + ["--workdir", workdir])
    assert report["serve"]["mismatches"] == 0, "serving diverged during refit"
    assert report["warm_recompiles"] == 0, "loop updates recompiled warm"
    assert report["staleness_s"], "no arrival ever reached serving"
    row = {
        "section": "continuous_loop",
        "base_rows": report["base_rows"],
        "total_rows": report["total_rows"],
        "updates": len(report["updates"]),
        "versions_activated": report["versions_activated"],
        "staleness_mean_s": round(report["staleness_mean_s"], 4),
        "staleness_max_s": round(report["staleness_max_s"], 4),
        "serve_requests": report["serve"]["requests"],
        "serve_during_updates": report["serve"]["during_update_requests"],
        "serve_p50_ms": round(report["serve"]["lat_p50_ms"], 3),
        "serve_p99_ms": round(report["serve"]["lat_p99_ms"], 3),
        "mismatches": report["serve"]["mismatches"],
        "recompiles_warm": report["warm_recompiles"],
    }
    rows.append(row)
    rep.add("online_oavi", **row)

    write_bench_json(
        "online",
        rows,
        meta={
            "quick": quick,
            "chunk_rows": CHUNK_ROWS,
            "gram_block": GRAM_BLOCK,
            "increment_frac": INCREMENT_FRAC,
            "min_speedup": MIN_SPEEDUP,
        },
    )
