"""Table 1: Pearson vs reverse-Pearson feature ordering.

The paper's claim: the specific (data-driven) ordering direction has little
impact on the test error — what matters is that *an* ordering fixes
permutation-sensitivity.  We also verify the invariance property itself.
"""

from __future__ import annotations

import numpy as np

from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier
from repro.data.synthetic import train_test_split, uci_like

from .common import Reporter


def run(rep: Reporter, quick: bool = True):
    datasets = ["bank", "seeds"] if quick else ["bank", "credit", "htru", "seeds", "skin", "spam"]
    for name in datasets:
        X, y = uci_like(name, seed=0)
        if quick and X.shape[0] > 4000:
            X, y = X[:4000], y[:4000]
        Xtr, ytr, Xte, yte = train_test_split(X, y, 0.4, seed=0)
        errs = {}
        for ordering in ["pearson", "reverse_pearson"]:
            clf = VanishingIdealClassifier(PipelineConfig(
                method="cgavi-ihb", psi=0.005,
                oavi_kw={"cap_terms": 64, "ordering": ordering}))
            clf.fit(Xtr, ytr)
            errs[ordering] = 100.0 * (1.0 - clf.score(Xte, yte))
        rep.add("table1_ordering", dataset=name,
                err_pearson=round(errs["pearson"], 2),
                err_reverse=round(errs["reverse_pearson"], 2))

    # invariance check: permuting input features leaves the output unchanged
    rng = np.random.default_rng(0)
    X, y = uci_like("seeds", seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, 0.4, seed=0)
    perm = rng.permutation(X.shape[1])
    a = VanishingIdealClassifier(PipelineConfig(
        method="cgavi-ihb", psi=0.005, oavi_kw={"cap_terms": 64}))
    a.fit(Xtr, ytr)
    b = VanishingIdealClassifier(PipelineConfig(
        method="cgavi-ihb", psi=0.005, oavi_kw={"cap_terms": 64}))
    b.fit(Xtr[:, perm], ytr)
    rep.add("table1_invariance",
            acc_original=round(a.score(Xte, yte), 4),
            acc_permuted=round(b.score(Xte[:, perm], yte), 4),
            G_plus_O_original=a.stats["G_plus_O"],
            G_plus_O_permuted=b.stats["G_plus_O"])
