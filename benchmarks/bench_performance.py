"""Table 3: classification performance of the full pipelines.

CGAVI-IHB+SVM, AGDAVI-IHB+SVM, BPCGAVI-WIHB+SVM, ABM+SVM, VCA+SVM, and the
polynomial-kernel SVM on UCI-shaped datasets (60/40 split): test error,
fit/test times, |G|+|O|, average generator degree, and (SPAR).
"""

from __future__ import annotations

import time


from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier
from repro.core.svm import PolySVM, PolySVMConfig
from repro.data.synthetic import train_test_split, uci_like

from .common import Reporter

# repro.api method specs (Table 3 rows)
METHODS = ["oavi:cgavi-ihb", "oavi:agdavi-ihb", "oavi:bpcgavi-wihb", "abm", "vca"]


def run(rep: Reporter, quick: bool = True):
    datasets = ["bank", "seeds"] if quick else ["bank", "credit", "htru", "seeds", "skin", "spam"]
    for name in datasets:
        X, y = uci_like(name, seed=0)
        if quick and X.shape[0] > 4000:
            X, y = X[:4000], y[:4000]
        Xtr, ytr, Xte, yte = train_test_split(X, y, 0.4, seed=0)
        for method in METHODS:
            kw = {"cap_terms": 64} if method != "vca" else {}
            clf = VanishingIdealClassifier(
                PipelineConfig(method=method, psi=0.005, oavi_kw=kw))
            clf.fit(Xtr, ytr)
            t0 = time.perf_counter()
            err = 100.0 * (1.0 - clf.score(Xte, yte))
            t_test = time.perf_counter() - t0
            # per-phase timings come from the classifier itself now
            s = clf.stats
            rep.add("table3", dataset=name, method=method,
                    err_test_pct=round(err, 2),
                    t_fit_s=round(s["time_total"], 2),
                    t_generators_s=round(s["time_generators"], 2),
                    t_transform_s=round(s["time_transform"], 4),
                    t_svm_s=round(s["time_svm"], 2),
                    t_test_s=round(t_test, 4),
                    G_plus_O=s["G_plus_O"],
                    avg_degree=round(clf.average_degree(), 2),
                    spar=round(clf.sparsity(), 2))
        # polynomial-kernel SVM baseline
        ps = PolySVM(PolySVMConfig(degree=3, lam=1e-4,
                                   max_iter=2000 if quick else 10000))
        t0 = time.perf_counter(); ps.fit(Xtr, ytr); t_fit = time.perf_counter() - t0
        t0 = time.perf_counter()
        err = 100.0 * (1.0 - ps.score(Xte, yte))
        t_test = time.perf_counter() - t0
        rep.add("table3", dataset=name, method="poly-svm",
                err_test_pct=round(err, 2), t_fit_s=round(t_fit, 2),
                t_test_s=round(t_test, 4), G_plus_O=0, avg_degree=3.0, spar=0.0)
