"""Resilience benchmark: what fault tolerance costs, and how fast recovery is.

Three numbers back the "degrade, don't die" claims:

* **happy-path overhead** — integrity verification (per-shard CRC32 on
  first read, checksummed checkpoint leaves) must cost **<= 5%** wall-clock
  on a warm out-of-core fit (asserted; soft under ``BENCH_SOFT=1``).  CRC32
  is one cheap sequential pass per shard, amortized across every chunk that
  shard feeds.
* **recovery time** — SIGKILL the continuous controller at a journaled
  phase transition, restart it on the same workdir, and report wall-clock
  to a fully caught-up, bit-identical model (asserted identical to an
  uninterrupted run; 0 warm recompiles after the cold catch-up update).
* **degraded-mode serving** — inject an activation failure mid-run; the
  controller keeps serving the last-good version (0 bitwise mismatches,
  asserted) and the report carries the degraded-window serve latency next
  to the clean run's.

Emits ``results/BENCH_resilience.json`` (``bench.v1`` schema).

    PYTHONPATH=src python -m benchmarks.run --only resilience_chaos
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro import streaming
from repro.core.oavi import OAVIConfig
from repro.data.synthetic import write_shards
from repro.launch import chaos_vi
from repro.resilience.chaos import Fault, FaultPlan
from repro.streaming.source import ShardDirSource

from .common import Reporter, timeit, write_bench_json

MAX_OVERHEAD = 0.05  # integrity verification budget on the happy path
SHARD_ROWS = 8192
CHUNK_ROWS = 4096


def _soft_assert(ok: bool, msg: str) -> None:
    """Wall-clock guard: hard failure locally, soft on constrained CI
    runners (BENCH_SOFT=1: noisy 2-vCPU machines miss timing targets
    without anything being wrong with the code)."""
    if ok:
        return
    if os.environ.get("BENCH_SOFT"):
        print(f"WARNING: {msg} (BENCH_SOFT set; not failing)")
    else:
        raise AssertionError(msg)


def _overhead_row(tmp: str, m: int) -> dict:
    """Warm streaming fit over a shard directory, CRC verification on/off."""
    shard_dir = os.path.join(tmp, f"shards_{m}")
    rng = np.random.default_rng(0)
    X = rng.uniform(0.0, 1.0, (m, 3)).astype(np.float32)
    X[:, 2] = np.clip(X[:, 0] * X[:, 1] + rng.normal(0, 0.01, m), 0, 1).astype(
        np.float32
    )
    write_shards(shard_dir, X, shard_rows=SHARD_ROWS)
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="pearson", cap_terms=64)

    def fit_with(verify: bool):
        # fresh source each run: per-shard verification is lazy + cached,
        # so a reused source would only pay the CRC on its first pass
        src = ShardDirSource(shard_dir, verify_checksums=verify)
        return streaming.fit(src, cfg, chunk_rows=CHUNK_ROWS)

    fit_with(True)  # warm compile caches both paths share
    t_off = timeit(lambda: fit_with(False), repeat=3)
    t_on = timeit(lambda: fit_with(True), repeat=3)
    overhead = t_on / max(t_off, 1e-9) - 1.0
    return {
        "section": "integrity_overhead",
        "m": m,
        "shard_rows": SHARD_ROWS,
        "chunk_rows": CHUNK_ROWS,
        "t_verify_off_s": round(t_off, 4),
        "t_verify_on_s": round(t_on, 4),
        "overhead_frac": round(overhead, 4),
        "budget_frac": MAX_OVERHEAD,
    }


def run(rep: Reporter, quick: bool = True):
    rows = []

    # ---- happy-path integrity overhead -----------------------------------
    with tempfile.TemporaryDirectory(prefix="bench_res_io_") as tmp:
        for m in [65_536] if quick else [65_536, 262_144]:
            row = _overhead_row(tmp, m)
            rows.append(row)
            rep.add("resilience_chaos", **row)
            _soft_assert(
                row["overhead_frac"] <= MAX_OVERHEAD,
                f"integrity verification overhead {row['overhead_frac']:.1%} "
                f"> {MAX_OVERHEAD:.0%} at m={m} "
                f"(on {row['t_verify_on_s']}s vs off {row['t_verify_off_s']}s)",
            )

    # ---- recovery time + degraded serving (controller subprocesses) ------
    with tempfile.TemporaryDirectory(prefix="bench_res_ctl_") as tmp:
        # uninterrupted baseline: the bit-identity reference and the clean
        # serve-latency yardstick
        base_dir = os.path.join(tmp, "baseline")
        t_base = time.perf_counter()
        proc = chaos_vi._run_controller(base_dir)
        t_base = time.perf_counter() - t_base
        assert proc.returncode == 0, proc.stderr[-2000:]
        base_rep = chaos_vi._report(base_dir)
        assert base_rep["serve"]["mismatches"] == 0
        reference = chaos_vi._final_leaves(base_dir)

        phases = [("state_saved", 1)] if quick else [
            ("state_saved", 1), ("activated", 1), ("update_start", 2)
        ]
        for phase, at in phases:
            workdir = os.path.join(tmp, f"kill_{phase}_{at}")
            plan = os.path.join(tmp, f"kill_{phase}_{at}.json")
            FaultPlan(
                [Fault(site=f"controller.{phase}", at=at, action="sigkill")]
            ).save(plan)
            proc = chaos_vi._run_controller(workdir, chaos_path=plan)
            assert proc.returncode == -9, (
                f"expected SIGKILL at {phase}#{at}, got {proc.returncode}\n"
                f"{proc.stderr[-2000:]}"
            )
            t_rec = time.perf_counter()
            proc = chaos_vi._run_controller(workdir)
            t_rec = time.perf_counter() - t_rec
            assert proc.returncode == 0, proc.stderr[-2000:]
            krep = chaos_vi._report(workdir)
            assert krep["resume"]["resumed"], "controller did not resume"
            assert krep["warm_recompiles"] == 0, "recovery recompiled warm"
            assert krep["serve"]["mismatches"] == 0
            chaos_vi._assert_bit_identical(
                chaos_vi._final_leaves(workdir), reference,
                f"recovery at {phase}#{at}",
            )
            row = {
                "section": "recovery",
                "killed_at": f"{phase}#{at}",
                "total_rows": krep["total_rows"],
                "state_rows_resumed": krep["resume"]["state_rows"],
                "caught_up_rows": krep["resume"]["caught_up_rows"],
                "t_uninterrupted_s": round(t_base, 3),
                "t_recovery_s": round(t_rec, 3),
                "t_catch_up_s": round(krep["resume"]["time_catch_up"], 3),
                "bit_identical": True,
                "recompiles_warm": krep["warm_recompiles"],
            }
            rows.append(row)
            rep.add("resilience_chaos", **row)

        # degraded-mode: one injected activation failure mid-run
        deg_dir = os.path.join(tmp, "degraded")
        plan = os.path.join(tmp, "degraded.json")
        FaultPlan([Fault(site="registry.activate", at=1, action="raise")]).save(plan)
        proc = chaos_vi._run_controller(deg_dir, chaos_path=plan)
        assert proc.returncode == 0, proc.stderr[-2000:]
        drep = chaos_vi._report(deg_dir)
        assert len(drep["update_failures"]) == 1, "activation fault not recorded"
        assert drep["serve"]["mismatches"] == 0, "degraded window served wrong bits"
        assert drep["health"] == "ok", "controller did not recover"
        chaos_vi._assert_bit_identical(
            chaos_vi._final_leaves(deg_dir), reference, "degraded run"
        )
        row = {
            "section": "degraded_serving",
            "update_failures": len(drep["update_failures"]),
            "health_final": drep["health"],
            "serve_p50_ms_clean": round(base_rep["serve"]["lat_p50_ms"], 3),
            "serve_p50_ms_degraded": round(drep["serve"]["lat_p50_ms"], 3),
            "serve_p99_ms_clean": round(base_rep["serve"]["lat_p99_ms"], 3),
            "serve_p99_ms_degraded": round(drep["serve"]["lat_p99_ms"], 3),
            "mismatches": drep["serve"]["mismatches"],
            "bit_identical": True,
        }
        rows.append(row)
        rep.add("resilience_chaos", **row)

    write_bench_json(
        "resilience",
        rows,
        meta={
            "quick": quick,
            "max_overhead_frac": MAX_OVERHEAD,
            "controller_args": chaos_vi.RUN_ARGS,
        },
    )
