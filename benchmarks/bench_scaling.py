"""Figure 4: training-time scaling in m for OAVI vs ABM vs VCA.

The paper's headline: OAVI's (IHB) time is linear in m with a small slope,
so it overtakes ABM/VCA on large data.  We measure CGAVI-IHB, AGDAVI-IHB,
ABM and VCA across sample counts on the paper's synthetic dataset and fit
log-log slopes.  Also includes the distributed weak-scaling check: the
shard_map OAVI on k fake devices vs 1 (collective bytes are m-independent).

``--streaming`` (CLI) switches the sweep to the out-of-core comparison:
streaming vs in-memory OAVI over the same planted-polynomial generator as
``bench_streaming`` (``benchmarks.common.scaled_planted_source`` — one data
setup, not two), reporting time and measured peak footprint per m.

    PYTHONPATH=src python -m benchmarks.bench_scaling [--full] [--streaming]
"""

from __future__ import annotations

import numpy as np

from repro.core import abm, oavi, vca
from repro.core.oavi import OAVIConfig
from repro.core.oracles import OracleConfig
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import appendix_c

from .common import Reporter, scaled_planted_source, timeit, write_bench_json


def run_streaming(rep: Reporter, quick: bool = True):
    """Streaming-vs-in-memory m-sweep (the ``--streaming`` mode)."""
    from repro import streaming

    sizes = [8_192, 32_768, 131_072] if quick else [131_072, 1_048_576, 8_388_608]
    cfg = OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64)
    rows = []
    for m in sizes:
        scaled = scaled_planted_source(m)
        streaming.fit(scaled, cfg)  # warm
        fits = []
        t_stream = timeit(lambda: fits.append(streaming.fit(scaled, cfg)))
        mdl = fits[-1]  # stats from the timed (warm) run — no extra fit
        row = {
            "m": m,
            "t_streaming": round(t_stream, 3),
            "live_bytes_streaming": mdl.stats.get("live_bytes_peak"),
            "peak_bytes_streaming": mdl.stats.get("peak_bytes"),
        }
        if m <= 2_000_000:
            X = scaled.read(0, m)
            oavi.fit(X, cfg)  # warm
            refs = []
            row["t_in_memory"] = round(timeit(lambda: refs.append(oavi.fit(X, cfg))), 3)
            row["live_bytes_in_memory"] = refs[-1].stats.get("live_bytes_peak")
        rows.append(dict(row))
        rep.add("fig4_scaling_streaming", **row)
    # distinct artifact: must not clobber the fig4 sweep's BENCH_scaling.json
    write_bench_json(
        "scaling_streaming", rows, meta={"quick": quick, "streaming": True}
    )


def run(rep: Reporter, quick: bool = True):
    sizes = [1000, 4000, 16000] if quick else [4000, 16000, 64000, 256000, 1000000, 2000000]
    psi = 0.005
    rows = []
    times = {k: [] for k in ["cgavi-ihb", "agdavi-ihb", "abm", "vca"]}
    for m in sizes:
        X, _ = appendix_c(m=m, seed=0)
        X = MinMaxScaler().fit_transform(X)
        row = {"m": m}

        cfg_cg = OAVIConfig(psi=psi, engine="oracle", ihb=True,
                            solver=OracleConfig(name="cg"), cap_terms=64)
        fitted = oavi.fit(X, cfg_cg)
        row["live_bytes_peak"] = fitted.stats.get("live_bytes_peak")
        row["peak_bytes"] = fitted.stats.get("peak_bytes")
        t = timeit(lambda: oavi.fit(X, cfg_cg)); row["t_cgavi_ihb"] = round(t, 3)
        times["cgavi-ihb"].append(t)

        cfg_agd = OAVIConfig(psi=psi, engine="oracle", ihb=True,
                             solver=OracleConfig(name="agd"), cap_terms=64)
        oavi.fit(X, cfg_agd)
        t = timeit(lambda: oavi.fit(X, cfg_agd)); row["t_agdavi_ihb"] = round(t, 3)
        times["agdavi-ihb"].append(t)

        cfg_abm = abm.ABMConfig(psi=psi, cap_terms=64)
        abm.fit(X, cfg_abm)
        t = timeit(lambda: abm.fit(X, cfg_abm)); row["t_abm"] = round(t, 3)
        times["abm"].append(t)

        t = timeit(lambda: vca.fit(X, vca.VCAConfig(psi=psi)))
        row["t_vca"] = round(t, 3)
        times["vca"].append(t)
        rows.append(dict(row))
        rep.add("fig4_scaling", **row)

    # log-log slope over the measured range (linear-in-m => slope ~<= 1)
    lm = np.log(np.asarray(sizes, float))
    for name, ts in times.items():
        if len(ts) >= 2:
            slope = float(np.polyfit(lm, np.log(np.maximum(ts, 1e-4)), 1)[0])
            rows.append({"method": name, "loglog_slope": round(slope, 3)})
            rep.add("fig4_slope", method=name, loglog_slope=round(slope, 3))

    write_bench_json("scaling", rows, meta={"psi": psi, "quick": quick})


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--streaming", action="store_true",
                    help="out-of-core vs in-memory OAVI sweep")
    args = ap.parse_args()
    reporter = Reporter()
    if args.streaming:
        run_streaming(reporter, quick=not args.full)
    else:
        run(reporter, quick=not args.full)
