"""Batched serving engine vs. naive per-request transform loop.

Replays a mixed-size request trace (log-normal row counts — lots of small
requests, a heavy tail) against two implementations of the same (FT):

* **naive** — a per-request ``api.feature_transform`` loop, the way every
  caller had to serve before :mod:`repro.serving`.  Timed twice: *cold*
  (first replay; every unique request size jit-compiles — the real cost of
  shape-polymorphic traffic on the direct path) and *warm* (second replay,
  all shapes cached — the steady state, and the conservative baseline).
* **batched** — :class:`~repro.serving.engine.TransformEngine` (pow2 row
  buckets, warmed up front) behind a
  :class:`~repro.serving.batcher.MicroBatcher`.  Throughput is measured
  open-loop (trace pre-queued, drained in coalesced batches — saturated
  offered load); latency percentiles closed-loop (``--concurrency``
  clients, one in-flight request each).

Asserts the batched path is bit-identical to the naive one and triggers
zero recompiles after warmup, then emits the standard ``BENCH_serve.json``
artifact.

    PYTHONPATH=src python -m benchmarks.run --only serve_engine
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro import api
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import appendix_c
from repro.launch.serve_vi import replay, synth_trace
from repro.serving import BatcherConfig, EngineConfig, MicroBatcher, TransformEngine

from .common import Reporter, write_bench_json

MEAN_ROWS = 96
MAX_BATCH_ROWS = 8192
MAX_DELAY_MS = 2.0
CONCURRENCY = 32


def _payloads(sizes: List[int], scaler, seed: int) -> List[np.ndarray]:
    pool, _ = appendix_c(m=max(sizes), seed=seed)
    pool = scaler.transform(pool)
    rng = np.random.default_rng(seed + 1)
    out = []
    for q in sizes:
        off = int(rng.integers(0, pool.shape[0] - q + 1))
        out.append(pool[off : off + q])
    return out


def run(rep: Reporter, quick: bool = True):
    num_requests = 240 if quick else 960

    # fit per-class models once (same setup as bench_transform)
    Xtr, ytr = appendix_c(m=4000, seed=0)
    scaler = MinMaxScaler(dtype="float32")
    Xtr = scaler.fit_transform(Xtr)
    models = [
        api.fit(Xtr[ytr == c], method="oavi:fast", psi=0.005,
                backend="local", cap_terms=64)
        for c in np.unique(ytr)
    ]

    sizes = synth_trace(num_requests, MEAN_ROWS, seed=3)
    payloads = _payloads(sizes, scaler, seed=5)
    rows_total = sum(sizes)

    # ---- naive per-request loop: cold (compiles) then warm (steady) ------
    t0 = time.perf_counter()
    ref = [np.asarray(api.feature_transform(models, Z)) for Z in payloads]
    t_naive_cold = time.perf_counter() - t0
    lat_naive = []
    t0 = time.perf_counter()
    for Z in payloads:
        t1 = time.perf_counter()
        api.feature_transform(models, Z)
        lat_naive.append((time.perf_counter() - t1) * 1e3)
    t_naive_warm = time.perf_counter() - t0

    # ---- batched engine: warmup, open-loop drain, closed-loop latency ----
    engine = TransformEngine(
        models, config=EngineConfig(min_bucket=64, max_bucket=MAX_BATCH_ROWS)
    )
    t0 = time.perf_counter()
    engine.warmup()
    t_warmup = time.perf_counter() - t0

    batcher = MicroBatcher(
        engine,
        config=BatcherConfig(
            max_batch_rows=MAX_BATCH_ROWS,
            max_delay_ms=MAX_DELAY_MS,
            max_queue=len(payloads) + 1,
        ),
    )
    futs = [batcher.submit(Z) for Z in payloads]
    t0 = time.perf_counter()
    batcher.run_once()
    t_batched = time.perf_counter() - t0
    outs = [f.result() for f in futs]

    # np.array_equal (not a diff-max) so NaN-producing divergence also fails
    mismatched = [i for i, (a, b) in enumerate(zip(ref, outs))
                  if not np.array_equal(a, b)]
    assert not mismatched, (
        f"batched engine output is not bit-identical on "
        f"{len(mismatched)}/{len(ref)} requests (first: #{mismatched[0]})"
    )
    assert engine.stats["recompiles"] == 0, (
        f"trace recompiled {engine.stats['recompiles']}x after warmup"
    )

    latency = replay(
        batcher.start(),
        payloads,
        kind="transform",
        concurrency=CONCURRENCY,
    )
    batcher.stop()
    assert engine.stats["recompiles"] == 0

    lat_naive_arr = np.asarray(lat_naive)
    row = {
        "requests": num_requests,
        "rows": rows_total,
        "unique_sizes": len(set(sizes)),
        "mean_rows": MEAN_ROWS,
        "num_features": engine.consts.num_features,
        "t_naive_cold_s": round(t_naive_cold, 4),
        "t_naive_warm_s": round(t_naive_warm, 4),
        "t_batched_s": round(t_batched, 4),
        "t_warmup_s": round(t_warmup, 4),
        "rows_per_s_naive": round(rows_total / max(t_naive_warm, 1e-9), 1),
        "rows_per_s_batched": round(rows_total / max(t_batched, 1e-9), 1),
        "speedup_vs_warm": round(t_naive_warm / max(t_batched, 1e-9), 2),
        "speedup_vs_cold": round(t_naive_cold / max(t_batched, 1e-9), 2),
        "naive_lat_p50_ms": round(float(np.percentile(lat_naive_arr, 50)), 3),
        "naive_lat_p99_ms": round(float(np.percentile(lat_naive_arr, 99)), 3),
        "batched_lat_p50_ms": round(latency["lat_p50_ms"], 3),
        "batched_lat_p99_ms": round(latency["lat_p99_ms"], 3),
        "closed_loop_rows_per_s": round(latency["rows_per_s"], 1),
        "device_calls": engine.stats["device_calls"],
        "padded_rows": engine.stats["padded_rows"],
        "recompiles_after_warmup": engine.stats["recompiles"],
        "warmup_compiles": engine.stats["warmup_compiles"],
        "bit_exact": True,  # asserted above via np.array_equal per request
    }
    rep.add("serve_engine", **row)

    write_bench_json(
        "serve",
        [row],
        meta={
            "method": "oavi:fast",
            "psi": 0.005,
            "max_batch_rows": MAX_BATCH_ROWS,
            "max_delay_ms": MAX_DELAY_MS,
            "concurrency": CONCURRENCY,
            "quick": quick,
            "note": (
                "throughput is an open-loop drain of the pre-queued trace; "
                "latency percentiles are closed-loop at `concurrency` clients; "
                "naive cold includes the per-unique-shape jit compiles the "
                "direct path pays on mixed-size traffic"
            ),
        },
    )
