"""Figure 2: PCGAVI vs BPCGAVI training time for varying sample counts.

Reproduces the paper's claim that replacing PCG with BPCG speeds up OAVI
(on most datasets), on the Appendix-C synthetic and UCI-shaped data.
"""

from __future__ import annotations

import numpy as np

from repro.core import oavi
from repro.core.oavi import OAVIConfig
from repro.core.oracles import OracleConfig
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import appendix_c, uci_like

from .common import Reporter, timeit


def _data(name: str, m: int, seed=0):
    if name == "synthetic":
        X, _ = appendix_c(m=m, seed=seed)
    else:
        X, _ = uci_like(name, seed=seed)
        X = X[:m]
    return MinMaxScaler().fit_transform(X)


def run(rep: Reporter, quick: bool = True):
    datasets = ["bank", "synthetic"] if quick else ["bank", "htru", "skin", "synthetic"]
    sizes = [500, 1000, 2000] if quick else [1000, 4000, 16000, 64000]
    psi = 0.005
    for name in datasets:
        for m in sizes:
            X = _data(name, m)
            if X.shape[0] < m:
                continue
            times = {}
            iters = {}
            for solver in ["pcg", "bpcg"]:
                cfg = OAVIConfig(
                    psi=psi, engine="oracle", ihb=False,
                    solver=OracleConfig(name=solver, max_iter=2000), cap_terms=64,
                )
                model = oavi.fit(X, cfg)  # includes jit warmup on first size
                t = timeit(lambda: oavi.fit(X, cfg))
                times[solver] = t
                iters[solver] = sum(model.stats["solver_iters"])
            rep.add("fig2_solvers", dataset=name, m=m,
                    t_pcgavi=round(times["pcg"], 3),
                    t_bpcgavi=round(times["bpcg"], 3),
                    iters_pcg=iters["pcg"], iters_bpcg=iters["bpcg"],
                    speedup=round(times["pcg"] / max(times["bpcg"], 1e-9), 2))
