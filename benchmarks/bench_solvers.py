"""Figure 2: PCGAVI vs BPCGAVI training time for varying sample counts.

Reproduces the paper's claim that replacing PCG with BPCG speeds up OAVI
(on most datasets), on the Appendix-C synthetic and UCI-shaped data.
"""

from __future__ import annotations


from repro import api
from repro.core.transform import MinMaxScaler
from repro.data.synthetic import appendix_c, uci_like

from .common import Reporter, timeit


def _data(name: str, m: int, seed=0):
    if name == "synthetic":
        X, _ = appendix_c(m=m, seed=seed)
    else:
        X, _ = uci_like(name, seed=seed)
        X = X[:m]
    return MinMaxScaler().fit_transform(X)


def run(rep: Reporter, quick: bool = True):
    datasets = ["bank", "synthetic"] if quick else ["bank", "htru", "skin", "synthetic"]
    sizes = [500, 1000, 2000] if quick else [1000, 4000, 16000, 64000]
    psi = 0.005
    for name in datasets:
        for m in sizes:
            X = _data(name, m)
            if X.shape[0] < m:
                continue
            times = {}
            iters = {}
            for solver, spec in [("pcg", "oavi:pcgavi"), ("bpcg", "oavi:bpcgavi")]:
                kw = dict(solver_kw={"max_iter": 2000}, cap_terms=64)
                # includes jit warmup on first size
                model = api.fit(X, method=spec, psi=psi, backend="local", **kw)
                t = timeit(lambda: api.fit(X, method=spec, psi=psi,
                                           backend="local", **kw))
                times[solver] = t
                iters[solver] = sum(model.stats["solver_iters"])
            rep.add("fig2_solvers", dataset=name, m=m,
                    t_pcgavi=round(times["pcg"], 3),
                    t_bpcgavi=round(times["bpcg"], 3),
                    iters_pcg=iters["pcg"], iters_bpcg=iters["bpcg"],
                    speedup=round(times["pcg"] / max(times["bpcg"], 1e-9), 2))
