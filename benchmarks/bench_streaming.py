"""Out-of-core OAVI benchmark: fit at m ≫ device memory, flat peak footprint.

What this measures (and asserts):

* **bit-exactness** — at the smallest sweep size, the streamed fit equals the
  in-memory fit bit for bit at matched capacity, on chunk sizes
  {256, 1024, 4096}, for the ``fast`` engine and a convex-oracle config.
* **m-sweep** — streaming vs in-memory fit across a >= 16x sample range
  (``--full`` reaches 1.6e7 rows, past any in-memory ceiling: the source is
  generator-backed and occupies no storage).  Streaming peak device
  footprint must stay ~flat (asserted within 1.5x across the sweep) while
  the in-memory path's grows linearly with m; memory is *measured* —
  ``peak_bytes`` from the device allocator where available, live-array
  accounting (``live_bytes_peak``) elsewhere (this container's CPU).
* **0 recompiles after warmup** — a warm streamed refit compiles nothing
  (asserted at every m).

Emits ``results/BENCH_streaming.json`` (``bench.v1`` schema).

    PYTHONPATH=src python -m benchmarks.run --only streaming_oavi
"""

from __future__ import annotations

import numpy as np

from repro import streaming
from repro.core import oavi
from repro.core.oavi import OAVIConfig
from repro.kernels.ops import GRAM_BLOCK

from .common import Reporter, scaled_planted_source, timeit, write_bench_json

CHUNK_ROWS = 4096
# in-memory OOM guard for the --full sweep: A alone is m * Lcap * 4 bytes
IN_MEMORY_MAX_M = 2_000_000


def _cfg(engine: str = "fast") -> OAVIConfig:
    if engine == "oracle":
        return OAVIConfig(psi=0.005, engine="oracle", ihb=True, ordering="none",
                          cap_terms=64)
    return OAVIConfig(psi=0.005, engine="fast", ordering="none", cap_terms=64)


def _assert_bit_exact(a: oavi.OAVIModel, b: oavi.OAVIModel) -> None:
    assert a.book.terms == b.book.terms, "term books differ"
    assert [g.term for g in a.generators] == [g.term for g in b.generators]
    for ga, gb in zip(a.generators, b.generators):
        assert np.array_equal(ga.coeffs, gb.coeffs), f"coeffs differ for {ga.term}"
        assert ga.mse == gb.mse


def run(rep: Reporter, quick: bool = True):
    sizes = (
        [8_192, 32_768, 131_072]  # 16x range
        if quick
        else [131_072, 524_288, 2_097_152, 8_388_608, 16_777_216]  # 128x, >= 1e7
    )
    rows = []

    # ---- bit-exactness at matched capacity (both engine families) --------
    m0 = sizes[0]
    scaled0 = scaled_planted_source(m0, chunk_rows=CHUNK_ROWS)
    X0 = scaled0.read(0, m0)
    for engine, chunks in (("fast", (256, 1024, 4096)), ("oracle", (1024,))):
        cfg = _cfg(engine)
        ref = oavi.fit(X0, cfg)
        for chunk_rows in chunks:
            mdl = streaming.fit(scaled0, cfg, chunk_rows=chunk_rows)
            _assert_bit_exact(mdl, ref)
        row = {
            "section": "bit_exact",
            "engine": engine,
            "m": m0,
            "chunk_sizes": list(chunks),
            "bit_exact": True,
        }
        rows.append(row)
        rep.add("streaming_oavi", **row)
    del X0, scaled0

    # ---- m-sweep: time + measured peak footprint -------------------------
    cfg = _cfg("fast")
    stream_peaks, memory_peaks = [], []
    for m in sizes:
        scaled = scaled_planted_source(m, chunk_rows=CHUNK_ROWS)
        streaming.fit(scaled, cfg, chunk_rows=CHUNK_ROWS)  # warm
        fits = []
        t_stream = timeit(
            lambda: fits.append(streaming.fit(scaled, cfg, chunk_rows=CHUNK_ROWS))
        )
        mdl = fits[-1]  # the timed run is warm: measure AND read stats from it
        assert mdl.stats["recompiles"] == 0, "warm streaming fit recompiled"

        row = {
            "section": "sweep",
            "m": m,
            "n": 3,
            "chunk_rows": CHUNK_ROWS,
            "num_chunks": mdl.stats["streaming"]["num_chunks"],
            "t_streaming_s": round(t_stream, 4),
            "recompiles_warm": mdl.stats["recompiles"],
            "num_O": mdl.num_O,
            "num_G": mdl.num_G,
            "peak_bytes_streaming": mdl.stats.get("peak_bytes"),
            "live_bytes_streaming": mdl.stats.get("live_bytes_peak"),
        }
        # live-array accounting is the per-fit comparable quantity; the
        # allocator peak is a process-lifetime high-water mark (monotone
        # across fits) and only a fallback
        peak = mdl.stats.get("live_bytes_peak") or mdl.stats.get("peak_bytes")
        if peak:
            stream_peaks.append(peak)

        if m <= IN_MEMORY_MAX_M:
            X = scaled.read(0, m)
            oavi.fit(X, cfg)  # warm
            refs = []
            row["t_in_memory_s"] = round(
                timeit(lambda: refs.append(oavi.fit(X, cfg))), 4
            )
            ref = refs[-1]
            row["peak_bytes_in_memory"] = ref.stats.get("peak_bytes")
            row["live_bytes_in_memory"] = ref.stats.get("live_bytes_peak")
            mem_peak = ref.stats.get("live_bytes_peak") or ref.stats.get("peak_bytes")
            if mem_peak:
                memory_peaks.append(mem_peak)
            del X, ref, refs
        else:
            row["t_in_memory_s"] = None
            row["in_memory_skipped"] = "oom_guard"
        rows.append(row)
        rep.add("streaming_oavi", **row)

    # streaming footprint must be ~flat across the whole sweep; the
    # in-memory footprint grows with m (reported, and sanity-checked when
    # the sweep spans enough range for A to dominate the fixed buffers)
    flat_ratio = max(stream_peaks) / min(stream_peaks)
    assert flat_ratio <= 1.5, f"streaming footprint grew {flat_ratio:.2f}x over the sweep"
    mem_ratio = (
        round(max(memory_peaks) / min(memory_peaks), 2) if len(memory_peaks) >= 2 else None
    )
    summary = {
        "section": "summary",
        "m_range": f"{sizes[0]}..{sizes[-1]} ({sizes[-1] // sizes[0]}x)",
        "streaming_peak_ratio": round(flat_ratio, 3),
        "in_memory_peak_ratio": mem_ratio,
        "flat_within_1_5x": True,
    }
    rows.append(summary)
    rep.add("streaming_oavi", **summary)

    write_bench_json(
        "streaming",
        rows,
        meta={
            "quick": quick,
            "chunk_rows": CHUNK_ROWS,
            "gram_block": GRAM_BLOCK,
            "in_memory_max_m": IN_MEMORY_MAX_M,
        },
    )
