"""Fused batched feature transform vs. the legacy per-model loop.

Fits one small OAVI model per class once, then transforms m in
{1e4, 1e5, 1e6} rows with (a) the legacy per-model numpy loop
(:func:`repro.core.transform.feature_transform`) and (b) the fused
single-dispatch evaluation (:func:`repro.api.feature_transform`, one
``evaluate_terms`` sweep + one matmul, ``batch_size``-chunked).  Emits the
standard ``BENCH_transform.json`` artifact via
:func:`benchmarks.common.write_bench_json`.

    PYTHONPATH=src python -m benchmarks.run --only transform_fused
"""

from __future__ import annotations

import numpy as np

from repro import api
from repro.core.transform import MinMaxScaler, feature_transform as legacy_transform
from repro.data.synthetic import appendix_c

from .common import Reporter, timeit, write_bench_json

BATCH_SIZE = 131_072


def run(rep: Reporter, quick: bool = True):
    sizes = [10_000, 100_000] if quick else [10_000, 100_000, 1_000_000]

    # fit per-class models once on a modest training slice
    Xtr, ytr = appendix_c(m=4000, seed=0)
    scaler = MinMaxScaler(dtype="float32")
    Xtr = scaler.fit_transform(Xtr)
    models = [
        api.fit(Xtr[ytr == c], method="oavi:fast", psi=0.005,
                backend="local", cap_terms=64)
        for c in np.unique(ytr)
    ]
    num_features = sum(m.num_G for m in models)

    rows = []
    for m in sizes:
        Z, _ = appendix_c(m=m, seed=1)
        Z = scaler.transform(Z)
        # one full-size pass per path: warms the jit traces at the timed
        # shape and provides the correctness comparison without extra runs
        ref = legacy_transform(models, Z)
        fused = api.feature_transform(models, Z, batch_size=BATCH_SIZE)
        np.testing.assert_allclose(fused, ref, rtol=1e-4, atol=1e-5)
        diff = float(np.abs(np.asarray(fused) - ref).max())

        t_legacy = timeit(lambda: legacy_transform(models, Z), repeat=3)
        t_fused = timeit(
            lambda: api.feature_transform(models, Z, batch_size=BATCH_SIZE),
            repeat=3,
        )
        row = {
            "m": m,
            "num_models": len(models),
            "num_features": num_features,
            "t_legacy_s": round(t_legacy, 4),
            "t_fused_s": round(t_fused, 4),
            "speedup": round(t_legacy / max(t_fused, 1e-9), 2),
            "max_abs_diff": diff,
        }
        rows.append(row)
        rep.add("transform_fused", **row)

    write_bench_json(
        "transform",
        rows,
        meta={
            "batch_size": BATCH_SIZE,
            "method": "oavi:fast",
            "psi": 0.005,
            "quick": quick,
        },
    )
