"""Validate the ``BENCH_*.json`` artifacts a benchmark run must emit.

Used by ``make bench-smoke``: after running the smoke benchmark subset, this
fails (exit 1) if any expected artifact is missing or malformed — missing
file, unparsable JSON, wrong schema tag, or an empty ``rows`` list.

    PYTHONPATH=src python -m benchmarks.check_artifacts fit transform scaling
"""

from __future__ import annotations

import json
import os
import sys

SCHEMA = "bench.v1"
DEFAULT_NAMES = [
    "fit", "transform", "scaling", "serve", "multiclass", "streaming", "online",
    "resilience", "obs",
]

# benches whose rows must cover specific sections (e.g. the oracle-engine
# class-batch speedup must actually be recorded, not silently dropped)
REQUIRED_SECTIONS = {
    "multiclass": ("equal_sizes", "bpcg_oracle", "lognormal_sizes"),
    "obs": ("fit_overhead", "serve_overhead", "trace_export",
            "sketch_accuracy", "device"),
}


def check(name: str, out_dir: str = "results") -> str:
    """Returns an error string, or '' when the artifact is well-formed."""
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    if not os.path.exists(path):
        return f"{path}: missing"
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return f"{path}: unreadable ({e})"
    if payload.get("schema") != SCHEMA:
        return f"{path}: schema={payload.get('schema')!r}, expected {SCHEMA!r}"
    if payload.get("bench") != name:
        return f"{path}: bench={payload.get('bench')!r}, expected {name!r}"
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        return f"{path}: empty or non-list rows"
    if not all(isinstance(r, dict) for r in rows):
        return f"{path}: non-dict row"
    required = REQUIRED_SECTIONS.get(name, ())
    got = {r.get("section") for r in rows}
    missing = [s for s in required if s not in got]
    if missing:
        return f"{path}: missing required section(s) {missing} (got {sorted(got)})"
    return ""


def main(argv=None) -> int:
    names = (argv if argv is not None else sys.argv[1:]) or DEFAULT_NAMES
    errors = [e for e in (check(n) for n in names) if e]
    for e in errors:
        print(f"BENCH artifact check FAILED: {e}", file=sys.stderr)
    if not errors:
        print(f"BENCH artifacts OK: {', '.join('BENCH_' + n + '.json' for n in names)}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
