"""Shared benchmark utilities: timing, CSV/JSON emission, dataset access."""

from __future__ import annotations

import csv
import json
import os
import time
from typing import Callable, Dict, List, Optional


class Reporter:
    """Collects (benchmark, metric, value) rows; prints CSV at the end."""

    def __init__(self):
        self.rows: List[Dict] = []

    def add(self, bench: str, **kv):
        row = {"bench": bench, **kv}
        self.rows.append(row)
        parts = ", ".join(f"{k}={v}" for k, v in kv.items())
        print(f"[{bench}] {parts}", flush=True)

    def write_csv(self, path: str):
        if not self.rows:
            return
        keys: List[str] = []
        for r in self.rows:
            for k in r:
                if k not in keys:
                    keys.append(k)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys)
            w.writeheader()
            w.writerows(self.rows)
        print(f"wrote {path} ({len(self.rows)} rows)")


def write_bench_json(
    name: str,
    rows: List[Dict],
    *,
    meta: Optional[Dict] = None,
    out_dir: str = "results",
) -> str:
    """Emit the standard ``BENCH_<name>.json`` artifact.

    Schema (``bench.v1``)::

        {"bench": <name>, "schema": "bench.v1", "created_unix": <float>,
         "meta": {...}, "rows": [{...}, ...]}
    """
    payload = {
        "bench": name,
        "schema": "bench.v1",
        "created_unix": time.time(),
        "meta": meta or {},
        "rows": rows,
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path} ({len(rows)} rows)")
    return path


def scaled_planted_source(m: int, n: int = 3, seed: int = 0, chunk_rows: int = 4096):
    """The planted-polynomial stream scaled to ``[0, 1]^n`` — the shared data
    setup of ``bench_streaming`` and ``bench_scaling --streaming``.  Rows are
    synthesized deterministically per tile (no storage at any ``m``) and
    min-max scaled one chunk at a time."""
    from repro.data.synthetic import planted_source
    from repro.streaming import ScaledSource, StreamingMinMaxScaler

    source = planted_source(m, n=n, seed=seed)
    scaler = StreamingMinMaxScaler(dtype="float32").fit_source(source, chunk_rows)
    return ScaledSource(source, scaler)


def timeit(fn: Callable, *, repeat: int = 1) -> float:
    """Best-of-repeat wall time in seconds."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best
