"""§Perf hillclimbing driver: lower a cell under config variants, report the
roofline-term deltas per iteration.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell A|B|C|D

Each cell runs its iteration ladder (baseline + candidate changes in
predicted-win order) and appends records to results/hillclimb.json.  The
narrative (hypothesis / napkin math / verdict) lives in EXPERIMENTS.md §Perf;
this file is the measurement harness.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import time


from repro import configs
from repro.launch import dryrun as D
from repro.launch import mesh as mesh_mod

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def measure(arch_id, shape_name, cfg, mesh, label):
    """Compile the cell variant and its 1/2-period unrolled cost variants."""
    t0 = time.time()
    lowered, _ = D.lower_cell(arch_id, shape_name, mesh, cfg=cfg)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    def cost_for(n):
        c = dataclasses.replace(cfg, n_periods=n, unroll_scan=True)
        lw, _ = D.lower_cell(arch_id, shape_name, mesh, cfg=c)
        cm = lw.compile()
        cost = cm.cost_analysis()
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": D.collective_bytes(cm.as_text()),
        }

    c1, c2 = cost_for(1), cost_for(2)
    n = cfg.n_periods
    df = max(c2["flops"] - c1["flops"], 0.0)
    db = max(c2["bytes"] - c1["bytes"], 0.0)
    dc = max(c2["coll"]["total"] - c1["coll"]["total"], 0)
    flops = c1["flops"] + (n - 1) * df
    byts = c1["bytes"] + (n - 1) * db
    coll = c1["coll"]["total"] + (n - 1) * dc
    rec = {
        "label": label,
        "arch": arch_id,
        "shape": shape_name,
        "flops": flops,
        "bytes": byts,
        "coll": coll,
        "t_compute_s": flops / PEAK_FLOPS,
        "t_memory_s": byts / HBM_BW,
        "t_collective_s": coll / ICI_BW,
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "compile_s": round(t_compile, 1),
    }
    terms = {k: rec[f"t_{k}_s"] for k in ("compute", "memory", "collective")}
    rec["dominant"] = max(terms, key=terms.get)
    rec["bound_s"] = terms[rec["dominant"]]
    print(f"  [{label}] compute={rec['t_compute_s']:.2f}s "
          f"memory={rec['t_memory_s']:.2f}s coll={rec['t_collective_s']:.2f}s "
          f"dominant={rec['dominant']} temp={rec['temp_bytes']/2**30:.1f}GiB",
          flush=True)
    return rec


def cell_A(mesh, iters=None):
    """kimi-k2-1t-a32b x train_4k — collective-dominated (MoE dispatch)."""
    arch, shape = "kimi-k2-1t-a32b", "train_4k"
    base = configs.get_config(arch)
    out = []
    iters = iters or {"baseline", "1", "2"}
    if "baseline" in iters:
        out.append(measure(arch, shape, base, mesh, "baseline(global-dispatch)"))
    row = dataclasses.replace(base, moe=base.moe._replace(dispatch="rowwise"))
    if "1" in iters:
        out.append(measure(arch, shape, row, mesh, "iter1:rowwise-dispatch"))
    if "2" in iters:
        row2 = dataclasses.replace(row, ce_impl="chunked")
        out.append(measure(arch, shape, row2, mesh, "iter2:+chunked-ce"))
    if "3" in iters:
        # iter3 = rowwise + use-site expert-weight gathering (code change in
        # moe._forward_rowwise; measured against the same config as iter1)
        out.append(measure(arch, shape, row, mesh, "iter3:rowwise+weight-gather"))
    return out


def cell_B(mesh):
    """qwen3-8b x train_4k — memory-dominated dense train."""
    arch, shape = "qwen3-8b", "train_4k"
    base = configs.get_config(arch)
    out = [measure(arch, shape, base, mesh, "baseline(remat-full,plain-ce)")]
    v1 = dataclasses.replace(base, remat_policy="dots")
    out.append(measure(arch, shape, v1, mesh, "iter1:remat-dots"))
    v2 = dataclasses.replace(v1, ce_impl="chunked")
    out.append(measure(arch, shape, v2, mesh, "iter2:+chunked-ce"))
    v3 = dataclasses.replace(v2, attn_impl="chunked")
    out.append(measure(arch, shape, v3, mesh, "iter3:+chunked-attn"))
    return out


def cell_C(mesh):
    """deepseek-v2-lite-16b x prefill_32k — worst useful_ratio (dense S^2)."""
    arch, shape = "deepseek-v2-lite-16b", "prefill_32k"
    base = configs.get_config(arch)
    out = [measure(arch, shape, base, mesh, "baseline(reference-attn)")]
    v1 = dataclasses.replace(base, attn_impl="chunked", attn_chunk=2048)
    out.append(measure(arch, shape, v1, mesh, "iter1:chunked-attn-2k"))
    v2 = dataclasses.replace(base, attn_impl="chunked", attn_chunk=8192)
    out.append(measure(arch, shape, v2, mesh, "iter2:chunked-attn-8k"))
    return out


def _oavi_variant(mesh, label, **kw):
    rec = D.run_oavi_cell(mesh, "pod16x16", **kw)
    rec["label"] = label
    rec["t_compute_s"] = rec["flops"] / PEAK_FLOPS
    rec["t_memory_s"] = rec["bytes_accessed"] / HBM_BW
    rec["t_collective_s"] = rec["collective_bytes"]["total"] / ICI_BW
    terms = {k: rec[f"t_{k}_s"] for k in ("compute", "memory", "collective")}
    rec["dominant"] = max(terms, key=terms.get)
    rec["bound_s"] = terms[rec["dominant"]]
    print(f"  [{label}] compute={rec['t_compute_s']*1e3:.3f}ms "
          f"memory={rec['t_memory_s']*1e3:.3f}ms "
          f"coll={rec['t_collective_s']*1e3:.3f}ms dominant={rec['dominant']}",
          flush=True)
    return rec


def cell_D(mesh):
    """oavi-gram-step — the paper's technique.

    The degree step is memory-term-bound (arithmetic intensity ~= K per A
    read); the ladder raises intensity (bigger candidate batches K) and
    halves streaming bytes (bf16 A/X with the Gram psum'd in f32).
    """
    recs = [_oavi_variant(mesh, "baseline(K=64,f32)", Kcap=64)]
    recs.append(_oavi_variant(mesh, "iter1:K=256", Kcap=256))
    recs.append(_oavi_variant(mesh, "iter2:K=256,bf16", Kcap=256, dtype="bfloat16"))
    return recs


CELLS = {"A": cell_A, "B": cell_B, "C": cell_C, "D": cell_D}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--iters", default=None,
                    help="comma-separated subset, e.g. 'baseline,1,3'")
    ap.add_argument("--out", default="results/hillclimb.json")
    args = ap.parse_args()
    mesh = mesh_mod.make_production_mesh()
    print(f"=== hillclimb cell {args.cell} ===")
    kw = {}
    if args.iters and args.cell == "A":
        kw["iters"] = set(args.iters.split(","))
    recs = CELLS[args.cell](mesh, **kw)
    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    existing.extend(recs)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(existing, f, indent=1, default=str)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
