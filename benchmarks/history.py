"""Bench history writer + the noise-aware perf-regression gate CLI.

Every ``benchmarks/run.py`` invocation appends ONE record to
``results/history.jsonl``: git SHA, an environment fingerprint, every
headline number flattened out of the ``results/BENCH_*.json`` artifacts,
and serialized histogram-sketch snapshots of the run's timing series.  The
file is append-only JSONL so the history survives schema evolution (old
records with a foreign schema tag are skipped, never deleted) and a torn
tail (killed writer) loses at most the last record.

``--gate`` is the regression decision (``make bench-gate``): the newest
record is compared against the rolling baseline of all earlier ones via
:func:`repro.obs.baseline.check_regression` — per-metric spread-aware
allowances plus merged-sketch p99 bands.  Two escapes keep the gate honest
on noisy runners, both borrowed from ``bench_obs``:

* a **zero-overhead control run** (the paired estimator timing a workload
  against itself) measures this machine's noise floor right now; when the
  floor cannot resolve the tolerance, a failure downgrades to a warning;
* ``BENCH_SOFT=1`` downgrades any remaining failure to a warning (shared
  constrained-CI idiom).

    PYTHONPATH=src python -m benchmarks.history            # append a record
    PYTHONPATH=src python -m benchmarks.history --gate     # regression check
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import platform
import subprocess
import time
from typing import Dict, Optional

from repro import obs
from repro.obs import baseline
from repro.obs.metrics import Histogram

DEFAULT_RESULTS = "results"
DEFAULT_HISTORY = os.path.join(DEFAULT_RESULTS, "history.jsonl")


def git_sha() -> Optional[str]:
    """HEAD commit of the working tree (None outside a git checkout)."""
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
    except Exception:
        return None
    sha = proc.stdout.strip()
    return sha if proc.returncode == 0 and sha else None


def env_fingerprint() -> Dict:
    """Enough environment to explain a perf shift without ssh'ing anywhere."""
    env: Dict = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "machine": platform.machine(),
        "cpus": os.cpu_count(),
    }
    try:
        import jax

        env["jax"] = jax.__version__
        env["backend"] = jax.default_backend()
        env["device_count"] = jax.device_count()
    except Exception:
        pass
    return env


def flatten_bench(doc: Dict) -> Dict[str, float]:
    """Flatten one ``bench.v1`` document into gateable metric keys.

    Key shape: ``<bench>[.quick|.full]/<section>/<i>:<field>`` — the index
    is the row's position within its section, stable because bench rows are
    emitted deterministically.  Quick and full runs get distinct keys so a
    ``--full`` run never poisons the quick baseline (or vice versa).  Only
    scalar numbers survive; booleans are config, not measurements.
    """
    name = str(doc.get("bench", "?"))
    meta = doc.get("meta") or {}
    if "quick" in meta:
        name += ".quick" if meta["quick"] else ".full"
    out: Dict[str, float] = {}
    counters: Dict[str, int] = {}
    for row in doc.get("rows", []):
        if not isinstance(row, dict):
            continue
        section = str(row.get("section", "rows"))
        i = counters.get(section, 0)
        counters[section] = i + 1
        for field, v in row.items():
            if field == "section" or isinstance(v, bool):
                continue
            if isinstance(v, (int, float)):
                out[f"{name}/{section}/{i}:{field}"] = float(v)
    return out


def registry_sketch_states(reg=None) -> Dict[str, Dict]:
    """Serialized states of every non-empty histogram series in a registry."""
    reg = reg if reg is not None else obs.registry()
    states: Dict[str, Dict] = {}
    names = {r["name"] for r in reg.snapshot() if r.get("type") == "histogram"}
    for name in sorted(names):
        for labels, metric in reg.find(name):
            if not isinstance(metric, Histogram) or metric.count == 0:
                continue
            key = name
            if labels:
                inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
                key = f"{name}{{{inner}}}"
            states[key] = metric.to_state()
    return states


def collect_record(results_dir: str = DEFAULT_RESULTS) -> Dict:
    """One history record from the BENCH artifacts currently on disk."""
    benches: Dict[str, Dict] = {}
    metrics: Dict[str, float] = {}
    for path in sorted(glob.glob(os.path.join(results_dir, "BENCH_*.json"))):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (json.JSONDecodeError, OSError):
            continue  # torn artifact of a dead run; the gate never guesses
        if doc.get("schema") != "bench.v1":
            continue
        name = str(doc.get("bench") or os.path.basename(path))
        benches[name] = {
            "created_unix": doc.get("created_unix"),
            "rows": len(doc.get("rows") or []),
            "meta": doc.get("meta") or {},
        }
        metrics.update(flatten_bench(doc))
    return {
        "schema": baseline.RECORD_SCHEMA,
        "created_unix": time.time(),
        "git_sha": git_sha(),
        "env": env_fingerprint(),
        "benches": benches,
        "metrics": metrics,
        "sketches": registry_sketch_states(),
    }


def append_record(record: Dict, path: str = DEFAULT_HISTORY) -> str:
    """Append one record (single JSON line, flushed) to the history file."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    line = json.dumps(record, sort_keys=True)
    with open(path, "a") as f:
        f.write(line + "\n")
        f.flush()
        os.fsync(f.fileno())
    return path


def measure_noise_floor(repeat: int = 5) -> float:
    """This machine's timing-noise floor, right now: the paired best-of-N
    estimator from ``bench_obs`` timing a fixed workload against itself.
    The true overhead is exactly zero, so anything it reads is noise."""
    from .bench_obs import _paired_overhead

    payload = list(range(20_000))

    def work():
        acc = 0
        for v in payload:
            acc += v * v
        return acc

    _, _, control = _paired_overhead(work, work, repeat)
    return abs(control)


def run_gate(history_path: str, *, tolerance: float = 0.25) -> int:
    """The ``make bench-gate`` decision; returns a process exit code."""
    records, warnings = baseline.load_history(history_path)
    for w in warnings:
        print(f"bench-gate: {w}")
    if len(records) < 2:
        print(
            f"bench-gate: {len(records)} history record(s) in {history_path}; "
            f"need >= 2 to compare — vacuous pass"
        )
        return 0
    current, base = records[-1], records[:-1]
    verdict = baseline.check_regression(current, base, tolerance=tolerance)
    sha = (current.get("git_sha") or "?")[:12]
    print(
        f"bench-gate: {verdict['status']} at {sha} — {verdict['checked']} "
        f"metric(s) checked against {len(base)} baseline record(s), "
        f"{len(verdict['skipped'])} skipped"
    )
    for s in verdict["skipped"]:
        print(f"  skip {s}")
    for f in verdict["findings"]:
        print(
            f"  REGRESSION [{f['kind']}] {f['key']}: {f['current']:.4g} vs "
            f"baseline {f['baseline_best']:.4g} "
            f"(allowed {f['allowed']:.4g}, {f['ratio']:.2f}x)"
        )
    if verdict["status"] != "fail":
        return 0
    # escape 1: can this box even resolve the tolerance right now?
    floor = measure_noise_floor()
    if floor > tolerance / 2:
        print(
            f"WARNING: bench-gate found regressions but the zero-overhead "
            f"control measured {floor:.1%} noise — this machine cannot "
            f"resolve the {tolerance:.0%} tolerance; not failing"
        )
        return 0
    # escape 2: the shared constrained-CI idiom
    if os.environ.get("BENCH_SOFT"):
        print(
            f"WARNING: {len(verdict['findings'])} perf regression(s) "
            f"(BENCH_SOFT set; not failing)"
        )
        return 0
    print(f"bench-gate: FAILED with {len(verdict['findings'])} regression(s)")
    return 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--results-dir", type=str, default=DEFAULT_RESULTS)
    ap.add_argument("--history", type=str, default=None,
                    help=f"history JSONL path (default: <results-dir>/"
                    f"{os.path.basename(DEFAULT_HISTORY)})")
    ap.add_argument("--gate", action="store_true",
                    help="check the newest record against the rolling "
                    "baseline instead of appending a new one")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="fractional slowdown allowed over the baseline best")
    args = ap.parse_args(argv)
    history_path = args.history or os.path.join(
        args.results_dir, os.path.basename(DEFAULT_HISTORY)
    )
    if args.gate:
        return run_gate(history_path, tolerance=args.tolerance)
    rec = collect_record(args.results_dir)
    path = append_record(rec, history_path)
    print(
        f"history: appended record ({len(rec['metrics'])} metrics, "
        f"{len(rec['sketches'])} sketches, sha {(rec['git_sha'] or '?')[:12]}) "
        f"-> {path}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
