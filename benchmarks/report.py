"""Render EXPERIMENTS.md §Roofline table + §Dry-run memory notes from the
results JSONs.

    PYTHONPATH=src python -m benchmarks.report > results/roofline.md
"""

from __future__ import annotations

import json
import os

from .roofline import analyse


def main():
    path = "results/dryrun_pod16x16.json"
    recs = json.load(open(path))
    rows = []
    for r in recs:
        if "flops" not in r:
            continue
        a = analyse(r)
        a["temp_gib"] = r.get("memory", {}).get("temp_size_bytes", 0) / 2**30
        rows.append(a)
    rows.sort(key=lambda a: (a["arch"], a["shape"]))

    print("| arch | shape | compute s | memory s | collective s | dominant | "
          "MODEL_FLOPS | useful | MFU-bound | temp GiB/dev |")
    print("|---|---|---:|---:|---:|---|---:|---:|---:|---:|")
    for a in rows:
        print(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.3g} | "
            f"{a['t_memory_s']:.3g} | {a['t_collective_s']:.3g} | "
            f"**{a['dominant']}** | {a['model_flops']:.2e} | "
            f"{a['useful_ratio']:.3f} | {a['mfu_bound']:.3f} | "
            f"{a['temp_gib']:.1f} |"
        )

    # one-sentence bottleneck notes per dominant category
    print()
    mem = [a for a in rows if a["dominant"] == "memory"]
    col = [a for a in rows if a["dominant"] == "collective"]
    cmp_ = [a for a in rows if a["dominant"] == "compute"]
    print(f"- memory-dominated: {len(mem)} cells; "
          f"collective-dominated: {len(col)}; compute-dominated: {len(cmp_)}.")

    # multi-pod compile proof table
    mp = "results/dryrun_pod2x16x16.json"
    if os.path.exists(mp):
        recs2 = json.load(open(mp))
        print(f"\nMulti-pod (2x16x16 = 512 chips): {len(recs2)} cells "
              "lower+compile OK:")
        for r in sorted(recs2, key=lambda r: (r["arch"], r["shape"])):
            print(f"  - {r['arch']} x {r['shape']}: compile "
                  f"{r['time_compile_s']}s, raw coll/dev "
                  f"{r['collective_bytes_raw']['total'] if 'collective_bytes_raw' in r else r['collective_bytes']['total']:.2e} B")


if __name__ == "__main__":
    main()
