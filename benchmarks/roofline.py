"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Per (arch x shape x mesh) cell, using the per-device quantities extracted by
``launch/dryrun.py`` (cost_analysis is per-partition — calibrated against a
known sharded matmul):

    compute term    = HLO_FLOPs_per_dev / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_dev / HBM_BW_per_chip
    collective term = collective_bytes_per_dev / ICI_BW_per_chip

The dominant term is the projected step-time lower bound; MODEL_FLOPS
(6·N·D for training, 2·N·D prefill, 2·N_active·B decode) over total HLO
FLOPs measures how much compiled compute is "useful" (catches remat +
resharding waste + attention's non-parameter FLOPs).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

# v5e-class hardware constants (per prompt)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def count_params(arch_id: str):
    """(total_params, active_params) — active discounts routed experts."""
    import jax

    from repro import configs
    from repro.models import model as M

    cfg = configs.get_config(arch_id)
    ap = M.abstract_params(cfg)
    total = active = 0.0
    moe = cfg.moe

    def visit(path, leaf):
        nonlocal total, active
        n = float(np.prod(leaf.shape))
        total += n
        names = [getattr(k, "key", None) for k in path]
        is_expert = (
            moe is not None
            and names[0] == "blocks"
            and names[-1] in ("w_in", "w_out")
            and len(leaf.shape) == 4  # (periods, E, in, out)
        )
        if is_expert:
            active += n * (moe.top_k / moe.num_experts)
        else:
            active += n

    jax.tree_util.tree_map_with_path(visit, ap)
    return total, active


def model_flops(arch_id: str, shape_name: str) -> float:
    """Useful-FLOPs reference for the cell (global, not per-device)."""
    from repro import configs

    if arch_id.startswith("oavi"):
        # oavi-gram-step shape string: m{M}M_n{n}_L{L}_K{K}
        parts = dict(p[0] for p in [[("m", s[1:-1]) if s.startswith("m") and s.endswith("M")
                                      else (s[0], s[1:])] for s in shape_name.split("_")])
        m = float(parts["m"]) * 1e6
        L, K = float(parts["L"]), float(parts["K"])
        # useful work per degree step: B = gather*mul (m*K), A^T B, B^T B
        return m * K + 2.0 * m * L * K + 2.0 * m * K * K

    shape = configs.SHAPES[shape_name]
    total, active = count_params(arch_id)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch


def analyse(rec: Dict) -> Dict:
    devs = rec["devices"]
    flops_dev = rec["flops"]
    bytes_dev = rec["bytes_accessed"]
    coll_dev = rec["collective_bytes"]["total"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * devs
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_fraction": terms["compute"] / terms[dominant] if terms[dominant] else 0.0,
        "mfu_bound": (mf / devs / PEAK_FLOPS) / terms[dominant] if terms[dominant] else 0.0,
    }


def load_records(results_dir: str = "results") -> List[Dict]:
    recs = []
    for name in sorted(os.listdir(results_dir)) if os.path.isdir(results_dir) else []:
        if name.startswith("dryrun_") and name.endswith(".json"):
            with open(os.path.join(results_dir, name)) as f:
                recs.extend(json.load(f))
    return recs


def run(rep, quick: bool = True, results_dir: str = "results"):
    recs = load_records(results_dir)
    if not recs:
        rep.add("roofline", note="no dry-run records found; run "
                "`python -m repro.launch.dryrun --all` first")
        return
    rows = []
    for rec in recs:
        if "flops" not in rec:
            continue
        a = analyse(rec)
        # single-pod records carry loop-corrected costs (1/2-period unrolled
        # extrapolation); multi-pod records are compile-proof only and carry
        # RAW per-device costs (while bodies counted once) — flagged so the
        # two are never compared directly.
        a["cost_basis"] = "corrected" if "cost_detail" in rec else "raw"
        rows.append(a)
        rep.add("roofline", arch=a["arch"], shape=a["shape"], mesh=a["mesh"],
                cost_basis=a["cost_basis"],
                t_compute_ms=round(a["t_compute_s"] * 1e3, 2),
                t_memory_ms=round(a["t_memory_s"] * 1e3, 2),
                t_collective_ms=round(a["t_collective_s"] * 1e3, 2),
                dominant=a["dominant"],
                useful_ratio=round(a["useful_ratio"], 3),
                mfu_bound=round(a["mfu_bound"], 3))
    # write the EXPERIMENTS-ready table
    os.makedirs(results_dir, exist_ok=True)
    with open(os.path.join(results_dir, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
