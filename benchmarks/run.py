"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,table3]

Default (quick) sizes finish on CPU in ~10 minutes; ``--full`` uses the
paper-scale sample counts (up to 2M).  Results go to results/benchmarks.csv.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

from . import (
    history,
    bench_ablation,
    bench_bound,
    bench_fit,
    bench_ihb,
    bench_multiclass,
    bench_obs,
    bench_online,
    bench_ordering,
    bench_performance,
    bench_resilience,
    bench_scaling,
    bench_serve,
    bench_solvers,
    bench_streaming,
    bench_transform,
    roofline,
)
from .common import Reporter

BENCHES = {
    "fig1_bound": bench_bound.run,
    "fig2_solvers": bench_solvers.run,
    "fig3_ihb": bench_ihb.run,
    "fig4_scaling": bench_scaling.run,
    "table1_ordering": bench_ordering.run,
    "table3_performance": bench_performance.run,
    "ablation_psi": bench_ablation.run,
    "transform_fused": bench_transform.run,
    "fit_fused": bench_fit.run,
    "serve_engine": bench_serve.run,
    "multiclass_batched": bench_multiclass.run,
    "streaming_oavi": bench_streaming.run,
    "online_oavi": bench_online.run,
    "resilience_chaos": bench_resilience.run,
    "obs_overhead": bench_obs.run,
    "roofline": roofline.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated subset of: " + ",".join(BENCHES))
    ap.add_argument("--out", type=str, default="results/benchmarks.csv")
    args = ap.parse_args(argv)

    names = list(BENCHES) if not args.only else args.only.split(",")
    rep = Reporter()
    t0 = time.time()
    for name in names:
        if name not in BENCHES:
            print(f"unknown benchmark {name!r}", file=sys.stderr)
            continue
        print(f"=== {name} ===", flush=True)
        t1 = time.time()
        BENCHES[name](rep, quick=not args.full)
        print(f"=== {name} done in {time.time() - t1:.1f}s ===", flush=True)
    rep.write_csv(args.out)
    # one history record per invocation: the perf-regression gate's raw data
    results_dir = os.path.dirname(args.out) or "results"
    history.append_record(
        history.collect_record(results_dir),
        os.path.join(results_dir, "history.jsonl"),
    )
    print(f"all benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
