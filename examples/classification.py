"""Algorithm 2 end-to-end: OAVI feature transform + linear SVM classifier.

Compares the paper's pipelines (CGAVI-IHB, BPCGAVI-WIHB) against ABM, VCA
and a polynomial-kernel SVM on the Appendix-C synthetic dataset.  Methods
are selected with :mod:`repro.api` spec strings; generator construction and
the fused feature transform run through the unified estimator API.

    PYTHONPATH=src python examples/classification.py [--m 20000]
"""

import argparse
import time

from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier
from repro.core.svm import PolySVM, PolySVMConfig
from repro.data.synthetic import appendix_c, train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=20000)
    ap.add_argument("--psi", type=float, default=0.005)
    args = ap.parse_args()

    X, y = appendix_c(m=args.m, seed=0)
    Xtr, ytr, Xte, yte = train_test_split(X, y, test_frac=0.4, seed=0)
    print(f"Appendix-C synthetic: {Xtr.shape[0]} train / {Xte.shape[0]} test\n")
    print(f"{'method':>16} {'test err %':>10} {'fit s':>8} {'|G|+|O|':>8} "
          f"{'avg deg':>8} {'SPAR':>6}")

    for method in ["oavi:cgavi-ihb", "oavi:bpcgavi-wihb", "abm", "vca"]:
        kw = {"cap_terms": 64} if method != "vca" else {}
        clf = VanishingIdealClassifier(
            PipelineConfig(method=method, psi=args.psi, oavi_kw=kw))
        t0 = time.perf_counter()
        clf.fit(Xtr, ytr)
        dt = time.perf_counter() - t0
        err = 100 * (1 - clf.score(Xte, yte))
        print(f"{method:>16} {err:>10.2f} {dt:>8.1f} "
              f"{clf.stats['G_plus_O']:>8} {clf.average_degree():>8.2f} "
              f"{clf.sparsity():>6.2f}")

    t0 = time.perf_counter()
    ps = PolySVM(PolySVMConfig(degree=3, lam=1e-4)).fit(Xtr, ytr)
    dt = time.perf_counter() - t0
    err = 100 * (1 - ps.score(Xte, yte))
    print(f"{'poly-svm':>16} {err:>10.2f} {dt:>8.1f} {'-':>8} {'-':>8} {'-':>6}")


if __name__ == "__main__":
    main()
