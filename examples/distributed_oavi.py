"""The paper's technique at scale: data-parallel OAVI via shard_map.

Shards one million Appendix-C samples over 8 (fake, on CPU) devices through
the unified estimator API — ``repro.api.fit(..., backend="sharded")`` routes
to :mod:`repro.core.distributed` without the caller ever importing it — and
verifies the distributed fit matches the single-device reference.  The
collectives are two small psums per degree, independent of m (weak-scaling).

    PYTHONPATH=src python examples/distributed_oavi.py
(sets XLA_FLAGS itself; run as a script, not -m, so the flag binds first)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import api  # noqa: E402
from repro.core.transform import MinMaxScaler  # noqa: E402
from repro.data.synthetic import appendix_c  # noqa: E402


def main():
    m = 1_000_000
    X, _ = appendix_c(m=m, seed=0)
    X = MinMaxScaler(dtype="float32").fit_transform(X)

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    print(f"devices: {len(jax.devices())}, samples: {m}")

    t0 = time.perf_counter()
    dist = api.fit(X, method="oavi:fast", psi=0.005, backend="sharded",
                   mesh=mesh, cap_terms=64)
    t_dist = time.perf_counter() - t0
    print(f"distributed: |G|={dist.num_G} |O|={dist.num_O} in {t_dist:.2f}s "
          f"(backend={dist.stats['api']['backend']})")

    t0 = time.perf_counter()
    ref = api.fit(X[:100_000], method="oavi:fast", psi=0.005, backend="local",
                  cap_terms=64)  # reference on a 10% slice
    t_ref = time.perf_counter() - t0
    print(f"single-dev (100k slice): |G|={ref.num_G} |O|={ref.num_O} in {t_ref:.2f}s")

    assert [g.term for g in dist.generators] == [g.term for g in ref.generators], \
        "leading terms differ between 1M distributed and 100k reference"
    print("leading terms agree; per-degree collective payload = "
          f"{dist.stats['border_sizes']} columns of Gram blocks (m-independent)")


if __name__ == "__main__":
    main()
