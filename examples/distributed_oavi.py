"""The paper's technique at scale: data-parallel OAVI via shard_map.

Shards one million Appendix-C samples over 8 (fake, on CPU) devices and
verifies the distributed fit matches the single-device reference — the
collectives are two small psums per degree, independent of m (weak-scaling).

    PYTHONPATH=src python examples/distributed_oavi.py
(sets XLA_FLAGS itself; run as a script, not -m, so the flag binds first)
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import distributed, oavi  # noqa: E402
from repro.core.oavi import OAVIConfig  # noqa: E402
from repro.core.transform import MinMaxScaler  # noqa: E402
from repro.data.synthetic import appendix_c  # noqa: E402


def main():
    m = 1_000_000
    X, _ = appendix_c(m=m, seed=0)
    X = MinMaxScaler().fit_transform(X)
    cfg = OAVIConfig(psi=0.005, engine="fast", cap_terms=64)

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    print(f"devices: {len(jax.devices())}, samples: {m}")

    t0 = time.perf_counter()
    dist = distributed.fit(X, cfg, mesh=mesh)
    t_dist = time.perf_counter() - t0
    print(f"distributed: |G|={dist.num_G} |O|={dist.num_O} in {t_dist:.2f}s")

    t0 = time.perf_counter()
    ref = oavi.fit(X[:100_000], cfg)  # reference on a 10% slice
    t_ref = time.perf_counter() - t0
    print(f"single-dev (100k slice): |G|={ref.num_G} |O|={ref.num_O} in {t_ref:.2f}s")

    assert [g.term for g in dist.generators] == [g.term for g in ref.generators], \
        "leading terms differ between 1M distributed and 100k reference"
    print("leading terms agree; per-degree collective payload = "
          f"{dist.stats['border_sizes']} columns of Gram blocks (m-independent)")


if __name__ == "__main__":
    main()
