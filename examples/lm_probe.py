"""OAVI as a representation probe on LM hidden states (DESIGN.md §4).

The paper's technique composes with the architecture zoo at the
representation level: pooled hidden states of a (tiny, randomly-initialized
vs lightly-trained) LM are min-max scaled into [0,1]^n and per-class
generator sets are constructed — exactly Algorithm 2 with X = activations.
Linear separability of the transformed features measures how much class
structure the representation carries (a vanishing-ideal linear probe).

    PYTHONPATH=src python examples/lm_probe.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier
from repro.models import model as M
from repro.optim import AdamW


def pooled_states(params, cfg, tokens):
    """Mean-pooled final hidden states (B, d)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, period_params):
        x, aux = carry
        for idx, btype in enumerate(cfg.period):
            x, aux = M._apply_block(btype, period_params[f"{idx:02d}_{btype}"],
                                    x, cfg, positions, aux)
        return (x, aux), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    return x.mean(axis=1)


def make_task(vocab, m, seed):
    """Two token 'languages': class 0 = ascending runs, class 1 = repeats."""
    rng = np.random.default_rng(seed)
    S = 24
    X = np.zeros((m, S), np.int32)
    y = rng.integers(0, 2, m)
    for i in range(m):
        if y[i] == 0:
            start = rng.integers(0, vocab - S)
            X[i] = (start + np.arange(S) * rng.integers(1, 3)) % vocab
        else:
            tok = rng.integers(0, vocab, 4)
            X[i] = np.tile(tok, S // 4)
    return X, y


def main():
    cfg = configs.get_reduced("qwen3-8b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    Xtok, y = make_task(cfg.vocab_size, 1200, seed=0)
    feats = np.asarray(pooled_states(params, cfg, jnp.asarray(Xtok)))
    cut = 800
    clf = VanishingIdealClassifier(PipelineConfig(
        method="cgavi-ihb", psi=0.01, oavi_kw={"cap_terms": 128, "max_degree": 3}))
    clf.fit(feats[:cut], y[:cut])
    acc = clf.score(feats[cut:], y[cut:])
    print(f"OAVI probe on {cfg.name} pooled states: test acc {acc:.3f} "
          f"(|G|+|O| = {clf.stats['G_plus_O']})")
    assert acc > 0.8, "probe should separate the two token languages"


if __name__ == "__main__":
    main()
