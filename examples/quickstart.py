"""Quickstart: construct approximate vanishing ideal generators with OAVI.

Fits CGAVI-IHB to points near the unit circle, prints the recovered
generators (the circle equation should appear), and evaluates them on
unseen points of the same variety.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import oavi, terms
from repro.core.oavi import OAVIConfig
from repro.core.oracles import OracleConfig
from repro.core.transform import MinMaxScaler


def circle_points(m, seed=0, noise=0.01):
    rng = np.random.default_rng(seed)
    theta = rng.uniform(0, 2 * np.pi, m)
    X = np.stack([np.cos(theta), np.sin(theta)], axis=1)
    return X + rng.normal(0, noise, X.shape)


def main():
    scaler = MinMaxScaler()
    X = scaler.fit_transform(circle_points(2000))

    config = OAVIConfig(
        psi=0.005,
        engine="oracle",          # paper-faithful oracle engine
        solver=OracleConfig(name="cg"),
        ihb=True,                 # Inverse Hessian Boosting warm starts
    )
    model = oavi.fit(X, config)

    print(f"|G| = {model.num_G} generators, |O| = {model.num_O} terms")
    print(f"Theorem 4.3 bound on |G|+|O|: {model.stats['thm43_bound']}")
    print(f"fit time: {model.stats['time_total']:.2f}s\n")

    for g in model.generators[:5]:
        parts = []
        for c, t in zip(g.coeffs, model.book.terms):
            if abs(c) > 1e-3:
                parts.append(f"{c:+.3f}*{terms.term_to_str(t)}")
        lead = terms.term_to_str(g.term)
        print(f"  g = {lead} {' '.join(parts)}   (MSE {g.mse:.2e})")

    Z = scaler.transform(circle_points(500, seed=1, noise=0.0))
    mses = np.asarray(model.mse(Z))
    print(f"\nout-of-sample MSE of generators: max {mses.max():.2e} "
          f"(psi = {model.psi}) -> generators vanish on unseen variety points")


if __name__ == "__main__":
    main()
