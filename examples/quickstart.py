"""Quickstart: construct approximate vanishing ideal generators with OAVI.

Uses the unified estimator API (:mod:`repro.api`): pick a method with a spec
string, fit, inspect the recovered generators (the circle equation should
appear), save the fitted model atomically, reload it, and evaluate on unseen
points of the same variety.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import numpy as np

from repro import api
from repro.core import terms
from repro.core.transform import MinMaxScaler


def circle_points(m, seed=0, noise=0.01):
    rng = np.random.default_rng(seed)
    theta = rng.uniform(0, 2 * np.pi, m)
    X = np.stack([np.cos(theta), np.sin(theta)], axis=1)
    return X + rng.normal(0, noise, X.shape)


def main():
    print("available methods:", ", ".join(api.available_methods()), "\n")

    scaler = MinMaxScaler(dtype="float32")
    X = scaler.fit_transform(circle_points(2000))

    # paper-faithful CGAVI-IHB: CG oracle + Inverse Hessian Boosting
    model = api.fit(X, method="oavi:cgavi-ihb", psi=0.005)

    print(f"|G| = {model.num_G} generators, |O| = {model.num_O} terms")
    print(f"Theorem 4.3 bound on |G|+|O|: {model.stats['thm43_bound']}")
    print(f"fit time: {model.stats['time_total']:.2f}s\n")

    for g in model.generators[:5]:
        parts = []
        for c, t in zip(g.coeffs, model.book.terms):
            if abs(c) > 1e-3:
                parts.append(f"{c:+.3f}*{terms.term_to_str(t)}")
        lead = terms.term_to_str(g.term)
        print(f"  g = {lead} {' '.join(parts)}   (MSE {g.mse:.2e})")

    # save -> load round trip through the atomic checkpoint manifest
    with tempfile.TemporaryDirectory() as d:
        path = model.save(d)
        restored = api.load(d)
        print(f"\nsaved to {path} and reloaded")

        Z = scaler.transform(circle_points(500, seed=1, noise=0.0))
        assert np.array_equal(model.transform(Z), restored.transform(Z)), \
            "save/load round trip must be bit-identical"
        mses = np.asarray(restored.mse(Z))
        print(f"out-of-sample MSE of generators: max {mses.max():.2e} "
              f"(psi = {restored.psi}) -> generators vanish on unseen variety points")


if __name__ == "__main__":
    main()
