"""End-to-end driver: train a ~100M-param qwen3-style LM for a few hundred
steps on the local mesh, with checkpointing and resume.

The config is a genuine member of the qwen3 family (qk-norm, GQA, SwiGLU)
scaled to ~100M params so the run completes on CPU; on TPU the same driver
(launch/train.py) takes the full config.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import os
import tempfile

from repro.launch.train import train
from repro.models.model import ModelConfig


def qwen3_100m() -> ModelConfig:
    # 12 layers x (1.6M attn + 7.1M mlp) + 25M embeddings ~= 130M params
    return ModelConfig(
        name="qwen3-100m", family="dense",
        n_periods=12, period=("attn", "mlp"),
        d_model=768, vocab_size=16384,
        n_heads=12, n_kv_heads=4, d_head=64,
        qk_norm=True, rope_theta=1e6,
        d_ff=3072, dtype="float32", remat=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    args = ap.parse_args()

    cfg = qwen3_100m()
    import jax
    import numpy as np

    from repro.models import model as M
    from repro.optim import AdamW

    n_params = sum(
        np.prod(l.shape) for l in jax.tree.leaves(M.abstract_params(cfg))
    )
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    ckpt = args.ckpt_dir or os.path.join(tempfile.gettempdir(), "qwen3_100m_ckpt")
    opt = AdamW(peak_lr=1e-3, warmup_steps=max(args.steps // 20, 5),
                total_steps=args.steps)
    report = train(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        ckpt_dir=ckpt, ckpt_every=100, opt=opt,
    )
    losses = report["losses"]
    k = max(len(losses) // 10, 1)
    print(f"loss: first-10-avg {sum(losses[:k])/k:.4f} -> "
          f"last-10-avg {sum(losses[-k:])/k:.4f}")
    print(f"checkpoints in {ckpt}; restarts={report.get('restarts', 0)}")


if __name__ == "__main__":
    main()
