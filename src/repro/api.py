"""Unified estimator API for vanishing-ideal generator construction.

One entry point over every algorithm family in the repo:

* **method registry** — algorithms register themselves with
  :func:`register`; callers pick one with a spec string such as ``"oavi"``,
  ``"oavi:bpcgavi-wihb"``, ``"abm"`` or ``"vca"`` (bare OAVI variant names
  like ``"cgavi-ihb"`` are accepted for backward compatibility).
  :func:`available_methods` lists every valid spec.
* **backend dispatch** — :func:`fit` routes OAVI to
  :mod:`repro.core.distributed` when a mesh is supplied (or, under
  ``backend="auto"``, when multiple devices are visible and ``m`` is large
  enough), so callers never import the distributed module directly.
* **VanishingIdealModel protocol** — every fitted model exposes
  ``evaluate_G`` / ``transform`` / ``to_state_dict`` / ``from_state_dict``;
  :func:`save` / :func:`load` persist models through the atomic
  :mod:`repro.checkpoint.store` manifest machinery, so a fitted model
  survives restarts and can be shipped to a serving process.
* **fused batched transform** — :func:`feature_transform` concatenates all
  per-class term books and generator matrices into a *single* jitted
  ``evaluate_terms`` call plus one matmul, with ``batch_size`` chunking so
  million-row transforms stream through device memory.
* **class-batched multi-class fitting** — :func:`fit_classes` (or
  :func:`fit` with a list of per-class arrays) drives eligible per-class
  OAVI fits through one vmapped degree step (:mod:`repro.core.class_batch`)
  grouped into shared pow2 row buckets, falling back to sequential fits for
  stragglers and non-batchable configs; :func:`aggregate_fit_stats` folds
  the per-group compile counters into classifier-level totals.
"""

from __future__ import annotations

import dataclasses
import json
import logging
from typing import (
    Any,
    Callable,
    Dict,
    List,
    Optional,
    Protocol,
    Sequence,
    Tuple,
    Union,
    runtime_checkable,
)

import numpy as np

# Canonical OAVI variant table (was ``pipeline.VARIANTS``; Section 6.1).
# name: (engine, solver, ihb, wihb)
OAVI_VARIANTS: Dict[str, Tuple[str, str, bool, bool]] = {
    "cgavi-ihb": ("oracle", "cg", True, False),
    "agdavi-ihb": ("oracle", "agd", True, False),
    "bpcgavi": ("oracle", "bpcg", False, False),
    "bpcgavi-wihb": ("oracle", "bpcg", True, True),
    "pcgavi": ("oracle", "pcg", False, False),
    "cgavi": ("oracle", "cg", False, False),
    "agdavi": ("oracle", "agd", False, False),
    "fast": ("fast", "bpcg", True, False),  # beyond-paper closed-form engine
}

# OAVI_VARIANTS must be defined before the core imports below:
# ``repro.core.pipeline`` lazily imports this module for its deprecated
# ``VARIANTS`` alias, which may happen while this module is mid-import.
import jax
import jax.numpy as jnp

from . import obs
from . import streaming as streaming_mod
from .checkpoint import store as ckpt_store
from .core import abm as abm_mod
from .core import class_batch as class_batch_mod
from .core import distributed as distributed_mod
from .core import oavi as oavi_mod
from .core import vca as vca_mod
from .core.oavi import OAVIModel, apply_wavefronts, wavefront_schedule
from .core.oracles import OracleConfig
from .core.transform import feature_transform as _legacy_feature_transform
from .core.vca import VCAModel
from .resilience.integrity import IntegrityError

_log = logging.getLogger("repro.api")

# ``backend="auto"``: shard only when the sample count amortizes the psum +
# shard_map overhead (the collectives are m-independent, the fixed cost isn't).
AUTO_SHARD_MIN_M = 100_000


# ---------------------------------------------------------------------------
# VanishingIdealModel protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class VanishingIdealModel(Protocol):
    """What every fitted generator model exposes (OAVIModel, VCAModel, ...)."""

    n: int
    psi: float
    stats: Dict

    def evaluate_G(self, Z) -> Any:
        """Evaluation matrix of all generators over Z: (q, |G|)."""
        ...

    def transform(self, Z) -> np.ndarray:
        """(FT) features for this model alone: ``|G(Z)|``."""
        ...

    def to_state_dict(self) -> Tuple[Dict[str, np.ndarray], Dict]:
        """(flat array tree, JSON-safe metadata) — see :func:`save`."""
        ...

    def save(self, path: str) -> str:
        """Persist via :func:`repro.api.save`."""
        ...


# ---------------------------------------------------------------------------
# Method registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MethodEntry:
    """A registered generator-construction algorithm."""

    name: str
    fit: Callable[..., VanishingIdealModel]
    variants: Tuple[str, ...] = ()
    default_variant: Optional[str] = None
    supports_sharded: bool = False
    description: str = ""

    def spec(self, variant: Optional[str]) -> str:
        return f"{self.name}:{variant}" if variant else self.name


_REGISTRY: Dict[str, MethodEntry] = {}


def register(
    name: str,
    *,
    variants: Sequence[str] = (),
    default_variant: Optional[str] = None,
    supports_sharded: bool = False,
    description: str = "",
):
    """Decorator: register ``fn(X, *, variant, psi, backend, mesh, data_axes,
    config, **kw) -> VanishingIdealModel`` under ``name``."""

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"method {name!r} is already registered")
        _REGISTRY[name] = MethodEntry(
            name=name,
            fit=fn,
            variants=tuple(variants),
            default_variant=default_variant,
            supports_sharded=supports_sharded,
            description=description,
        )
        return fn

    return deco


def available_methods() -> Tuple[str, ...]:
    """Every valid ``method=`` spec, e.g. ``('abm', 'oavi', 'oavi:cgavi', ...)``."""
    specs: List[str] = []
    for name in sorted(_REGISTRY):
        specs.append(name)
        specs.extend(f"{name}:{v}" for v in _REGISTRY[name].variants)
    return tuple(specs)


def resolve(spec: str) -> Tuple[MethodEntry, Optional[str]]:
    """``'oavi:cgavi-ihb'`` -> (oavi entry, 'cgavi-ihb').  Also accepts bare
    method names (default variant) and bare OAVI variant names (legacy)."""
    if not isinstance(spec, str):
        raise TypeError(f"method spec must be a string, got {type(spec).__name__}")
    if ":" in spec:
        name, variant = spec.split(":", 1)
        entry = _REGISTRY.get(name)
        if entry is None:
            raise ValueError(
                f"unknown method {name!r}; available: {', '.join(available_methods())}"
            )
        if variant not in entry.variants:
            raise ValueError(
                f"unknown variant {variant!r} for method {name!r}; "
                f"available: {', '.join(entry.variants) or '(none)'}"
            )
        return entry, variant
    if spec in _REGISTRY:
        entry = _REGISTRY[spec]
        return entry, entry.default_variant
    # legacy: bare OAVI variant names ("cgavi-ihb", "fast", ...)
    for entry in _REGISTRY.values():
        if spec in entry.variants:
            return entry, spec
    raise ValueError(
        f"unknown method {spec!r}; available: {', '.join(available_methods())}"
    )


# ---------------------------------------------------------------------------
# Registered methods
# ---------------------------------------------------------------------------


def oavi_config_for(variant: str, psi: float, **kw) -> oavi_mod.OAVIConfig:
    """Build an :class:`OAVIConfig` from a named paper variant."""
    engine, solver, ihb, wihb = OAVI_VARIANTS[variant]
    solver_cfg = OracleConfig(name=solver, **kw.pop("solver_kw", {}))
    return oavi_mod.OAVIConfig(
        psi=psi, engine=engine, solver=solver_cfg, ihb=ihb, wihb=wihb, **kw
    )


@register(
    "oavi",
    variants=tuple(OAVI_VARIANTS),
    default_variant="fast",
    supports_sharded=True,
    description="Oracle AVI (Algorithm 1); variants per Section 6.1",
)
def _fit_oavi(X, *, variant, psi, backend, mesh, data_axes, config=None, **kw):
    cfg = config if config is not None else oavi_config_for(variant or "fast", psi, **kw)
    if backend == "sharded":
        return distributed_mod.fit(X, cfg, mesh=mesh, data_axes=data_axes)
    return oavi_mod.fit(X, cfg)


@register("abm", description="Approximate Buchberger-Möller (Limbeck 2013)")
def _fit_abm(X, *, variant, psi, backend, mesh, data_axes, config=None, **kw):
    cfg = config if config is not None else abm_mod.ABMConfig(psi=psi, **kw)
    return abm_mod.fit(X, cfg)


@register("vca", description="Vanishing Component Analysis (Livni et al. 2013)")
def _fit_vca(X, *, variant, psi, backend, mesh, data_axes, config=None, **kw):
    cfg = config if config is not None else vca_mod.VCAConfig(psi=psi, **kw)
    return vca_mod.fit(X, cfg)


# ---------------------------------------------------------------------------
# Backend dispatch
# ---------------------------------------------------------------------------


def _default_mesh(data_axes: Sequence[str]):
    axes = tuple(data_axes)
    if len(axes) != 1:
        raise ValueError(
            "backend dispatch can only build a default mesh for a single data "
            f"axis; pass mesh= explicitly for data_axes={axes!r}"
        )
    return jax.make_mesh((len(jax.devices()),), axes)


def _resolve_backend(
    entry: MethodEntry, backend: str, mesh, m: int
) -> Tuple[str, Any]:
    if backend not in ("auto", "local", "sharded"):
        raise ValueError(
            f"unknown backend {backend!r}; expected 'auto', 'local' or 'sharded'"
        )
    if backend == "local":
        return "local", None
    if backend == "sharded":
        if not entry.supports_sharded:
            raise ValueError(
                f"method {entry.name!r} does not support backend='sharded'"
            )
        return "sharded", mesh
    # auto: shard when the method can, and a mesh was supplied or the device
    # count and sample count justify it.
    if entry.supports_sharded and (
        mesh is not None or (len(jax.devices()) > 1 and m >= AUTO_SHARD_MIN_M)
    ):
        return "sharded", mesh
    return "local", None


def fit(
    X,
    method: str = "oavi",
    *,
    psi: float = 0.005,
    backend: str = "auto",
    mesh=None,
    data_axes: Sequence[str] = ("data",),
    out_sharding=None,
    config=None,
    class_batch: str = "auto",
    source=None,
    chunk_rows: Optional[int] = None,
    capture_state: bool = False,
    **method_kw,
) -> Union[VanishingIdealModel, List[VanishingIdealModel]]:
    """Fit a vanishing-ideal model with the selected ``method`` and backend.

    Parameters
    ----------
    X : (m, n) array in ``[0, 1]^n`` — or a *list* of per-class arrays, in
        which case one model is fitted per class (see :func:`fit_classes`)
        and a list of models is returned — or a
        :class:`repro.streaming.DataSource`, which routes to the out-of-core
        streaming fit (equivalent to passing it as ``source=``).
    method : spec string — ``"oavi"``, ``"oavi:<variant>"``, ``"abm"``,
        ``"vca"``; see :func:`available_methods`.
    psi : vanishing tolerance.
    backend : ``"auto"`` (default) picks ``"sharded"`` for OAVI when a mesh
        is supplied or >1 device is visible and ``m >= AUTO_SHARD_MIN_M``;
        otherwise ``"local"``.
    mesh : optional :class:`jax.sharding.Mesh` for the sharded backend (a
        1-axis mesh over all devices is built when omitted).
    data_axes : mesh axes the sample dimension is sharded over.
    out_sharding : optional sharding hint attached to the returned model; the
        fused :func:`feature_transform` places its output there by default.
    config : pre-built method config (``OAVIConfig`` / ``ABMConfig`` /
        ``VCAConfig``); overrides ``psi`` and ``method_kw`` when given.
    class_batch : ``"auto"`` | ``"off"`` — multi-class fits only (``X`` a
        list): ``"auto"`` batches eligible per-class OAVI fits through one
        vmapped degree step (:mod:`repro.core.class_batch`).
    source : optional chunked data source (:mod:`repro.streaming`) — fits
        out-of-core through :func:`repro.streaming.fit`: the evaluation
        matrix is rematerialized per degree in ``chunk_rows``-row chunks and
        reduced to Gram statistics, so ``m`` is not bounded by device
        memory.  OAVI only; bit-exact vs the in-memory fit at matched
        capacity.  The source must already be scaled to ``[0, 1]^n``
        (compose with :class:`repro.streaming.ScaledSource`).
    chunk_rows : streaming chunk size (power of two, multiple of
        :data:`repro.kernels.ops.GRAM_BLOCK`); default
        :data:`repro.streaming.DEFAULT_CHUNK_ROWS`.  Setting it with an
        in-memory ``X`` (array or per-class list) streams through the
        array(s) as sources — same out-of-core fit path, OAVI only.
    capture_state : streaming OAVI fits only — also capture the incremental
        :class:`repro.online.FitState` (attached as ``model.fit_state``) so
        the model can later be refreshed in place with :func:`update` when
        the source grows.  Local backend only.
    **method_kw : forwarded to the method's config constructor (e.g.
        ``cap_terms=64``, ``solver_kw={"max_iter": 2000}``).
    """
    if source is None and streaming_mod.is_source(X):
        source, X = X, None
    if source is None and chunk_rows is not None and not isinstance(X, (list, tuple)):
        # chunk_rows on an in-memory array: stream through it as a source
        # (the fit never materializes the (m, Lcap) evaluation matrix)
        source, X = streaming_mod.as_source(np.asarray(X)), None
    if source is not None:
        return _fit_streaming(
            source,
            method,
            psi=psi,
            backend=backend,
            mesh=mesh,
            data_axes=data_axes,
            config=config,
            chunk_rows=chunk_rows,
            out_sharding=out_sharding,
            capture_state=capture_state,
            **method_kw,
        )
    if capture_state:
        raise ValueError(
            "capture_state=True needs the streaming fit path: pass source= "
            "(or an in-memory X together with chunk_rows=)"
        )
    if isinstance(X, (list, tuple)):
        return fit_classes(
            X,
            method,
            psi=psi,
            backend=backend,
            mesh=mesh,
            data_axes=data_axes,
            class_batch=class_batch,
            config=config,
            chunk_rows=chunk_rows,
            **method_kw,
        )
    if class_batch not in ("auto", "off"):
        raise ValueError(
            f"unknown class_batch {class_batch!r}; expected 'auto' or 'off'"
        )
    entry, variant = resolve(method)
    X = np.asarray(X)
    backend_r, mesh_r = _resolve_backend(entry, backend, mesh, X.shape[0])
    if backend_r == "sharded" and mesh_r is None:
        mesh_r = _default_mesh(data_axes)
    model = entry.fit(
        X,
        variant=variant,
        psi=psi,
        backend=backend_r,
        mesh=mesh_r,
        data_axes=tuple(data_axes),
        config=config,
        **method_kw,
    )
    model.stats["api"] = {"method": entry.spec(variant), "backend": backend_r}
    if out_sharding is not None:
        model.transform_out_sharding = out_sharding
    return model


def _fit_streaming(
    source,
    method: str,
    *,
    psi: float,
    backend: str,
    mesh,
    data_axes: Sequence[str],
    config,
    chunk_rows: Optional[int],
    out_sharding=None,
    capture_state: bool = False,
    **method_kw,
):
    """Out-of-core dispatch: route an OAVI spec to :func:`repro.streaming.fit`
    (or, with ``capture_state``, to :func:`repro.online.fit` — same fold,
    same caches, plus the persisted accumulators)."""
    entry, variant = resolve(method)
    if entry.name != "oavi":
        raise ValueError(
            f"streaming fit (source=) supports OAVI only, got method {method!r}"
        )
    cfg = config if config is not None else oavi_config_for(variant or "fast", psi, **method_kw)
    source = streaming_mod.as_source(source)
    backend_r, mesh_r = _resolve_backend(entry, backend, mesh, source.num_rows)
    if capture_state:
        if backend_r == "sharded":
            raise ValueError(
                "capture_state=True is local-only (an incremental update is "
                "O(new rows); run full sharded refits without it)"
            )
        from . import online as online_mod

        model, fit_state = online_mod.fit(
            source, cfg, chunk_rows=chunk_rows or streaming_mod.DEFAULT_CHUNK_ROWS
        )
        model.stats["api"] = {
            "method": entry.spec(variant),
            "backend": backend_r,
            "streaming": True,
            "online": True,
        }
        model.fit_state = fit_state
        if out_sharding is not None:
            model.transform_out_sharding = out_sharding
        return model
    if backend_r == "sharded" and mesh_r is None:
        mesh_r = _default_mesh(data_axes)
    model = streaming_mod.fit(
        source,
        cfg,
        chunk_rows=chunk_rows or streaming_mod.DEFAULT_CHUNK_ROWS,
        mesh=mesh_r if backend_r == "sharded" else None,
        data_axes=tuple(data_axes),
    )
    model.stats["api"] = {
        "method": entry.spec(variant),
        "backend": backend_r,
        "streaming": True,
    }
    if out_sharding is not None:
        model.transform_out_sharding = out_sharding
    return model


def update(model, state, source, **kw):
    """Refresh a :func:`fit(..., capture_state=True) <fit>` model in place
    after its source grew.

    Folds only the new rows into ``state``'s persisted per-degree Gram
    accumulators and re-runs the m-independent degree steps — bit-identical
    to refitting from scratch on the grown source at matched capacity, at
    O(new rows) cost and zero recompiles warm.  Returns the
    :class:`repro.online.UpdateResult` whose ``.model`` carries a fresh
    ``fit_state`` for the next increment.  See :func:`repro.online.update`
    for keyword arguments (``chunk_rows``, ``scaler``, ``prefetch``, ...).
    """
    from . import online as online_mod

    result = online_mod.update(model, state, source, **kw)
    api_stats = dict(getattr(model, "stats", {}).get("api") or {})
    api_stats.update({"backend": "local", "streaming": True, "online": True})
    result.model.stats["api"] = api_stats
    result.model.fit_state = result.state
    return result


# ---------------------------------------------------------------------------
# Multi-class fitting: class-batched when eligible, sequential otherwise
# ---------------------------------------------------------------------------


def fit_classes(
    Xs: Sequence,
    method: str = "oavi",
    *,
    psi: float = 0.005,
    backend: str = "auto",
    mesh=None,
    data_axes: Sequence[str] = ("data",),
    class_batch: str = "auto",
    config=None,
    chunk_rows: Optional[int] = None,
    **method_kw,
) -> List[VanishingIdealModel]:
    """Fit one model per class — Algorithm 2's generator-construction phase.

    With ``class_batch="auto"`` (default) and an eligible OAVI config
    (:func:`repro.core.oavi.class_batchable`: every engine with the Theorem
    4.9 ``inverse`` — the ``fast`` closed form AND the oracle solvers/WIHB,
    which run their masked fixed-schedule twins under ``vmap``; only the
    Cholesky engine is excluded), classes are grouped into shared pow2 row
    buckets (:func:`repro.core.class_batch.plan_class_groups`: greedy
    buckets, cross-bucket merges while padding stays ~2x, and straggler
    classes folded into their cheapest warm bucket rather than fitted
    sequentially) and every group is fitted through ONE vmapped jitted degree
    step (:func:`repro.core.class_batch.fit_classes`) — bit-exact against
    the sequential path at matched capacity, one dispatch per degree instead
    of k.  Non-OAVI methods and non-batchable configs fall back to per-class
    :func:`fit`.  Each batched model's ``stats["class_batch_padding"]``
    reports the padded-row bill its group paid.

    The sharded backend composes: when ``backend`` resolves to
    ``"sharded"``, batched groups run the vmap-inside-``shard_map`` step
    over ``mesh`` (class axis replicated, sample axis sharded).

    With ``chunk_rows`` (out-of-core classes) and a local backend, batchable
    configs route through :func:`repro.streaming.fit_classes`: each class
    streams its own chunks, and the per-degree acceptance decisions run as
    one vmapped statistics-only step — no row padding at all (streaming has
    no shared row bucket).  Sharded streaming stays per-class.

    Returns the fitted models in class order.  Batched models' stats carry a
    ``"class_batch"`` group dict whose shared ``recompiles`` / ``regrowths``
    must be aggregated once per group — use :func:`aggregate_fit_stats`.
    """
    if class_batch not in ("auto", "off"):
        raise ValueError(
            f"unknown class_batch {class_batch!r}; expected 'auto' or 'off'"
        )
    entry, variant = resolve(method)
    Xs = [np.asarray(X) for X in Xs]

    def seq_fit(X):
        if chunk_rows is not None and entry.name == "oavi":
            # out-of-core per-class fits: each class streams through the
            # chunk accumulator (bit-exact vs its in-memory fit); used when
            # the vmapped streaming class batch doesn't apply (sharded
            # streaming, non-batchable configs)
            return fit(
                X,
                method,
                psi=psi,
                backend=backend,
                mesh=mesh,
                data_axes=data_axes,
                config=config,
                source=streaming_mod.as_source(X),
                chunk_rows=chunk_rows,
                **dict(method_kw),
            )
        return fit(
            X,
            method,
            psi=psi,
            backend=backend,
            mesh=mesh,
            data_axes=data_axes,
            config=config,
            **dict(method_kw),
        )

    if class_batch == "off" or entry.name != "oavi" or len(Xs) < 2:
        return [seq_fit(X) for X in Xs]
    cfg = (
        config
        if config is not None
        else oavi_config_for(variant or "fast", psi, **dict(method_kw))
    )
    if not oavi_mod.class_batchable(cfg):
        return [seq_fit(X) for X in Xs]  # chol engine only: sequential

    backend_r, mesh_r = _resolve_backend(
        entry, backend, mesh, max(X.shape[0] for X in Xs)
    )
    if backend_r == "sharded" and mesh_r is None:
        mesh_r = _default_mesh(data_axes)

    if chunk_rows is not None:
        if backend_r == "sharded":
            # sharded streaming stays per-class (the vmapped streaming stats
            # step is local-only)
            return [seq_fit(X) for X in Xs]
        fitted = streaming_mod.fit_classes(Xs, cfg, chunk_rows=chunk_rows)
        for model in fitted:
            model.stats["api"] = {
                "method": entry.spec(variant),
                "backend": backend_r,
                "streaming": True,
                "class_batch": True,
            }
        return list(fitted)

    models: List[Optional[VanishingIdealModel]] = [None] * len(Xs)
    sizes = [X.shape[0] for X in Xs]
    for cap, idxs in class_batch_mod.plan_class_groups(sizes):
        fitted = class_batch_mod.fit_classes(
            [Xs[i] for i in idxs],
            cfg,
            mesh=mesh_r if backend_r == "sharded" else None,
            data_axes=tuple(data_axes),
            m_cap=cap,
        )
        # the dispatched row bucket (>= cap: sharding may round up)
        mc = int(fitted[0].stats["class_batch"]["m_cap"])
        group_rows = sum(sizes[i] for i in idxs)
        group_padded = mc * len(idxs) - group_rows
        for i, model in zip(idxs, fitted):
            model.stats["api"] = {
                "method": entry.spec(variant),
                "backend": backend_r,
                "class_batch": True,
            }
            model.stats["class_batch_padding"] = {
                "m_cap": mc,
                "rows": int(sizes[i]),
                "padded_rows": mc - int(sizes[i]),
                "group_rows": int(group_rows),
                "group_padded_rows": int(group_padded),
                # fraction of the group's dispatched rows that are padding
                "waste": group_padded / float(mc * len(idxs)),
            }
            models[i] = model
    return models


def aggregate_fit_stats(models: Sequence) -> Dict:
    """Classifier-level fit counters over per-class models.

    Class-batched models share ONE compile/regrowth schedule per batch group
    (their per-model stats all carry the same counts), so naively summing
    per-class stats overcounts by the group size; this counts each group
    once and each sequentially-fitted model individually.  The same dedup
    applies to the solver-discipline outcome (``solver_escalations`` is per
    batch, not per class); ``solver_schedule_len`` reports the longest
    schedule any group ran.  ``class_batch_padding`` rolls the per-model
    padding accounting up to dispatched/padded row totals and the overall
    waste fraction, and the aggregate is mirrored into the metric registry
    (``fit.solver_*`` / ``fit.class_batch_padding_waste`` with
    ``backend="aggregate"``) so obs_report sees the classifier-level view."""
    recompiles = regrowths = 0
    escalations = 0
    schedule_len: Optional[int] = None
    batched = 0
    groups = set()
    pad_groups = set()
    dispatched_rows = padded_rows = 0
    for model in models:
        stats = getattr(model, "stats", None) or {}
        sched = stats.get("solver_schedule_len")
        if sched is not None:
            schedule_len = max(int(sched), schedule_len or 0)
        group = stats.get("class_batch")
        padding = stats.get("class_batch_padding")
        if padding is not None:
            # group totals are replicated on every member; count each once
            pad_key = (padding["m_cap"], padding["group_rows"],
                       padding["group_padded_rows"])
            if pad_key not in pad_groups:
                pad_groups.add(pad_key)
                dispatched_rows += int(padding["group_rows"]) + int(
                    padding["group_padded_rows"]
                )
                padded_rows += int(padding["group_padded_rows"])
        if group is not None:
            batched += 1
            if group["group"] in groups:
                continue
            groups.add(group["group"])
            recompiles += int(group["recompiles"])
            regrowths += int(group["regrowths"])
            escalations += int(stats.get("solver_escalations", 0))
        else:
            recompiles += int(stats.get("recompiles", 0))
            regrowths += int(stats.get("regrowths", 0))
            escalations += int(stats.get("solver_escalations", 0))
    out: Dict = {
        "recompiles": recompiles,
        "regrowths": regrowths,
        "class_batched": batched,
        "class_batch_groups": len(groups),
        "solver_schedule_len": schedule_len,
        "solver_escalations": escalations,
    }
    if dispatched_rows:
        out["class_batch_padding"] = {
            "dispatched_rows": dispatched_rows,
            "padded_rows": padded_rows,
            "waste": padded_rows / float(dispatched_rows),
        }
    if obs.enabled():
        reg = obs.registry()
        if schedule_len is not None:
            reg.gauge(
                "fit.solver_schedule_len", backend="aggregate"
            ).set(float(schedule_len))
        if escalations:
            reg.counter(
                "fit.solver_escalations", backend="aggregate"
            ).inc(escalations)
        if dispatched_rows:
            reg.gauge("fit.class_batch_padding_waste").set(
                padded_rows / float(dispatched_rows)
            )
    return out


# ---------------------------------------------------------------------------
# Serialization: save / load through the checkpoint manifest machinery
# ---------------------------------------------------------------------------

_MODEL_KINDS: Dict[str, Any] = {"oavi": OAVIModel, "vca": VCAModel}
_FORMAT = "repro.vanishing_ideal_model.v1"


def _json_safe(obj):
    """Recursively convert numpy scalars/arrays so metadata JSON-serializes."""
    if isinstance(obj, dict):
        return {str(k): _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


def save_state_dict(path: str, arrays: Dict, meta: Dict, fmt: str, step: int = 0) -> str:
    """Write one ``(arrays, meta)`` state dict as a committed, format-tagged
    checkpoint — the single save-side protocol shared by :func:`save` and
    :meth:`VanishingIdealClassifier.save`.  Arrays land as manifest-tracked
    leaves, ``meta`` (made JSON-safe) in the manifest, and the COMMITTED
    marker makes the write crash-safe.  Returns the committed directory.

    ``step`` versions the save inside ``path``: a caller that checkpoints a
    lineage (e.g. the continuous controller's per-version ``FitState``)
    bumps it so :func:`load_state_dict` has older committed steps to fall
    back to when the head is corrupted after commit."""
    metadata = {
        "format": fmt,
        "kind": meta.get("kind"),
        "meta": _json_safe(meta),
        "array_keys": sorted(arrays),
    }
    return ckpt_store.save(path, step=step, tree=dict(arrays), metadata=metadata)


def load_state_dict(path: str, fmt: str) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Load the newest *verifiable* committed state dict at ``path``,
    checking its format tag — the restore-side counterpart of
    :func:`save_state_dict`.

    Every leaf is checksum-verified before deserializing (manifest v2); a
    corrupt head step falls back to the newest older committed step that
    verifies, so post-commit bit rot costs freshness, not availability.
    When every committed step is damaged, the head's
    :class:`~repro.resilience.integrity.IntegrityError` (naming the bad
    file) propagates."""
    steps = ckpt_store.committed_steps(path)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoint under {path!r}")
    head_err: Optional[IntegrityError] = None
    for step in reversed(steps):
        try:
            metadata, _ = ckpt_store.read_metadata(path, step)
            if metadata.get("format") != fmt:
                raise ValueError(
                    f"{path!r} is not a {fmt} checkpoint "
                    f"(format={metadata.get('format')!r})"
                )
            like = {k: np.zeros(()) for k in metadata["array_keys"]}
            arrays, metadata = ckpt_store.restore(path, step, like)
        except (IntegrityError, json.JSONDecodeError) as e:
            _log.warning("checkpoint step %d at %r failed verification: %s", step, path, e)
            if head_err is None:
                head_err = e if isinstance(e, IntegrityError) else IntegrityError(str(e))
            continue
        if step != steps[-1]:
            _log.warning(
                "loaded step %d from %r (newest committed step %d is corrupt)",
                step, path, steps[-1],
            )
        return arrays, metadata
    raise head_err


def save(model: VanishingIdealModel, path: str) -> str:
    """Persist a fitted model to ``path`` (a directory) atomically."""
    arrays, meta = model.to_state_dict()
    kind = meta.get("kind")
    if kind not in _MODEL_KINDS:
        raise ValueError(f"cannot save model of unknown kind {kind!r}")
    return save_state_dict(path, arrays, meta, _FORMAT)


def load(path: str) -> VanishingIdealModel:
    """Load a model previously written by :func:`save` (bit-identical)."""
    arrays, metadata = load_state_dict(path, _FORMAT)
    cls = _MODEL_KINDS[metadata["kind"]]
    return cls.from_state_dict(arrays, metadata["meta"])


# ---------------------------------------------------------------------------
# Fused batched transform
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _FusedPlan:
    """All per-class term books and generator matrices concatenated into one
    global book (constant term shared at index 0) so the whole (FT) is one
    ``evaluate_terms`` call plus one matmul."""

    parents: np.ndarray  # (L,) int32 — global term book parent chain
    vars: np.ndarray  # (L,) int32 — variable indices in ORIGINAL Z coords
    C: np.ndarray  # (L, Ktot) — block-diagonal generator coefficients
    gp: np.ndarray  # (Ktot,) int32 — leading-term parent (global index)
    gv: np.ndarray  # (Ktot,) int32 — leading-term variable (original coords)
    dtype: np.dtype
    num_features: int
    n: int  # input dimension (original Z coordinates)


def _fuse(models: Sequence) -> Optional[_FusedPlan]:
    """Build the fused plan, or None when a model is not term-book based
    (e.g. VCA) — callers fall back to the per-model loop."""
    models = [m for m in models]
    if not models or not all(type(m) is OAVIModel for m in models):
        return None
    n = models[0].n
    if any(m.n != n for m in models):
        return None
    dtype = np.dtype(models[0].dtype)
    if any(np.dtype(m.dtype) != dtype for m in models):
        return None  # mixed precision: evaluate each model in its own dtype
    g_parents: List[np.ndarray] = [np.zeros((1,), np.int32)]
    g_vars: List[np.ndarray] = [np.zeros((1,), np.int32)]
    c_blocks: List[Tuple[int, np.ndarray]] = []  # (row offset, (ell_b, k_b))
    gp_all: List[np.ndarray] = []
    gv_all: List[np.ndarray] = []
    offset = 1  # global slot of each model's first non-constant term
    for m in models:
        if m.num_G == 0:
            continue  # contributes no feature columns; skip its book entirely
        perm = (
            np.asarray(m.feature_perm, np.int64)
            if m.feature_perm is not None
            else np.arange(n, dtype=np.int64)
        )
        pb, vb = m.term_arrays()
        ell = pb.shape[0]
        C, gp, gv = m.generator_arrays()
        c_blocks.append((offset, C.astype(dtype, copy=False)))
        gp_all.append(np.where(gp == 0, 0, offset + gp - 1).astype(np.int32))
        gv_all.append(perm[gv].astype(np.int32))
        if ell > 1:
            g_parents.append(
                np.where(pb[1:] == 0, 0, offset + pb[1:] - 1).astype(np.int32)
            )
            g_vars.append(perm[vb[1:]].astype(np.int32))
        offset += ell - 1
    L = offset
    parents = np.concatenate(g_parents)
    vars_ = np.concatenate(g_vars)
    num_features = sum(b.shape[1] for _, b in c_blocks)
    C = np.zeros((L, num_features), dtype)
    col = 0
    for row_off, Cb in c_blocks:
        k = Cb.shape[1]
        C[0, col : col + k] = Cb[0]  # constant-term coefficients
        C[row_off : row_off + Cb.shape[0] - 1, col : col + k] = Cb[1:]
        col += k
    gp = np.concatenate(gp_all) if gp_all else np.zeros((0,), np.int32)
    gv = np.concatenate(gv_all) if gv_all else np.zeros((0,), np.int32)
    return _FusedPlan(
        parents=parents,
        vars=vars_,
        C=C,
        gp=gp,
        gv=gv,
        dtype=dtype,
        num_features=num_features,
        n=n,
    )


@dataclasses.dataclass(frozen=True)
class PlanConstants:
    """Trace-constant arrays of the fused (FT) evaluation, hoisted out of the
    jitted function.

    Everything here depends only on the fitted models (via the
    :class:`_FusedPlan`), never on the query batch, so per-shape retraces
    reuse the same host arrays instead of rebuilding them — and the serving
    engine (:mod:`repro.serving.engine`) shares them across its shape
    buckets and its local / ``shard_map`` execution paths.
    """

    waves: Tuple  # wavefront schedule over the fused book
    C_w: np.ndarray  # (L, k) generator coefficients, wavefront row order
    GPsel: np.ndarray  # (L, k) one-hot: leading-term parent column selector
    GVsel: np.ndarray  # (n, k) one-hot: leading-term variable selector
    dtype: np.dtype
    num_features: int
    n: int


def plan_constants(plan: "_FusedPlan") -> PlanConstants:
    """Hoist every trace constant of the fused evaluation out of the traced
    function.

    The fused multi-book column order is not degree-grouped, so instead of
    permuting the wavefront output at runtime the permutation is folded into
    the constants: the generator matrix rows are pre-gathered into wavefront
    order and both leading-term selections (parent column and variable) are
    one-hot matmuls — the whole transform is matmuls, no runtime gathers.
    """
    waves, perm = wavefront_schedule(plan.parents, plan.vars)
    L = int(np.asarray(plan.parents).shape[0])
    k = plan.C.shape[1]
    if perm is not None:
        # cols_original = cols_wave[:, perm]  =>  cols_original @ C ==
        # cols_wave @ C[order] with order = argsort(perm)
        order = np.argsort(perm)
        C_w = np.ascontiguousarray(plan.C[order])
        gp_w = perm[plan.gp]  # original index -> wavefront column
    else:
        C_w = plan.C
        gp_w = plan.gp
    GPsel = np.zeros((L, k), np.float32)
    GPsel[gp_w, np.arange(k)] = 1.0
    GVsel = np.zeros((plan.n, k), np.float32)
    GVsel[np.asarray(plan.gv), np.arange(k)] = 1.0
    return PlanConstants(
        waves=waves,
        C_w=C_w,
        GPsel=GPsel,
        GVsel=GVsel,
        dtype=plan.dtype,
        num_features=plan.num_features,
        n=plan.n,
    )


def eval_with_constants(consts: PlanConstants, Z) -> jax.Array:
    """Fused (FT) body over hoisted constants: a degree-wavefront term sweep
    (all terms of a degree in one batched select-matmul step — O(max_degree)
    sequential steps instead of O(|O|)) plus one matmul.  Pure and
    traceable: callers wrap it in ``jax.jit`` and/or ``shard_map``."""
    cols = apply_wavefronts(Z, consts.waves)  # (q, L) in wavefront order
    lead = (cols @ jnp.asarray(consts.GPsel, Z.dtype)) * (
        Z @ jnp.asarray(consts.GVsel, Z.dtype)
    )
    return jnp.abs(cols @ jnp.asarray(consts.C_w, Z.dtype) + lead)


def _make_fused_eval(plan: "_FusedPlan"):
    """Jitted fused (FT) evaluation for one plan (see
    :func:`eval_with_constants`; constants hoisted via
    :func:`plan_constants`)."""
    consts = plan_constants(plan)

    @jax.jit
    def fused_eval(Z):
        return eval_with_constants(consts, Z)

    return fused_eval


def _fused_plan_and_eval(models: Sequence):
    """Fused plan and its jitted wavefront evaluator, cached on the first
    model.

    The plan depends only on the fitted models, so serving loops calling
    :func:`feature_transform` repeatedly skip the per-call plan assembly and
    trace-constant upload.  The cache entry holds strong references to the
    models, which keeps their ids unique for as long as the key is live.
    """
    key = tuple(id(m) for m in models)
    cached = models[0].__dict__.get("_fused_plan_cache")
    if cached is not None and cached[0] == key:
        return cached[2], cached[3]
    plan = _fuse(models)
    if plan is None:
        return None, None
    fn = _make_fused_eval(plan)
    models[0].__dict__["_fused_plan_cache"] = (key, tuple(models), plan, fn)
    return plan, fn


def feature_transform(
    models: Sequence,
    Z,
    *,
    batch_size: Optional[int] = None,
    out_sharding=None,
    dtype: Optional[str] = None,
    engine=None,
) -> np.ndarray:
    """(FT) over all per-class models as ONE jitted evaluation.

    Drop-in replacement for :func:`repro.core.transform.feature_transform`:
    same output (within dtype tolerance), but all term books are evaluated in
    a single ``evaluate_terms`` sweep and all generators in one matmul.
    ``batch_size`` streams Z through device memory in fixed-size chunks (the
    trailing chunk is padded, so at most two jit traces exist).  Models
    without a term book (VCA) fall back to the per-model loop.

    ``engine`` routes the call through a warmed
    :class:`repro.serving.engine.TransformEngine` built for the same model
    set — shape-bucketed (zero recompiles at varying q) and optionally
    sharded over a serving mesh.  The engine path is bit-identical to the
    direct path at matched dtype.

    ``out_sharding`` (or a ``transform_out_sharding`` attribute left on the
    first model by :func:`fit`) places the result; the default returns host
    numpy.
    """
    if batch_size is not None and batch_size < 1:
        raise ValueError(f"batch_size must be a positive integer, got {batch_size}")
    if out_sharding is None and models:
        out_sharding = getattr(models[0], "transform_out_sharding", None)
    if engine is not None:
        if not engine.matches(models):
            raise ValueError(
                "engine was built for a different model set; build a "
                "TransformEngine over exactly these models"
            )
        out = engine.transform(Z)
        if dtype is not None:
            out = np.asarray(out).astype(np.dtype(dtype), copy=False)
        return jax.device_put(out, out_sharding) if out_sharding is not None else out
    plan, fused_eval = _fused_plan_and_eval(models) if models else (None, None)
    if plan is None:
        out = _legacy_feature_transform(models, Z, dtype=dtype)
        return jax.device_put(out, out_sharding) if out_sharding is not None else out
    Z = np.asarray(Z)
    q = Z.shape[0]
    out_dtype = np.dtype(dtype) if dtype is not None else plan.dtype
    if plan.num_features == 0:
        out = np.zeros((q, 0), out_dtype)
        return jax.device_put(out, out_sharding) if out_sharding is not None else out
    Zd = Z.astype(plan.dtype, copy=False)
    if batch_size is None or batch_size >= q:
        if q == 1:
            # XLA lowers single-row matmuls as gemv with a different
            # accumulation pattern than the q >= 2 gemm path; evaluate at
            # q=2 so direct, chunked and serving-bucket paths all see the
            # same row-stable lowering (bit-identical results).
            pad = np.zeros((2, Z.shape[1]), plan.dtype)
            pad[:1] = Zd
            out = fused_eval(jnp.asarray(pad))[:1]
        else:
            out = fused_eval(jnp.asarray(Zd))
        if out_sharding is not None:
            return jax.device_put(out, out_sharding)
        return np.asarray(out).astype(out_dtype, copy=False)
    out = np.empty((q, plan.num_features), out_dtype)
    # chunks must be >= 2 rows so no chunk hits the single-row gemv lowering
    # (see the q == 1 branch above); the output rows are unchanged
    batch_size = max(batch_size, 2)
    for start in range(0, q, batch_size):
        chunk = Zd[start : start + batch_size]
        if chunk.shape[0] < batch_size:  # pad trailing chunk: one trace only
            pad = np.zeros((batch_size, Z.shape[1]), plan.dtype)
            pad[: chunk.shape[0]] = chunk
            res = fused_eval(jnp.asarray(pad))[: chunk.shape[0]]
        else:
            res = fused_eval(jnp.asarray(chunk))
        out[start : start + batch_size] = np.asarray(res).astype(
            out_dtype, copy=False
        )
    return jax.device_put(out, out_sharding) if out_sharding is not None else out


__all__ = [
    "AUTO_SHARD_MIN_M",
    "MethodEntry",
    "OAVI_VARIANTS",
    "PlanConstants",
    "VanishingIdealModel",
    "aggregate_fit_stats",
    "available_methods",
    "eval_with_constants",
    "feature_transform",
    "fit",
    "fit_classes",
    "load",
    "load_state_dict",
    "oavi_config_for",
    "plan_constants",
    "register",
    "resolve",
    "save",
    "save_state_dict",
    "update",
]
