"""Checkpoint substrate: atomic sharded save/restore with elastic re-shard."""
from . import store
from .store import save, restore, latest_step, cleanup, AsyncSaver
__all__ = ["store", "save", "restore", "latest_step", "cleanup", "AsyncSaver"]
