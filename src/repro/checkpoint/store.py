"""Sharded checkpointing with atomic manifest commit and elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json       # tree structure, shapes, dtypes, checksums, metadata
        leaf_00000.npy ...  # one file per pytree leaf (host-gathered)
        COMMITTED           # written last — a checkpoint without it is junk

Why this design survives failures:

* **atomicity** — leaves are written into ``step_N.tmp`` and the directory is
  renamed only after the COMMITTED marker is fsync'd; a crash mid-save leaves
  a ``.tmp`` directory that restore ignores and the next save overwrites.
* **integrity** — manifest v2 records a CRC32 + byte length per leaf file,
  computed from the exact bytes written; :func:`restore` verifies them before
  any leaf reaches a kernel, raising
  :class:`~repro.resilience.integrity.IntegrityError` naming the bad file.  A
  committed-then-corrupted checkpoint (bit rot, torn page under the rename)
  therefore fails *loudly* — never silently-wrong numerics.  v1 manifests
  (no checksums) still load.
* **fallback** — :func:`load_latest` / :func:`latest_verifiable_step` walk
  committed steps newest-first and land on the newest one that passes
  verification, so one corrupt head degrades recovery freshness instead of
  killing it.
* **elasticity** — leaves are stored *unsharded* (host-gathered); restore
  device_puts them under whatever shardings the *new* mesh prescribes, so a
  job can resume on a different device count (tested: save@N -> restore@M).
  At true 1000-node scale the gather becomes per-host shard files keyed by
  (leaf, shard-index) — the manifest format already records per-leaf shape
  so that extension is additive.
* **async** — ``save_async`` snapshots to host (device_get) synchronously
  (cheap) and writes in a daemon thread, overlapping I/O with the next steps;
  a failed background write re-raises on ``wait()`` or the next ``save``.
"""

from __future__ import annotations

import io
import json
import logging
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from .. import obs
from ..resilience import chaos
from ..resilience.integrity import IntegrityError, checksum_bytes, verify_file

_MANIFEST = "manifest.json"
_MARKER = "COMMITTED"
MANIFEST_VERSION = 2  # v1: no checksums; v2: per-leaf crc32 + byte length

log = logging.getLogger("repro.checkpoint")


def _leaf_paths(tree) -> Tuple[Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    return treedef, leaves


def _encode_leaf(arr: np.ndarray) -> Tuple[np.ndarray, Optional[str]]:
    """``np.save`` cannot round-trip ml_dtypes extension types (bfloat16,
    fp8).  Upcast those to float32 — lossless, every extension value is
    exactly representable — and record the original dtype so restore can
    cast back bit-exactly."""
    if arr.dtype.kind == "V" or arr.dtype.name.startswith(("bfloat", "float8")):
        return arr.astype(np.float32), arr.dtype.name
    return arr, None


def _decode_leaf(arr: np.ndarray, stored_as: Optional[str]) -> np.ndarray:
    if stored_as is None:
        return arr
    import jax.numpy as jnp

    return arr.astype(np.dtype(jnp.dtype(stored_as)))


def save(directory: str, step: int, tree, metadata: Optional[Dict] = None) -> str:
    """Synchronous atomic save.  Returns the committed checkpoint path."""
    with obs.span("checkpoint/save", step=step):
        return _save(directory, step, tree, metadata)


def _save(directory: str, step: int, tree, metadata: Optional[Dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    treedef, leaves = _leaf_paths(tree)
    entries = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        encoded, stored_as = _encode_leaf(arr)
        # serialize in memory first: the checksum must cover the exact bytes
        # that land on disk (npy header included), not a re-read that could
        # already be damaged
        buf = io.BytesIO()
        np.save(buf, encoded)
        payload = buf.getvalue()
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(payload)
        entry = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "bytes": len(payload),
            "checksum": checksum_bytes(payload),
        }
        if stored_as is not None:
            entry["extension_dtype"] = stored_as
        entries.append(entry)
    manifest = {
        "manifest_version": MANIFEST_VERSION,
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "leaves": entries,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    chaos.fire("store.committed", path=final)
    obs.event("checkpoint/committed", step=step, leaves=len(leaves))
    obs.registry().counter("checkpoint.saves").inc()
    return final


class AsyncSaver:
    """Overlap checkpoint I/O with training: snapshot on call, write in a
    daemon thread.  ``wait()`` joins the in-flight save (call before exit).

    A failing background write is never swallowed: the exception is captured
    and re-raised on the next ``wait()`` or ``save()`` — a checkpoint the
    caller believes exists but does not is precisely the failure that turns
    a later crash into data loss."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.last_path: Optional[str] = None

    def save(self, directory: str, step: int, tree, metadata=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                self.last_path = save(directory, step, host_tree, metadata)
            except BaseException as e:  # noqa: BLE001 - surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                "async checkpoint write failed (the checkpoint does NOT exist)"
            ) from err


def committed_steps(directory: str) -> List[int]:
    """All committed steps in ``directory``, ascending (ignores .tmp wreckage)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        full = os.path.join(directory, name)
        if (
            name.startswith("step_")
            and not name.endswith(".tmp")
            and os.path.exists(os.path.join(full, _MARKER))
        ):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    """Largest committed step in ``directory`` (ignores .tmp wreckage)."""
    steps = committed_steps(directory)
    return steps[-1] if steps else None


def verify(directory: str, step: int) -> None:
    """Verify one committed step's content: every leaf file must match its
    manifest checksum and byte length.  Raises
    :class:`~repro.resilience.integrity.IntegrityError` naming the first bad
    file, or :class:`FileNotFoundError` when the step is not committed.  v1
    manifests (no checksums) verify only file presence."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, _MARKER)):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    try:
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise IntegrityError(
            f"{os.path.join(path, _MANIFEST)}: unreadable manifest ({e})",
            path=os.path.join(path, _MANIFEST),
        ) from e
    for entry in manifest["leaves"]:
        leaf_path = os.path.join(path, entry["file"])
        if "checksum" in entry:
            verify_file(leaf_path, entry["checksum"], entry.get("bytes"))
        elif not os.path.exists(leaf_path):
            raise IntegrityError(
                f"{leaf_path}: leaf file missing from committed checkpoint",
                path=leaf_path,
            )


def latest_verifiable_step(directory: str) -> Optional[int]:
    """Newest committed step that passes :func:`verify` — the recovery
    anchor when the head checkpoint was corrupted after commit."""
    for step in reversed(committed_steps(directory)):
        try:
            verify(directory, step)
            return step
        except IntegrityError as e:
            log.warning("checkpoint step %d fails verification (%s); falling back", step, e)
    return None


def read_metadata(directory: str, step: Optional[int] = None) -> Tuple[Dict, int]:
    """User metadata of the newest (or given) committed step in
    ``directory`` without touching any leaves.  Returns ``(metadata, step)``;
    raises ``FileNotFoundError`` when nothing is committed.  The single
    place format-dispatching loaders (``api.load``, classifier ``load``,
    ``serving.registry``) probe a checkpoint's manifest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory!r}")
    path = os.path.join(directory, f"step_{step:08d}", _MANIFEST)
    with open(path) as f:
        return json.load(f)["metadata"], step


def restore(
    directory: str,
    step: int,
    like,
    shardings=None,
    *,
    verify_integrity: bool = True,
):
    """Restore the step's pytree.  ``like`` provides the tree structure
    (abstract or concrete).  ``shardings`` (optional pytree of NamedSharding)
    re-shards onto the *current* mesh — elastic resume.

    ``verify_integrity`` (default on) checks every leaf file against its
    manifest checksum *before* deserializing — a flipped bit or truncation
    raises :class:`~repro.resilience.integrity.IntegrityError` naming the
    file instead of materializing corrupt numerics."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, _MARKER)):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with obs.span("checkpoint/restore", step=step):
        return _restore_committed(path, like, shardings, verify_integrity, directory, step)


def _restore_committed(path, like, shardings, verify_integrity, directory, step):
    if verify_integrity:
        verify(directory, step)
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    treedef = jax.tree.structure(like)
    if manifest["num_leaves"] != treedef.num_leaves:
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, expected {treedef.num_leaves}"
        )
    arrs = [
        _decode_leaf(np.load(os.path.join(path, e["file"])), e.get("extension_dtype"))
        for e in manifest["leaves"]
    ]
    tree = jax.tree.unflatten(treedef, arrs)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree, manifest["metadata"]


def load_latest(directory: str, like, shardings=None):
    """Restore the newest *verifiable* committed checkpoint: a corrupt head
    (post-commit bit rot) is skipped with a warning instead of killing the
    restore.  Returns ``(tree, metadata, step)``; raises
    :class:`FileNotFoundError` when nothing is committed and
    :class:`~repro.resilience.integrity.IntegrityError` when every committed
    step is damaged."""
    steps = committed_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoint under {directory!r}")
    last_err: Optional[IntegrityError] = None
    for step in reversed(steps):
        try:
            tree, metadata = restore(directory, step, like)
            if step != steps[-1]:
                log.warning(
                    "restored step %d (newest committed step %d failed "
                    "verification)", step, steps[-1],
                )
            if shardings is not None:
                tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
            return tree, metadata, step
        except IntegrityError as e:
            log.warning("step %d: %s", step, e)
            last_err = e
    raise IntegrityError(
        f"every committed checkpoint under {directory!r} fails verification "
        f"(newest failure: {last_err})",
        path=getattr(last_err, "path", None),
    )


def cleanup(directory: str, keep_last: int = 3):
    """Delete all but the newest ``keep_last`` committed checkpoints."""
    for s in committed_steps(directory)[:-keep_last]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
