"""Sharded checkpointing with atomic manifest commit and elastic restore.

Layout (one directory per step)::

    <dir>/step_000123/
        manifest.json       # tree structure, shapes, dtypes, user metadata
        leaf_00000.npy ...  # one file per pytree leaf (host-gathered)
        COMMITTED           # written last — a checkpoint without it is junk

Why this design survives failures:

* **atomicity** — leaves are written into ``step_N.tmp`` and the directory is
  renamed only after the COMMITTED marker is fsync'd; a crash mid-save leaves
  a ``.tmp`` directory that restore ignores and the next save overwrites.
* **elasticity** — leaves are stored *unsharded* (host-gathered); restore
  device_puts them under whatever shardings the *new* mesh prescribes, so a
  job can resume on a different device count (tested: save@N -> restore@M).
  At true 1000-node scale the gather becomes per-host shard files keyed by
  (leaf, shard-index) — the manifest format already records per-leaf shape
  so that extension is additive.
* **async** — ``save_async`` snapshots to host (device_get) synchronously
  (cheap) and writes in a daemon thread, overlapping I/O with the next steps.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"
_MARKER = "COMMITTED"


def _leaf_paths(tree) -> Tuple[Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    return treedef, leaves


def _encode_leaf(arr: np.ndarray) -> Tuple[np.ndarray, Optional[str]]:
    """``np.save`` cannot round-trip ml_dtypes extension types (bfloat16,
    fp8).  Upcast those to float32 — lossless, every extension value is
    exactly representable — and record the original dtype so restore can
    cast back bit-exactly."""
    if arr.dtype.kind == "V" or arr.dtype.name.startswith(("bfloat", "float8")):
        return arr.astype(np.float32), arr.dtype.name
    return arr, None


def _decode_leaf(arr: np.ndarray, stored_as: Optional[str]) -> np.ndarray:
    if stored_as is None:
        return arr
    import jax.numpy as jnp

    return arr.astype(np.dtype(jnp.dtype(stored_as)))


def save(directory: str, step: int, tree, metadata: Optional[Dict] = None) -> str:
    """Synchronous atomic save.  Returns the committed checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    treedef, leaves = _leaf_paths(tree)
    entries = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        encoded, stored_as = _encode_leaf(arr)
        np.save(os.path.join(tmp, fname), encoded)
        entry = {"file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        if stored_as is not None:
            entry["extension_dtype"] = stored_as
        entries.append(entry)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "num_leaves": len(leaves),
        "leaves": entries,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncSaver:
    """Overlap checkpoint I/O with training: snapshot on call, write in a
    daemon thread.  ``wait()`` joins the in-flight save (call before exit)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, directory: str, step: int, tree, metadata=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = save(directory, step, host_tree, metadata)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    """Largest committed step in ``directory`` (ignores .tmp wreckage)."""
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        full = os.path.join(directory, name)
        if (
            name.startswith("step_")
            and not name.endswith(".tmp")
            and os.path.exists(os.path.join(full, _MARKER))
        ):
            try:
                s = int(name.split("_")[1])
            except ValueError:
                continue
            best = s if best is None else max(best, s)
    return best


def read_metadata(directory: str, step: Optional[int] = None) -> Tuple[Dict, int]:
    """User metadata of the newest (or given) committed step in
    ``directory`` without touching any leaves.  Returns ``(metadata, step)``;
    raises ``FileNotFoundError`` when nothing is committed.  The single
    place format-dispatching loaders (``api.load``, classifier ``load``,
    ``serving.registry``) probe a checkpoint's manifest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {directory!r}")
    path = os.path.join(directory, f"step_{step:08d}", _MANIFEST)
    with open(path) as f:
        return json.load(f)["metadata"], step


def restore(
    directory: str,
    step: int,
    like,
    shardings=None,
):
    """Restore the step's pytree.  ``like`` provides the tree structure
    (abstract or concrete).  ``shardings`` (optional pytree of NamedSharding)
    re-shards onto the *current* mesh — elastic resume."""
    path = os.path.join(directory, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, _MARKER)):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    treedef = jax.tree.structure(like)
    if manifest["num_leaves"] != treedef.num_leaves:
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, expected {treedef.num_leaves}"
        )
    arrs = [
        _decode_leaf(np.load(os.path.join(path, e["file"])), e.get("extension_dtype"))
        for e in manifest["leaves"]
    ]
    tree = jax.tree.unflatten(treedef, arrs)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return tree, manifest["metadata"]


def cleanup(directory: str, keep_last: int = 3):
    """Delete all but the newest ``keep_last`` committed checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
        and os.path.exists(os.path.join(directory, n, _MARKER))
    )
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
