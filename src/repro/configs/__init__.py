"""Architecture registry: the 10 assigned configs + the paper's own setting.

``get_config(arch_id)`` / ``get_reduced(arch_id)`` resolve ``--arch`` flags;
``ARCHS`` lists every selectable id.  ``oavi_paper`` holds the paper's own
(non-LM) experiment configuration defaults.
"""

from __future__ import annotations

from typing import Dict

from ..models.model import ModelConfig
from . import (
    deepseek_v2_lite_16b,
    hubert_xlarge,
    jamba_1_5_large_398b,
    kimi_k2_1t_a32b,
    phi4_mini_3_8b,
    qwen1_5_4b,
    qwen2_1_5b,
    qwen2_vl_2b,
    qwen3_8b,
    shapes,
    xlstm_1_3b,
)
from .shapes import SHAPES, Shape, cell_supported, input_specs

_MODULES = [
    qwen3_8b,
    qwen1_5_4b,
    qwen2_1_5b,
    phi4_mini_3_8b,
    kimi_k2_1t_a32b,
    deepseek_v2_lite_16b,
    xlstm_1_3b,
    hubert_xlarge,
    qwen2_vl_2b,
    jamba_1_5_large_398b,
]

ARCHS: Dict[str, object] = {m.ARCH_ID: m for m in _MODULES}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; options: {sorted(ARCHS)}")
    return ARCHS[arch_id].config()


def get_reduced(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; options: {sorted(ARCHS)}")
    return ARCHS[arch_id].reduced()


def get_optimized(arch_id: str) -> ModelConfig:
    """The beyond-paper tuned profile from EXPERIMENTS.md §Perf: chunked
    (flash-in-XLA) attention everywhere, row-local MoE dispatch.  The plain
    ``get_config`` stays the paper-faithful baseline; both remain selectable
    so the reproduction and the optimization are separately measurable."""
    import dataclasses

    cfg = get_config(arch_id)
    cfg = dataclasses.replace(cfg, attn_impl="chunked", attn_chunk=1024)
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=cfg.moe._replace(dispatch="rowwise"))
    return cfg


def all_cells():
    """Every (arch_id, shape) pair with its supported/skip verdict."""
    out = []
    for arch_id in ARCHS:
        cfg = get_config(arch_id)
        for shape in SHAPES.values():
            ok, why = cell_supported(cfg, shape)
            out.append((arch_id, shape.name, ok, why))
    return out


__all__ = [
    "ARCHS",
    "get_config",
    "get_reduced",
    "all_cells",
    "SHAPES",
    "Shape",
    "cell_supported",
    "input_specs",
    "shapes",
]
