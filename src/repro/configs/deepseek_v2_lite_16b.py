"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H, MLA kv_lora=512,
expert d_ff=1408, vocab=102400, MoE 64 routed top-6 + 2 shared.
[arXiv:2405.04434; hf]

MLA head dims follow the paper: qk_nope=128, qk_rope=64, v=128.
"""

from ..models.mla import MLADims
from ..models.model import ModelConfig
from ..models.moe import MoEDims

ARCH_ID = "deepseek-v2-lite-16b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_periods=27, period=("mla", "moe"),
        d_model=2048, vocab_size=102400,
        rope_theta=1e4,
        mla=MLADims(n_heads=16, kv_lora_rank=512, qk_nope_dim=128,
                    qk_rope_dim=64, v_head_dim=128, rope_theta=1e4),
        moe=MoEDims(num_experts=64, top_k=6, d_ff=1408, n_shared=2),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_periods=2, period=("mla", "moe"),
        d_model=64, vocab_size=256,
        rope_theta=1e4,
        mla=MLADims(n_heads=4, kv_lora_rank=16, qk_nope_dim=16,
                    qk_rope_dim=8, v_head_dim=16, rope_theta=1e4),
        # capacity_factor=0 -> dropless routing: decode matches batch forward
        moe=MoEDims(num_experts=8, top_k=2, d_ff=32, n_shared=2,
                    capacity_factor=0.0),
        dtype="float32",
    )
