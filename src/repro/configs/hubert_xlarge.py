"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 —
encoder-only; the modality frontend is a STUB per the assignment
(input_specs provide precomputed frame embeddings).  No decode shapes.
[arXiv:2106.07447; unverified]

Adaptation note (DESIGN.md): HuBERT's conv feature extractor and conv
positional embedding are stubbed; the transformer backbone uses RoPE and
SwiGLU in place of learned-abs-pos + GELU (backbone-equivalent compute).
"""

from ..models.model import ModelConfig

ARCH_ID = "hubert-xlarge"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="audio",
        n_periods=48, period=("attn", "mlp"),
        d_model=1280, vocab_size=504,
        n_heads=16, n_kv_heads=16, d_head=80,
        d_ff=5120, causal=False,
        frontend="frames", supports_decode=False,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="audio",
        n_periods=2, period=("attn", "mlp"),
        d_model=64, vocab_size=64,
        n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, causal=False,
        frontend="frames", supports_decode=False, dtype="float32",
    )
