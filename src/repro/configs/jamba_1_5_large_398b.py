"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave, MoE every other
layer.  [arXiv:2403.19887; hf]

Period = 8 layers: attention at in-period index 4, Mamba elsewhere; the FFN
of every odd layer is MoE, even layers dense.  Mamba d_inner = 2*d_model,
d_state=16, conv=4.  Mamba state gives O(1)/token decode for the 63 Mamba
layers; the 9 attention layers keep a (sharded) KV cache, so the long_500k
decode cell runs.
"""

from ..models.model import ModelConfig
from ..models.moe import MoEDims
from ..models.ssm import MambaDims

ARCH_ID = "jamba-1.5-large-398b"


def _period():
    blocks = []
    for i in range(8):
        blocks.append("attn" if i == 4 else "mamba")
        blocks.append("moe" if i % 2 == 1 else "mlp")
    return tuple(blocks)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="hybrid",
        n_periods=9, period=_period(),
        d_model=8192, vocab_size=65536,
        n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=24576,
        mamba=MambaDims(d_inner=16384, d_state=16, d_conv=4),
        moe=MoEDims(num_experts=16, top_k=2, d_ff=24576),
        sub_quadratic=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        n_periods=1, period=_period(),
        d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, ssm_chunk=16,
        mamba=MambaDims(d_inner=128, d_state=8, d_conv=4),
        moe=MoEDims(num_experts=4, top_k=2, d_ff=64),
        sub_quadratic=True, dtype="float32",
    )
