"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384 routed experts top-8 + 1 shared — trillion-param MoE.
[arXiv:2501.kimi2; unverified]

Per the assignment table we model attention as GQA (kv=8); ~1.03T total
params, ~32B active per token (8/384 experts + shared + attention).
Single-pod (256 chip) training memory is over the v5e HBM budget even with
8-bit optimizer states — see EXPERIMENTS.md §Dry-run; the multi-pod mesh is
the supported training topology.
"""

from ..models.model import ModelConfig
from ..models.moe import MoEDims

ARCH_ID = "kimi-k2-1t-a32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="moe",
        n_periods=61, period=("attn", "moe"),
        d_model=7168, vocab_size=163840,
        n_heads=64, n_kv_heads=8, d_head=128,
        qk_norm=False, qkv_bias=False, rope_theta=5e4,
        moe=MoEDims(num_experts=384, top_k=8, d_ff=2048, n_shared=1),
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_periods=2, period=("attn", "moe"),
        d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, d_head=16,
        rope_theta=5e4,
        # capacity_factor=0 -> dropless routing: decode matches batch forward
        moe=MoEDims(num_experts=8, top_k=2, d_ff=32, n_shared=1,
                    capacity_factor=0.0),
        dtype="float32",
    )
