"""The paper's own experimental configuration (Section 6.1 defaults)."""

from ..core.oavi import OAVIConfig
from ..core.oracles import OracleConfig
from ..core.pipeline import PipelineConfig
from ..core.svm import LinearSVMConfig

PSI_DEFAULT = 0.005       # vanishing parameter used throughout the paper
TAU_DEFAULT = 1000.0      # l1 radius for (CCOP)
EPS_FRAC = 0.01           # solver accuracy = 0.01 * psi
MAX_SOLVER_ITER = 10_000  # paper's hard cap


def cgavi_ihb(psi: float = PSI_DEFAULT) -> OAVIConfig:
    return OAVIConfig(psi=psi, engine="oracle", ihb=True,
                      solver=OracleConfig(name="cg", tau=TAU_DEFAULT,
                                          eps_frac=EPS_FRAC, max_iter=MAX_SOLVER_ITER))


def bpcgavi_wihb(psi: float = PSI_DEFAULT) -> OAVIConfig:
    return OAVIConfig(psi=psi, engine="oracle", ihb=True, wihb=True,
                      solver=OracleConfig(name="bpcg", tau=TAU_DEFAULT,
                                          eps_frac=EPS_FRAC, max_iter=MAX_SOLVER_ITER))


def pipeline(method: str = "cgavi-ihb", psi: float = PSI_DEFAULT) -> PipelineConfig:
    return PipelineConfig(method=method, psi=psi, svm=LinearSVMConfig(lam=1e-4))
