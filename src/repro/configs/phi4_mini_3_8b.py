"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — RoPE SwiGLU GQA.  [arXiv:2412.08905; hf]"""

from ..models.model import ModelConfig

ARCH_ID = "phi4-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_periods=32, period=("attn", "mlp"),
        d_model=3072, vocab_size=200064,
        n_heads=24, n_kv_heads=8, d_head=128,
        qk_norm=False, qkv_bias=False, rope_theta=1e4,
        d_ff=8192, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_periods=2, period=("attn", "mlp"),
        d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, d_head=16,
        qk_norm=False, qkv_bias=False, rope_theta=1e4,
        d_ff=128, tie_embeddings=True, dtype="float32",
    )
