"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20 = MHA) d_ff=6912
vocab=151936 — QKV bias.  [hf:Qwen/Qwen1.5 family; hf]"""

from ..models.model import ModelConfig

ARCH_ID = "qwen1.5-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_periods=40, period=("attn", "mlp"),
        d_model=2560, vocab_size=151936,
        n_heads=20, n_kv_heads=20, d_head=128,
        qk_norm=False, qkv_bias=True, rope_theta=1e6,
        d_ff=6912,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_periods=2, period=("attn", "mlp"),
        d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=4, d_head=16,
        qk_norm=False, qkv_bias=True, rope_theta=1e6,
        d_ff=128, dtype="float32",
    )
