"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
— GQA, QKV bias, tied embeddings.  [arXiv:2407.10671; hf]"""

from ..models.model import ModelConfig

ARCH_ID = "qwen2-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_periods=28, period=("attn", "mlp"),
        d_model=1536, vocab_size=151936,
        n_heads=12, n_kv_heads=2, d_head=128,
        qk_norm=False, qkv_bias=True, rope_theta=1e6,
        d_ff=8960, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_periods=2, period=("attn", "mlp"),
        d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, d_head=16,
        qk_norm=False, qkv_bias=True, rope_theta=1e6,
        d_ff=128, tie_embeddings=True, dtype="float32",
    )
