"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
— M-RoPE (temporal/height/width rotary sections), dynamic resolution.
[arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: the backbone consumes
token ids; M-RoPE positions default to text mode (t=h=w=index).  d_head=128
-> rotary half-dim 64 split into sections (16, 24, 24) as in the release.
"""

from ..models.model import ModelConfig

ARCH_ID = "qwen2-vl-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="vlm",
        n_periods=28, period=("attn", "mlp"),
        d_model=1536, vocab_size=151936,
        n_heads=12, n_kv_heads=2, d_head=128,
        qkv_bias=True, rope_theta=1e6,
        mrope_sections=(16, 24, 24),
        d_ff=8960, tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        n_periods=2, period=("attn", "mlp"),
        d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=2, d_head=16,
        qkv_bias=True, rope_theta=1e6,
        mrope_sections=(2, 3, 3),
        d_ff=128, tie_embeddings=True, dtype="float32",
    )
