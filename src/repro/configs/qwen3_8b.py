"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936
— qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from ..models.model import ModelConfig

ARCH_ID = "qwen3-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="dense",
        n_periods=36, period=("attn", "mlp"),
        d_model=4096, vocab_size=151936,
        n_heads=32, n_kv_heads=8, d_head=128,
        qk_norm=True, qkv_bias=False, rope_theta=1e6,
        d_ff=12288,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_periods=2, period=("attn", "mlp"),
        d_model=64, vocab_size=256,
        n_heads=4, n_kv_heads=1, d_head=16,
        qk_norm=True, qkv_bias=False, rope_theta=1e6,
        d_ff=128, dtype="float32",
    )
