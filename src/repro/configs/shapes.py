"""The assigned input-shape grid and per-cell input specs.

Four shapes per architecture (40 cells total):

    train_4k     seq=4096    global_batch=256   -> train_step
    prefill_32k  seq=32768   global_batch=32    -> prefill_step
    decode_32k   seq=32768   global_batch=128   -> decode_step (1 new token)
    long_500k    seq=524288  global_batch=1     -> decode_step (sub-quadratic only)

``input_specs`` returns ShapeDtypeStruct stand-ins (no allocation) for the
step inputs; params/caches come from ``models.model.abstract_params`` /
``abstract_cache``.  ``cell_supported`` encodes the documented skips
(DESIGN.md §Arch-applicability): encoder-only archs have no decode step,
pure full-attention archs skip long_500k.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..models.model import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: Shape) -> Tuple[bool, str]:
    if shape.kind == "decode":
        if not cfg.supports_decode:
            return False, "encoder-only: no autoregressive decode"
        if shape.name == "long_500k" and not cfg.sub_quadratic:
            return False, "full quadratic attention: 500k decode excluded (DESIGN.md)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: Shape) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.frontend == "tokens":
            # +1 position: loss_fn shifts inputs/labels internally
            return {"tokens": jax.ShapeDtypeStruct((B, S + 1), i32)}
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.jax_dtype()),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if shape.kind == "prefill":
        if cfg.frontend == "tokens":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.jax_dtype())}
    # decode: one token against a seq_len-deep cache
    return {
        "token": jax.ShapeDtypeStruct((B,), i32),
        "pos": jax.ShapeDtypeStruct((B,), i32),
    }
