"""xlstm-1.3b [ssm]: 48L d_model=2048 4H vocab=50304, d_ff=0 — sLSTM + mLSTM
blocks (7:1 mLSTM:sLSTM interleave), recurrent O(1)/token decode, so the
long_500k cell runs.  [arXiv:2405.04517; unverified]"""

from ..models.model import ModelConfig
from ..models.ssm import MLSTMDims, SLSTMDims

ARCH_ID = "xlstm-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, family="ssm",
        n_periods=6,
        period=("mlstm",) * 7 + ("slstm",),
        d_model=2048, vocab_size=50304,
        mlstm=MLSTMDims(d_inner=4096, n_heads=4),
        slstm=SLSTMDims(n_heads=4),
        sub_quadratic=True,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        n_periods=2, period=("mlstm", "mlstm", "slstm"),
        d_model=64, vocab_size=256, ssm_chunk=16,
        mlstm=MLSTMDims(d_inner=128, n_heads=4),
        slstm=SLSTMDims(n_heads=4),
        sub_quadratic=True, dtype="float32",
    )
