"""Core library: the paper's contribution (OAVI / BPCG / IHB / ordering)."""

from .oavi import OAVIConfig, OAVIModel, Generator, fit, evaluate_terms
from .oracles import OracleConfig, solve_agd, solve_cg, solve_pcg, solve_bpcg
from .ordering import pearson_order, pearson_scores
from .pipeline import PipelineConfig, VanishingIdealClassifier
from .svm import LinearSVM, LinearSVMConfig, PolySVM, PolySVMConfig
from .transform import MinMaxScaler, feature_transform
from . import abm, class_batch, distributed, ihb, terms, vca


def __getattr__(name: str):
    # Deprecated alias, resolved lazily so importing repro.core does not pull
    # in repro.api: the canonical variant table is repro.api.OAVI_VARIANTS.
    if name == "VARIANTS":
        from .. import api

        return api.OAVI_VARIANTS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "OAVIConfig", "OAVIModel", "Generator", "fit", "evaluate_terms",
    "OracleConfig", "solve_agd", "solve_cg", "solve_pcg", "solve_bpcg",
    "pearson_order", "pearson_scores",
    "PipelineConfig", "VanishingIdealClassifier", "VARIANTS",
    "LinearSVM", "LinearSVMConfig", "PolySVM", "PolySVMConfig",
    "MinMaxScaler", "feature_transform",
    "abm", "class_batch", "distributed", "ihb", "terms", "vca",
]
