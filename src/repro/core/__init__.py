"""Core library: the paper's contribution (OAVI / BPCG / IHB / ordering)."""

from .oavi import OAVIConfig, OAVIModel, Generator, fit, evaluate_terms
from .oracles import OracleConfig, solve_agd, solve_cg, solve_pcg, solve_bpcg
from .ordering import pearson_order, pearson_scores
from .pipeline import PipelineConfig, VanishingIdealClassifier, VARIANTS
from .svm import LinearSVM, LinearSVMConfig, PolySVM, PolySVMConfig
from .transform import MinMaxScaler, feature_transform
from . import abm, distributed, ihb, terms, vca

__all__ = [
    "OAVIConfig", "OAVIModel", "Generator", "fit", "evaluate_terms",
    "OracleConfig", "solve_agd", "solve_cg", "solve_pcg", "solve_bpcg",
    "pearson_order", "pearson_scores",
    "PipelineConfig", "VanishingIdealClassifier", "VARIANTS",
    "LinearSVM", "LinearSVMConfig", "PolySVM", "PolySVMConfig",
    "MinMaxScaler", "feature_transform",
    "abm", "distributed", "ihb", "terms", "vca",
]
