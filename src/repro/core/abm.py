"""ABM — the Approximate Buchberger–Möller algorithm (Limbeck 2013).

Baseline used by the paper (Section 6).  Same border machinery as OAVI, but
each border term is decided by an eigendecomposition of the *extended* Gram
matrix ``[[A^T A, A^T b], [b^T A, b^T b]] / m`` (the paper's modification:
"instead of applying the SVD to O(X) we apply the SVD to A^T A when faster"):
the smallest eigenvalue is the minimal MSE of any unit-coefficient polynomial
with terms in O ∪ {u}, and its eigenvector gives the coefficients.

A border term becomes a generator iff ``lambda_min <= psi``.  Coefficients are
rescaled so the leading-term coefficient is 1 (monic) for the feature
transform, mirroring OAVI's (psi, 1)-convention; the acceptance test itself is
on the unit-norm polynomial (which is exactly ABM's spurious-vanishing-prone
behaviour the paper discusses).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from . import terms as terms_mod
from .oavi import Generator, OAVIModel, _append_columns
from .ordering import pearson_order


@dataclasses.dataclass(frozen=True)
class ABMConfig:
    psi: float = 0.005
    max_degree: int = 10
    cap_terms: int = 256
    cap_border: int = 64
    dtype: str = "float32"
    ordering: str = "pearson"


def _make_degree_step(cfg: ABMConfig, reduce_fn=None):
    rfn = reduce_fn if reduce_fn is not None else (lambda x: x)

    def degree_step(A, X, AtA, ell0, parents, vars_, valid, m_total):
        dtype = A.dtype
        Lcap = A.shape[1]
        K = parents.shape[0]
        psi = jnp.asarray(cfg.psi, dtype)
        inv_m = jnp.asarray(1.0 / m_total, dtype)

        P = jnp.take(A, parents, axis=1)
        B = P * jnp.take(X, vars_, axis=1)
        QL = rfn(A.T @ B) * inv_m  # (L, K)
        C = rfn(B.T @ B) * inv_m  # (K, K)

        def body(a, carry):
            AtA_c, ell, accepted, slots, coeffs, lams = carry
            q = QL[:, a]
            appended_before = (jnp.arange(K) < a) & (~accepted) & (slots < Lcap) & valid
            safe = jnp.where(appended_before, slots, 0)
            q = q.at[safe].add(jnp.where(appended_before, C[:, a], 0.0), mode="drop")
            btb = C[a, a]

            onehot = (jnp.arange(Lcap) == ell).astype(dtype)
            mask = (jnp.arange(Lcap) < ell).astype(dtype)
            # extended Gram with the candidate placed at slot `ell`;
            # inactive block diag set to 2 so padded eigvals are never minimal
            M = (
                AtA_c
                + jnp.outer(onehot, q)
                + jnp.outer(q, onehot)
                + btb * jnp.outer(onehot, onehot)
            )
            keepm = mask + onehot
            Mmask = M * keepm[:, None] * keepm[None, :]
            Mpad = Mmask + 2.0 * jnp.diag(1.0 - keepm)
            evals, evecs = jnp.linalg.eigh(Mpad)
            lam = evals[0]
            v = evecs[:, 0] * keepm
            accept = (lam <= psi) & valid[a]

            # monic coefficients: divide by the entry at slot ell
            lead = v[jnp.argmax(onehot)]
            lead = jnp.where(jnp.abs(lead) > 1e-12, lead, 1e-12)
            monic = v / lead
            coef = monic * mask  # non-leading part

            def appended(args):
                AtA_i, ell_i, slots_i = args
                AtA_n = (
                    AtA_i
                    + jnp.outer(onehot, q)
                    + jnp.outer(q, onehot)
                    + btb * jnp.outer(onehot, onehot)
                )
                return AtA_n, ell_i + 1, slots_i.at[a].set(ell_i)

            AtA_c, ell, slots = jax.lax.cond(
                (~accept) & valid[a], appended, lambda x: x, (AtA_c, ell, slots)
            )
            accepted = accepted.at[a].set(accept)
            coeffs = coeffs.at[a].set(jnp.where(accept, coef, 0.0))
            lams = lams.at[a].set(lam)
            return AtA_c, ell, accepted, slots, coeffs, lams

        carry = (
            AtA,
            ell0,
            jnp.zeros((K,), bool),
            jnp.full((K,), Lcap, jnp.int32),
            jnp.zeros((K, Lcap), dtype),
            jnp.zeros((K,), dtype),
        )
        AtA, ell, accepted, slots, coeffs, lams = jax.lax.fori_loop(0, K, body, carry)
        appended = (~accepted) & valid & (slots < Lcap)
        A = _append_columns(A, B, slots, appended)
        return A, AtA, ell, accepted, slots, coeffs, lams

    return degree_step


def fit(X, config: ABMConfig = ABMConfig()) -> OAVIModel:
    t0 = time.perf_counter()
    dtype = jnp.dtype(config.dtype)
    X = np.asarray(X)
    m, n = X.shape

    perm = None
    if config.ordering in ("pearson", "reverse_pearson"):
        perm = pearson_order(X, reverse=(config.ordering == "reverse_pearson"))
        X = X[:, perm]

    Xd = jnp.asarray(X, dtype)
    book = terms_mod.TermBook(n=n)
    generators: List[Generator] = []

    Lcap = int(config.cap_terms)
    A = jnp.zeros((m, Lcap), dtype).at[:, 0].set(1.0)
    AtA = jnp.zeros((Lcap, Lcap), dtype).at[0, 0].set(1.0)
    ell = 1

    degree_step = jax.jit(_make_degree_step(config))
    stats = {"border_sizes": [], "degrees": [], "m": m, "n": n}

    d = 0
    while True:
        d += 1
        if d > config.max_degree:
            stats["termination"] = "max_degree"
            break
        border = book.border(d)
        if not border:
            stats["termination"] = "empty_border"
            break
        K = len(border)
        stats["border_sizes"].append(K)
        stats["degrees"].append(d)
        if ell + K > Lcap:
            raise RuntimeError("ABM capacity exhausted; raise cap_terms")

        Kcap = max(config.cap_border, 1 << (K - 1).bit_length())
        parents = np.zeros((Kcap,), np.int32)
        vars_ = np.zeros((Kcap,), np.int32)
        valid = np.zeros((Kcap,), bool)
        for i, (term, parent, j) in enumerate(border):
            parents[i] = book.index[parent]
            vars_[i] = j
            valid[i] = True

        A, AtA, _, accepted, slots, coeffs, lams = degree_step(
            A, Xd, AtA, jnp.asarray(ell, jnp.int32), jnp.asarray(parents),
            jnp.asarray(vars_), jnp.asarray(valid), float(m),
        )
        accepted = np.asarray(accepted)
        coeffs = np.asarray(coeffs)
        lams = np.asarray(lams)

        for i, (term, parent, j) in enumerate(border):
            if accepted[i]:
                generators.append(
                    Generator(
                        term=term,
                        parent_idx=book.index[parent],
                        var=j,
                        coeffs=coeffs[i, : len(book)].copy(),
                        mse=float(lams[i]),
                    )
                )
            else:
                book.append(term, parent, j)
        ell = len(book)

    stats["time_total"] = time.perf_counter() - t0
    stats["num_G"] = len(generators)
    stats["num_O"] = len(book)
    stats["G_plus_O"] = len(generators) + len(book)
    return OAVIModel(
        n=n, psi=config.psi, book=book, generators=generators,
        feature_perm=perm, stats=stats, dtype=config.dtype,
    )
