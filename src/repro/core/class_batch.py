"""Class-batched OAVI: the k per-class fits of Algorithm 2 as ONE vmapped fit.

The paper's end-to-end classifier fits one generator model per class; the
per-class problems are embarrassingly parallel (they share nothing but the
algorithm), yet a sequential loop pays k full dispatch/sync pipelines per
degree.  This module stacks the k problems into one batched state and drives
them through a single jitted ``vmap`` of the exact same degree step the
sequential path uses (:func:`repro.core.oavi._make_degree_step`):

* **Padded class buckets** — evaluation matrices are padded to a shared pow2
  ``(m_cap, Lcap, Kcap)`` bucket.  Rows: each class's samples are padded to
  ``m_cap = pow2_bucket(max_c m_c)`` with the constant-1 column built as the
  per-class *row mask* (the same convention as the data-sharded path), so
  padded rows are exactly zero in every column of A and contribute nothing
  to any Gram quantity.  Columns: one shared ``Lcap`` / per-degree ``Kcap``
  across classes, regrown when the *largest* class overflows.
* **Batched state** — ``A`` is ``(k, m_cap, Lcap)``, the
  :class:`~repro.core.ihb.IHBState` factors gain a leading class axis
  ``(k, L, L)``, and the per-degree border index arrays are ``(k, Kcap)``.
* **One vmapped degree step** — the Gram products
  (:func:`repro.kernels.ops.gram_update`), the candidate ``fori_loop`` and
  the IHB updates (:func:`repro.kernels.ops.ihb_update`) execute as batched
  kernels: one dispatch per degree instead of k.
* **Per-class done masking** — classes terminate at different degrees; a
  finished class rides along with an all-``False`` validity mask, which makes
  its slice of the step a bitwise no-op (nothing accepted, nothing appended,
  ``ell`` and the IHB factors untouched).
* **Shared degree-step cache** — the jitted ``vmap``'d step lives in the
  global per-``(config, backend)`` cache of :mod:`repro.core.oavi`, keyed by
  ``backend_key='class_batch'`` (plus the mesh for the sharded composition),
  so a warm multi-class refit at the same ``(k, m_cap, Lcap, Kcap)`` bucket
  compiles nothing.

Bit-exactness
-------------
For eligible configs (:func:`repro.core.oavi.class_batchable`: every engine
with the Theorem 4.9 inverse) every primitive in the degree step is
vmap-bit-stable — batched matmuls, matvecs, gathers and scatters produce the
same bits as their per-slice counterparts — so the batched fit is
**bit-exact** against the sequential fit *at matched capacity*: same
``Lcap``/``Kcap`` buckets and same row count.  Classes whose
``m_c == m_cap`` (no row padding — e.g. equal-size class buckets at a pow2
size) therefore reproduce :func:`repro.core.oavi.fit` exactly; padded
classes are bit-exact against the matched-``m_cap`` reference (a ``k=1`` run
of this module) and structure-exact vs the unpadded sequential fit, with
coefficients differing only by the fp summation-order drift of the longer
(zero-extended) Gram reduction.

Oracle / WIHB configs additionally swap the data-dependent ``while_loop``
solvers for their masked fixed-schedule twins
(:mod:`repro.core.oracles`, ``solve_*_scheduled``): all classes share one
static iteration budget, converged lanes carry state as bitwise no-ops, and
whenever any valid lane reports an unconverged solve the driver doubles the
budget (pow2 buckets, mirroring capacity regrowth) and re-dispatches the
same degree — safe because the batched step donates nothing.  Escalated to
convergence, the fixed-schedule iterates compose exactly like the
``while_loop`` refs, so the bit-exactness contract above carries over to
oracle engines unchanged; the escalation trajectory is a deterministic
function of the data, so warm refits replay it with zero recompiles.

Distribution composes: with a mesh, the class axis (vmap) nests inside the
data-sharded ``shard_map`` psum path — see
:func:`repro.core.distributed.make_class_batched_sharded_degree_step`.
"""

from __future__ import annotations

import itertools
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import ihb as ihb_mod
from . import oracles as oracles_mod
from .. import obs
from . import terms as terms_mod
from .oavi import (
    FitScope,
    Generator,
    OAVIConfig,
    OAVIModel,
    _make_degree_step,
    _np_dtype,
    border_index_arrays,
    class_batchable,
    collect_degree,
    degree_step_entry,
    init_fit_stats,
    pow2_bucket,
)
from .ordering import pearson_order

# Monotonic id per batched fit: lets stats consumers (the classifier's
# aggregation) count each batch's shared recompiles/regrowths exactly once.
_GROUP_IDS = itertools.count()


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@partial(jax.jit, static_argnames=("Lcap", "factors"))
def _init_batch_arrays(mask, Lcap: int, factors):
    """Initial batched fit arrays in ONE cached dispatch: A with the row-mask
    constant column, plus the per-class IHB factors.  Built eagerly this is
    half a dozen scatter/eye dispatches per fit — measurable host overhead in
    the dispatch-bound regime the batched path exists for.  Same ops as the
    eager form, so the values are bit-identical."""
    k = mask.shape[0]
    dtype = mask.dtype
    A = jnp.zeros((k, mask.shape[1], Lcap), dtype).at[:, :, 0].set(mask)
    # normalized Gram convention: AtA[0,0] = ||mask_c||^2 / m_c = 1 per class
    state = ihb_mod.batch_state(
        ihb_mod.init_state(Lcap, jnp.asarray(1.0, dtype), dtype, factors=factors),
        k,
    )
    return A, state


def _batched_entry(config: OAVIConfig, mesh, data_axes, schedule=None):
    """Cached jitted batched step: plain ``jit(vmap(step))`` locally, the
    vmap-inside-shard_map composition when a mesh is given.  ``schedule``
    (oracle/WIHB configs) selects the fixed-schedule solver budget and is
    part of the cache key — each escalation level is its own jitted step, so
    a warm refit replaying the same escalations compiles nothing."""
    if mesh is None:
        return degree_step_entry(
            config,
            backend_key=("class_batch", schedule),
            jitted_builder=lambda: jax.jit(
                jax.vmap(_make_degree_step(config, schedule=schedule))
            ),
        )
    from . import distributed as distributed_mod

    axes = tuple(data_axes)
    return degree_step_entry(
        config,
        backend_key=("class_batch", mesh, axes, schedule),
        jitted_builder=lambda: distributed_mod.make_class_batched_sharded_degree_step(
            config, mesh, axes, schedule=schedule
        ),
    )


def needs_solver_schedule(config: OAVIConfig) -> bool:
    """Whether batched fits of this config must run the fixed-schedule
    solvers (any path that invokes a convex oracle under ``vmap``)."""
    return config.engine == "oracle" or config.wihb


def fit_classes(
    Xs: Sequence[np.ndarray],
    config: OAVIConfig = OAVIConfig(),
    *,
    mesh=None,
    data_axes: Sequence[str] = ("data",),
    m_cap: Optional[int] = None,
) -> List[OAVIModel]:
    """Fit one OAVI model per class, all classes batched through one vmapped
    degree step.  Same semantics as ``[oavi.fit(X, config) for X in Xs]``
    (bit-exact at matched capacity — see the module docstring).

    ``m_cap`` overrides the shared row bucket (default
    ``pow2_bucket(max_c m_c)``, rounded up to the data-shard count when a
    ``mesh`` is given).  Every returned model's stats carry a
    ``"class_batch"`` dict (``group``/``size``/``index``) whose shared
    ``recompiles``/``regrowths`` must be counted once per group, not once
    per class — see :func:`repro.api.aggregate_fit_stats`.
    """
    if not class_batchable(config):
        raise ValueError(
            "config is not class-batchable (inverse_engine='chol' batched "
            "triangular solves are not vmap-bit-stable); use sequential fits"
        )
    dtype = config.jax_dtype()
    Xs = [np.asarray(X) for X in Xs]
    if len(Xs) == 0:
        return []
    if len(Xs) == 1:
        # XLA folds size-1 batch dims into different fusions than k >= 2
        # (observed: the scalar reductions change bits at k=1 only), so a
        # lone class rides with a discarded copy of itself — results are
        # then independent of batch composition for every k.
        return fit_classes(
            [Xs[0], Xs[0]], config, mesh=mesh, data_axes=data_axes, m_cap=m_cap
        )[:1]
    k = len(Xs)
    n = Xs[0].shape[1]
    if any(X.ndim != 2 or X.shape[1] != n for X in Xs):
        raise ValueError("all classes must be (m_c, n) with one shared n")
    ms = [X.shape[0] for X in Xs]

    group = next(_GROUP_IDS)
    batch = {
        "group": group,
        "size": k,
        "m_cap": 0,  # filled once the shared row bucket is known
        "recompiles": 0,
        "regrowths": 0,
        "degree_times": [],
        "m": int(sum(ms)),
        "n": n,
    }
    scope = FitScope(batch, backend="class_batch")
    with scope:
        # per-class Pearson ordering (each class permutes its own features)
        perms: List[Optional[np.ndarray]] = []
        Xp: List[np.ndarray] = []
        for X in Xs:
            perm = None
            if config.ordering in ("pearson", "reverse_pearson"):
                perm = pearson_order(X, reverse=(config.ordering == "reverse_pearson"))
                X = X[:, perm]
            perms.append(perm)
            Xp.append(X)

        shards = 1
        if mesh is not None:
            from . import distributed as distributed_mod

            shards = distributed_mod.num_data_shards(mesh, data_axes)
        mc = m_cap if m_cap is not None else pow2_bucket(max(ms))
        mc = _round_up(max(mc, max(ms)), shards)
        batch["m_cap"] = int(mc)

        # stacked rows + per-class row masks (mask IS the constant column, so
        # padded rows are zero in every column of A)
        np_dt = _np_dtype(config.dtype)
        Xstack = np.zeros((k, mc, n), np_dt)
        mask = np.zeros((k, mc), np_dt)
        for c, X in enumerate(Xp):
            Xstack[c, : ms[c]] = X
            mask[c, : ms[c]] = 1.0
        Xd = jnp.asarray(Xstack)
        Lcap = pow2_bucket(config.cap_terms)
        A, state = _init_batch_arrays(
            jnp.asarray(mask), Lcap, config.ihb_factors()
        )
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from . import distributed as distributed_mod

            bspec = NamedSharding(mesh, distributed_mod.class_data_spec(data_axes))
            rep = NamedSharding(mesh, P())
            Xd = jax.device_put(Xd, bspec)
            A = jax.device_put(A, bspec)
            state = jax.device_put(state, rep)
        else:
            bspec = rep = None

        books = [terms_mod.TermBook(n=n) for _ in range(k)]
        generators: List[List[Generator]] = [[] for _ in range(k)]
        ells = [1] * k
        active = [True] * k

        # Fixed-schedule solver budget (oracle/WIHB configs): starts at the
        # config's pow2 bucket, doubles whenever any lane's solve was cut
        # short, persists across degrees (like capacity, it only grows).
        schedule = (
            oracles_mod.schedule_budget(config.solver)
            if needs_solver_schedule(config)
            else None
        )
        batch["solver_schedule_len"] = schedule
        batch["solver_escalations"] = 0

        m_total = jnp.asarray([float(m) for m in ms], dtype)

        per_class = [init_fit_stats(ms[c], n) for c in range(k)]

        d = 0
        while any(active):
            d += 1
            if d > config.max_degree:
                for c in range(k):
                    if active[c]:
                        per_class[c]["termination"] = f"max_degree={config.max_degree}"
                break
            borders: List[List] = []
            for c in range(k):
                b = books[c].border(d) if active[c] else []
                if active[c] and not b:
                    active[c] = False
                    per_class[c]["termination"] = "empty_border"
                borders.append(b)
            if not any(active):
                break
            Ks = [len(b) for b in borders]
            for c in range(k):
                if borders[c]:
                    per_class[c]["border_sizes"].append(Ks[c])
                    per_class[c]["degrees"].append(d)

            # shared capacity: regrow when the largest class overflows
            while max(ells[c] + Ks[c] for c in range(k)) > Lcap:
                Lcap *= 2
                scope.regrowth(Lcap)
                A = jax.lax.dynamic_update_slice(
                    jnp.zeros((k, mc, Lcap), dtype), A, (0, 0, 0)
                )
                state = ihb_mod.grow_state(state, Lcap)
                if mesh is not None:
                    A = jax.device_put(A, bspec)
                    state = jax.device_put(state, rep)
            Kcap = max(config.cap_border, pow2_bucket(max(Ks)))
            parents = np.zeros((k, Kcap), np.int32)
            vars_ = np.zeros((k, Kcap), np.int32)
            valid = np.zeros((k, Kcap), bool)  # done classes: all-False -> no-op
            for c in range(k):
                if borders[c]:
                    parents[c], vars_[c], valid[c] = border_index_arrays(
                        books[c], borders[c], Kcap
                    )

            ells_d = jnp.asarray(ells, jnp.int32)
            parents_d = jnp.asarray(parents)
            vars_d = jnp.asarray(vars_)
            valid_d = jnp.asarray(valid)

            with scope.degree(d, K=int(max(Ks)), k=k):
                # Escalation loop: the batched step donates nothing, so on an
                # unconverged budget we simply double the schedule and re-run
                # the same degree from the same inputs (iteration chunks
                # compose exactly — the longer run replays the shorter one's
                # iterations bit-for-bit, then continues).
                while True:
                    entry = _batched_entry(config, mesh, data_axes, schedule)
                    sig = (k, mc, n, Lcap, Kcap, str(dtype), schedule)
                    step_args = (
                        A, Xd, state, ells_d, parents_d, vars_d, valid_d, m_total
                    )
                    scope.note_signature(entry.seen, sig)
                    # cost capture rides the cold path: this degree window
                    # already absorbs the jit compile for a new signature
                    # (see FitScope docstring), lowering is a fraction of it
                    scope.step_cost(entry.fn, sig, step_args)
                    A_next, st = entry.fn(*step_args)
                    # one host sync per degree: the escalation verdict rides
                    # the same transfer as the accept/reject results
                    accepted, mses, coeffs, iters, unconverged = jax.device_get(
                        (st.accepted, st.mses, st.coeffs, st.iters, st.unconverged)
                    )
                    if schedule is None or not bool(np.any(unconverged)):
                        break
                    if schedule >= oracles_mod.max_schedule(config.solver):
                        break
                    schedule = oracles_mod.escalate_schedule(config.solver, schedule)
                    batch["solver_escalations"] += 1
                A = A_next
                state = st.ihb

            for c in range(k):
                if not borders[c]:
                    continue
                per_class[c]["solver_iters"].append(int(iters[c, : Ks[c]].sum()))
                ells[c] = collect_degree(
                    books[c], borders[c], accepted[c], mses[c], coeffs[c], generators[c]
                )

        batch["solver_schedule_len"] = schedule
        # publish the solver-discipline outcome so obs_report can diagnose
        # the escalation-bound regime (one hard lane taxing a whole batch)
        if schedule is not None:
            obs.registry().gauge(
                "fit.solver_schedule_len", backend="class_batch"
            ).set(float(schedule))
        if batch["solver_escalations"]:
            obs.registry().counter(
                "fit.solver_escalations", backend="class_batch"
            ).inc(batch["solver_escalations"])
        models: List[OAVIModel] = []
        for c in range(k):
            stats = per_class[c]
            # shared per-batch quantities: one compile/regrowth schedule and one
            # wall clock serve all k classes (aggregate once per group)
            stats["recompiles"] = batch["recompiles"]
            stats["regrowths"] = batch["regrowths"]
            stats["degree_times"] = list(batch["degree_times"])
            # one dispatch serves all classes: device cost is per batch, not
            # per class (escalation re-runs append their own entries)
            stats["flops_per_degree"] = list(batch.get("flops_per_degree", []))
            stats["solver_schedule_len"] = schedule
            stats["solver_escalations"] = batch["solver_escalations"]
            stats["class_batch"] = {
                "group": batch["group"],
                "size": k,
                "index": c,
                "m_cap": batch["m_cap"],
                "recompiles": batch["recompiles"],
                "regrowths": batch["regrowths"],
            }
            scope.finalize(books[c], generators[c], Lcap, config, stats=stats)
            models.append(
                OAVIModel(
                    n=n,
                    psi=config.psi,
                    book=books[c],
                    generators=generators[c],
                    feature_perm=perms[c],
                    stats=stats,
                    dtype=config.dtype,
                )
            )
    return models


def class_buckets(sizes: Sequence[int]) -> Dict[int, List[int]]:
    """Group class indices into shared row buckets (greedy, largest first):
    every class with ``m >= cap/2`` joins the bucket ``cap =
    pow2_bucket(largest remaining m)``, so per-class row padding stays <= 2x.
    With lognormal-skewed class sizes this keeps a giant class from
    inflating every small class's padded rows."""
    order = sorted(range(len(sizes)), key=lambda i: -sizes[i])
    buckets: Dict[int, List[int]] = {}
    i = 0
    while i < len(order):
        cap = pow2_bucket(sizes[order[i]])
        group = [j for j in order[i:] if 2 * sizes[j] >= cap]
        buckets[cap] = sorted(group)
        i += len(group)
    return buckets


def plan_class_groups(
    sizes: Sequence[int], pad_limit: float = 2.0
) -> List[tuple]:
    """Plan the shared row buckets of a multi-class fit as ``[(m_cap,
    class_indices), ...]`` — :func:`class_buckets` plus two refinements that
    trade padded rows for fewer dispatch groups:

    1. **Cross-bucket merging** (largest cap first): a smaller bucket folds
       into the preceding larger one while the merged group's total padded
       rows stay within ``pad_limit`` of its real rows, so near-boundary
       buckets don't each pay their own compile/dispatch pipeline.
    2. **No stragglers**: any group left with a single class is folded —
       unconditionally — into whichever surviving group grows its padded-row
       bill the least.  A size-1 "batch" would otherwise fall back to a
       sequential fit (a cold compile for exactly one class); eating some
       padding on an already-warm bucket is strictly cheaper.

    The resulting per-class padding is reported by the API layer in
    ``stats["class_batch_padding"]``.
    """
    if len(sizes) == 0:
        return []
    buckets = class_buckets(sizes)
    groups = [
        [cap, list(idxs)] for cap, idxs in sorted(buckets.items(), reverse=True)
    ]
    merged = [groups[0]]
    for cap, idxs in groups[1:]:
        host = merged[-1]
        count = len(host[1]) + len(idxs)
        real = sum(sizes[i] for i in host[1]) + sum(sizes[i] for i in idxs)
        if host[0] * count <= pad_limit * real:
            host[1] = sorted(host[1] + idxs)
        else:
            merged.append([cap, list(idxs)])
    while len(merged) > 1:
        singles = [g for g in merged if len(g[1]) == 1]
        if not singles:
            break
        g = singles[0]
        merged.remove(g)
        s = sizes[g[1][0]]

        def extra(h):
            new_cap = max(h[0], pow2_bucket(s))
            return new_cap * (len(h[1]) + 1) - h[0] * len(h[1])

        host = min(merged, key=extra)
        host[0] = max(host[0], pow2_bucket(s))
        host[1] = sorted(host[1] + g[1])
    return [(int(cap), idxs) for cap, idxs in merged]
