"""Data-parallel OAVI via ``shard_map`` — the paper's technique at pod scale.

The degree-batched Gram formulation of :mod:`repro.core.oavi` is the unit of
distribution.  With the sample axis ``m`` sharded over the mesh's data axes:

* step (1) — candidate-column construction ``B = A[:, parents] * X[:, vars]``
  is purely local (elementwise on the local shard),
* step (2) — the two Gram products run through the fused
  :func:`repro.kernels.ops.gram_update` kernel on each device's local shard
  (Pallas on TPU, the bit-identical jnp fallback elsewhere), followed by a
  ``psum`` over the data axes.  These psums are the *only* collectives:
  O(L*K + K*K) floats per degree, independent of m.
* step (3) — the sequential acceptance loop runs on the replicated Gram
  blocks, bit-identically on every device; appended columns are written back
  into the *local* shard of A.

Weak scaling is therefore exact: per-device FLOPs are O((m/devices) * L * K)
and collective bytes are m-independent — the distributed embodiment of the
paper's "linear in m" claim (Theorem 4.3 keeps L bounded).

Capacity growth and compiles follow :mod:`repro.core.oavi`: pow2 ``(Lcap,
Kcap)`` buckets, device-side regrowth, and a global cache of the jitted
sharded step keyed by ``(config, mesh, data_axes)`` — ``stats["recompiles"]``
counts the compiles a fit actually triggered.

Padding: ``m`` is padded up to a multiple of the number of data shards; the
constant-1 column is built as the *sample mask*, so padded rows are exactly
zero in every column of A (every term column is a product of the mask column
with data columns) and contribute nothing to any Gram quantity.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax.shard_map (with check_vma) is only public in newer jax; older releases
# ship it as jax.experimental.shard_map.shard_map (with check_rep).  Shared
# with repro.serving.engine, which wraps the fused transform the same way.
if hasattr(jax, "shard_map"):
    shard_map_compat = jax.shard_map
    SHARD_MAP_KW = {"check_vma": False}
else:
    from jax.experimental.shard_map import shard_map as shard_map_compat

    SHARD_MAP_KW = {"check_rep": False}

from . import ihb as ihb_mod
from . import terms as terms_mod
from .. import obs
from .oavi import (
    FitScope,
    Generator,
    OAVIConfig,
    OAVIModel,
    _make_degree_step,
    border_index_arrays,
    collect_degree,
    degree_step_entry,
    init_fit_stats,
    pow2_bucket,
)
from .ordering import pearson_order


def data_spec(data_axes: Sequence[str]) -> P:
    """PartitionSpec sharding the leading (sample/row) axis over ``data_axes``."""
    axes = tuple(data_axes)
    return P(axes if len(axes) > 1 else axes[0], None)


def class_data_spec(data_axes: Sequence[str]) -> P:
    """PartitionSpec for class-batched ``(k, m, ...)`` buffers: class axis
    replicated, sample axis sharded over ``data_axes``."""
    axes = tuple(data_axes)
    return P(None, axes if len(axes) > 1 else axes[0], None)


def num_data_shards(mesh: Mesh, data_axes: Sequence[str]) -> int:
    """Total device count along the mesh's data axes."""
    return int(np.prod([mesh.shape[a] for a in data_axes]))


def _emit_shard_event(name, shard) -> None:
    """Host half of the per-shard probe (``jax.debug.callback`` target)."""
    obs.event(str(name), shard=int(shard))


def shard_probe(step, mesh: Mesh, axes: Sequence[str], name: str):
    """Compile a per-shard instant-event probe into a shard_map'ed step.

    ``jax.debug.callback`` is an effect-only op — it changes no numerics and
    costs one host callback per shard per dispatch — so the probe lives in
    the cached compiled step unconditionally (the degree-step cache key is
    unchanged) and the *recording* is gated at runtime by
    :func:`repro.obs.enabled` inside ``obs.event``.  The emitted
    ``fit/shard_step`` instants are the per-shard visibility the PR 8 span
    work could not reach from host-side spans: one marker per device per
    degree step, labeled with the flat shard index.
    """
    sizes = [int(mesh.shape[a]) for a in axes]

    def probed(*args):
        idx = jnp.int32(0)
        for a, size in zip(axes, sizes):
            idx = idx * jnp.int32(size) + jax.lax.axis_index(a)
        jax.debug.callback(_emit_shard_event, name, idx)
        return step(*args)

    return probed


def make_sharded_degree_step(
    cfg: OAVIConfig, mesh: Mesh, data_axes: Sequence[str] = ("data",)
):
    """Jitted shard_map-wrapped degree step: Gram psums over ``data_axes``."""
    axes = tuple(data_axes)
    reduce_fn = lambda x: jax.lax.psum(x, axes)  # noqa: E731
    step = _make_degree_step(cfg, reduce_fn=reduce_fn)
    step = shard_probe(step, mesh, axes, "fit/shard_step")
    dspec = data_spec(axes)
    rep = P()

    sharded = shard_map_compat(
        step,
        mesh=mesh,
        in_specs=(dspec, dspec, rep, rep, rep, rep, rep, rep),
        out_specs=(dspec, rep),
        **SHARD_MAP_KW,
    )
    return jax.jit(sharded)


def make_class_batched_sharded_degree_step(
    cfg: OAVIConfig, mesh: Mesh, data_axes: Sequence[str] = ("data",),
    schedule=None,
):
    """Class-batched AND data-sharded degree step: the class axis (``vmap``)
    composed with the sample-sharded psum path.

    Layout: ``A``/``X`` are ``(k, m_cap, ·)`` with the class axis replicated
    and the sample axis sharded over ``data_axes`` — each device holds every
    class's row shard, the vmapped Gram products run on the local shards, and
    one psum per degree (now carrying ``(k, L, K) + (k, K, K)`` floats, still
    m-independent) replicates the blocks.  The candidate loop then replays
    bit-identically on every device for all classes at once.

    ``schedule`` (oracle/WIHB configs) selects the fixed-schedule solver
    budget the vmapped candidate loop runs at — see
    :func:`repro.core.class_batch._batched_entry`, which owns the escalation
    protocol and cache keying.
    """
    axes = tuple(data_axes)
    reduce_fn = lambda x: jax.lax.psum(x, axes)  # noqa: E731
    step = jax.vmap(_make_degree_step(cfg, reduce_fn=reduce_fn, schedule=schedule))
    # probe outside the vmap, inside the shard_map: one instant per device
    # per dispatch (not per class)
    step = shard_probe(step, mesh, axes, "fit/shard_step")
    bspec = class_data_spec(axes)
    rep = P()

    sharded = shard_map_compat(
        step,
        mesh=mesh,
        in_specs=(bspec, bspec, rep, rep, rep, rep, rep, rep),
        out_specs=(bspec, rep),
        **SHARD_MAP_KW,
    )
    return jax.jit(sharded)


def shard_samples(
    X: np.ndarray, mesh: Mesh, data_axes: Sequence[str] = ("data",), dtype=jnp.float32
) -> Tuple[jax.Array, jax.Array, int]:
    """Pad ``m`` to the data-shard count and place X on the mesh.

    Returns ``(X_sharded, mask_sharded, m_true)``; ``mask`` is 1.0 on real
    rows, 0.0 on padding.
    """
    m, n = X.shape
    shards = num_data_shards(mesh, data_axes)
    m_pad = ((m + shards - 1) // shards) * shards
    Xp = np.zeros((m_pad, n), dtype=np.asarray(X).dtype)
    Xp[:m] = X
    mask = np.zeros((m_pad, 1), dtype=np.float32)
    mask[:m] = 1.0
    dspec = data_spec(data_axes)
    xs = jax.device_put(jnp.asarray(Xp, dtype), NamedSharding(mesh, dspec))
    ms = jax.device_put(jnp.asarray(mask, dtype), NamedSharding(mesh, dspec))
    return xs, ms, m


def fit(
    X,
    config: OAVIConfig = OAVIConfig(),
    *,
    mesh: Mesh,
    data_axes: Sequence[str] = ("data",),
) -> OAVIModel:
    """Distributed OAVI: same semantics as :func:`repro.core.oavi.fit`, with
    the sample axis sharded over ``data_axes`` of ``mesh``."""
    dtype = config.jax_dtype()
    X = np.asarray(X)
    m, n = X.shape
    stats = init_fit_stats(
        m,
        n,
        mesh={a: int(mesh.shape[a]) for a in mesh.axis_names},
        data_axes=list(data_axes),
    )

    with FitScope(stats, backend="sharded") as scope:
        perm = None
        if config.ordering in ("pearson", "reverse_pearson"):
            perm = pearson_order(X, reverse=(config.ordering == "reverse_pearson"))
            X = X[:, perm]

        Xd, mask, m_true = shard_samples(X, mesh, data_axes, dtype)
        m_pad = Xd.shape[0]
        stats["m_padded"] = m_pad
        book = terms_mod.TermBook(n=n)
        generators: List[Generator] = []

        Lcap = pow2_bucket(config.cap_terms)
        dspec = data_spec(data_axes)
        a_shard = NamedSharding(mesh, dspec)
        rep = NamedSharding(mesh, P())
        # constant column = sample mask (zero on padded rows)
        A = jnp.zeros((m_pad, Lcap), dtype).at[:, 0:1].set(mask)
        A = jax.device_put(A, a_shard)
        # normalized convention: AtA[0,0] = ||mask||^2 / m = 1
        state = ihb_mod.init_state(
            Lcap, jnp.asarray(1.0, dtype), dtype, factors=config.ihb_factors()
        )
        state = jax.device_put(state, rep)
        ell = 1

        axes = tuple(data_axes)
        entry = degree_step_entry(
            config,
            backend_key=(mesh, axes),
            jitted_builder=lambda: make_sharded_degree_step(config, mesh, axes),
        )
        m_total = jnp.asarray(float(m_true), dtype)

        d = 0
        while True:
            d += 1
            if d > config.max_degree:
                stats["termination"] = f"max_degree={config.max_degree}"
                break
            border = book.border(d)
            if not border:
                stats["termination"] = "empty_border"
                break
            K = len(border)
            stats["border_sizes"].append(K)
            stats["degrees"].append(d)

            # capacity management: device-side regrowth into the next pow2 bucket
            while ell + K > Lcap:
                Lcap *= 2
                scope.regrowth(Lcap)
                A = jax.device_put(
                    jax.lax.dynamic_update_slice(
                        jnp.zeros((m_pad, Lcap), dtype), A, (0, 0)
                    ),
                    a_shard,
                )
                state = jax.device_put(ihb_mod.grow_state(state, Lcap), rep)

            Kcap = max(config.cap_border, pow2_bucket(K))
            parents, vars_, valid = border_index_arrays(book, border, Kcap)

            step_args = (
                A,
                Xd,
                state,
                jnp.asarray(ell, jnp.int32),
                jnp.asarray(parents),
                jnp.asarray(vars_),
                jnp.asarray(valid),
                m_total,
            )
            sig = (m_pad, n, Lcap, Kcap, str(dtype))
            scope.note_signature(entry.seen, sig)
            scope.step_cost(entry.fn, sig, step_args)

            with scope.degree(d, K=K):
                A, st = entry.fn(*step_args)
                state = st.ihb
                accepted = np.asarray(st.accepted)
                mses = np.asarray(st.mses)
                coeffs = np.asarray(st.coeffs)
                iters = np.asarray(st.iters)
            stats["solver_iters"].append(int(iters[:K].sum()))

            ell = collect_degree(book, border, accepted, mses, coeffs, generators)

        scope.finalize(book, generators, Lcap, config)
    return OAVIModel(
        n=n,
        psi=config.psi,
        book=book,
        generators=generators,
        feature_perm=perm,
        stats=stats,
        dtype=config.dtype,
    )
