"""Inverse Hessian Boosting (IHB) — Section 4.4 / Theorem 4.9.

OAVI solves a sequence of least-squares problems ``min_y ||A y + b||^2`` in
which ``A = O(X)`` grows by one column whenever a border term is appended to
``O``.  IHB maintains ``N = (A^T A)^{-1}`` across appends with the block
inverse update of Theorem 4.9 in ``O(l^2)`` elementary operations, so the
closed-form optimum ``y* = -N A^T b`` is available essentially for free and
serves as a (usually eps-accurate) warm start for the convex oracle.

All state is fixed-capacity: ``N`` is ``(L, L)`` with the *inactive* block set
to the identity (so the padded ``N`` is the exact inverse of the padded
``A^T A + I_inactive``), which keeps every update a dense masked operation
that jits once.

The state is *slimmed to the configured engine*: each of the three factors
(``AtA`` for the convex oracles, ``N`` for the Theorem 4.9 inverse, ``R``
for the beyond-paper Cholesky engine) is materialized and updated per
candidate only when the caller needs it — :func:`factors_for` maps an OAVI
configuration to its minimal factor set, and :func:`append_column` skips the
``None`` factors.  The paper-faithful full state (all three) remains the
default for direct users of this module.

The ``N`` update is dispatched through :func:`repro.kernels.ops.ihb_update`
(the fused Pallas kernel on TPU, its bit-identical jnp reference elsewhere).

Beyond the paper, the Cholesky-factor engine (maintain the upper-triangular
``R`` with ``A^T A = R^T R``; appends are triangular solves) has conditioning
``kappa(A)`` instead of ``kappa(A)^2`` — recorded as a beyond-paper
optimization in EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from ..kernels import ops as kernel_ops


class IHBState(NamedTuple):
    """Per-factor state; a factor the engine does not need is ``None``
    (``None`` is an empty pytree node, so slim and full states both jit)."""

    AtA: Optional[jax.Array]  # (L, L) Gram of active columns (zeros elsewhere)
    N: Optional[jax.Array]  # (L, L) inverse of (AtA_active ⊕ I_inactive)
    R: Optional[jax.Array]  # (L, L) upper-triangular Cholesky factor (ditto)


FACTORS_ALL: Tuple[str, ...] = ("ata", "n", "r")


def factors_for(
    engine: str,
    inverse_engine: str = "inverse",
    warm: bool = True,
    wihb: bool = False,
):
    """Minimal factor set for an OAVI configuration.

    * ``AtA`` — the Gram matrix itself is only needed as a solver Hessian:
      by the convex oracles (``engine='oracle'``) and by the WIHB sparse
      re-solve (``wihb``, which runs BPCG regardless of engine).
    * ``N`` / ``R`` — one of them backs the closed-form optimum: always for
      ``engine='fast'``, and for the oracle engine only when IHB warm starts
      are on (``warm``).
    """
    need = []
    if engine == "oracle" or wihb:
        need.append("ata")
    if engine == "fast" or warm:
        need.append("r" if inverse_engine == "chol" else "n")
    return tuple(need)


def init_state(Lcap: int, diag0: jax.Array, dtype=jnp.float32,
               factors: Tuple[str, ...] = FACTORS_ALL) -> IHBState:
    """State after the constant-1 column: AtA[0,0] = ||1||^2 = m."""
    eye = jnp.eye(Lcap, dtype=dtype)
    AtA = (
        jnp.zeros((Lcap, Lcap), dtype).at[0, 0].set(diag0)
        if "ata" in factors else None
    )
    N = eye.at[0, 0].set(1.0 / diag0) if "n" in factors else None
    R = eye.at[0, 0].set(jnp.sqrt(diag0)) if "r" in factors else None
    return IHBState(AtA=AtA, N=N, R=R)


def grow_state(state: IHBState, new_L: int) -> IHBState:
    """Double capacity device-side: each present factor is embedded into its
    padded identity/zero block with one ``dynamic_update_slice`` — no host
    numpy round-trip, so regrowth costs O(L^2) device work only.

    Factors may carry leading batch axes (the class-batched fit keeps one
    state per class, ``(k, L, L)``); only the trailing two axes grow.
    """

    def embed(M, identity: bool):
        if M is None:
            return None
        batch = M.shape[:-2]
        base = (
            jnp.eye(new_L, dtype=M.dtype)
            if identity else jnp.zeros((new_L, new_L), M.dtype)
        )
        base = jnp.broadcast_to(base, batch + (new_L, new_L))
        return jax.lax.dynamic_update_slice(base, M, (0,) * M.ndim)

    return IHBState(
        AtA=embed(state.AtA, identity=False),
        N=embed(state.N, identity=True),
        R=embed(state.R, identity=True),
    )


def batch_state(state: IHBState, k: int) -> IHBState:
    """Stack ``k`` copies of a (fresh) state along a new leading class axis —
    the batched initial state of the class-batched fit.  In the normalized
    Gram convention every class starts from the identical state
    (``AtA[0, 0] = 1``), so a broadcast-copy is exact."""
    rep = lambda M: None if M is None else jnp.repeat(M[None], k, axis=0)  # noqa: E731
    return IHBState(AtA=rep(state.AtA), N=rep(state.N), R=rep(state.R))


def closed_form_inverse(state: IHBState, q: jax.Array) -> jax.Array:
    """``y* = -N q`` (paper's IHB warm start).  ``q = A^T b`` padded."""
    return -(state.N @ q)


def closed_form_cholesky(state: IHBState, q: jax.Array) -> jax.Array:
    """``y* = -(R^T R)^{-1} q`` via two triangular solves (beyond-paper)."""
    z = solve_triangular(state.R, q, trans=1, lower=False)
    return -solve_triangular(state.R, z, trans=0, lower=False)


def mse_from_solution(q: jax.Array, btb: jax.Array, y: jax.Array, m) -> jax.Array:
    """MSE(g, X) = (btb + q^T y) / m at the closed-form optimum y = -N q.

    (||A y + b||^2 = y^T AtA y + 2 q^T y + btb = -q^T y - ... collapses to
    btb + q^T y when y is the exact minimizer.)

    The inner product reduces via ``sum(q * y)``, the vmap-bit-stable form
    every in-algorithm MSE reduction uses (a fused dot lowers differently
    batched vs per-instance, breaking the class-batched path's bit-exactness
    — see :func:`repro.kernels.ref.ihb_update_ref`).
    """
    return (btb + jnp.sum(q * y)) / m


def append_column(
    state: IHBState,
    q: jax.Array,  # (L,) A^T b for the new column b (zeros at inactive idx)
    btb: jax.Array,  # ||b||^2
    ell: jax.Array,  # current active count == index where b lands
) -> IHBState:
    """Theorem 4.9 block inverse update + Cholesky append, both O(l^2).

    Only the factors present in ``state`` are updated (``None`` stays
    ``None``) — the per-candidate cost tracks the configured engine instead
    of always paying for all three factors.
    """
    dtype = q.dtype
    Lcap = q.shape[0]
    onehot = (jnp.arange(Lcap) == ell).astype(dtype)
    keep = 1.0 - onehot

    AtA = N = R = None

    if state.AtA is not None:
        # ---- AtA update: add row/col ell = (q, btb)
        AtA = (
            state.AtA
            + jnp.outer(onehot, q)
            + jnp.outer(q, onehot)
            + btb * jnp.outer(onehot, onehot)
        )

    if state.N is not None:
        # ---- inverse update (Thm 4.9) — the fused kernel on TPU, its
        # bit-identical jnp reference elsewhere.
        N = kernel_ops.ihb_update(state.N, q, btb, ell)

    if state.R is not None:
        # ---- Cholesky append: R^T r = q ; rho = sqrt(btb - r^T r)
        r = solve_triangular(state.R, q, trans=1, lower=False)
        r = r * keep  # the inactive identity block must not leak into r
        rho2 = jnp.maximum(btb - r @ r, jnp.asarray(1e-30, dtype))
        rho = jnp.sqrt(rho2)
        col = r + rho * onehot
        # overwrite column ell of R (previously e_ell from the identity padding)
        R = state.R * (1.0 - onehot)[None, :] + jnp.outer(col, onehot)

    return IHBState(AtA=AtA, N=N, R=R)


def schur_complement(state: IHBState, q: jax.Array, btb: jax.Array) -> jax.Array:
    """``s = ||b||^2 - q^T N q`` — the (INF)/singularity guard of §4.4.3:
    if s <= 0 the new column is (numerically) dependent and IHB must stop."""
    return btb - q @ (state.N @ q)
