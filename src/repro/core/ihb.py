"""Inverse Hessian Boosting (IHB) — Section 4.4 / Theorem 4.9.

OAVI solves a sequence of least-squares problems ``min_y ||A y + b||^2`` in
which ``A = O(X)`` grows by one column whenever a border term is appended to
``O``.  IHB maintains ``N = (A^T A)^{-1}`` across appends with the block
inverse update of Theorem 4.9 in ``O(l^2)`` elementary operations, so the
closed-form optimum ``y* = -N A^T b`` is available essentially for free and
serves as a (usually eps-accurate) warm start for the convex oracle.

All state is fixed-capacity: ``N`` is ``(L, L)`` with the *inactive* block set
to the identity (so the padded ``N`` is the exact inverse of the padded
``A^T A + I_inactive``), which keeps every update a dense masked operation
that jits once.

Beyond the paper, we also provide a Cholesky-factor engine (maintain the
upper-triangular ``R`` with ``A^T A = R^T R``; appends are triangular solves)
whose conditioning is ``kappa(A)`` instead of ``kappa(A)^2`` — recorded as a
beyond-paper optimization in EXPERIMENTS.md.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular


class IHBState(NamedTuple):
    AtA: jax.Array  # (L, L) Gram matrix of active columns (zeros elsewhere)
    N: jax.Array  # (L, L) inverse of (AtA_active ⊕ I_inactive)
    R: jax.Array  # (L, L) upper-triangular Cholesky factor (ditto)


def init_state(Lcap: int, diag0: jax.Array, dtype=jnp.float32) -> IHBState:
    """State after the constant-1 column: AtA[0,0] = ||1||^2 = m."""
    eye = jnp.eye(Lcap, dtype=dtype)
    AtA = jnp.zeros((Lcap, Lcap), dtype).at[0, 0].set(diag0)
    N = eye.at[0, 0].set(1.0 / diag0)
    R = eye.at[0, 0].set(jnp.sqrt(diag0))
    return IHBState(AtA=AtA, N=N, R=R)


def closed_form_inverse(state: IHBState, q: jax.Array) -> jax.Array:
    """``y* = -N q`` (paper's IHB warm start).  ``q = A^T b`` padded."""
    return -(state.N @ q)


def closed_form_cholesky(state: IHBState, q: jax.Array) -> jax.Array:
    """``y* = -(R^T R)^{-1} q`` via two triangular solves (beyond-paper)."""
    z = solve_triangular(state.R, q, trans=1, lower=False)
    return -solve_triangular(state.R, z, trans=0, lower=False)


def mse_from_solution(q: jax.Array, btb: jax.Array, y: jax.Array, m) -> jax.Array:
    """MSE(g, X) = (btb + q^T y) / m at the closed-form optimum y = -N q.

    (||A y + b||^2 = y^T AtA y + 2 q^T y + btb = -q^T y - ... collapses to
    btb + q^T y when y is the exact minimizer.)
    """
    return (btb + q @ y) / m


def append_column(
    state: IHBState,
    q: jax.Array,  # (L,) A^T b for the new column b (zeros at inactive idx)
    btb: jax.Array,  # ||b||^2
    ell: jax.Array,  # current active count == index where b lands
) -> IHBState:
    """Theorem 4.9 block inverse update + Cholesky append, both O(l^2)."""
    dtype = state.N.dtype
    Lcap = state.N.shape[0]
    onehot = (jnp.arange(Lcap) == ell).astype(dtype)

    # ---- AtA update: add row/col ell = (q, btb)
    AtA = (
        state.AtA
        + jnp.outer(onehot, q)
        + jnp.outer(q, onehot)
        + btb * jnp.outer(onehot, onehot)
    )

    # ---- inverse update (Thm 4.9).  u = N q, s = btb - q^T u (Schur compl.)
    u = state.N @ q
    s = btb - q @ u
    s = jnp.maximum(s, jnp.asarray(1e-30, dtype))  # guarded; caller checks s
    P = state.N + jnp.outer(u, u) / s
    # zero out row/col ell (currently identity), then write n2 / n3 blocks
    keep = 1.0 - onehot
    P = P * keep[:, None] * keep[None, :]
    n2 = -u / s  # (zero outside active block since u is)
    N = P + jnp.outer(onehot, n2) + jnp.outer(n2, onehot) + (1.0 / s) * jnp.outer(onehot, onehot)

    # ---- Cholesky append: R^T r = q ; rho = sqrt(btb - r^T r)
    r = solve_triangular(state.R, q, trans=1, lower=False)
    r = r * keep  # the inactive identity block must not leak into r
    rho2 = jnp.maximum(btb - r @ r, jnp.asarray(1e-30, dtype))
    rho = jnp.sqrt(rho2)
    col = r + rho * onehot
    # overwrite column ell of R (previously e_ell from the identity padding)
    R = state.R * (1.0 - onehot)[None, :] + jnp.outer(col, onehot)

    return IHBState(AtA=AtA, N=N, R=R)


def schur_complement(state: IHBState, q: jax.Array, btb: jax.Array) -> jax.Array:
    """``s = ||b||^2 - q^T N q`` — the (INF)/singularity guard of §4.4.3:
    if s <= 0 the new column is (numerically) dependent and IHB must stop."""
    return btb - q @ (state.N @ q)
