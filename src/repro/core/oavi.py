"""OAVI — the Oracle Approximate Vanishing Ideal algorithm (Algorithm 1).

Structure
---------
Host-side Python owns the *combinatorics* (term book, DegLex borders — a few
hundred items, Theorem 4.3), jitted JAX owns the *linear algebra*.  Per degree
``d`` the whole border is processed by one jitted ``_degree_step``:

1.  Candidate columns ``B = A[:, parents] * X[:, vars]``  (gather + product)
2.  Gram blocks   ``QL = A^T B`` (L x K) and ``C = B^T B`` (K x K)
    — these two matmuls are the *only* O(m) work in the whole degree.  They
    are computed by :func:`repro.kernels.ops.gram_accumulate`: the fused
    Pallas kernel on TPU (border evaluation + both Grams in one VMEM-resident
    sweep), the bit-identical blocked reference elsewhere.  The reduction
    order is *canonical* (sequential fp32 accumulation over ``GRAM_BLOCK``
    row blocks), which is what lets the out-of-core fit
    (:mod:`repro.streaming.fit`) stream row chunks through the same op and
    land on identical bits.
3.  A small ``fori_loop`` over the K candidates replays the exact sequential
    semantics of Algorithm 1 (a term appended to O changes A for all later
    candidates of the same degree) using only the precomputed Gram blocks:
    the ``A^T b`` vector of candidate ``a`` is ``QL[:, a]`` plus ``C[j, a]``
    scattered into the slots of the candidates ``j < a`` appended this degree.

This "degree-batched Gram" formulation is bit-exact w.r.t. the sequential
algorithm yet makes OAVI matmul-bound (MXU-friendly) — the per-candidate work
inside the loop is O(l^2), independent of m.  It is also the unit of
distribution: with X sharded over samples, step (1)+(2) are local matmuls
followed by a psum of (L x K) + (K x K) buffers (see
:mod:`repro.core.distributed`).

Capacities and recompiles
-------------------------
``|O|`` capacity (``Lcap``) and border capacity (``Kcap``) are power-of-two
buckets; regrowth happens device-side (``dynamic_update_slice`` into padded
buffers, no host round-trip) and the jitted degree step is cached *globally*
per config, so the steady state compiles exactly once per ``(Lcap, Kcap)``
bucket — ``stats["recompiles"]`` counts the compiles a fit actually
triggered, and benchmarks assert it stays at zero once warm.

Engines
-------
* ``engine='oracle'`` — paper-faithful: each candidate is decided by the
  configured convex oracle (AGD / CG / PCG / BPCG), optionally warm-started by
  IHB (CGAVI-IHB / AGDAVI-IHB), optionally re-solved sparsely (WIHB).
* ``engine='fast'``  — beyond-paper: pure closed-form IHB decisions
  (exact unconstrained optima; equals AGDAVI-IHB with an accurate oracle).

The IHB state is slimmed to the engine: only the factor the configured
``inverse_engine`` needs is materialized and updated per candidate (``N`` or
``R``; ``AtA`` only for the convex oracles) — see
:func:`repro.core.ihb.factors_for`.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from functools import partial
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..kernels import ops as kernel_ops
from . import ihb as ihb_mod
from . import terms as terms_mod
from .oracles import (
    SCHEDULED_SOLVERS,
    SOLVERS,
    OracleConfig,
    solve_agd,
    solve_bpcg,
    solve_bpcg_scheduled,
    solve_cg,
    solve_pcg,
)
from .ordering import pearson_order

_SOLVER_FNS = {"agd": solve_agd, "cg": solve_cg, "pcg": solve_pcg, "bpcg": solve_bpcg}


def _np_dtype(dtype) -> np.dtype:
    """``np.dtype`` for possibly-extension dtype names (``"bfloat16"``):
    plain numpy only understands those once ml_dtypes is registered, which
    routing through ``jnp.dtype`` guarantees."""
    return np.dtype(jnp.dtype(dtype))


@dataclasses.dataclass(frozen=True)
class OAVIConfig:
    psi: float = 0.005
    engine: str = "fast"  # 'fast' | 'oracle'
    solver: OracleConfig = dataclasses.field(default_factory=OracleConfig)
    ihb: bool = True  # warm-start oracle with the closed-form optimum
    wihb: bool = False  # re-solve accepted generators sparsely (BPCGAVI-WIHB)
    inverse_engine: str = "inverse"  # 'inverse' (Thm 4.9) | 'chol' (beyond-paper)
    max_degree: int = 10
    cap_terms: int = 64  # initial |O| capacity bucket; grows device-side
    cap_border: int = 64  # initial border capacity; grows on demand
    dtype: str = "float32"
    ordering: str = "pearson"  # 'pearson' | 'none' | 'reverse_pearson'
    tol_dependent: float = 1e-9  # Schur-complement guard (relative)
    # Gram kernel dispatch: 'auto' (Pallas on TPU, jnp elsewhere), 'pallas',
    # 'interpret' (Pallas in interpreter mode — tests), 'jnp' (force fallback)
    kernel: str = "auto"

    def jax_dtype(self):
        return jnp.dtype(self.dtype)

    def ihb_factors(self) -> Tuple[str, ...]:
        return ihb_mod.factors_for(
            self.engine, self.inverse_engine, self.ihb, self.wihb
        )


class Generator(NamedTuple):
    term: terms_mod.Term  # leading term
    parent_idx: int  # index (into O) of the parent term, term = parent * x_var
    var: int
    coeffs: np.ndarray  # coefficients over O terms (length = |O| at accept time)
    mse: float


@dataclasses.dataclass
class OAVIModel:
    """Output of OAVI: term book for O, generators G, and transform machinery."""

    n: int
    psi: float
    book: terms_mod.TermBook
    generators: List[Generator]
    feature_perm: Optional[np.ndarray]  # Pearson ordering permutation (or None)
    stats: Dict
    dtype: str = "float32"

    @property
    def num_O(self) -> int:
        return len(self.book)

    @property
    def num_G(self) -> int:
        return len(self.generators)

    def term_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        return (
            np.asarray(self.book.parents, dtype=np.int32),
            np.asarray(self.book.vars, dtype=np.int32),
        )

    def generator_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        k = len(self.generators)
        ell = len(self.book)
        C = np.zeros((ell, k), dtype=_np_dtype(self.dtype))
        gp = np.zeros((k,), dtype=np.int32)
        gv = np.zeros((k,), dtype=np.int32)
        for j, g in enumerate(self.generators):
            C[: len(g.coeffs), j] = g.coeffs
            gp[j] = g.parent_idx
            gv[j] = g.var
        return C, gp, gv

    def evaluate_O(self, Z: jax.Array) -> jax.Array:
        """Evaluation matrix O(Z): (q, |O|) — degree-wavefront evaluation."""
        parents, vars_ = self.term_arrays()
        return evaluate_terms(jnp.asarray(Z, self.dtype), parents, vars_)

    def evaluate_G(self, Z: jax.Array) -> jax.Array:
        """Evaluation matrix G(Z): (q, |G|).  Theorem 4.2 machinery."""
        Z = jnp.asarray(Z, self.dtype)
        if self.feature_perm is not None:
            Z = Z[:, self.feature_perm]
        cols = self.evaluate_O(Z)
        if not self.generators:
            return jnp.zeros((Z.shape[0], 0), self.dtype)
        C, gp, gv = self.generator_arrays()
        lead = cols[:, gp] * Z[:, gv]  # leading-term columns
        return cols @ jnp.asarray(C) + lead

    def mse(self, Z: jax.Array) -> jax.Array:
        """Per-generator MSE over Z."""
        G = self.evaluate_G(Z)
        return jnp.mean(G * G, axis=0)

    # -- VanishingIdealModel protocol (see repro.api) ---------------------

    def transform(self, Z) -> np.ndarray:
        """(FT) for this model alone: ``|G(Z)|`` as (q, |G|) in model dtype."""
        return np.abs(np.asarray(self.evaluate_G(Z)))

    def to_state_dict(self) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Flat array tree + JSON-safe metadata.  The term book and generator
        leading terms are not stored explicitly: both replay from the
        ``(parent, var)`` chains, so the arrays below are the whole model."""
        parents, vars_ = self.term_arrays()
        k = len(self.generators)
        L = len(self.book)
        coeffs = np.zeros((k, L), dtype=_np_dtype(self.dtype))
        lens = np.zeros((k,), np.int32)
        gp = np.zeros((k,), np.int32)
        gv = np.zeros((k,), np.int32)
        mses = np.zeros((k,), np.float64)
        for j, g in enumerate(self.generators):
            coeffs[j, : len(g.coeffs)] = g.coeffs
            lens[j] = len(g.coeffs)
            gp[j] = g.parent_idx
            gv[j] = g.var
            mses[j] = g.mse
        perm = (
            np.asarray(self.feature_perm, np.int32)
            if self.feature_perm is not None
            else np.zeros((0,), np.int32)
        )
        arrays = {
            "book_parents": parents,
            "book_vars": vars_,
            "gen_coeffs": coeffs,
            "gen_lens": lens,
            "gen_parent": gp,
            "gen_var": gv,
            "gen_mse": mses,
            "feature_perm": perm,
        }
        meta = {
            "kind": "oavi",
            "n": int(self.n),
            "psi": float(self.psi),
            "dtype": str(self.dtype),
            "has_perm": self.feature_perm is not None,
            "stats": self.stats,
        }
        return arrays, meta

    @classmethod
    def from_state_dict(cls, arrays: Dict[str, np.ndarray], meta: Dict) -> "OAVIModel":
        n = int(meta["n"])
        dtype = str(meta["dtype"])
        bp = np.asarray(arrays["book_parents"]).astype(np.int64)
        bv = np.asarray(arrays["book_vars"]).astype(np.int64)
        book = terms_mod.TermBook(n=n)
        for i in range(1, bp.shape[0]):
            parent = book.terms[int(bp[i])]
            var = int(bv[i])
            book.append(terms_mod.multiply_by_var(parent, var), parent, var)
        coeffs = np.asarray(arrays["gen_coeffs"]).astype(_np_dtype(dtype))
        lens = np.asarray(arrays["gen_lens"]).astype(np.int64)
        gp = np.asarray(arrays["gen_parent"]).astype(np.int64)
        gv = np.asarray(arrays["gen_var"]).astype(np.int64)
        mses = np.asarray(arrays["gen_mse"]).astype(np.float64)
        generators = []
        for j in range(gp.shape[0]):
            p, v = int(gp[j]), int(gv[j])
            generators.append(
                Generator(
                    term=terms_mod.multiply_by_var(book.terms[p], v),
                    parent_idx=p,
                    var=v,
                    coeffs=coeffs[j, : int(lens[j])].copy(),
                    mse=float(mses[j]),
                )
            )
        perm = (
            np.asarray(arrays["feature_perm"]).astype(np.int64)
            if meta.get("has_perm")
            else None
        )
        return cls(
            n=n,
            psi=float(meta["psi"]),
            book=book,
            generators=generators,
            feature_perm=perm,
            stats=dict(meta.get("stats") or {}),
            dtype=dtype,
        )

    def save(self, path: str) -> str:
        """Atomic save via the checkpoint manifest machinery (repro.api)."""
        from .. import api

        return api.save(self, path)


@partial(jax.jit, donate_argnums=(0,))
def _append_columns(A, B, slots, appended):
    """Scatter appended candidate columns of B into A at their slots."""
    safe_slots = jnp.where(appended, slots, 0)
    contrib = jnp.where(appended[None, :], B, 0.0)
    return A.at[:, safe_slots].add(contrib, mode="drop")


# ---------------------------------------------------------------------------
# Term evaluation: degree-wavefront (serving hot path) + sequential reference
# ---------------------------------------------------------------------------


def evaluate_terms_sequential(
    Z: jax.Array, parents: jax.Array, vars_: jax.Array
) -> jax.Array:
    """Sequential reference: col_i = col_parent * Z[:, var], one term at a
    time (O(|O|) dependent steps).  Works with traced ``parents``/``vars_``;
    kept as the oracle for the wavefront path and for callers inside jit."""
    q = Z.shape[0]
    ell = parents.shape[0]
    cols0 = jnp.zeros((q, ell), Z.dtype).at[:, 0].set(1.0)

    def body(i, cols):
        col = cols[:, parents[i]] * Z[:, vars_[i]]
        return jax.lax.dynamic_update_slice(cols, col[:, None], (0, i))

    return jax.lax.fori_loop(1, ell, body, cols0)


def wavefront_schedule(parents, vars_):
    """Degree-wavefront evaluation plan for a term book.

    A term's parent has *exactly* one degree less (``term = parent * x_var``),
    so all terms of one degree evaluate in a single batched gather+product
    over the previous degree's block — O(max_degree) sequential steps instead
    of O(|O|), and each step only touches two thin blocks.

    Returns ``(waves, perm)``: ``waves[d] = (parent_pos, var)`` with
    ``parent_pos`` indexing into the degree-``d-1`` block, and ``perm`` the
    gather restoring original column order after concatenating the blocks
    (``None`` when the book is already degree-ordered — single-model books).
    """
    parents = np.asarray(parents, np.int64)
    vars_np = np.asarray(vars_, np.int64)
    L = parents.shape[0]
    deg = np.zeros((L,), np.int64)
    for i in range(1, L):
        deg[i] = deg[parents[i]] + 1
    waves = []
    prev_idx = np.zeros((1,), np.int64)  # wave 0: the constant column
    order = [prev_idx]
    for d in range(1, int(deg.max()) + 1 if L > 1 else 1):
        idx = np.nonzero(deg == d)[0]
        pos = np.searchsorted(prev_idx, parents[idx])
        assert np.array_equal(prev_idx[pos], parents[idx]), "parent not at degree d-1"
        waves.append((pos.astype(np.int32), vars_np[idx].astype(np.int32)))
        order.append(idx)
        prev_idx = idx
    order = np.concatenate(order)
    perm = None if np.array_equal(order, np.arange(L)) else np.argsort(order).astype(np.int32)
    return tuple(waves), perm


def apply_wavefronts(Z, waves, perm=None) -> jax.Array:
    """Evaluate a wavefront schedule over ``Z``: one select-matmul + product
    per degree (each reading only the previous degree's block), one concat,
    and — only for fused multi-book plans — one column permutation.

    The column selections are expressed as one-hot matmuls (the same
    gather-as-matmul trick as the gram kernel): exact for any dtype (each
    output sums one value plus hard zeros), MXU-friendly on TPU, and far
    faster than XLA's scalar gathers on CPU.
    """
    prev = jnp.ones((Z.shape[0], 1), Z.dtype)
    blocks = [prev]
    prev_size = 1
    n = Z.shape[1]
    for pos, var in waves:
        k = pos.shape[0]
        Psel = np.zeros((prev_size, k), np.float32)
        Psel[pos, np.arange(k)] = 1.0
        Vsel = np.zeros((n, k), np.float32)
        Vsel[var, np.arange(k)] = 1.0
        prev = (prev @ jnp.asarray(Psel, Z.dtype)) * (Z @ jnp.asarray(Vsel, Z.dtype))
        blocks.append(prev)
        prev_size = k
    cols = jnp.concatenate(blocks, axis=1) if len(blocks) > 1 else blocks[0]
    if perm is not None:
        cols = jnp.take(cols, jnp.asarray(perm), axis=1)
    return cols


# LRU-bounded: a long-lived process fitting many models must not pin one
# jitted evaluator (closure + compiled executable) per term book forever.
_WAVEFRONT_CACHE: "OrderedDict[Tuple[bytes, bytes], Callable]" = OrderedDict()
_WAVEFRONT_CACHE_SIZE = 64


def make_wavefront_evaluator(parents, vars_) -> Callable[[jax.Array], jax.Array]:
    """Jitted ``Z -> O(Z)`` for one (host-side) term book; cached per book so
    serving loops compile once per model set."""
    parents = np.asarray(parents, np.int32)
    vars_np = np.asarray(vars_, np.int32)
    key = (parents.tobytes(), vars_np.tobytes())
    fn = _WAVEFRONT_CACHE.get(key)
    if fn is None:
        waves, perm = wavefront_schedule(parents, vars_np)

        @jax.jit
        def fn(Z):
            return apply_wavefronts(Z, waves, perm)

        _WAVEFRONT_CACHE[key] = fn
        if len(_WAVEFRONT_CACHE) > _WAVEFRONT_CACHE_SIZE:
            _WAVEFRONT_CACHE.popitem(last=False)
    else:
        _WAVEFRONT_CACHE.move_to_end(key)
    return fn


def evaluate_terms(Z: jax.Array, parents, vars_) -> jax.Array:
    """Evaluate all O terms over Z incrementally: col_i = col_parent * Z[:, var].

    With concrete (host-side) ``parents``/``vars_`` — the serving case — the
    degree-wavefront evaluator runs all terms of a degree in one batched
    step.  Traced index arrays fall back to the sequential loop.
    """
    try:
        parents_np = np.asarray(parents)
        vars_np = np.asarray(vars_)
    except Exception:  # traced indices (inside someone else's jit)
        return evaluate_terms_sequential(Z, parents, vars_)
    return make_wavefront_evaluator(parents_np, vars_np)(jnp.asarray(Z))


# ---------------------------------------------------------------------------
# The jitted degree step
# ---------------------------------------------------------------------------


class _LoopState(NamedTuple):
    ihb: ihb_mod.IHBState
    ell: jax.Array  # active |O|
    ihb_live: jax.Array  # bool: IHB still enabled (INF guard, §4.4.3)
    accepted: jax.Array  # (K,) bool
    slots: jax.Array  # (K,) slot index for appended candidates
    coeffs: jax.Array  # (K, L)
    mses: jax.Array  # (K,)
    iters: jax.Array  # (K,) solver iterations (0 for pure closed-form)
    # bool: some valid candidate's fixed-schedule solve was cut short by the
    # iteration budget — the driver must escalate the schedule and re-dispatch
    # (always False for the while_loop refs and the 'fast' engine).
    unconverged: jax.Array


def _kernel_kwargs(cfg: OAVIConfig) -> Dict:
    return {
        "auto": {},
        "pallas": {"use_pallas": True},
        "interpret": {"interpret": True},
        "jnp": {"use_pallas": False},
    }[cfg.kernel]


def _make_stats_degree_step(cfg: OAVIConfig, reduce_fn=None, schedule=None):
    """Build the *statistics-only* degree step: every accept/reject decision
    of one degree from the raw Gram sufficient statistics alone — the
    evaluation matrix A never enters.  This is the piece the out-of-core fit
    (:mod:`repro.streaming.fit`) runs after its chunk accumulator has reduced
    A away; the in-memory :func:`_make_degree_step` wraps it with the Gram
    computation and the A column scatter.  ``reduce_fn`` (e.g. a psum) is
    applied to the raw Gram quantities; None means single-device.

    ``schedule`` selects the solver discipline for oracle/WIHB configs:
    ``None`` uses the data-dependent ``while_loop`` solvers (cheapest for a
    single sequential fit — they stop the moment a certificate fires), a
    static int uses the masked fixed-schedule solvers (vmap-bit-stable, so
    the step can ride the class-batched / streaming-batched paths).  When a
    valid lane's scheduled solve is cut short, the returned
    ``_LoopState.unconverged`` is True and the driver escalates (x2) and
    re-dispatches — iteration chunks compose exactly, so escalating to
    convergence reproduces the while_loop results bit-for-bit."""

    scheduled = schedule is not None
    if scheduled:
        schedule = int(schedule)
        solver = partial(SCHEDULED_SOLVERS[cfg.solver.name], schedule=schedule)
        wihb_solver = partial(solve_bpcg_scheduled, schedule=schedule)
    else:
        solver = SOLVERS[cfg.solver.name]
        wihb_solver = solve_bpcg
    use_chol = cfg.inverse_engine == "chol"
    engine_oracle = cfg.engine == "oracle"
    # closed-form optimum needed: always for 'fast', as a warm start otherwise
    need_closed_form = (not engine_oracle) or cfg.ihb
    rfn = reduce_fn if reduce_fn is not None else (lambda x: x)

    def stats_step(QL_raw, C_raw, state: ihb_mod.IHBState, ell0, valid, m_total):
        dtype = cfg.jax_dtype()
        Lcap = QL_raw.shape[0]
        K = valid.shape[0]
        psi = jnp.asarray(cfg.psi, dtype)
        # All Gram quantities are normalized by m (work with Abar = A/sqrt(m)):
        # entries stay in [0,1] (X in [0,1]^n), which keeps fp32 well behaved
        # for m in the millions, and MSE(g) = btb + q^T y exactly.
        inv_m = jnp.asarray(1.0 / m_total, dtype)
        one = jnp.asarray(1.0, dtype)

        QL = (rfn(QL_raw) * inv_m).astype(dtype)  # (L, K)
        C = (rfn(C_raw) * inv_m).astype(dtype)  # (K, K)

        # ---- (3): sequential acceptance over candidates ---------------
        def body(a, st: _LoopState) -> _LoopState:
            q = QL[:, a]
            # correction for columns appended earlier in this degree:
            appended_before = (jnp.arange(K) < a) & (~st.accepted) & (st.slots < Lcap) & valid
            safe_slots = jnp.where(appended_before, st.slots, 0)
            q = q.at[safe_slots].add(jnp.where(appended_before, C[:, a], 0.0), mode="drop")
            btb = C[a, a]

            mask = jnp.arange(Lcap) < st.ell
            if need_closed_form:
                if use_chol:
                    y0 = ihb_mod.closed_form_cholesky(st.ihb, q)
                else:
                    y0 = ihb_mod.closed_form_inverse(st.ihb, q)
                y0 = jnp.where(mask, y0, 0.0)

            unconverged = st.unconverged
            if not engine_oracle:
                # sum(q * y0), not q @ y0: the elementwise+reduce lowering is
                # bit-stable under vmap (class-batched fit); a fused dot is not
                mse0 = btb + jnp.sum(q * y0)
                y, mse_final, it = y0, mse0, jnp.asarray(0, jnp.int32)
                ihb_live = st.ihb_live
            else:
                if cfg.ihb:
                    # (INF) guard: if the warm start leaves the l1 ball, stop
                    # using IHB from now on (paper §4.4.3, second approach).
                    # Only *valid* candidates can trip it — padded lanes solve
                    # garbage Gram columns, and their verdicts must not leak
                    # into real candidates (padding differs across the
                    # sequential / class-batched paths).
                    feasible = jnp.sum(jnp.abs(y0)) <= (cfg.solver.tau - 1.0)
                    use_warm = st.ihb_live & feasible
                    ihb_live = st.ihb_live & (feasible | ~valid[a])
                    warm = jnp.where(use_warm, y0, 0.0)
                else:
                    ihb_live = st.ihb_live
                    warm = jnp.zeros((Lcap,), dtype)
                res = solver(st.ihb.AtA, q, btb, one, mask, psi, cfg.solver, warm)
                y, mse_final, it = res.y, res.f, res.iters
                if scheduled:
                    unconverged = unconverged | (valid[a] & ~res.converged)

            accept = (mse_final <= psi) & valid[a]

            if cfg.wihb:
                # re-solve accepted generators sparsely from a cold start
                if scheduled:
                    # select-based (both branches computed) so the step stays
                    # bit-stable under vmap; the kept values are identical to
                    # the lax.cond form either way.
                    res2 = wihb_solver(st.ihb.AtA, q, btb, one, mask, psi, cfg.solver, None)
                    ok = res2.f <= psi
                    take = accept & ok
                    y = jnp.where(take, res2.y, y)
                    mse_final = jnp.where(take, res2.f, mse_final)
                    it = it + jnp.where(accept, res2.iters, 0)
                    unconverged = unconverged | (accept & ~res2.converged)
                else:
                    def resolve():
                        res = wihb_solver(st.ihb.AtA, q, btb, one, mask, psi, cfg.solver, None)
                        ok = res.f <= psi
                        return jnp.where(ok, res.y, y), jnp.where(ok, res.f, mse_final), res.iters

                    y, mse_final, extra = jax.lax.cond(
                        accept, resolve, lambda: (y, mse_final, jnp.asarray(0, jnp.int32))
                    )
                    it = it + extra

            # On reject: append column to O (slot = ell), update Gram/inverse.
            do_append = (~accept) & valid[a]

            def appended(st_in: _LoopState):
                new_ihb = ihb_mod.append_column(st_in.ihb, q, btb, st_in.ell)
                return st_in._replace(
                    ihb=new_ihb,
                    ell=st_in.ell + 1,
                    slots=st_in.slots.at[a].set(st_in.ell),
                )

            st = jax.lax.cond(do_append, appended, lambda s: s, st)
            st = st._replace(
                ihb_live=ihb_live,
                accepted=st.accepted.at[a].set(accept),
                coeffs=st.coeffs.at[a].set(jnp.where(accept, y, 0.0)),
                mses=st.mses.at[a].set(mse_final),
                iters=st.iters.at[a].set(it),
                unconverged=unconverged,
            )
            return st

        st0 = _LoopState(
            ihb=state,
            ell=ell0,
            ihb_live=jnp.asarray(True),
            accepted=jnp.zeros((K,), bool),
            slots=jnp.full((K,), Lcap, jnp.int32),
            coeffs=jnp.zeros((K, Lcap), dtype),
            mses=jnp.zeros((K,), dtype),
            iters=jnp.zeros((K,), jnp.int32),
            unconverged=jnp.asarray(False),
        )
        return jax.lax.fori_loop(0, K, body, st0)

    return stats_step


def _make_degree_step(cfg: OAVIConfig, reduce_fn=None, schedule=None):
    """Build the jitted in-memory degree step: the fused Gram computation,
    the statistics-only acceptance loop (:func:`_make_stats_degree_step`),
    and the scatter of appended candidate columns into A."""

    stats_step = _make_stats_degree_step(cfg, reduce_fn, schedule=schedule)
    gram_kw = _kernel_kwargs(cfg)

    def degree_step(A, X, state: ihb_mod.IHBState, ell0, parents, vars_, valid, m_total):
        Lcap = A.shape[1]
        # ---- (1)+(2): all O(m) work, in one fused kernel dispatch ------
        # (Pallas on TPU: border eval + both Grams in a single VMEM sweep;
        # bit-identical gather+matmul fallback elsewhere.)  The reduction is
        # the canonical GRAM_BLOCK-row blocked order, so the streaming fit's
        # chunk accumulator lands on the same bits (repro.streaming.fit).
        QL_raw, C_raw = kernel_ops.gram_accumulate(A, X, parents, vars_, **gram_kw)
        # candidate columns, needed again to scatter appended ones into A
        B = jnp.take(A, parents, axis=1) * jnp.take(X, vars_, axis=1)

        st = stats_step(QL_raw, C_raw, state, ell0, valid, m_total)

        # ---- write appended columns into A -----------------------------
        appended = (~st.accepted) & valid & (st.slots < Lcap)
        A = _append_columns(A, B, st.slots, appended)
        return A, st

    return degree_step


# ---------------------------------------------------------------------------
# Degree-step cache: one jitted step per config, one compile per shape bucket
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _StepEntry:
    fn: Callable
    seen: set  # shape signatures already traced by ``fn``


_DEGREE_STEP_CACHE: Dict = {}


def degree_step_entry(
    config: OAVIConfig,
    backend_key=None,
    jitted_builder: Optional[Callable] = None,
    factory: Optional[Callable] = None,
) -> _StepEntry:
    """Jitted degree step, cached globally per ``(config, backend_key)``.

    ``jax.jit``'s own trace cache buckets on argument shapes; ``seen``
    mirrors it host-side so fits can count the compiles they actually
    trigger (``stats["recompiles"]``).  ``jitted_builder`` overrides how the
    cached step is built on a miss (the sharded backend).  A custom
    ``factory`` (test hook: zero-arg, returns an unjitted step) gets a fresh
    uncached entry.
    """
    if factory is not None:
        return _StepEntry(fn=jax.jit(factory()), seen=set())
    key = (config, backend_key)
    entry = _DEGREE_STEP_CACHE.get(key)
    if entry is None:
        build = jitted_builder or (lambda: jax.jit(_make_degree_step(config)))
        entry = _StepEntry(fn=build(), seen=set())
        _DEGREE_STEP_CACHE[key] = entry
    return entry


def pow2_bucket(x: int) -> int:
    """Smallest power of two >= x (shape bucketing for Lcap / Kcap / m_cap)."""
    return 1 << max(int(x) - 1, 1).bit_length() if x > 2 else 2


def class_batchable(config: OAVIConfig) -> bool:
    """Whether a config is eligible for the class-batched (vmapped) fit path
    (:mod:`repro.core.class_batch`).

    The batched path guarantees bit-exactness against the sequential fit at
    matched capacity and solver schedule, which restricts it to
    configurations whose degree step is built from vmap-bit-stable
    primitives (batched matmuls/matvecs match their per-slice counterparts
    on every backend we test).  Every engine qualifies now that the convex
    oracles have masked fixed-schedule twins (:mod:`repro.core.oracles`):
    oracle and WIHB configs run the ``solve_*_scheduled`` solvers under
    ``vmap`` — converged lanes ride as bitwise no-ops, and the driver
    escalates the shared schedule until every lane converges, which
    reproduces the per-class ``while_loop`` results bit-for-bit.

    The one remaining exclusion is ``inverse_engine='chol'``: batched
    triangular solves do not reduce in the same order as their
    single-instance lowering, breaking bit-exactness.
    """
    return config.inverse_engine == "inverse"


# Memory accounting moved to repro.obs.device (PR 10) — these aliases keep
# the long-standing call sites and benchmark imports working.  The device
# module adds the registry gauges and the trace-counter memory timeline on
# top of the same sampling.
device_memory_stats = obs.device.device_memory_stats
live_buffer_bytes = obs.device.live_buffer_bytes


def sample_memory_stats(stats: Dict) -> None:
    """Record the current memory high-water marks into a fit ``stats`` dict:
    ``peak_bytes`` from the device allocator where available (gracefully
    absent otherwise) and ``live_bytes_peak`` from live-array accounting.
    Fit loops call this per degree and once at finalize.

    ``peak_bytes`` is the allocator's *process-lifetime* high-water mark —
    it cannot be reset, so a fit that stays under an earlier fit's peak
    inherits it (compare against ``peak_bytes_start`` from
    :func:`init_fit_stats` to bound this fit's contribution).
    ``live_bytes_peak`` is sampled per fit and is the per-fit comparable
    quantity the memory benchmarks prefer.  Delegates to
    :func:`repro.obs.device.sample_memory`, which also refreshes the
    ``device.*`` gauges and appends the trace memory-timeline sample."""
    obs.device.sample_memory(stats)


def init_fit_stats(m: int, n: int, **extra) -> Dict:
    """Common ``stats`` skeleton shared by the local, sharded, class-batched
    and streaming fit loops."""
    stats = {
        "border_sizes": [],
        "solver_iters": [],
        "degrees": [],
        "degree_times": [],
        "recompiles": 0,
        "regrowths": 0,
        # fixed-schedule solver discipline (batched oracle/WIHB fits only):
        # final per-solve iteration budget and how many times the loop had to
        # escalate it; None/0 on paths using the while_loop refs.
        "solver_schedule_len": None,
        "solver_escalations": 0,
        # device-level accounting (repro.obs.device): HLO flop estimate per
        # degree step (None entries when capture is off/unavailable), XLA
        # backend-compile seconds attributed to this fit, and the realized
        # FLOP rate over the degree-step time.
        "flops_per_degree": [],
        "compile_seconds": 0.0,
        "achieved_gflops": None,
        "time_total": 0.0,
        "m": m,
        "n": n,
    }
    peak = device_memory_stats().get("peak_bytes_in_use")
    if peak is not None:
        stats["peak_bytes_start"] = int(peak)
    stats.update(extra)
    return stats


class _DegreeScope:
    """One degree step's timing window (see :class:`FitScope.degree`)."""

    __slots__ = ("_scope", "_span", "_t0")

    def __init__(self, scope: "FitScope", span) -> None:
        self._scope = scope
        self._span = span

    def __enter__(self) -> "_DegreeScope":
        self._span.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        self._span.__exit__(exc_type, exc, tb)
        scope = self._scope
        dur = t1 - self._t0
        if scope._t_first_degree is None:
            scope._t_first_degree = self._t0
        scope._t_last_degree_end = t1
        scope._time_degrees += dur
        scope.stats["degree_times"].append(round(dur, 6))
        sample_memory_stats(scope.stats)


class FitScope:
    """Instrumentation shared by every fit loop (local, sharded,
    class-batched, streaming, online).

    Owns the *timing contract* for fit ``stats`` — defined here once so the
    loops can no longer disagree on what ``time_total`` covers (asserted by
    ``tests/test_obs.py``)::

        time_total == time_setup + time_degrees + time_finalize
                      + time_unattributed          # exact, by construction

    * ``time_total``    wall time from scope entry to :meth:`finalize`.
    * ``time_setup``    entry -> first degree step: feature ordering, initial
      buffers, the first border (for the streaming fit this includes the
      Pearson moment pass when ordering is enabled).
    * ``time_degrees``  unrounded sum of the per-degree segments.  Each
      segment runs from the degree step's dispatch to the host sync of its
      outputs, so it *includes* any jit compile the step triggered —
      ``sum(stats["degree_times"])`` equals it up to the 6-decimal rounding
      of the public list.
    * ``time_finalize`` last degree's end -> :meth:`finalize` (final host
      bookkeeping and model assembly).
    * ``time_unattributed`` the residual: host combinatorics between degree
      steps (border construction, accept/reject collection).

    Timing itself is always on (two clock reads per degree); the global obs
    recorder sees the same segments as spans/events only when
    :func:`repro.obs.enabled` — and enabling it never changes what the fit
    computes (bit-identity asserted by ``benchmarks/bench_obs.py``).
    """

    def __init__(self, stats: Dict, backend: str = "local", name: str = "fit") -> None:
        self.stats = stats
        self.backend = backend
        attrs = {k: stats[k] for k in ("m", "n") if stats.get(k) is not None}
        self._span = obs.span(name, backend=backend, **attrs)
        self._t_start = time.perf_counter()
        self._t_first_degree: Optional[float] = None
        self._t_last_degree_end: Optional[float] = None
        self._time_degrees = 0.0
        self._timing: Optional[Dict] = None
        self._flops = 0.0
        # XLA compile attribution window: always-on (reading the listener's
        # accumulator never touches numerics or the device)
        self._compile0 = obs.device.compile_snapshot()

    def __enter__(self) -> "FitScope":
        self._span.__enter__()
        # env-gated jax.profiler window (OBS_JAX_PROFILE=<dir>): the whole
        # fit in one device-timeline capture, interleaved with obs spans
        self._profile = obs.device.profile_window(f"fit/{self.backend}")
        self._profile.__enter__()
        self._t_start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self._profile.__exit__(exc_type, exc, tb)
        self._span.__exit__(exc_type, exc, tb)

    def degree(self, d: int, **attrs) -> _DegreeScope:
        """Context manager timing one degree step.  On exit it appends the
        (rounded) segment to ``stats["degree_times"]``, accumulates the
        unrounded sum for the timing contract, and samples memory."""
        return _DegreeScope(
            self, obs.span("fit/degree", d=d, backend=self.backend, **attrs)
        )

    def note_signature(self, seen: set, sig, kind: str = "fit/compile") -> bool:
        """Count a compile against this fit iff ``sig`` is new to the jitted
        step's host-side trace-cache mirror; emits the compile event the
        degree-step cache owes the trace."""
        if sig in seen:
            return False
        seen.add(sig)
        self.stats["recompiles"] += 1
        obs.registry().counter("fit.recompiles", backend=self.backend).inc()
        obs.event(kind, backend=self.backend, signature=str(sig))
        return True

    def regrowth(self, Lcap: int) -> None:
        self.stats["regrowths"] += 1
        obs.registry().counter("fit.regrowths", backend=self.backend).inc()
        obs.event("fit/regrowth", backend=self.backend, Lcap=int(Lcap))

    def step_cost(self, fn, sig, args) -> None:
        """Record the degree step's HLO flop estimate for this signature.

        Call *between* :meth:`note_signature` and the :meth:`degree` window:
        the one-time lowering cost per new signature then lands in
        ``time_unattributed``, keeping ``degree_times`` pure device+sync
        time.  Appends to ``stats["flops_per_degree"]`` (None when capture
        is off) so the list stays aligned with ``stats["degrees"]``.
        """
        cost = obs.device.step_cost(fn, sig, args)
        self.record_flops(None if cost is None else cost["flops"])

    def record_flops(self, flops: Optional[float]) -> None:
        """Append one degree's flop estimate (None = capture unavailable).
        Composite paths (streaming: accumulator x chunks + stats step) sum
        their components and record through this."""
        self.stats.setdefault("flops_per_degree", []).append(flops)
        if flops:
            self._flops += flops

    def timing_fields(self) -> Dict:
        """The timing-contract fields, computed once (shared by every class
        of a batched fit so their stats agree to the bit)."""
        if self._timing is None:
            t_end = time.perf_counter()
            total = t_end - self._t_start
            if self._t_first_degree is None:
                setup, degrees, fin = total, 0.0, 0.0
            else:
                setup = self._t_first_degree - self._t_start
                degrees = self._time_degrees
                fin = t_end - self._t_last_degree_end
            self._timing = {
                "time_total": total,
                "time_setup": setup,
                "time_degrees": degrees,
                "time_finalize": fin,
                "time_unattributed": total - setup - degrees - fin,
            }
        return self._timing

    def finalize(
        self,
        book: terms_mod.TermBook,
        generators: List[Generator],
        Lcap: int,
        config: OAVIConfig,
        stats: Optional[Dict] = None,
    ) -> Dict:
        """Fill the summary + timing fields every fit loop reports."""
        stats = self.stats if stats is None else stats
        sample_memory_stats(stats)
        stats.update(self.timing_fields())
        s1, c1 = obs.device.compile_snapshot()
        stats["compile_seconds"] = round(s1 - self._compile0[0], 6)
        stats["xla_compiles"] = c1 - self._compile0[1]
        degrees_t = self._timing["time_degrees"] if self._timing else 0.0
        if self._flops > 0.0 and degrees_t > 0.0:
            stats["achieved_gflops"] = round(self._flops / degrees_t / 1e9, 3)
            obs.registry().gauge(
                "device.achieved_gflops", backend=self.backend
            ).set(stats["achieved_gflops"])
        stats["num_G"] = len(generators)
        stats["num_O"] = len(book)
        stats["G_plus_O"] = len(generators) + len(book)
        stats["Lcap_final"] = int(Lcap)
        stats["thm43_bound"] = terms_mod.theorem_4_3_size_bound(config.psi, book.n)
        obs.registry().histogram("fit.seconds", backend=self.backend).observe(
            stats["time_total"]
        )
        return stats


def border_index_arrays(book: terms_mod.TermBook, border, Kcap: int):
    """Padded (parents, vars, valid) host arrays for one degree's border."""
    parents = np.zeros((Kcap,), np.int32)
    vars_ = np.zeros((Kcap,), np.int32)
    valid = np.zeros((Kcap,), bool)
    for i, (term, parent, j) in enumerate(border):
        parents[i] = book.index[parent]
        vars_[i] = j
        valid[i] = True
    return parents, vars_, valid


def collect_degree(book, border, accepted, mses, coeffs, generators) -> int:
    """Host-side bookkeeping after a degree step: accepted candidates become
    generators, rejected ones extend the term book.  Returns the new |O|."""
    for i, (term, parent, j) in enumerate(border):
        if accepted[i]:
            ell_at = len(book)
            generators.append(
                Generator(
                    term=term,
                    parent_idx=book.index[parent],
                    var=j,
                    coeffs=coeffs[i, :ell_at].copy(),
                    mse=float(mses[i]),
                )
            )
        else:
            book.append(term, parent, j)
    return len(book)


def fit(
    X,
    config: OAVIConfig = OAVIConfig(),
    _degree_step_factory=None,
) -> OAVIModel:
    """Run OAVI on ``X`` (m, n) in [0,1]^n.  Returns the fitted model."""
    dtype = config.jax_dtype()
    X = np.asarray(X)
    m, n = X.shape
    stats = init_fit_stats(m, n)

    with FitScope(stats, backend="local") as scope:
        perm = None
        if config.ordering in ("pearson", "reverse_pearson"):
            perm = pearson_order(X, reverse=(config.ordering == "reverse_pearson"))
            X = X[:, perm]

        Xd = jnp.asarray(X, dtype)
        book = terms_mod.TermBook(n=n)
        generators: List[Generator] = []

        Lcap = pow2_bucket(config.cap_terms)
        A = jnp.zeros((m, Lcap), dtype).at[:, 0].set(1.0)
        # normalized Gram convention: AtA[0,0] = ||1||^2 / m = 1
        state = ihb_mod.init_state(
            Lcap, jnp.asarray(1.0, dtype), dtype, factors=config.ihb_factors()
        )
        ell = 1

        entry = degree_step_entry(config, factory=_degree_step_factory)
        m_total = jnp.asarray(float(m), dtype)

        d = 0
        while True:
            d += 1
            if d > config.max_degree:
                stats["termination"] = f"max_degree={config.max_degree}"
                break
            border = book.border(d)
            if not border:
                stats["termination"] = "empty_border"
                break
            K = len(border)
            stats["border_sizes"].append(K)
            stats["degrees"].append(d)

            # capacity management: device-side regrowth into the next pow2 bucket
            while ell + K > Lcap:
                Lcap *= 2
                scope.regrowth(Lcap)
                A = jax.lax.dynamic_update_slice(
                    jnp.zeros((m, Lcap), dtype), A, (0, 0)
                )
                state = ihb_mod.grow_state(state, Lcap)

            Kcap = max(config.cap_border, pow2_bucket(K))
            parents, vars_, valid = border_index_arrays(book, border, Kcap)

            step_args = (
                A,
                Xd,
                state,
                jnp.asarray(ell, jnp.int32),
                jnp.asarray(parents),
                jnp.asarray(vars_),
                jnp.asarray(valid),
                m_total,
            )
            sig = (m, n, Lcap, Kcap, str(dtype))
            scope.note_signature(entry.seen, sig)
            scope.step_cost(entry.fn, sig, step_args)

            with scope.degree(d, K=K):
                A, st = entry.fn(*step_args)
                state = st.ihb
                accepted = np.asarray(st.accepted)
                mses = np.asarray(st.mses)
                coeffs = np.asarray(st.coeffs)
                iters = np.asarray(st.iters)
            stats["solver_iters"].append(int(iters[:K].sum()))

            ell = collect_degree(book, border, accepted, mses, coeffs, generators)

        scope.finalize(book, generators, Lcap, config)
    return OAVIModel(
        n=n,
        psi=config.psi,
        book=book,
        generators=generators,
        feature_perm=perm,
        stats=stats,
        dtype=config.dtype,
    )
