"""Convex optimization oracles for OAVI (Line 7 / (CCOP)).

All solvers minimize the quadratic

    f(y) = (y^T Q y + 2 q^T y + btb) / m,      Q = A^T A,  q = A^T b,

either unconstrained (AGD) or over the l1-ball of radius ``r = tau - 1``
(CG / PCG / BPCG), exactly as in Sections 3.3 and 4.3 of the paper.  Working
in Gram form makes the per-iteration cost O(l^2) instead of O(m l); the
O(m l) part (computing Q, q incrementally) is done once per candidate term in
:mod:`repro.core.oavi` ("In BPCG, we first compute A^T A and A^T b").

Everything is fixed-capacity (padded to ``L`` columns with a boolean mask) so
the solvers jit once and are reused across OAVI's whole execution.

Early-termination rules follow Section 6.1 of the paper:
  * accuracy ``eps = eps_frac * psi`` (via the FW gap for CG variants, via the
    gradient norm for AGD),
  * stop when a vanishing coefficient vector has been constructed
    (``f <= psi``),
  * stop when no vanishing vector can exist (``f - gap > psi`` certifies
    ``f* > psi`` for CG variants),
  * hard iteration cap.

Each solver comes in two executions of the *same* per-iteration body:

  * ``solve_*`` — a data-dependent ``while_loop`` over the early-termination
    predicate.  Cheapest for a single cold solve (stops the moment a
    certificate fires) but the trip count is data-dependent, so it is not
    vmap-bit-stable and cannot ride the class-batched / streaming paths.
  * ``solve_*_scheduled`` — a fixed-schedule ``fori_loop`` over a static
    iteration budget where the early-termination predicate becomes a per-lane
    active mask: converged lanes carry their state as bitwise no-ops (the
    same trick ``class_batch`` uses for finished classes).  Batched fit loops
    escalate the budget (x2, pow2 buckets — mirroring capacity regrowth)
    while any lane reports ``converged == False``; because iteration chunks
    compose exactly, a scheduled solve escalated to convergence is
    bit-identical to the while_loop ref.

Both paths share ``cond``/``body``/``finish`` closures built by the per-solver
``_*_parts`` helpers, so parity is structural rather than numerical luck.
All vector reductions use :func:`vdot` (elementwise multiply + sum) instead of
fused ``a @ b`` dots: the fused form lowers to a different reduction order
under ``vmap``, which would break the bit-identity contract between a batched
solve and its single-lane twin.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -jnp.inf


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1) (schedule buckets)."""
    return 1 << max(int(x) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class OracleConfig:
    name: str = "bpcg"  # 'agd' | 'cg' | 'pcg' | 'bpcg'
    tau: float = 1000.0  # l1 radius is tau - 1 (CCOP); ignored by AGD
    max_iter: int = 10_000
    eps_frac: float = 0.01  # solver accuracy = eps_frac * psi
    # AGD: number of power iterations used to estimate the smoothness constant
    power_iters: int = 30
    # Fixed-schedule path: initial per-solve iteration budget (pow2-bucketed
    # by schedule_budget).  This only sets where device-side escalation
    # starts, never the reachable accuracy — batched fit loops double it
    # until every lane converges or max_iter is reached.  The default, 0, is
    # a certificate-check-only start: the solver state is initialized from
    # the warm start and the early-termination predicate is evaluated at
    # entry without running a single iteration — for IHB-warm solves (the
    # paper's flagship configs) the closed-form warm start already fires a
    # certificate, so budget 0 costs one gradient/gap evaluation per lane,
    # within epsilon of the early-exit while_loop ref.  Cold configs
    # escalate geometrically (0 -> 1 -> 2 -> ...) to whatever they need, and
    # the budget persists across degrees, so the escalation bill is paid
    # once per fit, not once per degree.
    schedule: int = 0


def schedule_budget(cfg: OracleConfig) -> int:
    """Initial fixed-schedule iteration budget: purely config-driven.

    pow2-bucketed (0 allowed: certificate-check only) so refits under the
    same config reuse the same compiled step.  Deliberately NOT
    capacity-coupled: a masked fixed-schedule lane pays its full budget in
    FLOPs whether or not it converged earlier, so over-provisioning the
    start burns more than the escalation re-dispatch it would save —
    warm-started solves finish in O(1) iterations at any base size, and
    cold solves find their level in log2(need) doublings."""
    s = max(int(cfg.schedule), 0)
    return min(next_pow2(int(cfg.max_iter)), next_pow2(s) if s else 0)


def max_schedule(cfg: OracleConfig) -> int:
    """Budget at which every lane is guaranteed ``converged`` (the
    ``k < max_iter`` clause falsifies the active mask)."""
    return next_pow2(int(cfg.max_iter))


def escalate_schedule(cfg: OracleConfig, schedule: int) -> int:
    return min(max_schedule(cfg), max(int(schedule) * 2, 1))


class SolveResult(NamedTuple):
    y: jax.Array  # (L,) solution (padded with zeros outside the mask)
    f: jax.Array  # objective value (MSE of the candidate polynomial)
    gap: jax.Array  # FW gap (CG variants) or squared grad norm (AGD)
    iters: jax.Array  # iterations used
    # True when the early-termination predicate held at exit: a certificate
    # fired, the accuracy target was met, or max_iter was reached.  Always
    # True for the while_loop refs; False from a fixed-schedule solver means
    # the budget cut the iteration short and the caller should escalate.
    converged: jax.Array = True


def vdot(a, b):
    """Vector dot as elementwise multiply + reduce — the vmap-bit-stable
    lowering (a fused ``a @ b`` reduces in a different order when batched;
    cf. ``repro.core.ihb.mse_from_solution``)."""
    return jnp.sum(a * b)


def quad_f(Q, q, btb, inv_m, y):
    return (vdot(y, Q @ y) + 2.0 * vdot(q, y) + btb) * inv_m


def quad_grad(Q, q, inv_m, y):
    return 2.0 * inv_m * (Q @ y + q)


def _line_search_quad(Q, inv_m, grad, d, gamma_max):
    """Exact line search for the quadratic along ``d``; clipped to
    ``[0, gamma_max]``.  f(y + g d) - f(y) = g <grad, d> + g^2 d^T Q d / m."""
    dQd = vdot(d, Q @ d) * inv_m
    num = -vdot(grad, d)
    gamma = jnp.where(dQd > 0, num / jnp.maximum(2.0 * dQd, 1e-30), gamma_max)
    return jnp.clip(gamma, 0.0, gamma_max)


# --------------------------------------------------------------------------
# Shared runners: one body, two trip-count disciplines
# --------------------------------------------------------------------------


def _run_while(state0, cond, body, finish) -> "SolveResult":
    final = jax.lax.while_loop(cond, body, state0)
    return finish(final)


def _run_scheduled(state0, cond, body, finish, schedule: int) -> "SolveResult":
    def step(_, st):
        active = cond(st)
        nxt = body(st)
        return jax.tree_util.tree_map(
            lambda new, old: jnp.where(active, new, old), nxt, st
        )

    final = jax.lax.fori_loop(0, int(schedule), step, state0)
    res = finish(final)
    return res._replace(converged=jnp.logical_not(cond(final)))


# --------------------------------------------------------------------------
# AGD (Nesterov) — unconstrained
# --------------------------------------------------------------------------


def _estimate_lmax(Q, mask, iters: int):
    """Power iteration on the masked Gram matrix."""
    v0 = jnp.where(mask, 1.0, 0.0).astype(Q.dtype)
    v0 = v0 / jnp.maximum(jnp.sqrt(vdot(v0, v0)), 1e-30)

    def body(_, v):
        w = Q @ v
        nrm = jnp.sqrt(vdot(w, w))
        return jnp.where(nrm > 0, w / jnp.maximum(nrm, 1e-30), v)

    v = jax.lax.fori_loop(0, iters, body, v0)
    return jnp.maximum(vdot(v, Q @ v), 1e-30)


def _agd_parts(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0):
    dtype = Q.dtype
    Lcap = Q.shape[0]
    inv_m = (1.0 / m).astype(dtype)
    maskf = mask.astype(dtype)
    if y0 is None:
        y0 = jnp.zeros((Lcap,), dtype)
    y0 = y0 * maskf
    lmax = _estimate_lmax(Q, mask, cfg.power_iters)
    step = 1.0 / (2.0 * lmax * inv_m)  # 1/L_smooth with L = 2 lmax / m
    eps = cfg.eps_frac * psi

    def cond(state):
        _, _, _, k, gnorm2 = state
        return jnp.logical_and(k < cfg.max_iter, gnorm2 > eps * eps)

    def body(state):
        y, z, t, k, _ = state
        g = quad_grad(Q, q, inv_m, z) * maskf
        y_new = z - step * g
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = y_new + ((t - 1.0) / t_new) * (y_new - y)
        return (y_new, z_new * maskf, t_new, k + 1, vdot(g, g))

    def finish(state):
        y, _, _, k, gnorm2 = state
        f = quad_f(Q, q, btb, inv_m, y)
        return SolveResult(y=y, f=f, gap=gnorm2, iters=k, converged=jnp.asarray(True))

    g0 = quad_grad(Q, q, inv_m, y0) * maskf
    state0 = (y0, y0, jnp.asarray(1.0, dtype), jnp.asarray(0, jnp.int32), vdot(g0, g0))
    return state0, cond, body, finish


@partial(jax.jit, static_argnames=("cfg",))
def solve_agd(
    Q: jax.Array,
    q: jax.Array,
    btb: jax.Array,
    m: jax.Array,
    mask: jax.Array,
    psi: jax.Array,
    cfg: OracleConfig,
    y0: Optional[jax.Array] = None,
) -> SolveResult:
    return _run_while(*_agd_parts(Q, q, btb, m, mask, psi, cfg, y0))


@partial(jax.jit, static_argnames=("cfg", "schedule"))
def solve_agd_scheduled(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None,
                        schedule: Optional[int] = None) -> SolveResult:
    if schedule is None:
        schedule = schedule_budget(cfg)
    return _run_scheduled(*_agd_parts(Q, q, btb, m, mask, psi, cfg, y0), schedule)


# --------------------------------------------------------------------------
# Frank-Wolfe variants on the l1-ball of radius r = tau - 1
# --------------------------------------------------------------------------


def _fw_vertex(grad, mask, r):
    """Global LMO over the l1 ball: vertex -r*sign(grad_i*) e_{i*}."""
    score = jnp.where(mask, jnp.abs(grad), NEG_INF)
    i = jnp.argmax(score)
    s = -jnp.sign(grad[i])
    s = jnp.where(s == 0, 1.0, s)
    return i, s * r  # index, signed coordinate value


def _weights_to_point(wp, wm, r):
    return r * (wp - wm)


def _decompose_point(y, r, mask):
    """Represent y (||y||_1 <= r) as convex weights on vertices +/- r e_i.

    Leftover mass (1 - ||y||_1 / r) is split evenly between +r e_0 and -r e_0
    so it contributes 0 to the reconstructed point.
    """
    maskf = mask.astype(y.dtype)
    wp = jnp.maximum(y, 0.0) / r * maskf
    wm = jnp.maximum(-y, 0.0) / r * maskf
    leftover = jnp.maximum(1.0 - jnp.sum(wp + wm), 0.0)
    wp = wp.at[0].add(0.5 * leftover)
    wm = wm.at[0].add(0.5 * leftover)
    return wp, wm


class _FWState(NamedTuple):
    y: jax.Array
    wp: jax.Array  # weights on +r e_i
    wm: jax.Array  # weights on -r e_i
    f: jax.Array
    gap: jax.Array
    k: jax.Array


def _fw_cond(cfg, psi, state: _FWState):
    eps = cfg.eps_frac * psi
    not_converged = state.gap > eps
    not_vanishing = state.f > psi  # generator already found -> stop
    feasible_possible = (state.f - state.gap) <= psi  # lower bound on f*
    return jnp.logical_and(
        state.k < cfg.max_iter,
        jnp.logical_and(not_converged, jnp.logical_and(not_vanishing, feasible_possible)),
    )


def _fw_state0(Q, q, btb, inv_m, y0, wp0, wm0, mask, r):
    """Entry state carrying the TRUE FW gap at ``y0`` (one gradient + LMO).

    With the real gap known at iteration 0, the Section 6.1 certificates can
    fire before any step is taken: a warm start that already vanishes
    (``f <= psi``) or is certifiably infeasible (``f - gap > psi``) makes the
    whole solve a no-op — which is what lets the fixed-schedule path run
    IHB-warm fits at budget 0 (certificate check only) instead of paying a
    full masked iteration per lane just to learn the gap."""
    maskf = mask.astype(Q.dtype)
    Qy = Q @ y0  # shared between f0 and grad: one matvec, not two
    f0 = (vdot(y0, Qy) + 2.0 * vdot(q, y0) + btb) * inv_m
    grad = (2.0 * inv_m) * (Qy + q) * maskf
    i, val = _fw_vertex(grad, mask, r)
    # <grad, w - y0> with w = val * e_i, without materializing w
    gap0 = vdot(grad, y0) - grad[i] * val
    return _FWState(y0, wp0, wm0, f0, gap0, jnp.asarray(0, jnp.int32))


def _fw_finish(state: _FWState) -> SolveResult:
    return SolveResult(y=state.y, f=state.f, gap=state.gap, iters=state.k,
                       converged=jnp.asarray(True))


def _cg_parts(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0):
    """Vanilla Frank-Wolfe (CG) with exact line search."""
    dtype = Q.dtype
    Lcap = Q.shape[0]
    inv_m = (1.0 / m).astype(dtype)
    r = jnp.asarray(cfg.tau - 1.0, dtype)
    maskf = mask.astype(dtype)
    if y0 is None:
        y0 = jnp.zeros((Lcap,), dtype)
    y0 = y0 * maskf

    def body(state: _FWState) -> _FWState:
        y = state.y
        grad = quad_grad(Q, q, inv_m, y) * maskf
        i, val = _fw_vertex(grad, mask, r)
        w = jnp.zeros_like(y).at[i].set(val)
        d = w - y
        gap = -vdot(grad, d)
        gamma = _line_search_quad(Q, inv_m, grad, d, jnp.asarray(1.0, dtype))
        y_new = y + gamma * d
        f = quad_f(Q, q, btb, inv_m, y_new)
        return _FWState(y_new, state.wp, state.wm, f, gap, state.k + 1)

    zero = jnp.zeros((Lcap,), dtype)
    state0 = _fw_state0(Q, q, btb, inv_m, y0, zero, zero, mask, r)
    return state0, partial(_fw_cond, cfg, psi), body, _fw_finish


def _active_extrema(grad, wp, wm, r):
    """Away vertex (argmax <grad, v>) and local FW vertex (argmin) over the
    active set.  Vertex +r e_i has score r*grad_i, -r e_i has -r*grad_i."""
    sp = r * grad
    sm = -r * grad
    away_p = jnp.where(wp > 0, sp, NEG_INF)
    away_m = jnp.where(wm > 0, sm, NEG_INF)
    ia_p, ia_m = jnp.argmax(away_p), jnp.argmax(away_m)
    away_is_p = away_p[ia_p] >= away_m[ia_m]
    loc_p = jnp.where(wp > 0, sp, -NEG_INF)
    loc_m = jnp.where(wm > 0, sm, -NEG_INF)
    il_p, il_m = jnp.argmin(loc_p), jnp.argmin(loc_m)
    local_is_p = loc_p[il_p] <= loc_m[il_m]
    return (away_is_p, ia_p, ia_m), (local_is_p, il_p, il_m)


def _signed_unit(i, sign_plus, r, Lcap, dtype):
    v = jnp.zeros((Lcap,), dtype)
    return v.at[i].set(jnp.where(sign_plus, r, -r))


def _pcg_parts(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0):
    """Pairwise Conditional Gradients (Lacoste-Julien & Jaggi 2015)."""
    dtype = Q.dtype
    Lcap = Q.shape[0]
    inv_m = (1.0 / m).astype(dtype)
    r = jnp.asarray(cfg.tau - 1.0, dtype)
    maskf = mask.astype(dtype)
    if y0 is None:
        y0 = jnp.zeros((Lcap,), dtype)
    y0 = y0 * maskf
    wp0, wm0 = _decompose_point(y0, r, mask)

    def body(state: _FWState) -> _FWState:
        y, wp, wm = state.y, state.wp, state.wm
        grad = quad_grad(Q, q, inv_m, y) * maskf
        # global FW vertex
        iw, val = _fw_vertex(grad, mask, r)
        w_plus = val > 0
        w_vec = _signed_unit(iw, w_plus, r, Lcap, dtype)
        # away vertex over active set
        (a_is_p, ia_p, ia_m), _ = _active_extrema(grad, wp, wm, r)
        ia = jnp.where(a_is_p, ia_p, ia_m)
        a_vec = _signed_unit(ia, a_is_p, r, Lcap, dtype)
        a_weight = jnp.where(a_is_p, wp[ia], wm[ia])
        d = w_vec - a_vec
        gap = -vdot(grad, w_vec - y)  # FW gap for stopping
        gamma = _line_search_quad(Q, inv_m, grad, d, a_weight)
        # move weight gamma from away to FW vertex
        wp = jnp.where(a_is_p, wp.at[ia].add(-gamma), wp)
        wm = jnp.where(a_is_p, wm, wm.at[ia].add(-gamma))
        wp = jnp.where(w_plus, wp.at[iw].add(gamma), wp)
        wm = jnp.where(w_plus, wm, wm.at[iw].add(gamma))
        wp = jnp.maximum(wp, 0.0)
        wm = jnp.maximum(wm, 0.0)
        y_new = _weights_to_point(wp, wm, r)
        f = quad_f(Q, q, btb, inv_m, y_new)
        return _FWState(y_new, wp, wm, f, gap, state.k + 1)

    state0 = _fw_state0(Q, q, btb, inv_m, y0, wp0, wm0, mask, r)
    return state0, partial(_fw_cond, cfg, psi), body, _fw_finish


def _bpcg_parts(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0):
    """Blended Pairwise Conditional Gradients (Tsuji et al. 2021, Alg. 3).

    The local/global branch is select-based (both branches computed, one
    kept) rather than ``lax.cond`` so the body stays bit-stable under vmap;
    the selected branch's values are identical either way.
    """
    dtype = Q.dtype
    Lcap = Q.shape[0]
    inv_m = (1.0 / m).astype(dtype)
    r = jnp.asarray(cfg.tau - 1.0, dtype)
    maskf = mask.astype(dtype)
    if y0 is None:
        y0 = jnp.zeros((Lcap,), dtype)
    y0 = y0 * maskf
    wp0, wm0 = _decompose_point(y0, r, mask)

    def body(state: _FWState) -> _FWState:
        y, wp, wm = state.y, state.wp, state.wm
        grad = quad_grad(Q, q, inv_m, y) * maskf
        iw, val = _fw_vertex(grad, mask, r)
        w_plus = val > 0
        w_vec = _signed_unit(iw, w_plus, r, Lcap, dtype)
        (a_is_p, ia_p, ia_m), (s_is_p, is_p, is_m) = _active_extrema(grad, wp, wm, r)
        ia = jnp.where(a_is_p, ia_p, ia_m)
        a_vec = _signed_unit(ia, a_is_p, r, Lcap, dtype)
        a_weight = jnp.where(a_is_p, wp[ia], wm[ia])
        is_ = jnp.where(s_is_p, is_p, is_m)
        s_vec = _signed_unit(is_, s_is_p, r, Lcap, dtype)
        gap = -vdot(grad, w_vec - y)
        # Line 7: local pairwise step iff <grad, w - y> >= <grad, s - a>
        local = vdot(grad, w_vec - y) >= vdot(grad, s_vec - a_vec)

        # local pairwise step
        d_l = s_vec - a_vec
        gamma_l = _line_search_quad(Q, inv_m, grad, d_l, a_weight)
        wp_l = jnp.where(a_is_p, wp.at[ia].add(-gamma_l), wp)
        wm_l = jnp.where(a_is_p, wm, wm.at[ia].add(-gamma_l))
        wp_l = jnp.where(s_is_p, wp_l.at[is_].add(gamma_l), wp_l)
        wm_l = jnp.where(s_is_p, wm_l, wm_l.at[is_].add(gamma_l))
        y_l = y + gamma_l * d_l

        # global FW step
        d_g = w_vec - y
        gamma_g = _line_search_quad(Q, inv_m, grad, d_g, jnp.asarray(1.0, dtype))
        wp_g = wp * (1.0 - gamma_g)
        wm_g = wm * (1.0 - gamma_g)
        wp_g = jnp.where(w_plus, wp_g.at[iw].add(gamma_g), wp_g)
        wm_g = jnp.where(w_plus, wm_g, wm_g.at[iw].add(gamma_g))
        y_g = y + gamma_g * d_g

        y_new = jnp.where(local, y_l, y_g)
        wp_new = jnp.maximum(jnp.where(local, wp_l, wp_g), 0.0)
        wm_new = jnp.maximum(jnp.where(local, wm_l, wm_g), 0.0)
        f = quad_f(Q, q, btb, inv_m, y_new)
        return _FWState(y_new, wp_new, wm_new, f, gap, state.k + 1)

    state0 = _fw_state0(Q, q, btb, inv_m, y0, wp0, wm0, mask, r)
    return state0, partial(_fw_cond, cfg, psi), body, _fw_finish


_PARTS = {
    "agd": _agd_parts,
    "cg": _cg_parts,
    "pcg": _pcg_parts,
    "bpcg": _bpcg_parts,
}


@partial(jax.jit, static_argnames=("cfg",))
def solve_cg(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None) -> SolveResult:
    return _run_while(*_cg_parts(Q, q, btb, m, mask, psi, cfg, y0))


@partial(jax.jit, static_argnames=("cfg",))
def solve_pcg(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None) -> SolveResult:
    return _run_while(*_pcg_parts(Q, q, btb, m, mask, psi, cfg, y0))


@partial(jax.jit, static_argnames=("cfg",))
def solve_bpcg(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None) -> SolveResult:
    return _run_while(*_bpcg_parts(Q, q, btb, m, mask, psi, cfg, y0))


def _make_scheduled(name: str):
    @partial(jax.jit, static_argnames=("cfg", "schedule"))
    def solve_scheduled_one(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None,
                            schedule: Optional[int] = None) -> SolveResult:
        if schedule is None:
            schedule = schedule_budget(cfg)
        parts = _PARTS[name](Q, q, btb, m, mask, psi, cfg, y0)
        return _run_scheduled(*parts, schedule)

    solve_scheduled_one.__name__ = f"solve_{name}_scheduled"
    return solve_scheduled_one


solve_cg_scheduled = _make_scheduled("cg")
solve_pcg_scheduled = _make_scheduled("pcg")
solve_bpcg_scheduled = _make_scheduled("bpcg")


SOLVERS = {
    "agd": solve_agd,
    "cg": solve_cg,
    "pcg": solve_pcg,
    "bpcg": solve_bpcg,
}

SCHEDULED_SOLVERS = {
    "agd": solve_agd_scheduled,
    "cg": solve_cg_scheduled,
    "pcg": solve_pcg_scheduled,
    "bpcg": solve_bpcg_scheduled,
}


def solve(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None) -> SolveResult:
    return SOLVERS[cfg.name](Q, q, btb, m, mask, psi, cfg, y0)


def solve_scheduled(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None,
                    schedule: Optional[int] = None) -> SolveResult:
    return SCHEDULED_SOLVERS[cfg.name](Q, q, btb, m, mask, psi, cfg, y0,
                                       schedule=schedule)
