"""Convex optimization oracles for OAVI (Line 7 / (CCOP)).

All solvers minimize the quadratic

    f(y) = (y^T Q y + 2 q^T y + btb) / m,      Q = A^T A,  q = A^T b,

either unconstrained (AGD) or over the l1-ball of radius ``r = tau - 1``
(CG / PCG / BPCG), exactly as in Sections 3.3 and 4.3 of the paper.  Working
in Gram form makes the per-iteration cost O(l^2) instead of O(m l); the
O(m l) part (computing Q, q incrementally) is done once per candidate term in
:mod:`repro.core.oavi` ("In BPCG, we first compute A^T A and A^T b").

Everything is fixed-capacity (padded to ``L`` columns with a boolean mask) so
the solvers jit once and are reused across OAVI's whole execution.

Early-termination rules follow Section 6.1 of the paper:
  * accuracy ``eps = eps_frac * psi`` (via the FW gap for CG variants, via the
    gradient norm for AGD),
  * stop when a vanishing coefficient vector has been constructed
    (``f <= psi``),
  * stop when no vanishing vector can exist (``f - gap > psi`` certifies
    ``f* > psi`` for CG variants),
  * hard iteration cap.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -jnp.inf


@dataclasses.dataclass(frozen=True)
class OracleConfig:
    name: str = "bpcg"  # 'agd' | 'cg' | 'pcg' | 'bpcg'
    tau: float = 1000.0  # l1 radius is tau - 1 (CCOP); ignored by AGD
    max_iter: int = 10_000
    eps_frac: float = 0.01  # solver accuracy = eps_frac * psi
    # AGD: number of power iterations used to estimate the smoothness constant
    power_iters: int = 30


class SolveResult(NamedTuple):
    y: jax.Array  # (L,) solution (padded with zeros outside the mask)
    f: jax.Array  # objective value (MSE of the candidate polynomial)
    gap: jax.Array  # FW gap (CG variants) or squared grad norm (AGD)
    iters: jax.Array  # iterations used


def quad_f(Q, q, btb, inv_m, y):
    return (y @ (Q @ y) + 2.0 * (q @ y) + btb) * inv_m


def quad_grad(Q, q, inv_m, y):
    return 2.0 * inv_m * (Q @ y + q)


def _line_search_quad(Q, inv_m, grad, d, gamma_max):
    """Exact line search for the quadratic along ``d``; clipped to
    ``[0, gamma_max]``.  f(y + g d) - f(y) = g <grad, d> + g^2 d^T Q d / m."""
    dQd = (d @ (Q @ d)) * inv_m
    num = -(grad @ d)
    gamma = jnp.where(dQd > 0, num / jnp.maximum(2.0 * dQd, 1e-30), gamma_max)
    return jnp.clip(gamma, 0.0, gamma_max)


# --------------------------------------------------------------------------
# AGD (Nesterov) — unconstrained
# --------------------------------------------------------------------------


def _estimate_lmax(Q, mask, iters: int):
    """Power iteration on the masked Gram matrix."""
    L = Q.shape[0]
    v0 = jnp.where(mask, 1.0, 0.0).astype(Q.dtype)
    v0 = v0 / jnp.maximum(jnp.linalg.norm(v0), 1e-30)

    def body(_, v):
        w = Q @ v
        nrm = jnp.linalg.norm(w)
        return jnp.where(nrm > 0, w / jnp.maximum(nrm, 1e-30), v)

    v = jax.lax.fori_loop(0, iters, body, v0)
    return jnp.maximum(v @ (Q @ v), 1e-30)


@partial(jax.jit, static_argnames=("cfg",))
def solve_agd(
    Q: jax.Array,
    q: jax.Array,
    btb: jax.Array,
    m: jax.Array,
    mask: jax.Array,
    psi: jax.Array,
    cfg: OracleConfig,
    y0: Optional[jax.Array] = None,
) -> SolveResult:
    dtype = Q.dtype
    Lcap = Q.shape[0]
    inv_m = (1.0 / m).astype(dtype)
    maskf = mask.astype(dtype)
    if y0 is None:
        y0 = jnp.zeros((Lcap,), dtype)
    y0 = y0 * maskf
    lmax = _estimate_lmax(Q, mask, cfg.power_iters)
    step = 1.0 / (2.0 * lmax * inv_m)  # 1/L_smooth with L = 2 lmax / m
    eps = cfg.eps_frac * psi

    def cond(state):
        y, z, t, k, gnorm2 = state
        return jnp.logical_and(k < cfg.max_iter, gnorm2 > eps * eps)

    def body(state):
        y, z, t, k, _ = state
        g = quad_grad(Q, q, inv_m, z) * maskf
        y_new = z - step * g
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = y_new + ((t - 1.0) / t_new) * (y_new - y)
        gnorm2 = g @ g
        return (y_new, z_new * maskf, t_new, k + 1, gnorm2)

    g0 = quad_grad(Q, q, inv_m, y0) * maskf
    state = (y0, y0, jnp.asarray(1.0, dtype), jnp.asarray(0, jnp.int32), g0 @ g0)
    y, _, _, k, gnorm2 = jax.lax.while_loop(cond, body, state)
    f = quad_f(Q, q, btb, inv_m, y)
    return SolveResult(y=y, f=f, gap=gnorm2, iters=k)


# --------------------------------------------------------------------------
# Frank-Wolfe variants on the l1-ball of radius r = tau - 1
# --------------------------------------------------------------------------


def _fw_vertex(grad, mask, r):
    """Global LMO over the l1 ball: vertex -r*sign(grad_i*) e_{i*}."""
    score = jnp.where(mask, jnp.abs(grad), NEG_INF)
    i = jnp.argmax(score)
    s = -jnp.sign(grad[i])
    s = jnp.where(s == 0, 1.0, s)
    return i, s * r  # index, signed coordinate value


def _weights_to_point(wp, wm, r):
    return r * (wp - wm)


def _decompose_point(y, r, mask):
    """Represent y (||y||_1 <= r) as convex weights on vertices +/- r e_i.

    Leftover mass (1 - ||y||_1 / r) is split evenly between +r e_0 and -r e_0
    so it contributes 0 to the reconstructed point.
    """
    maskf = mask.astype(y.dtype)
    wp = jnp.maximum(y, 0.0) / r * maskf
    wm = jnp.maximum(-y, 0.0) / r * maskf
    leftover = jnp.maximum(1.0 - jnp.sum(wp + wm), 0.0)
    wp = wp.at[0].add(0.5 * leftover)
    wm = wm.at[0].add(0.5 * leftover)
    return wp, wm


class _FWState(NamedTuple):
    y: jax.Array
    wp: jax.Array  # weights on +r e_i
    wm: jax.Array  # weights on -r e_i
    f: jax.Array
    gap: jax.Array
    k: jax.Array


def _fw_cond(cfg, psi, state: _FWState):
    eps = cfg.eps_frac * psi
    not_converged = state.gap > eps
    not_vanishing = state.f > psi  # generator already found -> stop
    feasible_possible = (state.f - state.gap) <= psi  # lower bound on f*
    return jnp.logical_and(
        state.k < cfg.max_iter,
        jnp.logical_and(not_converged, jnp.logical_and(not_vanishing, feasible_possible)),
    )


@partial(jax.jit, static_argnames=("cfg",))
def solve_cg(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None) -> SolveResult:
    """Vanilla Frank-Wolfe (CG) with exact line search."""
    dtype = Q.dtype
    Lcap = Q.shape[0]
    inv_m = (1.0 / m).astype(dtype)
    r = jnp.asarray(cfg.tau - 1.0, dtype)
    maskf = mask.astype(dtype)
    if y0 is None:
        y0 = jnp.zeros((Lcap,), dtype)
    y0 = y0 * maskf

    def body(state: _FWState) -> _FWState:
        y = state.y
        grad = quad_grad(Q, q, inv_m, y) * maskf
        i, val = _fw_vertex(grad, mask, r)
        w = jnp.zeros_like(y).at[i].set(val)
        d = w - y
        gap = -(grad @ d)
        gamma = _line_search_quad(Q, inv_m, grad, d, jnp.asarray(1.0, dtype))
        y_new = y + gamma * d
        f = quad_f(Q, q, btb, inv_m, y_new)
        return _FWState(y_new, state.wp, state.wm, f, gap, state.k + 1)

    f0 = quad_f(Q, q, btb, inv_m, y0)
    zero = jnp.zeros((Lcap,), dtype)
    state = _FWState(y0, zero, zero, f0, jnp.asarray(jnp.inf, dtype), jnp.asarray(0, jnp.int32))
    state = jax.lax.while_loop(partial(_fw_cond, cfg, psi), body, state)
    return SolveResult(y=state.y, f=state.f, gap=state.gap, iters=state.k)


def _active_extrema(grad, wp, wm, r):
    """Away vertex (argmax <grad, v>) and local FW vertex (argmin) over the
    active set.  Vertex +r e_i has score r*grad_i, -r e_i has -r*grad_i."""
    sp = r * grad
    sm = -r * grad
    away_p = jnp.where(wp > 0, sp, NEG_INF)
    away_m = jnp.where(wm > 0, sm, NEG_INF)
    ia_p, ia_m = jnp.argmax(away_p), jnp.argmax(away_m)
    away_is_p = away_p[ia_p] >= away_m[ia_m]
    loc_p = jnp.where(wp > 0, sp, -NEG_INF)
    loc_m = jnp.where(wm > 0, sm, -NEG_INF)
    il_p, il_m = jnp.argmin(loc_p), jnp.argmin(loc_m)
    local_is_p = loc_p[il_p] <= loc_m[il_m]
    return (away_is_p, ia_p, ia_m), (local_is_p, il_p, il_m)


def _signed_unit(i, sign_plus, r, Lcap, dtype):
    v = jnp.zeros((Lcap,), dtype)
    return v.at[i].set(jnp.where(sign_plus, r, -r))


@partial(jax.jit, static_argnames=("cfg",))
def solve_pcg(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None) -> SolveResult:
    """Pairwise Conditional Gradients (Lacoste-Julien & Jaggi 2015)."""
    dtype = Q.dtype
    Lcap = Q.shape[0]
    inv_m = (1.0 / m).astype(dtype)
    r = jnp.asarray(cfg.tau - 1.0, dtype)
    maskf = mask.astype(dtype)
    if y0 is None:
        y0 = jnp.zeros((Lcap,), dtype)
    y0 = y0 * maskf
    wp0, wm0 = _decompose_point(y0, r, mask)

    def body(state: _FWState) -> _FWState:
        y, wp, wm = state.y, state.wp, state.wm
        grad = quad_grad(Q, q, inv_m, y) * maskf
        # global FW vertex
        iw, val = _fw_vertex(grad, mask, r)
        w_plus = val > 0
        w_vec = _signed_unit(iw, w_plus, r, Lcap, dtype)
        # away vertex over active set
        (a_is_p, ia_p, ia_m), _ = _active_extrema(grad, wp, wm, r)
        ia = jnp.where(a_is_p, ia_p, ia_m)
        a_vec = _signed_unit(ia, a_is_p, r, Lcap, dtype)
        a_weight = jnp.where(a_is_p, wp[ia], wm[ia])
        d = w_vec - a_vec
        gap = -(grad @ (w_vec - y))  # FW gap for stopping
        gamma = _line_search_quad(Q, inv_m, grad, d, a_weight)
        # move weight gamma from away to FW vertex
        wp = jnp.where(a_is_p, wp.at[ia].add(-gamma), wp)
        wm = jnp.where(a_is_p, wm, wm.at[ia].add(-gamma))
        wp = jnp.where(w_plus, wp.at[iw].add(gamma), wp)
        wm = jnp.where(w_plus, wm, wm.at[iw].add(gamma))
        wp = jnp.maximum(wp, 0.0)
        wm = jnp.maximum(wm, 0.0)
        y_new = _weights_to_point(wp, wm, r)
        f = quad_f(Q, q, btb, inv_m, y_new)
        return _FWState(y_new, wp, wm, f, gap, state.k + 1)

    f0 = quad_f(Q, q, btb, inv_m, y0)
    state = _FWState(y0, wp0, wm0, f0, jnp.asarray(jnp.inf, dtype), jnp.asarray(0, jnp.int32))
    state = jax.lax.while_loop(partial(_fw_cond, cfg, psi), body, state)
    return SolveResult(y=state.y, f=state.f, gap=state.gap, iters=state.k)


@partial(jax.jit, static_argnames=("cfg",))
def solve_bpcg(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None) -> SolveResult:
    """Blended Pairwise Conditional Gradients (Tsuji et al. 2021, Alg. 3)."""
    dtype = Q.dtype
    Lcap = Q.shape[0]
    inv_m = (1.0 / m).astype(dtype)
    r = jnp.asarray(cfg.tau - 1.0, dtype)
    maskf = mask.astype(dtype)
    if y0 is None:
        y0 = jnp.zeros((Lcap,), dtype)
    y0 = y0 * maskf
    wp0, wm0 = _decompose_point(y0, r, mask)

    def body(state: _FWState) -> _FWState:
        y, wp, wm = state.y, state.wp, state.wm
        grad = quad_grad(Q, q, inv_m, y) * maskf
        iw, val = _fw_vertex(grad, mask, r)
        w_plus = val > 0
        w_vec = _signed_unit(iw, w_plus, r, Lcap, dtype)
        (a_is_p, ia_p, ia_m), (s_is_p, is_p, is_m) = _active_extrema(grad, wp, wm, r)
        ia = jnp.where(a_is_p, ia_p, ia_m)
        a_vec = _signed_unit(ia, a_is_p, r, Lcap, dtype)
        a_weight = jnp.where(a_is_p, wp[ia], wm[ia])
        is_ = jnp.where(s_is_p, is_p, is_m)
        s_vec = _signed_unit(is_, s_is_p, r, Lcap, dtype)
        gap = -(grad @ (w_vec - y))
        # Line 7: local pairwise step iff <grad, w - y> >= <grad, s - a>
        local = (grad @ (w_vec - y)) >= (grad @ (s_vec - a_vec))

        def local_step():
            d = s_vec - a_vec
            gamma = _line_search_quad(Q, inv_m, grad, d, a_weight)
            wp1 = jnp.where(a_is_p, wp.at[ia].add(-gamma), wp)
            wm1 = jnp.where(a_is_p, wm, wm.at[ia].add(-gamma))
            wp1 = jnp.where(s_is_p, wp1.at[is_].add(gamma), wp1)
            wm1 = jnp.where(s_is_p, wm1, wm1.at[is_].add(gamma))
            return y + gamma * d, wp1, wm1

        def global_step():
            d = w_vec - y
            gamma = _line_search_quad(Q, inv_m, grad, d, jnp.asarray(1.0, dtype))
            wp1 = wp * (1.0 - gamma)
            wm1 = wm * (1.0 - gamma)
            wp1 = jnp.where(w_plus, wp1.at[iw].add(gamma), wp1)
            wm1 = jnp.where(w_plus, wm1, wm1.at[iw].add(gamma))
            return y + gamma * d, wp1, wm1

        y_new, wp_new, wm_new = jax.lax.cond(local, local_step, global_step)
        wp_new = jnp.maximum(wp_new, 0.0)
        wm_new = jnp.maximum(wm_new, 0.0)
        f = quad_f(Q, q, btb, inv_m, y_new)
        return _FWState(y_new, wp_new, wm_new, f, gap, state.k + 1)

    f0 = quad_f(Q, q, btb, inv_m, y0)
    state = _FWState(y0, wp0, wm0, f0, jnp.asarray(jnp.inf, dtype), jnp.asarray(0, jnp.int32))
    state = jax.lax.while_loop(partial(_fw_cond, cfg, psi), body, state)
    return SolveResult(y=state.y, f=state.f, gap=state.gap, iters=state.k)


SOLVERS = {
    "agd": solve_agd,
    "cg": solve_cg,
    "pcg": solve_pcg,
    "bpcg": solve_bpcg,
}


def solve(Q, q, btb, m, mask, psi, cfg: OracleConfig, y0=None) -> SolveResult:
    return SOLVERS[cfg.name](Q, q, btb, m, mask, psi, cfg, y0)
