"""Data-driven term ordering via Pearson correlation (Section 5, Algorithm 5).

Features are sorted *increasingly* by their total absolute Pearson correlation
with all features, making monomial-aware algorithms (OAVI, ABM) invariant to
the initial feature permutation of the data set.
"""

from __future__ import annotations

import numpy as np


def pearson_correlation_matrix(X: np.ndarray) -> np.ndarray:
    """|r_{ij}| for all feature pairs; constant features get r = 0 (off-diag)."""
    X = np.asarray(X, dtype=np.float64)
    Xc = X - X.mean(axis=0, keepdims=True)
    std = np.sqrt((Xc * Xc).sum(axis=0))
    denom = np.outer(std, std)
    cov = Xc.T @ Xc
    with np.errstate(divide="ignore", invalid="ignore"):
        r = np.where(denom > 0, cov / np.maximum(denom, 1e-300), 0.0)
    np.fill_diagonal(r, 1.0)
    return np.abs(r)


def pearson_scores(X: np.ndarray) -> np.ndarray:
    """p_i = sum_j |r_{c_i c_j}| (Line 2 of Algorithm 5)."""
    return pearson_correlation_matrix(X).sum(axis=1)


def pearson_order(X: np.ndarray, reverse: bool = False) -> np.ndarray:
    """Permutation sorting features increasingly by p_i (decreasingly if
    ``reverse``).  Ties are broken by original index (stable), which the paper
    notes happens with probability 0 on noisy data."""
    p = pearson_scores(X)
    order = np.argsort(-p if reverse else p, kind="stable")
    return order.astype(np.int64)


def pearson_scores_from_moments(s1: np.ndarray, s2: np.ndarray, m: int) -> np.ndarray:
    """``p_i`` from streamed float64 sufficient statistics ``s1 = sum_r x_r``
    and ``s2 = sum_r x_r x_r^T`` — the out-of-core counterpart of
    :func:`pearson_scores`.  The centered covariance ``s2 - s1 s1^T / m``
    agrees with the two-pass in-memory formula up to float64 summation-order
    drift, which can only flip the resulting ordering on (measure-zero)
    near-exact score ties."""
    s1 = np.asarray(s1, np.float64)
    cov = np.asarray(s2, np.float64) - np.outer(s1, s1) / float(m)
    std = np.sqrt(np.maximum(np.diag(cov), 0.0))
    denom = np.outer(std, std)
    with np.errstate(divide="ignore", invalid="ignore"):
        r = np.where(denom > 0, cov / np.maximum(denom, 1e-300), 0.0)
    np.fill_diagonal(r, 1.0)
    return np.abs(r).sum(axis=1)


def pearson_order_from_moments(
    s1: np.ndarray, s2: np.ndarray, m: int, reverse: bool = False
) -> np.ndarray:
    """Streaming-moments variant of :func:`pearson_order`."""
    p = pearson_scores_from_moments(s1, s2, m)
    order = np.argsort(-p if reverse else p, kind="stable")
    return order.astype(np.int64)
