"""Data-driven term ordering via Pearson correlation (Section 5, Algorithm 5).

Features are sorted *increasingly* by their total absolute Pearson correlation
with all features, making monomial-aware algorithms (OAVI, ABM) invariant to
the initial feature permutation of the data set.
"""

from __future__ import annotations

import numpy as np


def pearson_correlation_matrix(X: np.ndarray) -> np.ndarray:
    """|r_{ij}| for all feature pairs; constant features get r = 0 (off-diag)."""
    X = np.asarray(X, dtype=np.float64)
    Xc = X - X.mean(axis=0, keepdims=True)
    std = np.sqrt((Xc * Xc).sum(axis=0))
    denom = np.outer(std, std)
    cov = Xc.T @ Xc
    with np.errstate(divide="ignore", invalid="ignore"):
        r = np.where(denom > 0, cov / np.maximum(denom, 1e-300), 0.0)
    np.fill_diagonal(r, 1.0)
    return np.abs(r)


def pearson_scores(X: np.ndarray) -> np.ndarray:
    """p_i = sum_j |r_{c_i c_j}| (Line 2 of Algorithm 5)."""
    return pearson_correlation_matrix(X).sum(axis=1)


def pearson_order(X: np.ndarray, reverse: bool = False) -> np.ndarray:
    """Permutation sorting features increasingly by p_i (decreasingly if
    ``reverse``).  Ties are broken by original index (stable), which the paper
    notes happens with probability 0 on noisy data."""
    p = pearson_scores(X)
    order = np.argsort(-p if reverse else p, kind="stable")
    return order.astype(np.int64)
