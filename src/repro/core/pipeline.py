"""Algorithm 2: per-class generator construction -> (FT) -> linear SVM.

The paper's end-to-end classification pipeline.  ``method`` is a
:mod:`repro.api` spec string (``"oavi:cgavi-ihb"``, ``"abm"``, ``"vca"``,
...; bare OAVI variant names like ``"fast"`` keep working).  Generator
construction is dispatched through :func:`repro.api.fit` (which picks the
local or sharded backend), the feature transform runs through the fused
:func:`repro.api.feature_transform`, and the features are classified by the
l1 squared-hinge :class:`~repro.core.svm.LinearSVM`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np

from .svm import LinearSVM, LinearSVMConfig
from .transform import MinMaxScaler


def __getattr__(name: str):
    # Deprecated alias: the canonical variant table lives in repro.api.
    if name == "VARIANTS":
        from .. import api

        return api.OAVI_VARIANTS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def oavi_config_for(variant: str, psi: float, **kw):
    """Deprecated alias for :func:`repro.api.oavi_config_for`."""
    from .. import api

    return api.oavi_config_for(variant, psi, **kw)


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    method: str = "fast"  # repro.api method spec (or bare OAVI variant name)
    psi: float = 0.005
    svm: LinearSVMConfig = dataclasses.field(default_factory=LinearSVMConfig)
    oavi_kw: Optional[Dict] = None  # forwarded to the method config
    backend: str = "auto"  # repro.api backend: 'auto' | 'local' | 'sharded'
    mesh: Optional[Any] = None  # jax Mesh for the sharded backend
    batch_size: Optional[int] = None  # fused-transform chunking (rows)


class VanishingIdealClassifier:
    """Fit per-class generators, transform, train a linear SVM (Algorithm 2)."""

    def __init__(self, config: PipelineConfig = PipelineConfig()):
        self.config = config
        # thread the model dtype through the scaler so float32 models are not
        # silently fed float64 inputs
        self.dtype = (config.oavi_kw or {}).get("dtype", "float32")
        self.scaler = MinMaxScaler(dtype=self.dtype)
        self.models: List = []
        self.svm = LinearSVM(config.svm)
        self.classes_: Optional[np.ndarray] = None
        self.stats: Dict = {}

    def _fit_generator_model(self, Xc: np.ndarray):
        from .. import api

        cfg = self.config
        return api.fit(
            Xc,
            method=cfg.method,
            psi=cfg.psi,
            backend=cfg.backend,
            mesh=cfg.mesh,
            **dict(cfg.oavi_kw or {}),
        )

    def _feature_transform(self, X) -> np.ndarray:
        from .. import api

        return np.asarray(
            api.feature_transform(
                self.models, X, batch_size=self.config.batch_size, dtype=self.dtype
            )
        )

    def fit(self, X, y) -> "VanishingIdealClassifier":
        t0 = time.perf_counter()
        X = self.scaler.fit_transform(X)
        y = np.asarray(y)
        self.classes_ = np.unique(y)
        self.models = []
        gen_stats = []
        for c in self.classes_:
            model = self._fit_generator_model(X[y == c])
            self.models.append(model)
            gen_stats.append(model.stats)
        t_gen = time.perf_counter() - t0
        Xt = self._feature_transform(X)
        self.svm.fit(Xt, y)
        self.stats = {
            "time_generators": t_gen,
            "time_total": time.perf_counter() - t0,
            "num_features": Xt.shape[1],
            "G_plus_O": sum(s.get("G_plus_O", 0) for s in gen_stats),
            "per_class": gen_stats,
            "svm": self.svm.stats,
        }
        return self

    def transform(self, X) -> np.ndarray:
        return self._feature_transform(self.scaler.transform(X))

    def predict(self, X) -> np.ndarray:
        return self.svm.predict(self.transform(X))

    def score(self, X, y) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y)))

    # -- reporting helpers (Table 3 quantities) ---------------------------

    def average_degree(self) -> float:
        degs = []
        for model in self.models:
            gens = getattr(model, "generators", None)
            if gens is not None:
                degs += [sum(g.term) for g in gens]
        return float(np.mean(degs)) if degs else 0.0

    def sparsity(self) -> float:
        """(SPAR): fraction of zero non-leading coefficients over all G."""
        z = e = 0
        for model in self.models:
            gens = getattr(model, "generators", None)
            if gens is None:
                continue
            for g in gens:
                e += len(g.coeffs)
                z += int(np.sum(g.coeffs == 0.0))
        return z / e if e else 0.0
