"""Algorithm 2: per-class generator construction -> (FT) -> linear SVM.

The paper's end-to-end classification pipeline.  ``method`` selects the
generator constructor: OAVI variants (CGAVI-IHB, AGDAVI-IHB, BPCGAVI,
BPCGAVI-WIHB, PCGAVI, fast), ABM, or VCA.  The feature-transformed data is
classified by the l1 squared-hinge :class:`~repro.core.svm.LinearSVM`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from . import abm as abm_mod
from . import oavi as oavi_mod
from . import vca as vca_mod
from .oracles import OracleConfig
from .svm import LinearSVM, LinearSVMConfig
from .transform import MinMaxScaler, feature_transform

# Named algorithm variants from the paper (Section 6.1).
VARIANTS = {
    # name: (engine, solver, ihb, wihb)
    "cgavi-ihb": ("oracle", "cg", True, False),
    "agdavi-ihb": ("oracle", "agd", True, False),
    "bpcgavi": ("oracle", "bpcg", False, False),
    "bpcgavi-wihb": ("oracle", "bpcg", True, True),
    "pcgavi": ("oracle", "pcg", False, False),
    "cgavi": ("oracle", "cg", False, False),
    "agdavi": ("oracle", "agd", False, False),
    "fast": ("fast", "bpcg", True, False),  # beyond-paper closed-form engine
}


def oavi_config_for(variant: str, psi: float, **kw) -> oavi_mod.OAVIConfig:
    engine, solver, ihb, wihb = VARIANTS[variant]
    solver_cfg = OracleConfig(name=solver, **kw.pop("solver_kw", {}))
    return oavi_mod.OAVIConfig(
        psi=psi, engine=engine, solver=solver_cfg, ihb=ihb, wihb=wihb, **kw
    )


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    method: str = "fast"  # VARIANTS key | 'abm' | 'vca'
    psi: float = 0.005
    svm: LinearSVMConfig = dataclasses.field(default_factory=LinearSVMConfig)
    oavi_kw: Optional[Dict] = None


class VanishingIdealClassifier:
    """Fit per-class generators, transform, train a linear SVM (Algorithm 2)."""

    def __init__(self, config: PipelineConfig = PipelineConfig()):
        self.config = config
        self.scaler = MinMaxScaler()
        self.models: List = []
        self.svm = LinearSVM(config.svm)
        self.classes_: Optional[np.ndarray] = None
        self.stats: Dict = {}

    def _fit_generator_model(self, Xc: np.ndarray):
        cfg = self.config
        kw = dict(cfg.oavi_kw or {})
        if cfg.method == "abm":
            return abm_mod.fit(Xc, abm_mod.ABMConfig(psi=cfg.psi, **kw))
        if cfg.method == "vca":
            return vca_mod.fit(Xc, vca_mod.VCAConfig(psi=cfg.psi, **kw))
        return oavi_mod.fit(Xc, oavi_config_for(cfg.method, cfg.psi, **kw))

    def fit(self, X, y) -> "VanishingIdealClassifier":
        t0 = time.perf_counter()
        X = self.scaler.fit_transform(X)
        y = np.asarray(y)
        self.classes_ = np.unique(y)
        self.models = []
        gen_stats = []
        for c in self.classes_:
            model = self._fit_generator_model(X[y == c])
            self.models.append(model)
            gen_stats.append(model.stats)
        t_gen = time.perf_counter() - t0
        Xt = feature_transform(self.models, X)
        self.svm.fit(Xt, y)
        self.stats = {
            "time_generators": t_gen,
            "time_total": time.perf_counter() - t0,
            "num_features": Xt.shape[1],
            "G_plus_O": sum(s.get("G_plus_O", 0) for s in gen_stats),
            "per_class": gen_stats,
            "svm": self.svm.stats,
        }
        return self

    def transform(self, X) -> np.ndarray:
        return feature_transform(self.models, self.scaler.transform(X))

    def predict(self, X) -> np.ndarray:
        return self.svm.predict(self.transform(X))

    def score(self, X, y) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y)))

    # -- reporting helpers (Table 3 quantities) ---------------------------

    def average_degree(self) -> float:
        degs = []
        for model in self.models:
            gens = getattr(model, "generators", None)
            if gens is not None:
                degs += [sum(g.term) for g in gens]
        return float(np.mean(degs)) if degs else 0.0

    def sparsity(self) -> float:
        """(SPAR): fraction of zero non-leading coefficients over all G."""
        z = e = 0
        for model in self.models:
            gens = getattr(model, "generators", None)
            if gens is None:
                continue
            for g in gens:
                e += len(g.coeffs)
                z += int(np.sum(g.coeffs == 0.0))
        return z / e if e else 0.0
