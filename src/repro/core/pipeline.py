"""Algorithm 2: per-class generator construction -> (FT) -> linear SVM.

The paper's end-to-end classification pipeline.  ``method`` is a
:mod:`repro.api` spec string (``"oavi:cgavi-ihb"``, ``"abm"``, ``"vca"``,
...; bare OAVI variant names like ``"fast"`` keep working).  Generator
construction is dispatched through :func:`repro.api.fit_classes` — with
``class_batch="auto"`` (default) eligible per-class OAVI fits are grouped
into shared pow2 row buckets and driven through ONE vmapped jitted degree
step (:mod:`repro.core.class_batch`; bit-exact vs sequential at matched
capacity) — oracle/WIHB configs run their masked fixed-schedule solvers
under the vmap, stragglers fold into their cheapest warm bucket, and only
the Cholesky engine falls back to sequential fits — the feature transform
runs through the fused
:func:`repro.api.feature_transform`, and the features are classified by the
l1 squared-hinge :class:`~repro.core.svm.LinearSVM`.

A fitted pipeline serializes whole (scaler + per-class models + SVM head)
through the checkpoint manifest machinery (``save`` / ``load``), and
``attach_engine`` routes ``transform`` / ``predict`` through the serving
:class:`~repro.serving.engine.TransformEngine` (shape-bucketed, optionally
sharded; per-model fallback kept for VCA).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .svm import LinearSVM, LinearSVMConfig
from .transform import MinMaxScaler

CLASSIFIER_FORMAT = "repro.vanishing_ideal_classifier.v1"


def __getattr__(name: str):
    # Deprecated alias: the canonical variant table lives in repro.api.
    if name == "VARIANTS":
        from .. import api

        return api.OAVI_VARIANTS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def oavi_config_for(variant: str, psi: float, **kw):
    """Deprecated alias for :func:`repro.api.oavi_config_for`."""
    from .. import api

    return api.oavi_config_for(variant, psi, **kw)


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    method: str = "fast"  # repro.api method spec (or bare OAVI variant name)
    psi: float = 0.005
    svm: LinearSVMConfig = dataclasses.field(default_factory=LinearSVMConfig)
    oavi_kw: Optional[Dict] = None  # forwarded to the method config
    backend: str = "auto"  # repro.api backend: 'auto' | 'local' | 'sharded'
    mesh: Optional[Any] = None  # jax Mesh for the sharded backend
    batch_size: Optional[int] = None  # fused-transform chunking (rows)
    # 'auto': batch eligible per-class OAVI fits through one vmapped degree
    # step, grouped into shared pow2 row buckets with stragglers folded into
    # their cheapest warm bucket (repro.core.class_batch.plan_class_groups);
    # oracle/WIHB configs use the masked fixed-schedule solvers, only the
    # chol engine falls back to sequential.  'off': always sequential.
    class_batch: str = "auto"
    # out-of-core generator construction: when set, each per-class OAVI fit
    # streams through repro.streaming.fit in chunk_rows-row chunks instead of
    # materializing its evaluation matrix (bit-exact at matched capacity;
    # takes precedence over class_batch).  None: in-memory fits.
    chunk_rows: Optional[int] = None
    # incremental fitting: capture each per-class fit's persisted Gram state
    # (repro.online.FitState, stored on clf.fit_states in class order) so the
    # per-class models can later be refreshed with repro.api.update when data
    # arrives.  Requires chunk_rows (the streaming fit path) and an OAVI
    # method; forces sequential per-class fits (states are per-class).
    capture_fit_state: bool = False


class VanishingIdealClassifier:
    """Fit per-class generators, transform, train a linear SVM (Algorithm 2)."""

    def __init__(self, config: PipelineConfig = PipelineConfig()):
        self.config = config
        # thread the model dtype through the scaler so float32 models are not
        # silently fed float64 inputs
        self.dtype = (config.oavi_kw or {}).get("dtype", "float32")
        self.scaler = MinMaxScaler(dtype=self.dtype)
        self.models: List = []
        self.svm = LinearSVM(config.svm)
        self.classes_: Optional[np.ndarray] = None
        self.stats: Dict = {}
        self.engine = None  # optional serving TransformEngine (attach_engine)
        self.fit_states: List = []  # per-class FitState (capture_fit_state)

    def _fit_generator_models(self, Xcs) -> List:
        """Per-class generator construction through :func:`repro.api.fit_classes`
        (class-batched when the config is eligible, sequential otherwise)."""
        from .. import api

        cfg = self.config
        self.fit_states = []
        if cfg.capture_fit_state:
            if cfg.chunk_rows is None:
                raise ValueError(
                    "capture_fit_state=True requires chunk_rows (the "
                    "streaming fit path persists the Gram accumulators)"
                )
            models = []
            for Xc in Xcs:
                model = api.fit(
                    Xc,
                    method=cfg.method,
                    psi=cfg.psi,
                    backend=cfg.backend,
                    mesh=cfg.mesh,
                    chunk_rows=cfg.chunk_rows,
                    capture_state=True,
                    **dict(cfg.oavi_kw or {}),
                )
                models.append(model)
                self.fit_states.append(model.fit_state)
            return models
        return api.fit_classes(
            Xcs,
            method=cfg.method,
            psi=cfg.psi,
            backend=cfg.backend,
            mesh=cfg.mesh,
            class_batch=cfg.class_batch,
            chunk_rows=cfg.chunk_rows,
            **dict(cfg.oavi_kw or {}),
        )

    def _feature_transform(self, X) -> np.ndarray:
        from .. import api

        engine = self.engine
        if engine is not None and not engine.matches(self.models):
            engine = None  # models were refitted since attach_engine
        return np.asarray(
            api.feature_transform(
                self.models,
                X,
                batch_size=self.config.batch_size,
                dtype=self.dtype,
                engine=engine,
            )
        )

    def attach_engine(
        self,
        engine=None,
        *,
        mesh=None,
        data_axes=("data",),
        engine_config=None,
        warmup: bool = True,
    ):
        """Route ``transform`` / ``predict`` through a serving
        :class:`~repro.serving.engine.TransformEngine` (shape-bucketed, zero
        recompiles at varying query sizes, optionally ``shard_map``-sharded
        over ``mesh``).

        Builds one over ``self.models`` when ``engine`` is omitted.  Model
        sets without a fused term-book plan (VCA) keep the per-model
        fallback: the engine stays ``None`` and ``None`` is returned.
        """
        from ..serving.engine import EngineConfig, TransformEngine, UnsupportedModelError

        if engine is None:
            try:
                engine = TransformEngine(
                    self.models,
                    mesh=mesh,
                    data_axes=data_axes,
                    config=engine_config or EngineConfig(),
                )
            except UnsupportedModelError:
                self.engine = None
                return None
        elif not engine.matches(self.models):
            raise ValueError("engine was built for a different model set")
        if warmup:
            engine.warmup()  # idempotent: already-traced buckets are skipped
        self.engine = engine
        return engine

    def head(self, feats) -> np.ndarray:
        """Classifier head over precomputed (FT) features: SVM argmax.

        The cheap per-request tail of ``predict`` — the serving batcher
        applies it after the coalesced feature transform."""
        return self.svm.predict(np.asarray(feats))

    def fit(self, X, y) -> "VanishingIdealClassifier":
        from .. import api

        t0 = time.perf_counter()
        # an engine attached to a previous fit's models would be silently
        # bypassed by matches() on every call while pinning the old model
        # set and its compiled buckets — drop it; re-attach_engine() after
        self.engine = None
        X = self.scaler.fit_transform(X)
        y = np.asarray(y)
        self.classes_ = np.unique(y)
        self.models = self._fit_generator_models([X[y == c] for c in self.classes_])
        gen_stats = [m.stats for m in self.models]
        t_gen = time.perf_counter() - t0
        t1 = time.perf_counter()
        Xt = self._feature_transform(X)
        t_transform = time.perf_counter() - t1
        t2 = time.perf_counter()
        self.svm.fit(Xt, y)
        t_svm = time.perf_counter() - t2
        # recompiles/regrowths: class-batched groups share one compile
        # schedule — aggregate once per group, not once per class
        agg = api.aggregate_fit_stats(self.models)
        self.stats = {
            "time_generators": t_gen,
            "time_transform": t_transform,
            "time_svm": t_svm,
            "time_total": time.perf_counter() - t0,
            "num_features": Xt.shape[1],
            "G_plus_O": sum(s.get("G_plus_O", 0) for s in gen_stats),
            "recompiles": agg["recompiles"],
            "regrowths": agg["regrowths"],
            "class_batched": agg["class_batched"],
            "solver_schedule_len": agg["solver_schedule_len"],
            "solver_escalations": agg["solver_escalations"],
            "per_class": gen_stats,
            "svm": self.svm.stats,
        }
        if "class_batch_padding" in agg:
            self.stats["class_batch_padding"] = agg["class_batch_padding"]
        return self

    def transform(self, X) -> np.ndarray:
        return self._feature_transform(self.scaler.transform(X))

    def predict(self, X) -> np.ndarray:
        return self.svm.predict(self.transform(X))

    def score(self, X, y) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y)))

    # -- reporting helpers (Table 3 quantities) ---------------------------

    def average_degree(self) -> float:
        degs = []
        for model in self.models:
            gens = getattr(model, "generators", None)
            if gens is not None:
                degs += [sum(g.term) for g in gens]
        return float(np.mean(degs)) if degs else 0.0

    def sparsity(self) -> float:
        """(SPAR): fraction of zero non-leading coefficients over all G."""
        z = e = 0
        for model in self.models:
            gens = getattr(model, "generators", None)
            if gens is None:
                continue
            for g in gens:
                e += len(g.coeffs)
                z += int(np.sum(g.coeffs == 0.0))
        return z / e if e else 0.0

    # -- serialization (serving: registry load / hot-swap) ------------------

    def to_state_dict(self) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Flat array tree + JSON-safe metadata for the WHOLE pipeline:
        scaler, per-class generator models, and the SVM head — everything a
        serving process needs to answer predict requests."""
        from .. import api

        if self.svm.W is None or self.classes_ is None:
            raise ValueError("cannot serialize an unfitted classifier")
        arrays: Dict[str, np.ndarray] = {}
        model_metas = []
        for i, model in enumerate(self.models):
            a, meta = model.to_state_dict()
            if meta.get("kind") not in api._MODEL_KINDS:
                raise ValueError(
                    f"per-class model {i} has unserializable kind {meta.get('kind')!r}"
                )
            for k, v in a.items():
                arrays[f"model_{i:03d}.{k}"] = v
            model_metas.append(meta)
        arrays["scaler_lo"] = np.asarray(self.scaler.lo)
        arrays["scaler_scale"] = np.asarray(self.scaler.scale)
        arrays["svm_W"] = np.asarray(self.svm.W)
        arrays["svm_b"] = np.asarray(self.svm.b)
        arrays["classes"] = np.asarray(self.classes_)
        cfg = self.config
        meta = {
            "kind": "classifier",
            "num_models": len(self.models),
            "models": model_metas,
            "dtype": self.dtype,
            "config": {
                "method": cfg.method,
                "psi": cfg.psi,
                "svm": dataclasses.asdict(cfg.svm),
                "oavi_kw": cfg.oavi_kw,
                "backend": cfg.backend,
                "batch_size": cfg.batch_size,
                "class_batch": cfg.class_batch,
                "chunk_rows": cfg.chunk_rows,
                "capture_fit_state": cfg.capture_fit_state,
            },
            "svm_stats": self.svm.stats,
            "stats": self.stats,
        }
        return arrays, meta

    @classmethod
    def from_state_dict(
        cls, arrays: Dict[str, np.ndarray], meta: Dict
    ) -> "VanishingIdealClassifier":
        from .. import api

        cfg_meta = meta["config"]
        config = PipelineConfig(
            method=cfg_meta["method"],
            psi=cfg_meta["psi"],
            svm=LinearSVMConfig(**cfg_meta["svm"]),
            oavi_kw=cfg_meta["oavi_kw"],
            backend=cfg_meta["backend"],
            batch_size=cfg_meta["batch_size"],
            # pre-class-batch checkpoints lack the key; 'auto' is the default
            class_batch=cfg_meta.get("class_batch", "auto"),
            # pre-streaming checkpoints lack the key; None = in-memory fits
            chunk_rows=cfg_meta.get("chunk_rows"),
            capture_fit_state=cfg_meta.get("capture_fit_state", False),
        )
        clf = cls(config)
        clf.scaler.lo = np.asarray(arrays["scaler_lo"])
        clf.scaler.scale = np.asarray(arrays["scaler_scale"])
        clf.models = []
        for i, model_meta in enumerate(meta["models"]):
            prefix = f"model_{i:03d}."
            sub = {
                k[len(prefix):]: v for k, v in arrays.items() if k.startswith(prefix)
            }
            model_cls = api._MODEL_KINDS[model_meta["kind"]]
            clf.models.append(model_cls.from_state_dict(sub, model_meta))
        clf.svm.W = np.asarray(arrays["svm_W"])
        clf.svm.b = np.asarray(arrays["svm_b"])
        clf.svm.classes_ = np.asarray(arrays["classes"])
        clf.svm.stats = dict(meta.get("svm_stats") or {})
        clf.classes_ = np.asarray(arrays["classes"])
        clf.stats = dict(meta.get("stats") or {})
        return clf

    def save(self, path: str) -> str:
        """Persist the fitted pipeline to ``path`` (a directory) atomically
        via the checkpoint manifest machinery (same layout as
        :func:`repro.api.save`, format :data:`CLASSIFIER_FORMAT`)."""
        from .. import api

        arrays, meta = self.to_state_dict()
        return api.save_state_dict(path, arrays, meta, CLASSIFIER_FORMAT)

    @classmethod
    def load(cls, path: str) -> "VanishingIdealClassifier":
        """Load a pipeline written by :meth:`save` (bit-identical predict)."""
        from .. import api

        arrays, metadata = api.load_state_dict(path, CLASSIFIER_FORMAT)
        return cls.from_state_dict(arrays, metadata["meta"])
