"""Pure-JAX SVMs for the paper's classification pipeline (Algorithm 2).

Two models, built from scratch (no scikit-learn in this container):

* :class:`LinearSVM` — l1-regularized squared-hinge linear SVM, one-vs-rest,
  trained with FISTA (accelerated proximal gradient; the l1 prox is
  soft-thresholding).  This is the paper's downstream classifier for the
  OAVI/ABM/VCA feature transforms ("l1-penalized squared hinge loss",
  Section 6.1).
* :class:`PolySVM` — polynomial-kernel SVM baseline with l2 regularization,
  one-vs-rest, trained in the (kernelized) primal with accelerated gradient
  descent on the dual coefficients.  Exact kernel up to ``max_kernel_samples``
  training points; beyond that a uniform subsample anchors the kernel
  expansion (documented in stats, mirrors the paper's iteration-capped
  LIBSVM behaviour on `skin`).

Both train loops are jitted ``lax.while_loop``s with fixed shapes.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Linear l1 squared-hinge SVM (FISTA)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinearSVMConfig:
    lam: float = 1e-4  # l1 penalty
    max_iter: int = 10_000
    tol: float = 1e-4
    dtype: str = "float32"


def _squared_hinge_grad(W, b, Xb, Y):
    """Mean squared-hinge loss + gradients.  Y in {-1, +1}, shape (m, k)."""
    m = Xb.shape[0]
    scores = Xb @ W + b  # (m, k)
    margin = 1.0 - Y * scores
    active = jnp.maximum(margin, 0.0)
    loss = jnp.mean(jnp.sum(active * active, axis=1))
    g_scores = (-2.0 / m) * (active * Y)  # (m, k)
    gW = Xb.T @ g_scores
    gb = jnp.sum(g_scores, axis=0)
    return loss, gW, gb


def _soft_threshold(x, t):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


@partial(jax.jit, static_argnames=("max_iter",))
def _fista(X, Y, lam, step, max_iter, tol):
    p, k = X.shape[1], Y.shape[1]
    dtype = X.dtype
    W = jnp.zeros((p, k), dtype)
    b = jnp.zeros((k,), dtype)

    def cond(state):
        W, b, Wz, bz, t, i, delta = state
        return jnp.logical_and(i < max_iter, delta > tol)

    def body(state):
        W, b, Wz, bz, t, i, _ = state
        _, gW, gb = _squared_hinge_grad(Wz, bz, X, Y)
        W_new = _soft_threshold(Wz - step * gW, step * lam)
        b_new = bz - step * gb  # bias unpenalized
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        beta = (t - 1.0) / t_new
        Wz_new = W_new + beta * (W_new - W)
        bz_new = b_new + beta * (b_new - b)
        delta = jnp.max(jnp.abs(W_new - W)) + jnp.max(jnp.abs(b_new - b))
        return (W_new, b_new, Wz_new, bz_new, t_new, i + 1, delta)

    one = jnp.asarray(1.0, dtype)
    state = (W, b, W, b, one, jnp.asarray(0, jnp.int32), jnp.asarray(jnp.inf, dtype))
    W, b, *_, i, delta = jax.lax.while_loop(cond, body, state)
    return W, b, i


class LinearSVM:
    """One-vs-rest l1 squared-hinge linear SVM."""

    def __init__(self, config: LinearSVMConfig = LinearSVMConfig()):
        self.config = config
        self.W: Optional[np.ndarray] = None
        self.b: Optional[np.ndarray] = None
        self.classes_: Optional[np.ndarray] = None
        self.stats: Dict = {}

    def fit(self, X, y) -> "LinearSVM":
        dt = jnp.dtype(self.config.dtype)
        X = jnp.asarray(np.asarray(X), dt)
        y = np.asarray(y)
        self.classes_ = np.unique(y)
        Y = np.where(y[:, None] == self.classes_[None, :], 1.0, -1.0)
        Y = jnp.asarray(Y, dt)
        # Lipschitz constant of the squared-hinge gradient: 2/m * lmax(X~^T X~)
        m = X.shape[0]
        Xb = jnp.concatenate([X, jnp.ones((m, 1), dt)], axis=1)
        # power iteration for the top singular value
        v = jnp.ones((Xb.shape[1],), dt)
        for _ in range(20):
            v = Xb.T @ (Xb @ v)
            v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)
        lmax = v @ (Xb.T @ (Xb @ v))
        step = 1.0 / jnp.maximum(2.0 * lmax / m, 1e-12)
        W, b, iters = _fista(
            X, Y, jnp.asarray(self.config.lam, dt), step,
            self.config.max_iter, jnp.asarray(self.config.tol, dt),
        )
        self.W, self.b = np.asarray(W), np.asarray(b)
        self.stats = {"iters": int(iters), "nnz": int((np.abs(self.W) > 0).sum())}
        return self

    def decision_function(self, X) -> np.ndarray:
        return np.asarray(X) @ self.W + self.b

    def predict(self, X) -> np.ndarray:
        return self.classes_[np.argmax(self.decision_function(X), axis=1)]

    def score(self, X, y) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y)))


# ---------------------------------------------------------------------------
# Polynomial-kernel SVM (l2, squared hinge, kernelized primal)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PolySVMConfig:
    degree: int = 3
    coef0: float = 1.0
    gamma: float = 1.0
    lam: float = 1e-3  # l2 penalty
    max_iter: int = 10_000
    tol: float = 1e-3
    max_kernel_samples: int = 4096
    dtype: str = "float32"
    seed: int = 0


def _poly_kernel(Xa, Xb, gamma, coef0, degree):
    return (gamma * (Xa @ Xb.T) + coef0) ** degree


@partial(jax.jit, static_argnames=("max_iter",))
def _kernel_agd(K, Y, lam, step, max_iter, tol):
    """Accelerated GD on f(alpha) = mean squared hinge(K alpha) + lam alpha^T K alpha.

    Stops on *relative* gradient norm (||g||_inf <= tol * ||g_0||_inf) so the
    criterion is scale-free w.r.t. kernel magnitude and step size.
    """
    r, k = K.shape[1], Y.shape[1]
    dtype = K.dtype
    m = Y.shape[0]
    A = jnp.zeros((r, k), dtype)

    def grad(Az):
        scores = K @ Az  # (m, k) — K here is the (m, r) cross-kernel
        margin = jnp.maximum(1.0 - Y * scores, 0.0)
        g_scores = (-2.0 / m) * (margin * Y)
        return K.T @ g_scores + 2.0 * lam * Az

    g0 = jnp.max(jnp.abs(grad(A)))

    def cond(state):
        A, Az, t, i, gnorm = state
        return jnp.logical_and(i < max_iter, gnorm > tol * g0)

    def body(state):
        A, Az, t, i, _ = state
        g = grad(Az)
        A_new = Az - step * g
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        Az_new = A_new + ((t - 1.0) / t_new) * (A_new - A)
        return (A_new, Az_new, t_new, i + 1, jnp.max(jnp.abs(g)))

    one = jnp.asarray(1.0, dtype)
    state = (A, A, one, jnp.asarray(0, jnp.int32), jnp.asarray(jnp.inf, dtype))
    A, _, _, i, _ = jax.lax.while_loop(cond, body, state)
    return A, i


class PolySVM:
    def __init__(self, config: PolySVMConfig = PolySVMConfig()):
        self.config = config
        self.anchors: Optional[np.ndarray] = None
        self.A: Optional[np.ndarray] = None
        self.classes_: Optional[np.ndarray] = None
        self.stats: Dict = {}

    def fit(self, X, y) -> "PolySVM":
        cfg = self.config
        dt = jnp.dtype(cfg.dtype)
        X = np.asarray(X)
        y = np.asarray(y)
        m = X.shape[0]
        rng = np.random.default_rng(cfg.seed)
        if m > cfg.max_kernel_samples:
            idx = rng.choice(m, cfg.max_kernel_samples, replace=False)
            anchors = X[idx]
            self.stats["subsampled"] = True
        else:
            anchors = X
            self.stats["subsampled"] = False
        self.anchors = anchors
        self.classes_ = np.unique(y)
        Y = jnp.asarray(np.where(y[:, None] == self.classes_[None, :], 1.0, -1.0), dt)
        K = _poly_kernel(jnp.asarray(X, dt), jnp.asarray(anchors, dt),
                         cfg.gamma, cfg.coef0, cfg.degree)
        # step from the Lipschitz constant 2 lmax(K^T K)/m + 2 lam lmax(K)
        v = jnp.ones((K.shape[1],), dt)
        for _ in range(20):
            v = K.T @ (K @ v)
            v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)
        lmax = v @ (K.T @ (K @ v))
        L = 2.0 * lmax / m + 2.0 * cfg.lam * jnp.sqrt(lmax)
        step = 1.0 / jnp.maximum(L, 1e-12)
        A, iters = _kernel_agd(K, Y, jnp.asarray(cfg.lam, dt), step,
                               cfg.max_iter, jnp.asarray(cfg.tol, dt))
        self.A = np.asarray(A)
        self.stats["iters"] = int(iters)
        return self

    def decision_function(self, X) -> np.ndarray:
        cfg = self.config
        K = _poly_kernel(jnp.asarray(np.asarray(X), jnp.dtype(cfg.dtype)),
                         jnp.asarray(self.anchors, jnp.dtype(cfg.dtype)),
                         cfg.gamma, cfg.coef0, cfg.degree)
        return np.asarray(K @ self.A)

    def predict(self, X) -> np.ndarray:
        return self.classes_[np.argmax(self.decision_function(X), axis=1)]

    def score(self, X, y) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y)))
