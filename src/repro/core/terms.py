"""Monomial bookkeeping for OAVI: DegLex ordering and border construction.

Terms (monomials) over n variables are represented as exponent tuples
``(e_1, ..., e_n)``.  All combinatorics here are host-side Python: the number
of terms is bounded by Theorem 4.3 (``|G| + |O| <= C(D+n, D)``), i.e. a few
hundred in practice, while the numeric heavy lifting (evaluation vectors, Gram
updates, solves) lives in jitted JAX code (see :mod:`repro.core.oavi`).

The degree-lexicographic order used by the paper (Section 2.2) enumerates,
for variables ``t < u < v``::

    1 < t < u < v < t^2 < tu < tv < u^2 < uv < v^2 < t^3 < ...

i.e. ascending total degree, and within a degree the term with the larger
exponent on the *earlier* variable comes first.  This corresponds to the sort
key ``(deg, tuple(-e_i))``.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

Term = Tuple[int, ...]


def degree(term: Term) -> int:
    return sum(term)


def deglex_key(term: Term) -> Tuple[int, Tuple[int, ...]]:
    """Sort key implementing the paper's DegLex order (ascending)."""
    return (sum(term), tuple(-e for e in term))


def constant_term(n: int) -> Term:
    return (0,) * n


def multiply_by_var(term: Term, j: int) -> Term:
    out = list(term)
    out[j] += 1
    return tuple(out)


def divide_by_var(term: Term, j: int) -> Term:
    assert term[j] > 0
    out = list(term)
    out[j] -= 1
    return tuple(out)


def immediate_divisors(term: Term) -> List[Term]:
    """All terms ``term / x_j`` for variables with positive exponent."""
    return [divide_by_var(term, j) for j in range(len(term)) if term[j] > 0]


def border(
    O_by_degree: Dict[int, List[Term]],
    d: int,
    n: int,
) -> List[Tuple[Term, Term, int]]:
    """Degree-``d`` border of the order ideal ``O`` (Definition 2.5).

    ``O_by_degree`` maps degree -> list of terms of that degree currently in
    ``O``.  Because OAVI only ever appends border terms, ``O`` is an order
    ideal (divisor-closed), so a degree-``d`` candidate lies in the border iff
    *all* its immediate (degree ``d-1``) divisors are in ``O``.

    Returns a DegLex-sorted list of ``(term, parent, var)`` triples where
    ``term = parent * x_var`` and ``parent`` is in ``O_{d-1}``; the evaluation
    vector of ``term`` is the elementwise product of ``parent``'s evaluation
    column and the ``var``-th data column.
    """
    prev = O_by_degree.get(d - 1, [])
    if not prev:
        return []
    prev_set = set(prev) if d > 1 else {constant_term(n)}
    # Candidate generation: multiply each degree-(d-1) term in O by each var.
    candidates: Dict[Term, Tuple[Term, int]] = {}
    for parent in prev:
        for j in range(n):
            cand = multiply_by_var(parent, j)
            if cand not in candidates:
                candidates[cand] = (parent, j)
    out: List[Tuple[Term, Term, int]] = []
    for cand, (parent, j) in candidates.items():
        if all(div in prev_set for div in immediate_divisors(cand)):
            out.append((cand, parent, j))
    out.sort(key=lambda tpl: deglex_key(tpl[0]))
    return out


def theorem_4_3_degree_bound(psi: float) -> int:
    """``D = ceil(-log(psi) / log(4))`` — the termination degree of Thm 4.3."""
    if psi <= 0:
        raise ValueError("Theorem 4.3 requires psi > 0")
    if psi >= 1:
        return 1
    return max(1, math.ceil(-math.log(psi) / math.log(4.0)))


def theorem_4_3_size_bound(psi: float, n: int) -> int:
    """``|G| + |O| <= C(D+n, D)`` (number-of-samples-agnostic bound)."""
    D = theorem_4_3_degree_bound(psi)
    return math.comb(D + n, D)


def tau_bound(psi: float) -> float:
    """Remark 4.5: ``tau >= (3/2)^D`` guarantees Thm 4.3 under (CCOP)."""
    D = theorem_4_3_degree_bound(psi)
    return 1.5**D


@dataclass
class TermBook:
    """Incremental registry of the terms in ``O`` (in DegLex order).

    Keeps, per term, the ``(parent_index, var)`` pair used to evaluate its
    column incrementally: ``col(term) = col(parent) * X[:, var]``.  Index 0 is
    the constant-1 term with sentinel parent ``(-1, -1)``.
    """

    n: int
    terms: List[Term] = field(default_factory=list)
    parents: List[int] = field(default_factory=list)
    vars: List[int] = field(default_factory=list)
    index: Dict[Term, int] = field(default_factory=dict)
    by_degree: Dict[int, List[Term]] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.terms:
            one = constant_term(self.n)
            self.terms = [one]
            self.parents = [-1]
            self.vars = [-1]
            self.index = {one: 0}
            self.by_degree = {0: [one]}

    def __len__(self) -> int:
        return len(self.terms)

    def append(self, term: Term, parent: Term, var: int) -> int:
        idx = len(self.terms)
        self.terms.append(term)
        self.parents.append(self.index[parent] if degree(term) > 1 else 0)
        self.vars.append(var)
        self.index[term] = idx
        self.by_degree.setdefault(degree(term), []).append(term)
        return idx

    def border(self, d: int) -> List[Tuple[Term, Term, int]]:
        return border(self.by_degree, d, self.n)


def all_terms_up_to_degree(n: int, d: int) -> List[Term]:
    """All monomials in ``n`` variables of degree <= d, DegLex-sorted."""
    out: List[Term] = []
    for total in range(d + 1):
        for combo in itertools.combinations_with_replacement(range(n), total):
            exps = [0] * n
            for j in combo:
                exps[j] += 1
            out.append(tuple(exps))
    out = sorted(set(out), key=deglex_key)
    return out


def term_to_str(term: Term) -> str:
    if sum(term) == 0:
        return "1"
    parts = []
    for j, e in enumerate(term):
        if e == 1:
            parts.append(f"x{j}")
        elif e > 1:
            parts.append(f"x{j}^{e}")
    return "*".join(parts)
