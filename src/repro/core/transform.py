"""Feature transformation (FT) and scaling utilities (Section 3.2).

``x -> (|g_1(x)|, ..., |g_|G|(x)|)`` over the union of per-class generator
sets, plus the min-max scaler the paper applies to bring data into [0,1]^n.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class MinMaxScaler:
    """Min-max feature scaling into [0, 1]^n (fit on train, reused on test).

    Statistics are computed in float64 for numerical safety; ``dtype`` (when
    set) casts the *output*, so downstream float32 models are not silently
    fed float64 data.  ``dtype=None`` preserves the historical float64
    behaviour.
    """

    lo: Optional[np.ndarray] = None
    scale: Optional[np.ndarray] = None
    dtype: Optional[str] = None

    def fit(self, X) -> "MinMaxScaler":
        X = np.asarray(X, dtype=np.float64)
        self.lo = X.min(axis=0)
        rng = X.max(axis=0) - self.lo
        self.scale = np.where(rng > 0, 1.0 / np.maximum(rng, 1e-300), 0.0)
        return self

    def transform(self, X) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        out = np.clip((X - self.lo) * self.scale, 0.0, 1.0)
        return out.astype(self.dtype) if self.dtype is not None else out

    def fit_transform(self, X) -> np.ndarray:
        return self.fit(X).transform(X)


def feature_transform(models: Sequence, Z, dtype: Optional[str] = None) -> np.ndarray:
    """(FT): stack ``|g(Z)|`` over the generators of every per-class model.

    ``models`` — one fitted generator model per class (OAVIModel / VCAModel /
    anything exposing ``evaluate_G``).  Returns (q, sum_i |G^i|) in ``dtype``
    (default: the first model's dtype, so float32 models yield float32
    features instead of silently promoting to float64).

    This is the legacy per-model loop; the fused single-dispatch version
    lives in :func:`repro.api.feature_transform`.
    """
    out_dtype = np.dtype(dtype) if dtype is not None else None
    cols: List[np.ndarray] = []
    for model in models:
        G = np.asarray(model.evaluate_G(Z))
        if out_dtype is None:
            out_dtype = G.dtype
        cols.append(np.abs(G).astype(out_dtype, copy=False))
    if not cols:
        return np.zeros((np.asarray(Z).shape[0], 0), out_dtype or np.float64)
    return np.concatenate(cols, axis=1)
