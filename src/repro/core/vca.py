"""VCA — Vanishing Component Analysis (Livni et al. 2013).

Monomial-agnostic baseline used by the paper (Section 6).  Degree-wise, VCA
maintains a set of *non-vanishing* polynomials ``F`` (normalized so their
evaluation vectors have unit norm) and a set of *vanishing components* ``V``
(the generators).  At degree ``d`` the candidates are all pairwise products
``f * g`` with ``f in F_{d-1}`` and ``g in F_1``; candidates are projected
onto the orthogonal complement of ``span F`` and an SVD of the residual
matrix splits the span into vanishing directions (singular value small) and
new non-vanishing directions.

Acceptance uses the paper's MSE convention (``sigma^2 / m <= psi``) so VCA,
ABM and OAVI are compared on the same vanishing scale.  As the paper
discusses (Section 1.2), VCA is susceptible to the spurious-vanishing
problem and may construct many more generators than monomial-aware methods —
we reproduce that behaviour, not fix it.

Evaluation on unseen data replays the construction tree: each degree-d
polynomial is a linear combination of (candidate products of lower-degree
polynomials) minus its projection onto previously constructed ``F`` polys.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class VCAConfig:
    psi: float = 0.005
    max_degree: int = 10
    dtype: str = "float32"
    # cap on |F_d| per degree to bound candidate blow-up (paper's VCA has no
    # cap; ours triggers only on pathological data and is recorded in stats)
    max_components_per_degree: int = 512


@dataclasses.dataclass
class _DegreeBlock:
    """Replayable construction of one degree's polynomials.

    candidates = F_{d-1}(Z)[:, pair_f] * F_1(Z)[:, pair_g]       (q, K)
    raw        = candidates - F_all(Z) @ proj                    (q, K)
    polys      = raw @ combo                                     (q, r)
    of which the first ``num_vanishing`` columns are generators (V_d) and the
    rest are the normalized non-vanishing components appended to F_d.
    """

    pair_f: np.ndarray  # (K,) indices into F_{d-1}
    pair_g: np.ndarray  # (K,) indices into F_1
    proj: np.ndarray  # (|F_all_before|, K) projection coefficients
    combo: np.ndarray  # (K, r) SVD combination
    num_vanishing: int
    num_nonvanishing: int


@dataclasses.dataclass
class VCAModel:
    n: int
    psi: float
    deg1_coeffs: np.ndarray  # (n+1, r1) polys over [1, x_1..x_n]
    deg1_num_vanishing: int
    blocks: List[_DegreeBlock]
    stats: Dict
    sqrt_m: float = 1.0  # train-time normalization of the constant component
    dtype: str = "float32"

    @property
    def num_G(self) -> int:
        k = self.deg1_num_vanishing
        return k + sum(b.num_vanishing for b in self.blocks)

    @property
    def num_F(self) -> int:
        k = (self.deg1_coeffs.shape[1] - self.deg1_num_vanishing) + 1  # + const
        return k + sum(b.num_nonvanishing for b in self.blocks)

    def evaluate_G(self, Z) -> np.ndarray:
        """Evaluation matrix of all vanishing components over Z: (q, |G|)."""
        Z = np.asarray(Z, dtype=self.dtype)
        q = Z.shape[0]
        ones = np.ones((q, 1), dtype=self.dtype)
        basis1 = np.concatenate([ones, Z], axis=1)  # (q, n+1)
        deg1 = basis1 @ self.deg1_coeffs  # (q, r1)
        kv = self.deg1_num_vanishing
        V_cols = [deg1[:, :kv]]
        F_prev = deg1[:, kv:]  # F_1 (normalized on train)
        F1 = F_prev
        # constant component is the *function* x -> 1/sqrt(m_train)
        F_all = np.concatenate([ones / self.sqrt_m, F_prev], axis=1)
        for b in self.blocks:
            cand = F_prev[:, b.pair_f] * F1[:, b.pair_g]  # (q, K)
            raw = cand - F_all[:, : b.proj.shape[0]] @ b.proj
            polys = raw @ b.combo
            V_cols.append(polys[:, : b.num_vanishing])
            F_new = polys[:, b.num_vanishing :]
            F_all = np.concatenate([F_all, F_new], axis=1)
            F_prev = F_new
        return np.concatenate(V_cols, axis=1)

    def mse(self, Z) -> np.ndarray:
        G = self.evaluate_G(Z)
        return (G * G).mean(axis=0)

    # -- VanishingIdealModel protocol (see repro.api) ---------------------

    def transform(self, Z) -> np.ndarray:
        """(FT) for this model alone: ``|G(Z)|`` as (q, |G|) in model dtype."""
        return np.abs(np.asarray(self.evaluate_G(Z)))

    def to_state_dict(self) -> Tuple[Dict[str, np.ndarray], Dict]:
        """Flat array tree + JSON-safe metadata.  Each degree block is stored
        under ``block_<i>_*`` keys; the replayable construction tree is the
        whole model."""
        arrays: Dict[str, np.ndarray] = {"deg1_coeffs": self.deg1_coeffs}
        block_meta = []
        for i, b in enumerate(self.blocks):
            arrays[f"block_{i:04d}_pair_f"] = b.pair_f
            arrays[f"block_{i:04d}_pair_g"] = b.pair_g
            arrays[f"block_{i:04d}_proj"] = b.proj
            arrays[f"block_{i:04d}_combo"] = b.combo
            block_meta.append(
                {
                    "num_vanishing": int(b.num_vanishing),
                    "num_nonvanishing": int(b.num_nonvanishing),
                }
            )
        meta = {
            "kind": "vca",
            "n": int(self.n),
            "psi": float(self.psi),
            "dtype": str(self.dtype),
            "deg1_num_vanishing": int(self.deg1_num_vanishing),
            "sqrt_m": float(self.sqrt_m),
            "blocks": block_meta,
            "stats": self.stats,
        }
        return arrays, meta

    @classmethod
    def from_state_dict(cls, arrays: Dict[str, np.ndarray], meta: Dict) -> "VCAModel":
        blocks = []
        for i, bm in enumerate(meta.get("blocks") or []):
            blocks.append(
                _DegreeBlock(
                    pair_f=np.asarray(arrays[f"block_{i:04d}_pair_f"]),
                    pair_g=np.asarray(arrays[f"block_{i:04d}_pair_g"]),
                    proj=np.asarray(arrays[f"block_{i:04d}_proj"]),
                    combo=np.asarray(arrays[f"block_{i:04d}_combo"]),
                    num_vanishing=int(bm["num_vanishing"]),
                    num_nonvanishing=int(bm["num_nonvanishing"]),
                )
            )
        return cls(
            n=int(meta["n"]),
            psi=float(meta["psi"]),
            deg1_coeffs=np.asarray(arrays["deg1_coeffs"]),
            deg1_num_vanishing=int(meta["deg1_num_vanishing"]),
            blocks=blocks,
            stats=dict(meta.get("stats") or {}),
            sqrt_m=float(meta["sqrt_m"]),
            dtype=str(meta["dtype"]),
        )

    def save(self, path: str) -> str:
        """Atomic save via the checkpoint manifest machinery (repro.api)."""
        from .. import api

        return api.save(self, path)


def fit(X, config: VCAConfig = VCAConfig()) -> VCAModel:
    t0 = time.perf_counter()
    dt = np.dtype(config.dtype)
    X = np.asarray(X, dtype=dt)
    m, n = X.shape
    psi = config.psi
    sqrt_m = np.sqrt(float(m))

    stats: Dict = {"border_sizes": [], "degrees": [], "m": m, "n": n}

    # ---- degree 1 --------------------------------------------------------
    ones = np.ones((m, 1), dtype=dt)
    basis1 = np.concatenate([ones, X], axis=1)  # (m, n+1)
    const = ones / sqrt_m  # normalized constant component
    # project x_i onto the constant, SVD the residual
    resid = X - const @ (const.T @ X)  # mean-centered columns
    # combo over [1, x]: subtracting the projection = -1 * mean per column
    proj_coeff = (const.T @ X) / sqrt_m  # (1, n) over the *raw* ones column
    U, S, Vt = np.linalg.svd(resid, full_matrices=False)
    # polynomials: resid @ Vt.T, with singular values S; MSE = S^2 / m
    mses = (S * S) / m
    vanishing = mses <= psi
    # order: vanishing first (generators), then non-vanishing (normalized)
    idx_v = np.where(vanishing)[0]
    idx_f = np.where(~vanishing)[0]
    combos = []
    for j in idx_v:
        combos.append(Vt[j])  # keep raw scale (LTC-analogue: unit combo)
    for j in idx_f:
        combos.append(Vt[j] / max(S[j], 1e-30))  # normalize eval to unit norm
    C = np.stack(combos, axis=1) if combos else np.zeros((n, 0), dt)
    # deg1 polys over [1, x]: x @ C - ones @ (proj_coeff @ C)
    deg1_coeffs = np.concatenate([-(proj_coeff @ C), C], axis=0).astype(dt)
    deg1 = basis1 @ deg1_coeffs
    kv1 = len(idx_v)
    F1 = deg1[:, kv1:]
    F_all = np.concatenate([const, F1], axis=1)
    F_prev = F1
    stats["degrees"].append(1)
    stats["border_sizes"].append(n)

    blocks: List[_DegreeBlock] = []
    capped = False
    for d in range(2, config.max_degree + 1):
        if F_prev.shape[1] == 0 or F1.shape[1] == 0:
            stats["termination"] = "no_nonvanishing_left"
            break
        kf, kg = F_prev.shape[1], F1.shape[1]
        pair_f = np.repeat(np.arange(kf), kg).astype(np.int32)
        pair_g = np.tile(np.arange(kg), kf).astype(np.int32)
        cand = F_prev[:, pair_f] * F1[:, pair_g]  # (m, K)
        proj = F_all.T @ cand  # (|F_all|, K)
        raw = cand - F_all @ proj
        U, S, Vt = np.linalg.svd(raw, full_matrices=False)
        mses = (S * S) / m
        vanishing = mses <= psi
        idx_v = np.where(vanishing)[0]
        idx_f = np.where(~vanishing)[0]
        if len(idx_f) > config.max_components_per_degree:
            idx_f = idx_f[: config.max_components_per_degree]
            capped = True
        combos = [Vt[j] for j in idx_v]
        combos += [Vt[j] / max(S[j], 1e-30) for j in idx_f]
        combo = np.stack(combos, axis=1) if combos else np.zeros((len(pair_f), 0), dt)
        blocks.append(
            _DegreeBlock(
                pair_f=pair_f,
                pair_g=pair_g,
                proj=proj.astype(dt),
                combo=combo.astype(dt),
                num_vanishing=len(idx_v),
                num_nonvanishing=len(idx_f),
            )
        )
        stats["degrees"].append(d)
        stats["border_sizes"].append(len(pair_f))
        polys = raw @ combo
        F_new = polys[:, len(idx_v) :]
        F_all = np.concatenate([F_all, F_new], axis=1)
        F_prev = F_new
        if F_new.shape[1] == 0:
            stats["termination"] = "no_nonvanishing_left"
            break
    else:
        stats["termination"] = "max_degree"

    stats["time_total"] = time.perf_counter() - t0
    stats["capped"] = capped
    model = VCAModel(
        n=n,
        psi=psi,
        deg1_coeffs=deg1_coeffs,
        deg1_num_vanishing=kv1,
        blocks=blocks,
        stats=stats,
        sqrt_m=float(sqrt_m),
        dtype=config.dtype,
    )
    stats["num_G"] = model.num_G
    stats["num_O"] = model.num_F  # F plays the role of O for size comparisons
    stats["G_plus_O"] = model.num_G + model.num_F
    return model
