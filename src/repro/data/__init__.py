"""Data substrate: synthetic datasets (paper App. C + UCI shapes) and the
deterministic sharded LM token pipeline."""

from . import lm, synthetic
from .synthetic import (
    appendix_c,
    planted_source,
    random_cube,
    train_test_split,
    uci_like,
    write_shards,
)

__all__ = [
    "lm",
    "synthetic",
    "appendix_c",
    "planted_source",
    "random_cube",
    "train_test_split",
    "uci_like",
    "write_shards",
]
