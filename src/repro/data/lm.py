"""Deterministic, sharded, checkpointable token pipeline for the LM substrate.

Real deployments stream tokenized shards from blob storage; offline we
generate synthetic token streams that are

* **deterministic in (seed, step)** — batch ``t`` is a pure function of the
  pipeline state, so training is bit-reproducible across restarts and the
  pipeline state that must be checkpointed is just ``(seed, step)``,
* **shardable** — each data-parallel rank materializes only its slice of the
  global batch (``global_batch / dp_degree`` rows), indexed so the global
  batch is identical regardless of dp_degree (elastic re-sharding safe),
* **structured** — a degree-2 Markov chain over the vocabulary rather than
  iid noise, so cross-entropy actually decreases during the example runs.

For the audio/VLM stub frontends (per assignment: "the modality frontend is
a STUB"), :func:`frame_embeddings` generates deterministic precomputed
frame/patch embeddings with the same (seed, step) contract.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


@dataclasses.dataclass
class PipelineState:
    """The whole checkpointable state of the pipeline."""

    step: int = 0

    def to_dict(self):
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(step=int(d["step"]))


def _fold(seed: int, *xs: int) -> np.random.Generator:
    ss = np.random.SeedSequence([seed, *[int(x) & 0x7FFFFFFF for x in xs]])
    return np.random.default_rng(ss)


@functools.lru_cache(maxsize=64)
def _grammar(cfg: PipelineConfig) -> Tuple[int, int]:
    """LCG "grammar" (a, b): a function of the pipeline seed ALONE.

    The transition rule must be shared across rows and steps — if every row
    drew its own (a, b), each sequence would follow a private random chain,
    the marginal next-token distribution would be uniform, and cross-entropy
    could never drop below ln(V) no matter how long training runs.  With a
    global grammar the transition map is learnable across batches while the
    trajectories (start token, noise) stay per-(seed, step, row).
    """
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 0xA11CE]))
    a = int(rng.integers(3, 64)) * 2 + 1
    b = int(rng.integers(0, cfg.vocab_size))
    return a, b


def _markov_row(cfg: PipelineConfig, seed_vec: np.ndarray) -> np.ndarray:
    """One sequence from the seed's Markov chain over a hashed alphabet."""
    V = cfg.vocab_size
    T = cfg.seq_len
    # token t+1 = (a * token_t + b + noise) mod V — linear-congruential grammar
    a, b = _grammar(cfg)
    rng = np.random.default_rng(np.random.SeedSequence(seed_vec.tolist()))
    toks = np.empty((T,), np.int32)
    toks[0] = int(rng.integers(0, V))
    noise = rng.integers(0, 17, size=T)
    for t in range(1, T):
        toks[t] = (a * int(toks[t - 1]) + b + int(noise[t])) % V
    return toks


def global_batch_at(cfg: PipelineConfig, step: int) -> np.ndarray:
    """The full (global_batch, seq_len) token batch at ``step`` (testing)."""
    return host_batch_at(cfg, step, 0, cfg.global_batch)


def host_batch_at(
    cfg: PipelineConfig, step: int, row_start: int, row_count: int
) -> np.ndarray:
    """Rows [row_start, row_start+row_count) of the global batch at ``step``.

    Each row is keyed by (seed, step, global_row), so any sharding of rows
    across hosts reconstructs the same global batch.
    """
    out = np.empty((row_count, cfg.seq_len), np.int32)
    for i in range(row_count):
        g = row_start + i
        seed_vec = np.array([cfg.seed, step, g], dtype=np.int64)
        out[i] = _markov_row(cfg, seed_vec)
    return out


def batch_for_mesh(
    cfg: PipelineConfig,
    step: int,
    mesh,
    batch_axes: Tuple[str, ...] = ("data",),
) -> jax.Array:
    """Materialize the global batch sharded over ``batch_axes`` of ``mesh``.

    In a true multi-host setting each host would call :func:`host_batch_at`
    for its addressable rows and assemble via
    ``jax.make_array_from_single_device_arrays``; single-host (incl. the
    dry-run's 512 fake devices) can device_put the host batch directly.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    tokens = global_batch_at(cfg, step)
    spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None)
    return jax.device_put(jnp.asarray(tokens), NamedSharding(mesh, spec))


def targets_from_tokens(tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Next-token prediction: inputs = tokens[:, :-1], labels = tokens[:, 1:]."""
    return tokens[:, :-1], tokens[:, 1:]


def frame_embeddings(
    d_model: int,
    seq_len: int,
    batch: int,
    seed: int = 0,
    step: int = 0,
    dtype=jnp.float32,
) -> jax.Array:
    """Precomputed modality-frontend output (audio frames / vision patches).

    Deterministic in (seed, step); unit RMS per frame.
    """
    rng = _fold(seed, step, d_model, seq_len, batch)
    x = rng.standard_normal((batch, seq_len, d_model)).astype(np.float32)
    x /= np.sqrt((x * x).mean(axis=-1, keepdims=True) + 1e-6)
    return jnp.asarray(x, dtype)
