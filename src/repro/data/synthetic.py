"""Datasets for the paper's experiments.

UCI data is not available offline, so every benchmark dataset is generated
procedurally:

* :func:`appendix_c` — the paper's 2M-sample synthetic dataset, to its exact
  specification (Appendix C): class 1 satisfies ``x1^2 + 0.01 x2 + x3^2 = 1``,
  class 2 satisfies ``x1^2 + x3^2 = 1.3``, both perturbed by N(0, 0.05^2).
* :func:`uci_like` — datasets matching the (m, n, #classes) shapes of the
  paper's UCI table (bank/credit/htru/seeds/skin/spam), with classes planted
  on distinct random algebraic sets so generator-constructing methods have
  signal to find.  The paper's *relative* claims (speed-ups, scaling slopes,
  bound satisfaction) are shape-driven, so these stand in for UCI.
* :func:`random_cube` — uniform noise in [0,1]^n (Figure 1's setting).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

# (m, n, num_classes) of the paper's Table 2 datasets.
UCI_SHAPES: Dict[str, Tuple[int, int, int]] = {
    "bank": (1372, 4, 2),
    "credit": (30000, 22, 2),
    "htru": (17898, 8, 2),
    "seeds": (210, 7, 3),
    "skin": (245057, 3, 2),
    "spam": (4601, 57, 2),
}


def appendix_c(m: int = 2_000_000, seed: int = 0, noise: float = 0.05):
    """The paper's synthetic dataset (Appendix C).  Returns (X, y) raw
    (un-scaled); apply min-max scaling as the pipeline does."""
    rng = np.random.default_rng(seed)
    m1 = m // 2
    m2 = m - m1
    # class 1: x1^2 + 0.01 x2 + x3^2 - 1 = 0
    x2 = rng.uniform(0.0, 1.0, m1)
    theta = rng.uniform(0.0, 2.0 * np.pi, m1)
    r2 = np.maximum(1.0 - 0.01 * x2, 0.0)
    x1 = np.sqrt(r2) * np.cos(theta)
    x3 = np.sqrt(r2) * np.sin(theta)
    c1 = np.stack([x1, x2, x3], axis=1)
    # class 2: x1^2 + x3^2 - 1.3 = 0  (x2 free)
    theta = rng.uniform(0.0, 2.0 * np.pi, m2)
    x1 = np.sqrt(1.3) * np.cos(theta)
    x3 = np.sqrt(1.3) * np.sin(theta)
    x2 = rng.uniform(0.0, 1.0, m2)
    c2 = np.stack([x1, x2, x3], axis=1)
    X = np.concatenate([c1, c2], axis=0)
    X += rng.normal(0.0, noise, X.shape)
    y = np.concatenate([np.zeros(m1, np.int32), np.ones(m2, np.int32)])
    perm = rng.permutation(m)
    return X[perm].astype(np.float32), y[perm]


def _planted_class(rng, m: int, n: int, degree: int = 2, noise: float = 0.03):
    """Sample points near a random degree-``degree`` algebraic set in R^n.

    We draw a random polynomial constraint on the first 3 (or n) coordinates
    and project random points onto it approximately via one Newton step, then
    add noise — cheap, and guarantees an approximately-vanishing polynomial
    exists for the class.
    """
    k = min(3, n)
    w = rng.uniform(0.5, 1.5, k)
    c = rng.uniform(0.5, 1.5)
    X = rng.uniform(0.0, 1.0, (m, n))
    # constraint sum_j w_j x_j^degree = c on the first k coords; rescale those
    s = (w * X[:, :k] ** degree).sum(axis=1)
    scale = (c / np.maximum(s, 1e-9)) ** (1.0 / degree)
    X[:, :k] *= scale[:, None]
    X += rng.normal(0.0, noise, X.shape)
    return X


def uci_like(name: str, seed: int = 0):
    """Procedural stand-in with the (m, n, k) shape of the named UCI set."""
    if name not in UCI_SHAPES:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(UCI_SHAPES)}")
    m, n, k = UCI_SHAPES[name]
    rng = np.random.default_rng(seed)
    sizes = [m // k] * k
    sizes[-1] += m - sum(sizes)
    Xs, ys = [], []
    for c, mc in enumerate(sizes):
        Xs.append(_planted_class(rng, mc, n, degree=2 + (c % 2)))
        ys.append(np.full(mc, c, np.int32))
    X = np.concatenate(Xs, axis=0)
    y = np.concatenate(ys)
    perm = rng.permutation(m)
    return X[perm].astype(np.float32), y[perm]


def multiclass_planted(sizes, n: int = 4, seed: int = 0):
    """k classes of the given ``sizes``, each planted on its own random
    algebraic set (see :func:`_planted_class`) — the multi-class fit
    benchmark's dataset.  Returns shuffled ``(X, y)``."""
    rng = np.random.default_rng(seed)
    Xs, ys = [], []
    for c, mc in enumerate(sizes):
        Xs.append(_planted_class(rng, int(mc), n, degree=2 + (c % 2)))
        ys.append(np.full(int(mc), c, np.int32))
    X = np.concatenate(Xs, axis=0)
    y = np.concatenate(ys)
    perm = rng.permutation(X.shape[0])
    return X[perm].astype(np.float32), y[perm]


def lognormal_sizes(k: int, mean_rows: int, sigma: float = 0.8, seed: int = 0):
    """Lognormal-skewed class sizes with the given mean — the skewed-classes
    regime of the multi-class benchmark (min size clipped to 32)."""
    rng = np.random.default_rng(seed)
    raw = rng.lognormal(mean=0.0, sigma=sigma, size=k)
    sizes = np.maximum((raw / raw.mean() * mean_rows).astype(int), 32)
    return [int(s) for s in sizes]


def random_cube(m: int, n: int, seed: int = 0):
    """Uniform [0,1]^n noise (Figure 1 setting: no algebraic structure)."""
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 1.0, (m, n)).astype(np.float32)


# ---------------------------------------------------------------------------
# Streaming data: deterministic planted-polynomial tiles + .npy shard writer
# ---------------------------------------------------------------------------

STREAM_TILE_ROWS = 4096  # fixed tile granularity of the streamed generators


def planted_stream_tile(
    tile_idx: int, n: int = 3, seed: int = 0, degree: int = 2, noise: float = 0.03
) -> np.ndarray:
    """One full ``(STREAM_TILE_ROWS, n)`` tile of the planted-polynomial
    stream — the same near-algebraic-set construction as
    :func:`_planted_class`, made *tile-deterministic*: the constraint
    parameters come from ``seed`` alone and each tile gets its own derived
    rng, so row ``r`` has identical values no matter how the stream is
    chunked or how large ``m`` is.  This is the shared generator behind the
    streaming benchmarks (``bench_streaming`` and ``bench_scaling
    --streaming``) and the shard writer."""
    rng_w = np.random.default_rng(seed)
    k = min(3, n)
    w = rng_w.uniform(0.5, 1.5, k)
    c = rng_w.uniform(0.5, 1.5)
    rng = np.random.default_rng(np.random.SeedSequence([seed + 1, tile_idx]))
    X = rng.uniform(0.0, 1.0, (STREAM_TILE_ROWS, n))
    s = (w * X[:, :k] ** degree).sum(axis=1)
    scale = (c / np.maximum(s, 1e-9)) ** (1.0 / degree)
    X[:, :k] *= scale[:, None]
    X += rng.normal(0.0, noise, X.shape)
    return X.astype(np.float32)


def planted_source(m: int, n: int = 3, seed: int = 0, degree: int = 2,
                   noise: float = 0.03):
    """Generator-backed :class:`repro.streaming.source.SyntheticSource` over
    the planted-polynomial stream: ``m`` rows that occupy no storage."""
    from ..streaming.source import SyntheticSource

    return SyntheticSource(
        lambda idx: planted_stream_tile(idx, n=n, seed=seed, degree=degree,
                                        noise=noise),
        num_rows=m,
        num_features=n,
        tile_rows=STREAM_TILE_ROWS,
    )


def write_shards(
    path: str,
    data,
    shard_rows: int = 1 << 16,
    dtype: str = "float32",
    append: bool = False,
) -> Dict:
    """Write a source (or array) as a memory-mappable ``.npy`` shard
    directory readable by :class:`repro.streaming.source.ShardDirSource`:
    ``shard_00000.npy``, ... plus ``meta.json`` (format
    ``repro.shards.v1``).  Returns the metadata dict.

    ``meta.json`` records a CRC32 content checksum and byte length per shard
    file (``checksums`` / ``shard_bytes``, aligned with shard index);
    :class:`~repro.streaming.source.ShardDirSource` verifies each shard
    against them before its rows are served, so a flipped bit or truncated
    shard raises :class:`~repro.resilience.integrity.IntegrityError` naming
    the file instead of feeding corrupt rows to a fit.  Appending to a
    pre-checksum directory keeps the old shards' entries as ``null``
    (unknown — verification is skipped for them rather than paying a full
    re-read of history).

    ``append=True`` grows an existing shard directory in place with the rows
    of ``data``: new shard files are written first, ``meta.json`` is
    replaced last via an atomic rename — a concurrent
    :class:`~repro.streaming.source.ShardDirSource` (or its ``refresh()``)
    therefore always sees a committed, self-consistent directory, never the
    half-written state.  Appending requires the existing row count to be a
    multiple of ``shard_rows`` (all existing shards full): the reader
    indexes rows as ``pos // shard_rows``, so growth may only ever add
    shards, not rewrite history.
    """
    import json
    import os

    from ..resilience import chaos
    from ..resilience.integrity import checksum_file
    from ..streaming.source import SHARD_FORMAT, SHARD_META, as_source

    source = as_source(data)
    m, n = source.num_rows, source.num_features
    os.makedirs(path, exist_ok=True)
    np_dtype = np.dtype(dtype)
    first_shard, row_offset = 0, 0
    checksums: list = []
    shard_bytes: list = []
    if append:
        with open(os.path.join(path, SHARD_META)) as f:
            meta = json.load(f)
        if meta.get("format") != SHARD_FORMAT:
            raise ValueError(
                f"{path!r} is not a {SHARD_FORMAT} shard directory "
                f"(format={meta.get('format')!r})"
            )
        if int(meta["num_features"]) != n or str(meta["dtype"]) != str(np_dtype):
            raise ValueError(
                f"append mismatch at {path!r}: existing "
                f"(n={meta['num_features']}, dtype={meta['dtype']}), "
                f"appending (n={n}, dtype={np_dtype})"
            )
        shard_rows = int(meta["shard_rows"])
        row_offset = int(meta["num_rows"])
        if row_offset % shard_rows != 0:
            raise ValueError(
                f"cannot append to {path!r}: existing num_rows={row_offset} "
                f"is not a multiple of shard_rows={shard_rows} (the trailing "
                "shard is partial; readers assume all but the last shard are "
                "full)"
            )
        first_shard = int(meta["num_shards"])
        if first_shard * shard_rows != row_offset:
            raise ValueError(
                f"{path!r}: meta.json is inconsistent — "
                f"num_shards={first_shard} * shard_rows={shard_rows} != "
                f"num_rows={row_offset} (partial write?)"
            )
        # extend the checksum ledger; a pre-checksum directory keeps None
        # (unknown) for its existing shards instead of re-reading history
        checksums = list(meta.get("checksums") or [None] * first_shard)
        shard_bytes = list(meta.get("shard_bytes") or [None] * first_shard)
    num_new = max((m + shard_rows - 1) // shard_rows, 0 if append else 1)
    for idx in range(num_new):
        lo = idx * shard_rows
        hi = min(lo + shard_rows, m)
        block = np.asarray(source.read(lo, hi), np_dtype)
        fname = os.path.join(path, f"shard_{first_shard + idx:05d}.npy")
        np.save(fname, block)
        crc, nbytes = checksum_file(fname)
        checksums.append(crc)
        shard_bytes.append(nbytes)
        chaos.fire("shards.shard_written", path=fname)
    meta = {
        "format": SHARD_FORMAT,
        "num_rows": int(row_offset + m),
        "num_features": int(n),
        "shard_rows": int(shard_rows),
        "num_shards": int(first_shard + num_new),
        "dtype": str(np_dtype),
        "checksums": checksums,
        "shard_bytes": shard_bytes,
    }
    # meta commits the write: tmp + rename is atomic on POSIX, so readers see
    # either the old or the new directory state, never a torn meta.json
    tmp = os.path.join(path, SHARD_META + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(path, SHARD_META))
    chaos.fire("shards.committed", path=os.path.join(path, SHARD_META))
    return meta


def train_test_split(X, y, test_frac: float = 0.4, seed: int = 0):
    """Paper's 60/40 random partition."""
    rng = np.random.default_rng(seed)
    m = X.shape[0]
    perm = rng.permutation(m)
    cut = int(round(m * (1.0 - test_frac)))
    tr, te = perm[:cut], perm[cut:]
    return X[tr], y[tr], X[te], y[te]
