"""Pallas TPU kernels for the framework's compute hot spots.

- gram_update:     fused border-eval + tall-skinny Gram (OAVI hot loop)
- ihb_update:      Theorem 4.9 block-inverse update
- flash_attention: blocked causal GQA attention (LM substrate)

``ops`` holds the public jit wrappers (with jnp fallback on non-TPU
backends); ``ref`` holds the pure-jnp oracles the tests compare against.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
