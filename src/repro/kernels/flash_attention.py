"""Blocked (flash-style) causal GQA attention — Pallas TPU kernel.

The LM substrate's dominant compute hot spot.  Online-softmax attention,
streaming K/V through VMEM in ``bk``-row blocks while Q stays resident in
``bq``-row blocks:

    grid = (batch * q_heads, S_q / bq, S_k / bk)       (kv innermost)

GQA is folded into the K/V index maps: query head ``h`` reads kv head
``h // group`` — no materialized broadcast of K/V (saves HBM bandwidth,
which is the roofline term this kernel attacks; see EXPERIMENTS.md §Perf).

Causal masking skips whole (iq, ik) blocks above the diagonal via
``pl.when`` — for long sequences that halves the FLOPs, and the mask inside
the diagonal block is an iota comparison on the VPU.

VMEM per step: bq*d + 2*bk*d + bq*bk + 2*(bq,) accumulators; defaults
(bq=bk=512, d=128) ≈ 1.8 MB.  MXU shapes (bq x d) @ (d x bk) are 128-aligned.

``ops.py`` provides the jit wrapper with padding + reference fallback;
``ref.py`` holds the pure-jnp oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, causal, bq, bk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: block row iq attends to block cols ik with ik*bk <= iq*bq + bq-1
    run = (ik * bk <= iq * bq + (bq - 1)) if causal else True

    @pl.when(run)
    def _step():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        v = v_ref[0]  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "q_heads_per_kv", "interpret")
)
def flash_attention(
    q: jax.Array,  # (BH_q, S_q, d)   flattened batch*q_heads
    k: jax.Array,  # (BH_kv, S_k, d)  flattened batch*kv_heads
    v: jax.Array,  # (BH_kv, S_k, d)
    *,
    causal: bool = True,
    q_heads_per_kv: int = 1,
    bq: int = 512,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    BHq, Sq, d = q.shape
    BHkv, Sk, _ = k.shape
    dv = v.shape[-1]  # v head dim may differ (e.g. MLA nope+rope keys)
    assert BHq == BHkv * q_heads_per_kv
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    scale = 1.0 / (d**0.5)
    grid = (BHq, Sq // bq, Sk // bk)
    from jax.experimental.pallas import tpu as pltpu

    kv_map = lambda h, iq, ik: (h // q_heads_per_kv, ik, 0)  # noqa: E731
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, dv), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BHq, Sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
