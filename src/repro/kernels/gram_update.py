"""Fused border-evaluation + Gram kernel — OAVI's only O(m) hot spot.

Per degree, OAVI needs (Section 4 / our degree-batched formulation):

    B  = A[:, parents] * X[:, vars]        candidate columns     (m, K)
    QL = A^T B / m                         cross-Gram            (L, K)
    C  = B^T B / m                         candidate Gram        (K, K)

TPU adaptation (DESIGN.md §3): the column *gather* is re-expressed as a
matmul with one-hot selection matrices ``Psel (L, K)`` and ``Vsel (n, K)`` —
gathers are VPU-hostile on TPU while (bm, L) x (L, K) matmuls run on the
MXU.  The kernel streams A and X through VMEM in ``bm``-row blocks and
accumulates both Gram products in fp32 VMEM scratch across the grid:

    grid = (m / bm,)
    per step:  Ab (bm, L), Xb (bm, n)  ->  Bb = (Ab @ Psel) * (Xb @ Vsel)
               QL += Ab^T Bb ;  C += Bb^T Bb

VMEM footprint per step: bm*(L+n+K) + L*K + K*K floats.  With the default
``bm=512``, L=K=256, n<=64: ~0.9 MB streaming + 0.3 MB accumulators — far
under the ~16 MB/core VMEM budget; MXU dims (L, K multiples of 128 by
padding) are hardware-aligned.

``ops.py`` wraps this with padding + the jnp fallback; ``ref.py`` is the
pure-jnp oracle used by the tests (interpret=True comparison).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(a_ref, x_ref, psel_ref, vsel_ref, ql_ref, c_ref):
    """One m-block: fused select-matmul, product, and Gram accumulation."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        ql_ref[...] = jnp.zeros_like(ql_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    a = a_ref[...]  # (bm, L)
    x = x_ref[...]  # (bm, n)
    # gather-as-matmul: parent columns and variable columns for all K cands
    parents = jnp.dot(a, psel_ref[...], preferred_element_type=jnp.float32)
    varcols = jnp.dot(x, vsel_ref[...], preferred_element_type=jnp.float32)
    b = parents * varcols  # (bm, K) candidate columns
    ql_ref[...] += jnp.dot(a.T, b, preferred_element_type=jnp.float32)
    c_ref[...] += jnp.dot(b.T, b, preferred_element_type=jnp.float32)


def _gram_acc_kernel(a_ref, x_ref, psel_ref, vsel_ref, ql0_ref, c0_ref, ql_ref, c_ref):
    """Carry-in variant: the accumulators start from ``(ql0, c0)`` instead of
    zero, so a stream of calls over row chunks reduces in exactly the same
    block order as one call over the concatenated rows (out-of-core OAVI)."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        ql_ref[...] = ql0_ref[...]
        c_ref[...] = c0_ref[...]

    a = a_ref[...]  # (bm, L)
    x = x_ref[...]  # (bm, n)
    parents = jnp.dot(a, psel_ref[...], preferred_element_type=jnp.float32)
    varcols = jnp.dot(x, vsel_ref[...], preferred_element_type=jnp.float32)
    b = parents * varcols  # (bm, K) candidate columns
    ql_ref[...] += jnp.dot(a.T, b, preferred_element_type=jnp.float32)
    c_ref[...] += jnp.dot(b.T, b, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def gram_update(
    A: jax.Array,  # (m, L) evaluation matrix (padded columns are zero)
    X: jax.Array,  # (m, n) data
    Psel: jax.Array,  # (L, K) one-hot parent selectors
    Vsel: jax.Array,  # (n, K) one-hot variable selectors
    *,
    bm: int = 512,
    interpret: bool = False,
):
    """Returns ``(QL, C) = (A^T B, B^T B)`` (un-normalized; caller divides by m).

    ``m`` must be a multiple of ``bm`` (ops.py pads; zero rows are harmless
    since they contribute zero to both Gram products).
    """
    m, L = A.shape
    n = X.shape[1]
    K = Psel.shape[1]
    assert m % bm == 0, f"m={m} not a multiple of bm={bm}"
    grid = (m // bm,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, L), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((L, K), lambda i: (0, 0)),
            pl.BlockSpec((n, K), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, K), lambda i: (0, 0)),
            pl.BlockSpec((K, K), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, K), jnp.float32),
            jax.ShapeDtypeStruct((K, K), jnp.float32),
        ],
        interpret=interpret,
    )(A, X, Psel, Vsel)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def gram_update_acc(
    A: jax.Array,  # (m, L) evaluation matrix (padded columns are zero)
    X: jax.Array,  # (m, n) data
    Psel: jax.Array,  # (L, K) one-hot parent selectors
    Vsel: jax.Array,  # (n, K) one-hot variable selectors
    ql0: jax.Array,  # (L, K) fp32 carry-in cross-Gram accumulator
    c0: jax.Array,  # (K, K) fp32 carry-in candidate-Gram accumulator
    *,
    bm: int = 512,
    interpret: bool = False,
):
    """``(ql0 + A^T B, c0 + B^T B)`` accumulated sequentially over ``bm``-row
    blocks — the streamable carry-in form of :func:`gram_update`: feeding row
    chunks (each a multiple of ``bm``) through this kernel one at a time is
    bit-identical to one call over all rows.
    """
    m, L = A.shape
    n = X.shape[1]
    K = Psel.shape[1]
    assert m % bm == 0, f"m={m} not a multiple of bm={bm}"
    grid = (m // bm,)
    return pl.pallas_call(
        _gram_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, L), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((L, K), lambda i: (0, 0)),
            pl.BlockSpec((n, K), lambda i: (0, 0)),
            pl.BlockSpec((L, K), lambda i: (0, 0)),
            pl.BlockSpec((K, K), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((L, K), lambda i: (0, 0)),
            pl.BlockSpec((K, K), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, K), jnp.float32),
            jax.ShapeDtypeStruct((K, K), jnp.float32),
        ],
        interpret=interpret,
    )(A, X, Psel, Vsel, ql0, c0)
