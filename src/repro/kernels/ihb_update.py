"""IHB block-inverse update (Theorem 4.9) as a Pallas TPU kernel.

The O(l^2) hot path of Inverse Hessian Boosting: given ``N = (A^T A)^{-1}``
(padded to capacity L with an identity block), the new column's Gram vector
``q = A^T b`` and squared norm ``btb``, produce the updated inverse after
appending column ``b`` at slot ``ell``:

    u  = N q
    s  = btb - q^T u              (Schur complement)
    N' = [[N + u u^T / s, -u/s], [-u^T/s, 1/s]]   (written in place at slot ell)

A single-block kernel: everything fits VMEM for L <= ~1024 (L^2 fp32 = 4 MB
at L=1024).  The matvec ``N q`` runs on the MXU; the rank-one update is a
VPU outer product.  Masking with the ``ell`` one-hot keeps the padded
identity block intact, exactly like :func:`repro.core.ihb.append_column`
(the ref oracle).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ihb_kernel(n_ref, q_ref, scal_ref, out_ref):
    N = n_ref[...]  # (L, L)
    q = q_ref[...]  # (1, L) row vector
    btb = scal_ref[0, 0]
    ell = scal_ref[0, 1].astype(jnp.int32)
    L = N.shape[0]

    onehot = (jax.lax.broadcasted_iota(jnp.int32, (1, L), 1) == ell).astype(N.dtype)
    u = jnp.dot(q, N.T, preferred_element_type=jnp.float32)  # (1, L) = (N q)^T
    s = btb - jnp.sum(q * u)
    s = jnp.maximum(s, jnp.asarray(1e-30, N.dtype))
    P = N + jnp.dot(u.T, u, preferred_element_type=jnp.float32) / s
    keep = 1.0 - onehot  # zero out row/col ell (currently identity)
    P = P * keep.T * keep
    n2 = -u / s
    out_ref[...] = (
        P
        + jnp.dot(onehot.T, n2, preferred_element_type=jnp.float32)
        + jnp.dot(n2.T, onehot, preferred_element_type=jnp.float32)
        + (1.0 / s) * jnp.dot(onehot.T, onehot, preferred_element_type=jnp.float32)
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def ihb_update(
    N: jax.Array,  # (L, L) current padded inverse
    q: jax.Array,  # (L,) A^T b (zeros at inactive slots)
    btb: jax.Array,  # scalar ||b||^2
    ell: jax.Array,  # scalar int: append slot
    *,
    interpret: bool = False,
) -> jax.Array:
    L = N.shape[0]
    scal = jnp.stack([btb.astype(N.dtype), ell.astype(N.dtype)]).reshape(1, 2)
    return pl.pallas_call(
        _ihb_kernel,
        in_specs=[
            pl.BlockSpec((L, L), lambda: (0, 0)),
            pl.BlockSpec((1, L), lambda: (0, 0)),
            pl.BlockSpec((1, 2), lambda: (0, 0), memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((L, L), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((L, L), N.dtype),
        interpret=interpret,
    )(N, q.reshape(1, L), scal)
