"""Jit'd public wrappers around the Pallas kernels.

Each op pads its inputs to kernel-aligned shapes, dispatches to the Pallas
kernel on TPU (or ``interpret=True`` when requested), and falls back to the
pure-jnp reference on backends without Pallas-TPU support (this container's
CPU, and the dry-run's 512 fake CPU devices).  The fallback is semantically
identical — ``ref.py`` *is* the spec — so models can be built against these
ops unconditionally.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention as _flash_kernel
from .gram_update import gram_update as _gram_kernel
from .gram_update import gram_update_acc as _gram_acc_kernel
from .ihb_update import ihb_update as _ihb_kernel

# Row-block granularity of the canonical (streamable) Gram reduction: the
# degree step and the out-of-core chunk accumulator both reduce in GRAM_BLOCK
# row blocks, so a streamed fit is bit-identical to the in-memory fit for any
# chunk size that is a multiple of this.
GRAM_BLOCK = 256


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # backend not initialized yet
        return False


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def selection_matrices(parents, vars_, L: int, n: int, dtype=jnp.float32):
    """One-hot (L, K) / (n, K) selectors for gather-as-matmul (gram kernel)."""
    parents = jnp.asarray(parents)
    vars_ = jnp.asarray(vars_)
    K = parents.shape[0]
    Psel = (parents[None, :] == jnp.arange(L)[:, None]).astype(dtype)
    Vsel = (vars_[None, :] == jnp.arange(n)[:, None]).astype(dtype)
    return Psel, Vsel


def gram_update(A, X, parents, vars_, *, bm: int = 512, use_pallas=None, interpret=False):
    """``(QL, C) = (A^T B, B^T B)`` with ``B = A[:, parents] * X[:, vars]``.

    Un-normalized (caller divides by m).  Pads m to a multiple of ``bm``.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        # off-TPU the one-hot-selection matmul is pure overhead: gather the
        # columns directly (bit-identical — see ref.gram_update_gather_ref)
        return ref.gram_update_gather_ref(A, X, parents, vars_)
    L, n = A.shape[1], X.shape[1]
    Psel, Vsel = selection_matrices(parents, vars_, L, n, A.dtype)
    m = A.shape[0]
    m_pad = _round_up(m, bm)
    if m_pad != m:
        A = jnp.pad(A, ((0, m_pad - m), (0, 0)))
        X = jnp.pad(X, ((0, m_pad - m), (0, 0)))
    return _gram_kernel(A, X, Psel, Vsel, bm=min(bm, m_pad), interpret=interpret)


def gram_accumulate(
    A, X, parents, vars_, acc=None, *, bm: int = GRAM_BLOCK, use_pallas=None,
    interpret=False,
):
    """Canonical blocked Gram reduction with carry: ``(acc_QL + A^T B,
    acc_C + B^T B)`` accumulated sequentially over ``bm``-row blocks.

    This is the degree step's Gram op.  Unlike :func:`gram_update` (whose
    off-TPU fallback is one un-blocked matmul, kept for bit-compat with the
    pre-streaming formulation), the reduction order here is *defined*: fp32
    block partials folded strictly left to right, matching the Pallas grid
    accumulation bit for bit.  That makes it streamable — the out-of-core fit
    feeds row chunks through the same op one at a time (carrying ``acc``) and
    lands on the identical bits as the in-memory fit's single call.

    ``acc=None`` starts from zeros.  ``m`` is padded up to a multiple of
    ``bm`` with zero rows (bitwise no-ops: the OAVI domain is >= +0.0).
    Un-normalized; the caller divides by m.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    L, n = A.shape[1], X.shape[1]
    K = parents.shape[0]
    if acc is None:
        acc = (jnp.zeros((L, K), jnp.float32), jnp.zeros((K, K), jnp.float32))
    m = A.shape[0]
    m_pad = _round_up(m, bm)
    if m_pad != m:
        A = jnp.pad(A, ((0, m_pad - m), (0, 0)))
        X = jnp.pad(X, ((0, m_pad - m), (0, 0)))
    if not (use_pallas or interpret):
        return ref.gram_accumulate_ref(A, X, parents, vars_, acc[0], acc[1], bm=bm)
    Psel, Vsel = selection_matrices(parents, vars_, L, n, A.dtype)
    return _gram_acc_kernel(A, X, Psel, Vsel, acc[0], acc[1], bm=bm, interpret=interpret)


def ihb_update(N, q, btb, ell, *, use_pallas=None, interpret=False):
    """Theorem 4.9 padded block-inverse update."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        return ref.ihb_update_ref(N, q, btb, ell)
    return _ihb_kernel(
        N, q, jnp.asarray(btb, N.dtype), jnp.asarray(ell, jnp.int32), interpret=interpret
    )


def multihead_attention(
    q, k, v, *, causal=True, bq=512, bk=512, use_pallas=None, interpret=False
):
    """Flash attention over (B, Hq, S, d) / (B, Hkv, S, d) tensors (GQA-aware).

    Pads S to block multiples.  Padding keys are masked out by causality for
    causal=True; for non-causal we mask via an explicit -inf pad on scores in
    the reference path and rely on zero-padded V rows contributing ~0 weight
    otherwise, so non-causal padded shapes route to the reference.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    B, Hq, Sq, d = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # may differ from d (MLA)
    group = Hq // Hkv
    qf = q.reshape(B * Hq, Sq, d)
    kf = k.reshape(B * Hkv, Sk, d)
    vf = v.reshape(B * Hkv, Sk, dv)
    pad_q = _round_up(Sq, bq) - Sq
    pad_k = _round_up(Sk, bk) - Sk
    padded = pad_q > 0 or pad_k > 0
    if not (use_pallas or interpret) or (padded and not causal):
        out = ref.attention_ref(qf, kf, vf, causal=causal, q_heads_per_kv=group)
        return out.reshape(B, Hq, Sq, dv)
    if padded:
        # causal: padded (future) keys are masked by the causal test; padded
        # query rows produce garbage rows that are sliced off below.
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))
    out = _flash_kernel(
        qf, kf, vf,
        causal=causal, q_heads_per_kv=group,
        bq=min(bq, qf.shape[1]), bk=min(bk, kf.shape[1]),
        interpret=interpret,
    )
    return out[:, :Sq].reshape(B, Hq, Sq, dv)
