"""Pure-jnp reference oracles for every Pallas kernel.

These define the semantics; the kernels must match them (tests sweep shapes
and dtypes and assert allclose against these, with the kernels run in
interpret=True mode on CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_update_ref(A, X, Psel, Vsel):
    """(QL, C): candidate columns via one-hot selection, then both Grams."""
    B = (A @ Psel) * (X @ Vsel)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    return Af.T @ Bf, Bf.T @ Bf


def gram_update_gather_ref(A, X, parents, vars_):
    """(QL, C) with the candidate columns built by direct gather.

    Bit-identical to :func:`gram_update_ref` (a one-hot matmul row sums
    exactly one nonzero entry plus exact zeros), but O(m*K) instead of
    O(m*L*K) column construction — the fast CPU/GPU fallback used by
    ``ops.gram_update`` off-TPU, where gathers are cheap and the selection
    matmul trick buys nothing.
    """
    B = jnp.take(A, parents, axis=1) * jnp.take(X, vars_, axis=1)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    return Af.T @ Bf, Bf.T @ Bf


def border_columns_ref(A, X, parents, vars_):
    """Candidate columns by direct gather (semantic ground truth)."""
    return jnp.take(A, parents, axis=1) * jnp.take(X, vars_, axis=1)


def gram_accumulate_ref(A, X, parents, vars_, ql0, c0, *, bm: int):
    """Blocked carry-in Gram reduction — the jnp mirror of the Pallas grid
    accumulation (``gram_update_acc``): per ``bm``-row block compute both
    Grams, then fold the blocks into ``(ql0, c0)`` strictly left to right.

    This sequence of fp32 adds makes the reduction *streamable*: accumulating
    row chunks one call at a time (any chunk size that is a multiple of
    ``bm``, zero rows appended at the end are bitwise no-ops) produces the
    identical bits as one call over the concatenated rows.  The per-block
    Grams run as one batched matmul, which matches the per-block 2D matmul
    bit for bit on every backend we test (the same batched-matmul stability
    the class-batched fit relies on), so this reference and the Pallas kernel
    agree exactly at matched ``bm``.

    ``A.shape[0]`` must be a multiple of ``bm`` (ops.py pads with zero rows;
    every value in the OAVI domain is >= +0.0, so zero-block adds cannot even
    flip a signed zero).
    """
    m = A.shape[0]
    nb = m // bm
    B = jnp.take(A, parents, axis=1) * jnp.take(X, vars_, axis=1)
    Af = A.astype(jnp.float32).reshape(nb, bm, A.shape[1])
    Bf = B.astype(jnp.float32).reshape(nb, bm, B.shape[1])
    QLb = jnp.einsum("bmi,bmj->bij", Af, Bf)
    Cb = jnp.einsum("bmi,bmj->bij", Bf, Bf)

    def body(carry, blocks):
        ql, c = carry
        gql, gc = blocks
        return (ql + gql, c + gc), None

    (ql, c), _ = jax.lax.scan(body, (ql0, c0), (QLb, Cb))
    return ql, c


def ihb_update_ref(N, q, btb, ell):
    """Theorem 4.9 block-inverse update on the padded inverse (identity in
    the inactive block) — mirrors :func:`repro.core.ihb.append_column`.

    Contract (what every in-algorithm caller satisfies): ``q`` is zero at
    slot ``ell`` and beyond (A has no active columns there) and row/col
    ``ell`` of ``N`` is its identity row, so ``u[ell] = q[ell] = 0``.  Under
    that contract the row/col write below is bit-identical to the masked
    formulation ``P*keep*keepᵀ + onehot⊗n2 + n2⊗onehot + (1/s)onehot⊗onehot``
    (kept entries are multiplied by exactly 1.0) while replacing four O(L^2)
    elementwise passes with two O(L) ``dynamic_update_slice`` writes — the
    candidate loop of the (class-batched) degree step runs this once per
    candidate, so the constant matters.

    Two vmap-bit-stability points the class-batched fit relies on: the Schur
    complement reduces via ``sum(q * u)`` rather than a fused dot (matching
    the Pallas kernel), and every remaining op is elementwise, a matvec, or
    a dus — all of which produce identical bits batched and per-instance.
    """
    dtype = N.dtype
    L = N.shape[0]
    onehot = (jnp.arange(L) == ell).astype(dtype)
    keep = 1.0 - onehot
    u = N @ q
    s = jnp.maximum(btb - jnp.sum(q * u), jnp.asarray(1e-30, dtype))
    n2 = -u / s
    P = N + jnp.outer(u, u) / s
    colrow = n2 * keep + onehot / s  # new row & column ell (diag = 1/s)
    P = jax.lax.dynamic_update_slice(P, colrow[:, None], (0, ell))
    return jax.lax.dynamic_update_slice(P, colrow[None, :], (ell, 0))


def attention_ref(q, k, v, *, causal=True, q_heads_per_kv=1):
    """Dense softmax attention oracle.

    q: (BHq, Sq, d); k, v: (BHkv, Sk, d) with BHq = BHkv * q_heads_per_kv.
    """
    BHq, Sq, d = q.shape
    BHkv, Sk, _ = k.shape
    if q_heads_per_kv != 1:
        k = jnp.repeat(k, q_heads_per_kv, axis=0)
        v = jnp.repeat(v, q_heads_per_kv, axis=0)
    scale = 1.0 / (d**0.5)
    s = jnp.einsum("hqd,hkd->hqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p.astype(v.dtype), v).astype(q.dtype)
