"""Chaos harness: drive the continuous controller through injected faults.

Each scenario asserts the resilience contracts of
:mod:`repro.launch.continuous_vi` end to end, with failures scheduled by a
deterministic :class:`~repro.resilience.chaos.FaultPlan` (never by timing
luck):

``kill_resume``
    SIGKILL the controller subprocess at a chosen journaled phase
    transition (``controller.update_start`` / ``state_saved`` / ``staged``
    / ``activated``), re-run it on the same workdir, and assert the resumed
    final model is **bit-identical** to an uninterrupted run's — the fold
    carry-in contract makes recovery exact, not approximate.  The resumed
    run must also serve with zero bitwise mismatches and zero warm
    recompiles after its first (cold) catch-up update.
``corrupt_state``
    Flip one bit in the newest committed ``FitState`` checkpoint leaf.
    Resume must land on the older verifiable step (corruption is never
    silent), catch up, and still reach the bit-identical final model.
``degraded_activation``
    Inject an activation failure mid-run.  The controller must keep serving
    the last-good version (zero mismatches), report the failed attempt, and
    recover to ``ok`` health on the retry.
``transient_engine``
    Inject transient device failures at the serving engine.  The batcher's
    bounded retry must absorb them: the run completes with zero mismatches.
``poison_isolation``
    Coalesce a poison request (payload carries the chaos sentinel) with
    good requests.  Bisection must fail exactly the poison request; the
    good requests' results stay bit-identical to direct engine outputs.
``torn_shard``
    Corrupt a shard file after its checksum was recorded.  Reading it must
    raise :class:`~repro.resilience.integrity.IntegrityError` naming the
    file — corrupt rows are never served to a fit.

Trace export: every ``kill_resume`` kill runs its controller subprocesses
with the obs flight recorder on, then merges the killed run's trace with
the resumed run's (:func:`repro.obs.merge_traces`) into ONE Perfetto-valid
``--trace-dir/kill_<phase>_<at>.trace.json`` — two processes on one
timeline with ``chaos/sigkill`` / ``chaos/recovery`` instant markers at
the crash boundary, so the recovery story is *visible*, not just asserted.

Usage::

    PYTHONPATH=src python -m repro.launch.chaos_vi --fast --out report.json
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

from .. import obs

# small enough that one controller subprocess finishes in seconds, large
# enough for two drift-quiet updates (the phases kill_resume targets)
RUN_ARGS = [
    "--base-rows", "2048",
    "--increments", "2",
    "--increment-rows", "1024",
    "--shard-rows", "1024",
    "--chunk-rows", "512",
    "--min-update-rows", "1024",
    "--serve-threads", "1",
]


def _run_controller(
    workdir: str,
    *,
    chaos_path: Optional[str] = None,
    timeout_s: float = 300.0,
    extra: Optional[List[str]] = None,
    obs_dir: Optional[str] = None,
) -> subprocess.CompletedProcess:
    out = os.path.join(workdir, "report.json")
    cmd = [
        sys.executable, "-m", "repro.launch.continuous_vi",
        *RUN_ARGS, "--workdir", workdir, "--out", out, *(extra or []),
    ]
    if chaos_path:
        cmd += ["--chaos", chaos_path]
    env = dict(os.environ)
    src = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    if obs_dir:
        # flight recorder on: each activation re-exports, so even the
        # SIGKILL'd run leaves a usable (if partial) trace behind
        cmd += ["--obs-dir", obs_dir]
        env["OBS_ENABLED"] = "1"
    return subprocess.run(
        cmd, capture_output=True, text=True, timeout=timeout_s, env=env
    )


def _merge_scenario_trace(
    trace_dir: str, name: str, run_obs_dirs: List[str], markers: List[Dict]
) -> Optional[str]:
    """Merge per-run flight-recorder traces into one validated timeline."""
    docs = []
    for d in run_obs_dirs:
        p = os.path.join(d, "trace.json")
        if not os.path.exists(p):
            return None  # a run died before its first export; nothing to show
        with open(p) as f:
            docs.append(json.load(f))
    merged = obs.merge_traces(docs, markers=markers)
    obs.validate_chrome_trace(merged)
    os.makedirs(trace_dir, exist_ok=True)
    out = os.path.join(trace_dir, f"{name}.trace.json")
    tmp_path = out + ".tmp"
    with open(tmp_path, "w") as f:
        json.dump(merged, f)
    os.replace(tmp_path, out)
    return out


def _report(workdir: str) -> Dict:
    with open(os.path.join(workdir, "report.json")) as f:
        return json.load(f)


def _final_leaves(workdir: str) -> Dict[str, np.ndarray]:
    from .. import api

    model = api.load(os.path.join(workdir, "final_model"))
    arrays, _ = model.to_state_dict()
    return arrays


def _assert_bit_identical(a: Dict, b: Dict, label: str) -> None:
    assert set(a) == set(b), f"{label}: leaf sets differ"
    for k in sorted(a):
        assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), (
            f"{label}: leaf {k!r} differs bitwise"
        )


def _check_completed(rep: Dict, label: str) -> None:
    assert rep["serve"]["mismatches"] == 0, f"{label}: served bitwise mismatches"
    assert rep["updates"] or rep["resume"]["resumed"], f"{label}: did no work"


# ---------------------------------------------------------------------------
# Scenarios
# ---------------------------------------------------------------------------


def scenario_kill_resume(
    tmp: str, reference: Dict, phases, trace_dir: Optional[str] = None
) -> Dict:
    from ..resilience.chaos import Fault, FaultPlan

    results = []
    for phase, at in phases:
        workdir = os.path.join(tmp, f"kill_{phase}_{at}")
        plan_path = os.path.join(tmp, f"kill_{phase}_{at}.json")
        FaultPlan([Fault(site=f"controller.{phase}", at=at, action="sigkill")]).save(
            plan_path
        )
        obs_killed = os.path.join(workdir, "obs_killed")
        obs_resumed = os.path.join(workdir, "obs_resumed")
        t0 = time.perf_counter()
        proc = _run_controller(workdir, chaos_path=plan_path, obs_dir=obs_killed)
        assert proc.returncode == -9, (
            f"kill at {phase}#{at}: expected SIGKILL exit, got "
            f"{proc.returncode}\n{proc.stderr[-2000:]}"
        )
        proc = _run_controller(workdir, obs_dir=obs_resumed)  # resume, no faults
        recovery_s = time.perf_counter() - t0
        assert proc.returncode == 0, (
            f"resume after kill at {phase}#{at} failed:\n{proc.stderr[-2000:]}"
        )
        rep = _report(workdir)
        assert rep["resume"]["resumed"], f"kill at {phase}#{at}: did not resume"
        assert rep["warm_recompiles"] == 0, (
            f"kill at {phase}#{at}: warm recompiles after catch-up"
        )
        _check_completed(rep, f"kill at {phase}#{at}")
        _assert_bit_identical(
            _final_leaves(workdir), reference, f"kill at {phase}#{at}"
        )
        trace_path = None
        if trace_dir:
            trace_path = _merge_scenario_trace(
                trace_dir,
                f"kill_{phase}_{at}",
                [obs_killed, obs_resumed],
                markers=[
                    {"name": "chaos/sigkill", "after_doc": 0,
                     "args": {"phase": phase, "at": at}},
                    {"name": "chaos/recovery", "after_doc": 0,
                     "args": {"phase": phase}},
                ],
            )
        results.append(
            {"phase": phase, "at": at, "recovery_s": recovery_s,
             "caught_up_rows": rep["resume"]["caught_up_rows"],
             "trace": trace_path}
        )
    return {"ok": True, "kills": results}


def scenario_corrupt_state(tmp: str, reference: Dict) -> Dict:
    from ..checkpoint import store as ckpt_store
    from ..resilience.integrity import flip_bit

    workdir = os.path.join(tmp, "corrupt_state")
    proc = _run_controller(workdir)
    assert proc.returncode == 0, proc.stderr[-2000:]
    state_dir = os.path.join(workdir, "state")
    steps = ckpt_store.committed_steps(state_dir)
    assert len(steps) >= 2, "need >= 2 committed steps to exercise fallback"
    head = os.path.join(state_dir, f"step_{steps[-1]:08d}")
    leaves = [n for n in sorted(os.listdir(head)) if n.endswith(".npy")]
    victim = max((os.path.join(head, n) for n in leaves), key=os.path.getsize)
    flip_bit(victim, byte_offset=-1, bit=3)
    # corruption must be detected, never silent
    try:
        ckpt_store.verify(state_dir, steps[-1])
        raise AssertionError("flipped bit passed verification")
    except Exception as e:
        assert os.path.basename(victim) in str(e), "error does not name bad file"
    proc = _run_controller(workdir)  # resume: falls back to older step
    assert proc.returncode == 0, proc.stderr[-2000:]
    rep = _report(workdir)
    assert rep["resume"]["resumed"]
    assert rep["resume"]["state_rows"] < rep["total_rows"], (
        "resume should have landed on an OLDER (pre-corruption) step"
    )
    _check_completed(rep, "corrupt_state")
    _assert_bit_identical(_final_leaves(workdir), reference, "corrupt_state")
    return {"ok": True, "fallback_from_rows": rep["resume"]["state_rows"]}


def scenario_degraded_activation(tmp: str, reference: Dict) -> Dict:
    from ..resilience.chaos import Fault, FaultPlan

    workdir = os.path.join(tmp, "degraded_activation")
    plan_path = os.path.join(tmp, "degraded_activation.json")
    FaultPlan([Fault(site="registry.activate", at=1, action="raise")]).save(plan_path)
    proc = _run_controller(workdir, chaos_path=plan_path)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rep = _report(workdir)
    assert len(rep["update_failures"]) == 1, "activation fault not recorded"
    assert "InjectedFault" in rep["update_failures"][0]["error"]
    assert rep["health"] == "ok", "controller did not recover after the retry"
    _check_completed(rep, "degraded_activation")
    _assert_bit_identical(_final_leaves(workdir), reference, "degraded_activation")
    return {"ok": True, "failures": rep["update_failures"]}


def scenario_transient_engine(tmp: str, reference: Dict) -> Dict:
    from ..resilience.chaos import Fault, FaultPlan

    workdir = os.path.join(tmp, "transient_engine")
    plan_path = os.path.join(tmp, "transient_engine.json")
    # two one-shot transient faults at serving-path device calls; bounded
    # retry (max_retries=2 default) must absorb each
    FaultPlan(
        [
            Fault(site="engine.transform", at=20, action="raise_transient"),
            Fault(site="engine.transform", at=40, action="raise_transient"),
        ]
    ).save(plan_path)
    proc = _run_controller(workdir, chaos_path=plan_path)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rep = _report(workdir)
    _check_completed(rep, "transient_engine")
    _assert_bit_identical(_final_leaves(workdir), reference, "transient_engine")
    return {"ok": True, "serve_faults": rep["serve"]["faults"]}


def scenario_poison_isolation(tmp: str) -> Dict:
    """In-process: a poison request coalesced with good ones fails alone;
    the good requests' outputs stay bit-identical to direct evaluation."""
    from .. import api
    from ..resilience import chaos
    from ..resilience.chaos import Fault, FaultPlan, PoisonRequestError
    from ..serving import BatcherConfig, MicroBatcher, TransformEngine

    rng0 = np.random.default_rng(5)
    X = rng0.uniform(0, 1, (512, 3)).astype(np.float32)
    X[:, 2] = np.clip(X[:, 0] * X[:, 1] + rng0.normal(0, 0.01, 512), 0, 1)
    model = api.fit(X, method="oavi:fast", psi=0.01, backend="local", cap_terms=64)
    engine = TransformEngine([model])
    engine.warmup()
    rng = np.random.default_rng(11)
    good = [rng.uniform(0, 1, (q, 3)).astype(np.float32) for q in (4, 8, 5)]
    expected = [np.asarray(engine.transform(g)) for g in good]
    poison = rng.uniform(0, 1, (3, 3)).astype(np.float32)
    poison[1, 2] = chaos.POISON_SENTINEL

    chaos.install(FaultPlan([Fault(site="engine.transform", action="poison")]))
    try:
        batcher = MicroBatcher(
            engine, config=BatcherConfig(max_delay_ms=20.0)
        )
        batcher.start()
        try:
            futs = [batcher.submit(g, "transform") for g in good]
            bad = batcher.submit(poison, "transform")
            outs = [f.result(timeout=60) for f in futs]
            try:
                bad.result(timeout=60)
                raise AssertionError("poison request did not fail")
            except PoisonRequestError:
                pass
        finally:
            batcher.stop()
    finally:
        chaos.uninstall()
    for out, exp in zip(outs, expected):
        assert np.array_equal(out, exp), (
            "good request diverged after poison bisection"
        )
    assert batcher.stats["isolated_failures"] >= 1
    return {
        "ok": True,
        "bisections": batcher.stats["bisections"],
        "isolated_failures": batcher.stats["isolated_failures"],
    }


def scenario_torn_shard(tmp: str) -> Dict:
    """In-process: corrupt a shard after its checksum commits; the reader
    must refuse it loudly, naming the file."""
    from ..data.synthetic import write_shards
    from ..resilience.integrity import IntegrityError, flip_bit
    from ..streaming.source import ShardDirSource

    shard_dir = os.path.join(tmp, "torn_shards")
    rng = np.random.default_rng(3)
    write_shards(shard_dir, rng.uniform(0, 1, (256, 4)).astype(np.float32),
                 shard_rows=64)
    victim = os.path.join(shard_dir, "shard_00002.npy")
    flip_bit(victim, byte_offset=200, bit=5)
    src = ShardDirSource(shard_dir)
    assert np.asarray(src.read(0, 64)).shape == (64, 4)  # clean shard serves
    try:
        src.read(128, 192)  # rows of the corrupted shard
        raise AssertionError("corrupt shard rows were served")
    except IntegrityError as e:
        assert "shard_00002.npy" in str(e), "error does not name the bad shard"
    return {"ok": True}


# ---------------------------------------------------------------------------


def main(argv=None) -> Dict:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--fast", action="store_true",
                    help="kill at 2 phases instead of all journaled phases")
    ap.add_argument("--scenarios", type=str, default=None,
                    help="comma-separated subset to run (default: all)")
    ap.add_argument("--tmp", type=str, default=None)
    ap.add_argument("--out", type=str, default=None)
    ap.add_argument("--trace-dir", type=str, default="results/chaos",
                    help="write merged kill/resume Perfetto traces here "
                    "(empty string: skip trace export)")
    args = ap.parse_args(argv)

    tmp = args.tmp or tempfile.mkdtemp(prefix="chaos_vi_")
    os.makedirs(tmp, exist_ok=True)
    wanted = set(args.scenarios.split(",")) if args.scenarios else None

    def want(name: str) -> bool:
        return wanted is None or name in wanted

    report: Dict = {"tmp": tmp, "scenarios": {}}
    t_all = time.perf_counter()

    reference: Optional[Dict[str, np.ndarray]] = None
    needs_ref = any(
        want(s)
        for s in ("kill_resume", "corrupt_state", "degraded_activation",
                  "transient_engine")
    )
    if needs_ref:
        ref_dir = os.path.join(tmp, "reference")
        print("chaos_vi: uninterrupted reference run ...")
        proc = _run_controller(ref_dir)
        assert proc.returncode == 0, proc.stderr[-2000:]
        reference = _final_leaves(ref_dir)
        ref_rep = _report(ref_dir)
        assert ref_rep["serve"]["mismatches"] == 0
        report["reference_rows"] = ref_rep["total_rows"]

    if want("kill_resume"):
        phases = [("state_saved", 1), ("activated", 1)]
        if not args.fast:
            phases += [("update_start", 1), ("staged", 1), ("update_start", 2)]
        print(f"chaos_vi: kill_resume at {len(phases)} phases ...")
        report["scenarios"]["kill_resume"] = scenario_kill_resume(
            tmp, reference, phases, trace_dir=args.trace_dir or None
        )
        traces = [
            k["trace"] for k in report["scenarios"]["kill_resume"]["kills"]
            if k.get("trace")
        ]
        if traces:
            print(f"chaos_vi: {len(traces)} merged traces -> {args.trace_dir}")
    if want("corrupt_state"):
        print("chaos_vi: corrupt_state ...")
        report["scenarios"]["corrupt_state"] = scenario_corrupt_state(tmp, reference)
    if want("degraded_activation"):
        print("chaos_vi: degraded_activation ...")
        report["scenarios"]["degraded_activation"] = scenario_degraded_activation(
            tmp, reference
        )
    if want("transient_engine"):
        print("chaos_vi: transient_engine ...")
        report["scenarios"]["transient_engine"] = scenario_transient_engine(
            tmp, reference
        )
    if want("poison_isolation"):
        print("chaos_vi: poison_isolation ...")
        report["scenarios"]["poison_isolation"] = scenario_poison_isolation(tmp)
    if want("torn_shard"):
        print("chaos_vi: torn_shard ...")
        report["scenarios"]["torn_shard"] = scenario_torn_shard(tmp)

    report["time_total_s"] = time.perf_counter() - t_all
    ok = all(s.get("ok") for s in report["scenarios"].values())
    report["ok"] = ok
    print(
        f"chaos_vi: {len(report['scenarios'])} scenarios "
        f"{'PASSED' if ok else 'FAILED'} in {report['time_total_s']:.1f}s"
    )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
    if not ok:  # pragma: no cover - assertions raise before this
        raise SystemExit(1)
    return report


if __name__ == "__main__":
    main()
