"""Continuous vanishing-ideal fitting: ingest -> drift-gated update -> hot-swap.

The online analogue of :mod:`repro.launch.serve_vi`: instead of fitting once
and replaying a trace, this driver keeps a model CURRENT while its training
data grows, without ever taking serving down:

1. **ingest** — a writer thread appends row batches to a shard directory
   (:func:`repro.data.synthetic.write_shards` ``append=True``; meta.json
   committed last, atomically), the arrival pattern of a production feature
   store.  The fit-side :class:`~repro.streaming.source.ShardDirSource`
   picks the rows up in place via ``refresh()``.
2. **drift gate** — every arrival's rows feed a
   :class:`~repro.online.DriftMonitor` (one-pass moments in the scaled
   space).  An update runs when drift triggers, when enough rows are
   pending (``--min-update-rows``), or when the stream ends.
3. **update** — :func:`repro.online.update` folds the new rows into the
   persisted per-degree Gram state: bit-identical to a full refit on all
   rows, O(new rows) of data work, zero recompiles warm.
4. **activate** — the refreshed model is *staged* into the
   :class:`~repro.serving.ModelRegistry` (``activate=False``), its engine
   warmed and its expected probe outputs recorded, then hot-swapped
   atomically.  Serving traffic (closed-loop prober threads through a
   per-version :class:`~repro.serving.MicroBatcher`) never stops; every
   response is checked bitwise against the expected output of the version
   that served it, so a half-swapped or torn model would fail loudly.

Resilience (the contracts the chaos harness in
:mod:`repro.launch.chaos_vi` exercises):

* **crash recovery** — every phase transition (ingest commit, update start,
  state persisted, version staged/activated) is journaled durably
  (:class:`~repro.resilience.journal.Journal`, fsync per append) *before*
  its effects matter.  The per-update :class:`~repro.online.FitState` is
  checkpointed with content checksums under ``workdir/state``.  A SIGKILL'd
  controller re-run with the same ``--workdir`` resumes: it loads the newest
  *verifiable* state, rebuilds + catches up the model with one
  :func:`~repro.online.update` call (fold commutativity makes the final
  model bit-identical to an uninterrupted run), and the ingest thread skips
  batches whose shards are already committed (re-writing any torn orphan
  shard deterministically, since batches are keyed by ``(seed, batch)``).
* **degrade, don't die** — a failed update / stage / activation is
  journaled, any leaked staged version is removed, and the loop keeps
  serving the last-good version in a ``degraded`` health state; it recovers
  on the next successful update, and only ``--max-failures`` *consecutive*
  failures abort the process.
* **fault injection** — ``--chaos plan.json`` installs a deterministic
  :class:`~repro.resilience.chaos.FaultPlan`; controller sites
  (``controller.update_start`` / ``state_saved`` / ``staged`` /
  ``activated``) fire *after* the corresponding journal append, so a
  ``sigkill`` fault there is exactly a crash between durable transitions.

Reported: per-update fold/replay accounting and warm recompile counts,
staleness (data arrival -> serving activation latency) per arrival, serve
p50/p99 and the update/serve overlap (requests completed while an update
was in flight — the point of the exercise), plus health / failure / resume
accounting.

Usage::

    PYTHONPATH=src python -m repro.launch.continuous_vi --increments 4
    PYTHONPATH=src python -m repro.launch.continuous_vi \
        --base-rows 65536 --increment-rows 4096 --drift-at-increment 2
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from .. import obs


# ---------------------------------------------------------------------------
# Data: deterministic arrival batches over the planted-polynomial stream
# ---------------------------------------------------------------------------


def arrival_batch(
    batch_idx: int, rows: int, n: int, seed: int, drifted: bool = False
) -> np.ndarray:
    """One deterministic ingest batch: near-algebraic-set rows (the
    construction behind :func:`repro.data.synthetic.planted_stream_tile`),
    keyed by ``(seed, batch_idx)`` so replays are exact.  ``drifted`` batches
    are affinely shifted — the distribution the model was fitted on moved,
    which the frozen-scaler drift signals (mean shift, out-of-range values)
    are built to catch."""
    rng_w = np.random.default_rng(seed)
    k = min(3, n)
    w = rng_w.uniform(0.5, 1.5, k)
    c = rng_w.uniform(0.5, 1.5)
    rng = np.random.default_rng(np.random.SeedSequence([seed, batch_idx + 1]))
    X = rng.uniform(0.0, 1.0, (rows, n))
    s = (w * X[:, :k] ** 2).sum(axis=1)
    scale = (c / np.maximum(s, 1e-9)) ** 0.5
    X[:, :k] *= scale[:, None]
    X += rng.normal(0.0, 0.03, X.shape)
    if drifted:
        X = 0.6 * X + 0.35
    return X.astype(np.float32)


# ---------------------------------------------------------------------------
# Serving handle: one version's batcher + expected probe outputs
# ---------------------------------------------------------------------------


class ServingHandle:
    """Everything a prober needs from ONE model version, bound together so a
    single atomic reference swap retargets traffic: requests submitted
    through a handle are checked against the expected outputs of exactly the
    version that computes them (a torn swap cannot silently pass)."""

    def __init__(self, version: int, entry, batcher, expected: List[np.ndarray]):
        self.version = version
        self.entry = entry
        self.batcher = batcher
        self.expected = expected


def stage_handle(registry, name: str, version: int, probes, batcher_config):
    """Build the serving handle for a STAGED version: compute its expected
    probe outputs through the (already warmed) engine and start its
    micro-batcher — all before any traffic sees the version."""
    from ..serving import MicroBatcher

    entry = registry.get(name, version)
    expected = [np.asarray(entry.transform(p, scaled=True)) for p in probes]
    batcher = MicroBatcher(entry.engine, head=entry.head, config=batcher_config)
    batcher.start()
    return ServingHandle(version, entry, batcher, expected)


# ---------------------------------------------------------------------------
# The loop
# ---------------------------------------------------------------------------


def main(argv=None) -> Dict:
    from .. import api as vi_api
    from ..checkpoint import store as ckpt_store
    from ..core.oavi import OAVIConfig
    from ..data.synthetic import write_shards
    from ..online import DriftConfig, DriftMonitor, FitState
    from ..online import fit as online_fit
    from ..online import update as online_update
    from ..resilience import chaos
    from ..resilience.integrity import IntegrityError
    from ..resilience.journal import Journal, JournalError
    from ..serving import (
        BatcherConfig,
        EngineConfig,
        ModelRegistry,
        ShutdownError,
    )
    from ..streaming import ScaledSource, ShardDirSource
    from ..streaming.scaler import StreamingMinMaxScaler

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--base-rows", type=int, default=4096,
                    help="rows in the initial (offline) fit")
    ap.add_argument("--increments", type=int, default=4,
                    help="number of ingest batches appended after the base")
    ap.add_argument("--increment-rows", type=int, default=1024,
                    help="rows per ingest batch (multiple of --shard-rows)")
    ap.add_argument("--shard-rows", type=int, default=1024)
    ap.add_argument("--chunk-rows", type=int, default=512)
    ap.add_argument("--n", type=int, default=3)
    ap.add_argument("--psi", type=float, default=0.005)
    ap.add_argument("--engine", choices=["fast", "oracle"], default="fast")
    ap.add_argument("--min-update-rows", type=int, default=2048,
                    help="pending-row trigger when drift stays quiet")
    ap.add_argument("--drift-at-increment", type=int, default=-1,
                    help="first drifted ingest batch index (-1: no drift)")
    ap.add_argument("--interval-ms", type=float, default=0.0,
                    help="ingest inter-arrival time (0: replay as fast as possible)")
    ap.add_argument("--serve-threads", type=int, default=2)
    ap.add_argument("--probe-rows", type=str, default="8,24,64",
                    help="comma-separated probe request sizes")
    ap.add_argument("--max-delay-ms", type=float, default=1.0)
    ap.add_argument("--workdir", type=str, default=None,
                    help="persistent working directory: shards/, state/, "
                    "journal.jsonl, final_model/ (default: a fresh temp dir)")
    ap.add_argument("--out", type=str, default=None,
                    help="write the report dict as JSON here")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chaos", type=str, default=None,
                    help="install a JSON FaultPlan (see repro.resilience.chaos)")
    ap.add_argument("--max-failures", type=int, default=3,
                    help="consecutive failed updates tolerated before aborting")
    ap.add_argument("--keep-states", type=int, default=3,
                    help="FitState checkpoint steps retained under workdir/state")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore an existing journal/state and restart from scratch")
    ap.add_argument("--journal-max-records", type=int, default=0,
                    help="compact the journal after an activation once it "
                    "exceeds this many records (0: never compact)")
    ap.add_argument("--obs-dir", type=str, default=None,
                    help="export obs artifacts here: trace.json "
                    "(Chrome/Perfetto), metrics.jsonl and slo.json — "
                    "re-exported after every activation (flight recorder), "
                    "so a SIGKILL'd run still leaves its last snapshot")
    ap.add_argument("--slo-p99-ms", type=float, default=50.0,
                    help="serve latency SLO: at most --slo-budget of probe "
                    "requests may exceed this many milliseconds")
    ap.add_argument("--slo-budget", type=float, default=0.01,
                    help="error-budget fraction of the latency SLO "
                    "(0.01 == a p99 target at --slo-p99-ms)")
    args = ap.parse_args(argv)

    if args.increment_rows % args.shard_rows or args.base_rows % args.shard_rows:
        raise SystemExit(
            "--base-rows and --increment-rows must be multiples of "
            "--shard-rows (append only ever adds whole shards)"
        )
    if args.chaos:
        chaos.install(chaos.FaultPlan.load(args.chaos))

    workdir = args.workdir or tempfile.mkdtemp(prefix="continuous_vi_")
    os.makedirs(workdir, exist_ok=True)
    shard_dir = os.path.join(workdir, "shards")
    state_dir = os.path.join(workdir, "state")
    final_dir = os.path.join(workdir, "final_model")
    journal_path = os.path.join(workdir, "journal.jsonl")

    # -- resume decision: a dead process left a durable lineage behind? ----
    # A mid-history-corrupted journal (JournalError) is not resumable — the
    # lineage is a lie; fall through to a loud from-scratch restart.
    try:
        journal = Journal(journal_path)
        resumed = (
            not args.no_resume
            and journal.last("base_fitted") is not None
            and bool(ckpt_store.committed_steps(state_dir))
        )
    except JournalError as e:
        print(f"journal unusable ({e}); restarting from scratch")
        journal = None
        resumed = False

    # the frozen scaler is recomputed, not persisted: the base batch is
    # deterministic in (seed, base_rows, n), so fresh and resumed processes
    # derive bit-identical scaling — a prerequisite for bit-identical folds
    base = arrival_batch(-1, args.base_rows, args.n, args.seed)
    scaler = StreamingMinMaxScaler().fit(base)
    config = OAVIConfig(psi=args.psi, engine=args.engine)
    total_rows = args.base_rows + args.increments * args.increment_rows

    state: Optional[FitState] = None
    if resumed:
        try:
            state = FitState.load(state_dir)  # newest VERIFIABLE committed step
        except (IntegrityError, FileNotFoundError, ValueError) as e:
            print(f"resume failed ({e}); refitting from scratch")
            resumed = False
    if not resumed:
        # fresh start: clear any half-written artifacts of a dead process
        if journal is not None:
            journal.close()
        for p in (shard_dir, state_dir, final_dir):
            if os.path.exists(p):
                shutil.rmtree(p)
        if os.path.exists(journal_path):
            os.remove(journal_path)
        journal = Journal(journal_path)
        write_shards(shard_dir, base, shard_rows=args.shard_rows)

    raw_src = ShardDirSource(shard_dir)
    src = ScaledSource(raw_src, scaler)

    # -- serving scaffolding (probes are deterministic on both paths) ------
    registry = ModelRegistry(engine_config=EngineConfig(), warmup=True)
    probe_sizes = [int(s) for s in args.probe_rows.split(",") if s]
    pool = src.read(0, min(args.base_rows, 4096))
    rng = np.random.default_rng(args.seed + 7)
    probes = []
    for q in probe_sizes:
        take = rng.integers(0, pool.shape[0] - q + 1)
        probes.append(np.ascontiguousarray(pool[take : take + q]))
    batcher_config = BatcherConfig(max_delay_ms=args.max_delay_ms)
    handle_lock = threading.Lock()
    handle_box: Dict[str, Optional[ServingHandle]] = {"h": None}
    old_handles: List[ServingHandle] = []
    updating = threading.Event()

    # -- SLO monitor: burn-rate alerting drives the health state -----------
    # Serve latency reads the per-engine serve.transform_seconds histograms
    # the engines already feed; update reliability reads the loop counters
    # incremented below.  An alert degrades health long before
    # --max-failures would abort the process.
    updates_total_ctr = obs.registry().counter("loop.updates_total")
    update_failures_ctr = obs.registry().counter("loop.update_failures")
    slo_monitor = obs.slo.SLOMonitor([
        obs.slo.latency_objective(
            "serve-latency", "serve.transform_seconds",
            threshold_s=args.slo_p99_ms / 1e3, budget_frac=args.slo_budget,
        ),
        # a quarter of update attempts may fail before the budget burns:
        # transient faults are survivable by design (degrade, don't die)
        obs.slo.error_objective(
            "update-errors", "loop.update_failures", "loop.updates_total",
            budget_frac=0.25,
        ),
    ])
    slo_alerts_fired = 0

    def export_obs() -> Dict:
        """Flight-recorder export: trace + metrics + SLO state, atomically
        re-written after every activation so a killed run keeps its last
        consistent snapshot (the chaos harness merges these per-run docs)."""
        os.makedirs(args.obs_dir, exist_ok=True)
        paths = {
            "trace": os.path.join(args.obs_dir, "trace.json"),
            "metrics": os.path.join(args.obs_dir, "metrics.jsonl"),
            "slo": os.path.join(args.obs_dir, "slo.json"),
        }
        obs.export_trace(paths["trace"])
        obs.export_metrics(paths["metrics"])
        tmp = paths["slo"] + ".tmp"
        with open(tmp, "w") as f:
            json.dump(slo_monitor.state(), f, indent=1)
        os.replace(tmp, paths["slo"])
        return paths

    # -- journaled update cycle --------------------------------------------
    # Each chaos site fires AFTER its journal append: a sigkill fault there
    # is a crash between durable transitions, the exact case resume covers.
    arrivals: List[Dict] = []  # {"cum_rows", "t_arrival"} per batch
    arrivals_lock = threading.Lock()
    staleness: List[float] = []
    updates: List[Dict] = []
    failures: List[Dict] = []
    health = {"state": "ok", "consecutive_failures": 0}
    model = None
    fitted_rows = 0
    next_step = (ckpt_store.committed_steps(state_dir)[-1] + 1) if resumed else 1
    update_seq = sum(1 for r in journal.replay() if r["kind"] == "update_start")

    def update_cycle() -> Dict:
        """Fold -> persist state -> stage -> activate, each transition
        journaled first.  On failure: journal it, unwind any staged leak,
        re-raise — the caller decides degraded-vs-fatal."""
        nonlocal model, state, fitted_rows, next_step, update_seq
        idx = update_seq
        update_seq += 1
        staged_version = None
        new_handle = None
        updating.set()
        t_up = time.perf_counter()
        updates_total_ctr.inc()
        journal.append("update_start", update=idx, rows_visible=src.num_rows)
        chaos.fire("controller.update_start", update=idx)
        try:
            result = online_update(model, state, src, scaler=scaler)
            step = next_step
            result.state.save(state_dir, step=step)
            ckpt_store.cleanup(state_dir, args.keep_states)
            journal.append(
                "state_saved", update=idx, step=step, rows=result.state.num_rows
            )
            chaos.fire("controller.state_saved", update=idx)
            staged = registry.register("vi", result.model, activate=False)
            staged_version = staged.version
            new_handle = stage_handle(
                registry, "vi", staged.version, probes, batcher_config
            )
            journal.append("staged", update=idx, version=staged.version)
            chaos.fire("controller.staged", update=idx)
            registry.activate("vi", staged.version)
            with handle_lock:
                old = handle_box["h"]
                handle_box["h"] = new_handle
            journal.append(
                "activated",
                update=idx,
                version=staged.version,
                rows=result.state.num_rows,
            )
            chaos.fire("controller.activated", update=idx)
            obs.registry().gauge("serve.active_version").set(staged.version)
            obs.event("serve/activate", version=staged.version, update=idx)
            if (
                args.journal_max_records
                and len(journal.replay()) > args.journal_max_records
            ):
                dropped = journal.compact()
                if dropped:
                    print(f"journal compacted: dropped {dropped} records")
        except Exception as e:
            update_failures_ctr.inc()
            journal.append(
                "update_failed", update=idx, error=f"{type(e).__name__}: {e}"
            )
            if new_handle is not None:
                new_handle.batcher.stop()
            if staged_version is not None:
                try:
                    registry.remove("vi", staged_version)
                except KeyError:
                    pass  # never got registered
            raise
        finally:
            updating.clear()
        next_step = step + 1
        model, state = result.model, result.state
        fitted_rows = result.state.num_rows
        if old is not None:
            old_handles.append(old)  # stopped after the loop; drains in-flight
        t_active = time.perf_counter()
        with arrivals_lock:
            for a in arrivals:
                if "t_active" not in a and a["cum_rows"] <= fitted_rows:
                    a["t_active"] = t_active
                    staleness.append(t_active - a["t_arrival"])
        rec = dict(result.stats)
        rec.update(
            version=staged_version,
            rows=fitted_rows,
            time_to_active=t_active - t_up,
        )
        return rec

    # -- initial activation: base fit (fresh) or catch-up update (resumed) --
    resume_info: Dict = {"resumed": False}
    t_base_fit = 0.0
    if resumed:
        t0 = time.perf_counter()
        state_rows = state.num_rows
        rec = update_cycle()  # model=None: rebuild from state + fold pending
        resume_info = {
            "resumed": True,
            "state_rows": int(state_rows),
            "caught_up_rows": int(fitted_rows),
            "recompiles": rec["recompiles"],  # cold: excluded from warm count
            "time_catch_up": time.perf_counter() - t0,
        }
        print(
            f"resumed: state at m={state_rows}, caught up to m={fitted_rows} "
            f"in {resume_info['time_catch_up']:.2f}s "
            f"({rec['recompiles']} cold compiles)"
        )
    else:
        t0 = time.perf_counter()
        model, state = online_fit(
            src, config, chunk_rows=args.chunk_rows, scaler=scaler
        )
        t_base_fit = time.perf_counter() - t0
        state.save(state_dir, step=0)
        journal.append("base_fitted", rows=state.num_rows, step=0)
        fitted_rows = state.num_rows
        entry = registry.register("vi", model, activate=True)
        if entry.engine is None:
            raise SystemExit("model set has no fused plan; nothing to serve")
        handle_box["h"] = stage_handle(
            registry, "vi", entry.version, probes, batcher_config
        )
        obs.registry().gauge("serve.active_version").set(entry.version)
        print(
            f"base fit: m={args.base_rows} |G|+|O|={model.stats['G_plus_O']} "
            f"in {t_base_fit:.2f}s ({model.stats['recompiles']} compiles)"
        )
    monitor = DriftMonitor.from_fit_state(state, DriftConfig())
    if args.obs_dir:
        export_obs()  # first flight-recorder snapshot: base fit / catch-up

    # -- serving traffic: closed-loop probers, bitwise-checked -------------
    stop_serving = threading.Event()
    serve_lat: List[List[float]] = [[] for _ in range(args.serve_threads)]
    serve_overlap = [0] * args.serve_threads  # completed while updating
    serve_mismatch = [0] * args.serve_threads
    serve_fault = [0] * args.serve_threads  # degraded-mode request failures
    serve_errors: List[BaseException] = []

    def prober(tid: int):
        prng = np.random.default_rng(args.seed + 100 + tid)
        while not stop_serving.is_set():
            i = int(prng.integers(0, len(probes)))
            with handle_lock:
                h = handle_box["h"]
            t_req = time.perf_counter()
            try:
                out = h.batcher.submit(probes[i], "transform").result()
            except ShutdownError:
                continue  # handle swapped under us and its batcher stopped
            except RuntimeError:
                serve_fault[tid] += 1  # injected/transient fault; keep serving
                continue
            except BaseException as e:  # pragma: no cover - surfaced below
                serve_errors.append(e)
                return
            serve_lat[tid].append((time.perf_counter() - t_req) * 1e3)
            if updating.is_set():
                serve_overlap[tid] += 1
            if not np.array_equal(out, h.expected[i]):
                serve_mismatch[tid] += 1

    serve_threads = [
        threading.Thread(target=prober, args=(t,), daemon=True)
        for t in range(args.serve_threads)
    ]
    for t in serve_threads:
        t.start()

    # -- ingest: append arrival batches to the shard dir -------------------
    # On resume, batches whose shards are already committed (meta.json rows)
    # are skipped; a torn append (orphan shard files past the committed
    # meta) is harmlessly re-written — batches are deterministic, so the
    # overwrite is bit-identical and the meta commit completes it.
    already = max(0, (raw_src.num_rows - args.base_rows) // args.increment_rows)
    ingest_done = threading.Event()
    ingest_errors: List[BaseException] = []

    def ingest():
        try:
            cum = args.base_rows + already * args.increment_rows
            for b in range(already, args.increments):
                drifted = 0 <= args.drift_at_increment <= b
                rows = arrival_batch(
                    b, args.increment_rows, args.n, args.seed, drifted
                )
                write_shards(shard_dir, rows, append=True)
                cum += args.increment_rows
                journal.append("ingested", batch=b, cum_rows=cum)
                with arrivals_lock:
                    arrivals.append(
                        {"cum_rows": cum, "t_arrival": time.perf_counter()}
                    )
                if args.interval_ms:
                    time.sleep(args.interval_ms / 1e3)
        except BaseException as e:  # surfaced by the controller loop
            ingest_errors.append(e)
        finally:
            ingest_done.set()

    ingest_thread = threading.Thread(target=ingest, daemon=True)
    ingest_thread.start()

    # -- controller: refresh -> drift gate -> update -> stage -> activate --
    try:
        while fitted_rows < total_rows:
            if ingest_errors:
                raise ingest_errors[0]
            alerts = slo_monitor.tick()
            if alerts:
                slo_alerts_fired += len(alerts)
                if health["state"] == "ok":
                    health["state"] = "degraded"
                    a = alerts[0]
                    obs.event(
                        "slo/alert", objective=a["objective"],
                        burn=round(a["burn"], 2),
                    )
                    print(
                        f"SLO alert [{a['objective']}]: burn "
                        f"{a['burn']:.1f}x >= {a['max_burn']}x "
                        f"(bad_frac {a['bad_frac']:.4f} vs budget "
                        f"{a['budget_frac']}); health degraded"
                    )
            elif health["state"] == "degraded" and not health["consecutive_failures"]:
                # the short window drained and updates are healthy again
                health["state"] = "ok"
                obs.event("slo/recovered")
                print("SLO recovered; health ok")
            grew = raw_src.refresh()
            if grew:
                # fold the freshly visible rows into the drift window
                for lo in range(src.num_rows - grew, src.num_rows, args.chunk_rows):
                    monitor.observe(
                        src.read(lo, min(lo + args.chunk_rows, src.num_rows))
                    )
            pending = src.num_rows - fitted_rows
            drifted, sig = monitor.should_refit()
            run = pending > 0 and (
                drifted
                or pending >= args.min_update_rows
                or (ingest_done.is_set() and src.num_rows == total_rows)
            )
            if not run:
                time.sleep(0.002)
                continue

            try:
                rec = update_cycle()
            except Exception as e:
                failures.append(
                    {"update": update_seq - 1, "error": f"{type(e).__name__}: {e}"}
                )
                health["consecutive_failures"] += 1
                health["state"] = "degraded"
                serving = handle_box["h"]
                print(
                    f"update failed ({type(e).__name__}: {e}); serving stays "
                    f"on last-good v{serving.version} "
                    f"[{health['consecutive_failures']} consecutive]"
                )
                if health["consecutive_failures"] > args.max_failures:
                    raise
                time.sleep(0.002)
                continue
            health["consecutive_failures"] = 0
            if not slo_monitor.alerting():
                health["state"] = "ok"
            rec["drift"] = sig
            updates.append(rec)
            monitor.rebase()
            if args.obs_dir:
                export_obs()  # flight recorder: survive a SIGKILL mid-loop
            print(
                f"update v{rec['version']}: +{rec['new_rows']} rows -> "
                f"{fitted_rows}, folded {rec['folded_degrees']} / replayed "
                f"{rec['replayed_degrees']} degrees, "
                f"{rec['recompiles']} recompiles, active in "
                f"{rec['time_to_active']:.3f}s"
                + (f" [drift: {sig['triggered']}]" if sig["triggered"] else "")
            )
        ingest_thread.join()
        journal.append("done", rows=fitted_rows)
    finally:
        stop_serving.set()
        for t in serve_threads:
            t.join()
        for h in old_handles + [handle_box["h"]]:
            if h is not None:
                h.batcher.stop()
        journal.close()
    if serve_errors:
        raise serve_errors[0]

    # -- final model: persisted for the chaos harness's bit comparison ----
    if os.path.exists(final_dir):
        shutil.rmtree(final_dir)
    vi_api.save(model, final_dir)

    # -- report ------------------------------------------------------------
    # same sketch-backed summary as every other obs report (adds p999)
    lat = obs.percentile_summary(x for per in serve_lat for x in per)
    overlap_requests = int(sum(serve_overlap))
    mismatches = int(sum(serve_mismatch))
    update_busy = float(sum(u["time_to_active"] for u in updates))
    report = {
        "base_rows": args.base_rows,
        "total_rows": total_rows,
        "increments": args.increments,
        "engine": args.engine,
        "time_base_fit": t_base_fit,
        "updates": updates,
        "warm_recompiles": int(sum(u["recompiles"] for u in updates)),
        "versions_activated": 1 + len(updates),
        "staleness_s": staleness,
        "staleness_mean_s": float(np.mean(staleness)) if staleness else 0.0,
        "staleness_max_s": float(np.max(staleness)) if staleness else 0.0,
        "serve": {
            "requests": int(lat["count"]) if lat else 0,
            "mismatches": mismatches,
            "faults": int(sum(serve_fault)),
            "during_update_requests": overlap_requests,
            "lat_p50_ms": lat["p50"] if lat else 0.0,
            "lat_p99_ms": lat["p99"] if lat else 0.0,
            "lat_p999_ms": lat["p999"] if lat else 0.0,
        },
        "overlap": {
            "update_busy_s": update_busy,
            "served_during_updates": overlap_requests,
        },
        "health": health["state"],
        "update_failures": failures,
        "resume": resume_info,
        "workdir": workdir,
        "final_model": final_dir,
    }
    slo_monitor.tick()
    report["slo"] = {
        "alerts_fired": slo_alerts_fired,
        "alerting": slo_monitor.alerting(),
        "p99_target_ms": args.slo_p99_ms,
        "budget_frac": args.slo_budget,
        "objectives": [
            {k: o.get(k) for k in ("name", "kind", "total", "bad", "alerting")}
            for o in slo_monitor.state().get("objectives", [])
        ],
    }
    print(
        f"{len(updates)} updates to m={total_rows} "
        f"({report['warm_recompiles']} warm recompiles), staleness "
        f"mean {report['staleness_mean_s']:.3f}s max {report['staleness_max_s']:.3f}s"
    )
    print(
        f"served {report['serve']['requests']} probe requests "
        f"(p50 {report['serve']['lat_p50_ms']:.2f}ms, "
        f"p99 {report['serve']['lat_p99_ms']:.2f}ms), "
        f"{overlap_requests} completed during in-flight updates, "
        f"{mismatches} bitwise mismatches"
    )
    if failures:
        print(
            f"{len(failures)} failed update attempts survived in degraded "
            f"mode (final health: {health['state']})"
        )
    if mismatches:
        print("ERROR: served responses diverged from their version's expected output")
    if report["slo"]["alerts_fired"]:
        print(
            f"SLO: {report['slo']['alerts_fired']} alert ticks fired "
            f"(final health: {health['state']})"
        )
    if args.obs_dir:
        paths = export_obs()
        report["obs"] = paths
        print(
            f"obs: trace -> {paths['trace']} (load in ui.perfetto.dev), "
            f"metrics -> {paths['metrics']}, slo -> {paths['slo']}"
        )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
    return report


if __name__ == "__main__":
    main()
