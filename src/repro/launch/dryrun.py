import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init), which is why the docstring sits below them and
# `from __future__` is omitted in this module.

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract params/caches (ShapeDtypeStruct, no
allocation), jits the appropriate step function with explicit in/out
shardings on the production mesh, and runs ``.lower().compile()``.  Success
proves the sharding configuration is coherent end-to-end (no sharding
mismatches, no unsupported collectives); the compiled artifact yields

* ``memory_analysis()``  — bytes/device (proves the cell fits or documents
  that it does not),
* ``cost_analysis()``    — HLO FLOPs and bytes for the roofline terms,
* the HLO text           — parsed for per-collective byte counts.

Results are appended to ``results/dryrun_<mesh>.json`` for
``benchmarks/roofline.py`` and EXPERIMENTS.md.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..models import model as M
from ..optim import AdamW
from . import mesh as mesh_mod

from .hlo_analysis import collective_bytes  # noqa: E402  (env must be set above)

# ---------------------------------------------------------------------------
# Per-cell lowering
# ---------------------------------------------------------------------------


def _sharded(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(
    arch_id: str,
    shape_name: str,
    mesh,
    *,
    optimizer: Optional[AdamW] = None,
    cfg=None,
):
    """Lower one (arch, shape) cell on ``mesh``.  Returns (lowered, meta)."""
    cfg = cfg or configs.get_config(arch_id)
    shape = configs.SHAPES[shape_name]
    ok, why = configs.cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{arch_id} x {shape_name} skipped: {why}")

    aparams = M.abstract_params(cfg)
    pspecs = M.param_specs(cfg, aparams, mesh)
    pshard = _sharded(pspecs, mesh)
    bspecs = M.batch_specs(cfg, mesh, shape.kind, shape.global_batch)
    bshard = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}
    abatch = configs.input_specs(cfg, shape)

    if shape.kind == "train":
        opt = optimizer or AdamW()
        astate = jax.eval_shape(opt.init, aparams)
        sspecs = opt.state_specs(pspecs)
        sshard = _sharded(sspecs, mesh)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(M.loss_fn)(params, batch, cfg)
            params, opt_state = opt.update(params, grads, opt_state)
            return loss, params, opt_state

        fn = jax.jit(
            train_step,
            in_shardings=(pshard, sshard, bshard),
            out_shardings=(NamedSharding(mesh, P()), pshard, sshard),
        )
        with mesh:
            lowered = fn.lower(aparams, astate, abatch)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return M.prefill(params, batch, cfg, S_max=shape.seq_len)

        acache = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cshard = _sharded(M.cache_specs(cfg, acache, mesh), mesh)
        fn = jax.jit(
            prefill_step,
            in_shardings=(pshard, bshard),
            out_shardings=(NamedSharding(mesh, P()), cshard),
        )
        with mesh:
            lowered = fn.lower(aparams, abatch)
    else:  # decode
        acache = M.abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cshard = _sharded(M.cache_specs(cfg, acache, mesh), mesh)

        def serve_step(params, cache, token, pos):
            return M.decode_step(params, cache, token, pos, cfg)

        fn = jax.jit(
            serve_step,
            in_shardings=(pshard, cshard, bshard["token"], bshard["pos"]),
            out_shardings=(NamedSharding(mesh, P()), cshard),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = fn.lower(
                aparams, acache, abatch["token"], abatch["pos"]
            )

    meta = {"arch": arch_id, "shape": shape_name, "kind": shape.kind}
    return lowered, meta


def _cost_compile(arch_id: str, shape_name: str, mesh, n_periods: int) -> Dict:
    """Compile an ``n_periods``-deep, fully-unrolled variant for cost terms.

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, so the production scan-over-periods module under-reports FLOPs by
    ~n_periods x.  The unrolled 1- and 2-period compiles let us recover the
    exact per-period cost by differencing (collectives and bytes likewise).
    """
    import dataclasses as _dc

    cfg = configs.get_config(arch_id)
    cfg = _dc.replace(cfg, n_periods=n_periods, unroll_scan=True)
    lowered, _ = lower_cell(arch_id, shape_name, mesh, cfg=cfg)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
    }


def run_cell(arch_id: str, shape_name: str, mesh, mesh_name: str,
             *, with_cost: bool = True) -> Dict:
    t0 = time.perf_counter()
    lowered, meta = lower_cell(arch_id, shape_name, mesh)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    rec = {
        **meta,
        "mesh": mesh_name,
        "devices": int(len(mesh.devices.reshape(-1))),
        "time_lower_s": round(t_lower, 2),
        "time_compile_s": round(t_compile, 2),
        "flops_raw": float(cost.get("flops", -1.0)),
        "bytes_raw": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes_raw": coll,
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
    }
    if with_cost:
        n = configs.get_config(arch_id).n_periods
        c1 = _cost_compile(arch_id, shape_name, mesh, 1)
        c2 = _cost_compile(arch_id, shape_name, mesh, 2)
        # per-period marginals clamped at 0: XLA occasionally CSEs the
        # 2-period module harder than the 1-period one, which would
        # otherwise extrapolate to nonsense (negative collectives)
        df = max(c2["flops"] - c1["flops"], 0.0)
        db = max(c2["bytes_accessed"] - c1["bytes_accessed"], 0.0)
        rec["flops"] = c1["flops"] + (n - 1) * df
        rec["bytes_accessed"] = c1["bytes_accessed"] + (n - 1) * db
        coll_true = {}
        for kind in list(c1["collectives"]):
            dk = max(c2["collectives"][kind] - c1["collectives"][kind], 0)
            coll_true[kind] = int(c1["collectives"][kind] + (n - 1) * dk)
        rec["collective_bytes"] = coll_true
        rec["cost_detail"] = {"p1": c1, "p2": c2, "n_periods": n}
    else:
        rec["flops"] = rec["flops_raw"]
        rec["bytes_accessed"] = rec["bytes_raw"]
        rec["collective_bytes"] = coll
    return rec


def run_oavi_cell(mesh, mesh_name: str, *, m_global: int = 4_194_304,
                  n_features: int = 57, Lcap: int = 256, Kcap: int = 64,
                  dtype: str = "float32") -> Dict:
    """The paper's technique on the production mesh: one OAVI degree step
    (fused border-eval + Gram + sequential acceptance) with the sample axis
    sharded over every data axis.  m is chosen spam-shaped (n=57) at ~4M
    samples; the collectives are the two Gram psums (L x K + K x K floats),
    m-independent — the weak-scaling signature of Theorem 4.3.
    """
    import jax.numpy as jnp

    from ..core.distributed import make_sharded_degree_step
    from ..core.oavi import OAVIConfig
    from ..core import ihb as ihb_mod

    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    cfg = OAVIConfig(psi=0.005, engine="fast", cap_terms=Lcap, dtype=dtype)
    step = make_sharded_degree_step(cfg, mesh, data_axes=axes)
    dt = jnp.dtype(dtype)
    aA = jax.ShapeDtypeStruct((m_global, Lcap), dt)
    aX = jax.ShapeDtypeStruct((m_global, n_features), dt)
    # the state is slimmed to the configured engine's factor set (here:
    # engine='fast' -> the Theorem 4.9 inverse only), matching what fit passes
    astate = jax.eval_shape(
        lambda: ihb_mod.init_state(
            Lcap, jnp.asarray(1.0, dt), dt, factors=cfg.ihb_factors()
        )
    )
    i32 = jnp.int32
    t0 = time.perf_counter()
    with mesh:
        lowered = step.lower(
            aA, aX, astate,
            jax.ShapeDtypeStruct((), i32),
            jax.ShapeDtypeStruct((Kcap,), i32),
            jax.ShapeDtypeStruct((Kcap,), i32),
            jax.ShapeDtypeStruct((Kcap,), jnp.bool_),
            jax.ShapeDtypeStruct((), dt),
        )
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "arch": "oavi-gram-step",
        "shape": f"m{m_global // 1_000_000}M_n{n_features}_L{Lcap}_K{Kcap}",
        "kind": "oavi",
        "mesh": mesh_name,
        "devices": int(len(mesh.devices.reshape(-1))),
        "time_lower_s": round(t_lower, 2),
        "time_compile_s": round(t_compile, 2),
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes": coll,
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--oavi", action="store_true",
                    help="lower the paper's OAVI degree step on the mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", type=str, default="results")
    args = ap.parse_args()

    mesh = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    os.makedirs(args.out, exist_ok=True)
    out_path = os.path.join(args.out, f"dryrun_{mesh_name}.json")
    results = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"]) for r in results}

    if args.oavi:
        rec = run_oavi_cell(mesh, mesh_name)
        results = [r for r in results
                   if (r["arch"], r["shape"]) != (rec["arch"], rec["shape"])]
        results.append(rec)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  oavi ok: flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
              f"coll={rec['collective_bytes']['total']:.3e}")
        if not args.all:
            return

    if args.all:
        cells = [
            (a, s) for a, s, ok, _ in configs.all_cells() if ok
        ]
    else:
        cells = [(args.arch, args.shape)]

    for arch_id, shape_name in cells:
        if (arch_id, shape_name) in done:
            print(f"[skip-done] {arch_id} x {shape_name}")
            continue
        print(f"[dryrun:{mesh_name}] {arch_id} x {shape_name} ...", flush=True)
        # cost-extraction compiles (1/2-period unrolled) feed the roofline
        # table, which is single-pod only; the multi-pod pass proves the
        # "pod" axis shards and records raw per-device numbers.
        rec = run_cell(arch_id, shape_name, mesh, mesh_name,
                       with_cost=not args.multi_pod)
        results.append(rec)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        print(
            f"  ok: flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
            f"coll={rec['collective_bytes']['total']:.3e} "
            f"compile={rec['time_compile_s']}s"
        )
    print(f"wrote {out_path} ({len(results)} cells)")


if __name__ == "__main__":
    main()
