"""Pure HLO-text analysis helpers (no jax import, no env side effects).

Split out of launch/dryrun.py so tests and benchmarks can use the parsers
without triggering dryrun's mandatory
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` import-time side
effect (which must stay in dryrun.py, before any jax import, per the
dry-run contract — but must never leak into an in-process pytest session).
"""

from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_RESULT_RE = re.compile(
    r"=\s+(.*?)\s+(" + "|".join(_COLLECTIVES) + r")(?:-start)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in the HLO, by kind."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _RESULT_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pairs: count the -start only
        result_type, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(result_type)
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out
