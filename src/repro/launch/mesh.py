"""Production mesh construction.

Single pod: (data=16, model=16) — 256 v5e chips.
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis extends
data parallelism across the inter-pod DCI links.

Defined as functions (not module constants) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(devices)} "
            "(dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import)"
        )
    dev_array = np.asarray(devices[:need]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_local_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    devices = jax.devices()
    n = len(devices)
    assert n % model_parallel == 0
    shape = (n // model_parallel, model_parallel)
    dev_array = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, ("data", "model"))
