"""Render exported obs artifacts as a human-readable report.

Reads the files a driver run leaves behind (``continuous_vi --obs-dir``,
``bench_obs``, or anything calling :func:`repro.obs.export_metrics` /
:func:`repro.obs.export_trace`) and prints:

* the metric table — every counter/gauge/histogram series with its labels,
  histograms as ``n/mean/p50/p99/p999/max`` (the same renderer the in-process
  ``obs.report_lines`` uses, so live and post-hoc reports read identically);
* a trace summary — per-span event counts and total/mean durations, plus
  instant-event counts, aggregated from the Chrome-trace JSON.

``--follow`` re-reads and re-renders every ``--interval`` seconds — a poor
man's dashboard for watching a continuous loop from another terminal.  The
trace itself is best viewed in ui.perfetto.dev; this summary is for when all
you have is a shell.

Usage::

    PYTHONPATH=src python -m repro.launch.obs_report --obs-dir runs/obs
    PYTHONPATH=src python -m repro.launch.obs_report --obs-dir runs/obs --follow
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional

from .. import obs


def load_metric_rows(path: str) -> Optional[List[Dict]]:
    """Rows of a ``metrics.jsonl`` export (None when the file is absent)."""
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def trace_summary_lines(path: str) -> List[str]:
    """Aggregate a Chrome-trace JSON into per-name span/event totals."""
    if not os.path.exists(path):
        return [f"(no trace at {path})"]
    with open(path) as f:
        doc = json.load(f)
    events = obs.validate_chrome_trace(doc)
    spans: Dict[str, List[float]] = {}
    instants: Dict[str, int] = {}
    for e in events:
        if e["ph"] == "X":
            tot = spans.setdefault(e["name"], [0.0, 0])
            tot[0] += e.get("dur", 0.0)
            tot[1] += 1
        elif e["ph"] == "i":
            instants[e["name"]] = instants.get(e["name"], 0) + 1
    lines = [f"trace: {len(events)} events"]
    for name, (dur_us, n) in sorted(spans.items(), key=lambda kv: -kv[1][0]):
        lines.append(
            f"  span  {name:<28} n={n:<7} total={dur_us / 1e6:.3f}s "
            f"mean={dur_us / n / 1e3:.3f}ms"
        )
    for name, n in sorted(instants.items()):
        lines.append(f"  event {name:<28} n={n}")
    return lines


def report(obs_dir: str) -> List[str]:
    """The full report for one obs export directory."""
    rows = load_metric_rows(os.path.join(obs_dir, "metrics.jsonl"))
    lines: List[str] = []
    if rows is None:
        lines.append(f"(no metrics at {os.path.join(obs_dir, 'metrics.jsonl')})")
    else:
        # reuse the in-process renderer on the exported rows: the snapshot
        # schema is exactly what export_metrics wrote; drop its trace footer
        # (the real trace summary below aggregates the exported trace.json)
        snap = {"metrics": rows, "trace": {}}
        lines.extend(obs.report_lines(snap)[:-1] if rows else ["(no metrics recorded)"])
    lines.append("")
    lines.extend(trace_summary_lines(os.path.join(obs_dir, "trace.json")))
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--obs-dir", type=str, default="results/obs",
                    help="directory holding metrics.jsonl and trace.json")
    ap.add_argument("--follow", action="store_true",
                    help="re-render every --interval seconds until interrupted")
    ap.add_argument("--interval", type=float, default=2.0)
    args = ap.parse_args(argv)

    lines = report(args.obs_dir)
    print("\n".join(lines))
    if args.follow:
        try:
            while True:
                time.sleep(max(args.interval, 0.1))
                lines = report(args.obs_dir)
                print(f"\n--- {time.strftime('%H:%M:%S')} ---")
                print("\n".join(lines))
        except KeyboardInterrupt:
            pass
    return lines


if __name__ == "__main__":
    main()
