"""Render exported obs artifacts as a human-readable report.

Reads the files a driver run leaves behind (``continuous_vi --obs-dir``,
``bench_obs``, or anything calling :func:`repro.obs.export_metrics` /
:func:`repro.obs.export_trace`) and prints:

* the metric table — every counter/gauge/histogram series with its labels,
  histograms as ``n/mean/p50/p99/p999/max`` (the same renderer the in-process
  ``obs.report_lines`` uses, so live and post-hoc reports read identically);
* the SLO state — per-objective burn rates and alert status from the
  ``slo.json`` the continuous loop's flight recorder exports;
* a trace summary — per-span event counts and total/mean durations, plus
  instant-event counts, aggregated from the Chrome-trace JSON.

``--follow`` re-reads and re-renders every ``--interval`` seconds — a poor
man's dashboard for watching a continuous loop from another terminal; a
torn tail in ``metrics.jsonl`` (the writer died mid-line) is skipped with a
warning, like ``Journal``'s torn-tail handling, instead of crashing the
watch loop.  ``--format json`` emits the aggregates as one JSON document
for scripting.  The trace itself is best viewed in ui.perfetto.dev; this
summary is for when all you have is a shell.

Usage::

    PYTHONPATH=src python -m repro.launch.obs_report --obs-dir runs/obs
    PYTHONPATH=src python -m repro.launch.obs_report --obs-dir runs/obs --follow
    PYTHONPATH=src python -m repro.launch.obs_report --obs-dir runs/obs \
        --format json | jq .slo.alerting
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Optional, Tuple

from .. import obs


def load_metric_rows(path: str) -> Tuple[Optional[List[Dict]], List[str]]:
    """Rows of a ``metrics.jsonl`` export plus warnings.

    ``(None, [...])`` when the file is absent.  A torn LAST line (the writer
    was killed mid-append) is skipped with a warning; a bad line anywhere
    else means the file is corrupt, not torn, and raises ``ValueError``.
    """
    if not os.path.exists(path):
        return None, [f"(no metrics at {path})"]
    with open(path) as f:
        lines = [ln for ln in f if ln.strip()]
    rows: List[Dict] = []
    warnings: List[str] = []
    for i, ln in enumerate(lines):
        try:
            rows.append(json.loads(ln))
        except json.JSONDecodeError as e:
            if i == len(lines) - 1:
                warnings.append(f"(torn tail skipped: {path} line {i + 1})")
                break
            raise ValueError(f"corrupt metrics file {path} at line {i + 1}: {e}")
    return rows, warnings


def trace_summary(path: str) -> Optional[Dict]:
    """Aggregate a Chrome-trace JSON into per-name span/event totals."""
    if not os.path.exists(path):
        return None
    with open(path) as f:
        doc = json.load(f)
    events = obs.validate_chrome_trace(doc)
    spans: Dict[str, List[float]] = {}
    instants: Dict[str, int] = {}
    for e in events:
        if e["ph"] == "X":
            tot = spans.setdefault(e["name"], [0.0, 0])
            tot[0] += e.get("dur", 0.0)
            tot[1] += 1
        elif e["ph"] == "i":
            instants[e["name"]] = instants.get(e["name"], 0) + 1
    return {
        "events": len(events),
        "spans": {
            name: {"n": int(n), "total_s": dur_us / 1e6,
                   "mean_ms": dur_us / n / 1e3}
            for name, (dur_us, n) in spans.items()
        },
        "instants": instants,
    }


def trace_summary_lines(summary: Optional[Dict], path: str) -> List[str]:
    if summary is None:
        return [f"(no trace at {path})"]
    lines = [f"trace: {summary['events']} events"]
    for name, s in sorted(
        summary["spans"].items(), key=lambda kv: -kv[1]["total_s"]
    ):
        lines.append(
            f"  span  {name:<28} n={s['n']:<7} total={s['total_s']:.3f}s "
            f"mean={s['mean_ms']:.3f}ms"
        )
    for name, n in sorted(summary["instants"].items()):
        lines.append(f"  event {name:<28} n={n}")
    return lines


def load_slo(path: str) -> Optional[Dict]:
    """The ``slo.json`` flight-recorder export (None when absent/torn)."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except json.JSONDecodeError:
        return None  # mid-replace torn read under --follow; next pass wins


def slo_lines(slo: Optional[Dict]) -> List[str]:
    if slo is None:
        return []
    lines = [f"slo: {'ALERTING' if slo.get('alerting') else 'ok'} "
             f"({slo.get('ticks', 0)} ticks)"]
    for o in slo.get("objectives", []):
        worst = 0.0
        for w in o.get("windows", []):
            worst = max(worst, w["long"]["burn"], w["short"]["burn"])
        target = (
            f"<= {o['threshold_s'] * 1e3:g}ms" if o["kind"] == "latency"
            else f"{o.get('bad_metric')}/{o.get('total_metric')}"
        )
        lines.append(
            f"  {'ALERT' if o.get('alerting') else 'ok   '} {o['name']:<20} "
            f"{target:<28} bad {o.get('bad', 0):g}/{o.get('total', 0):g} "
            f"budget {o['budget_frac']:g} worst-burn {worst:.2f}x"
        )
    return lines


def report_data(obs_dir: str) -> Dict:
    """Aggregates of one obs export directory (the ``--format json`` doc)."""
    rows, warnings = load_metric_rows(os.path.join(obs_dir, "metrics.jsonl"))
    return {
        "obs_dir": obs_dir,
        "metrics": rows,
        "warnings": warnings,
        "slo": load_slo(os.path.join(obs_dir, "slo.json")),
        "trace": trace_summary(os.path.join(obs_dir, "trace.json")),
    }


def report(obs_dir: str, data: Optional[Dict] = None) -> List[str]:
    """The full human-readable report for one obs export directory."""
    data = data or report_data(obs_dir)
    rows = data["metrics"]
    lines: List[str] = list(data["warnings"])
    if rows:
        # reuse the in-process renderer on the exported rows: the snapshot
        # schema is exactly what export_metrics wrote; drop its trace footer
        # (the real trace summary below aggregates the exported trace.json)
        snap = {"metrics": rows, "trace": {}}
        lines.extend(obs.report_lines(snap)[:-1])
    elif rows is not None:
        lines.append("(no metrics recorded)")
    slo = slo_lines(data["slo"])
    if slo:
        lines.append("")
        lines.extend(slo)
    lines.append("")
    lines.extend(
        trace_summary_lines(data["trace"], os.path.join(obs_dir, "trace.json"))
    )
    return lines


def main(argv=None) -> List[str]:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--obs-dir", type=str, default="results/obs",
                    help="directory holding metrics.jsonl, trace.json and "
                    "(when the driver exports one) slo.json")
    ap.add_argument("--follow", action="store_true",
                    help="re-render every --interval seconds until interrupted")
    ap.add_argument("--interval", type=float, default=2.0)
    ap.add_argument("--format", choices=["text", "json"], default="text",
                    help="json: one machine-readable document on stdout")
    args = ap.parse_args(argv)

    def render() -> List[str]:
        data = report_data(args.obs_dir)
        if args.format == "json":
            lines = [json.dumps(data, indent=1)]
        else:
            lines = report(args.obs_dir, data)
        print("\n".join(lines))
        return lines

    lines = render()
    if args.follow:
        try:
            while True:
                time.sleep(max(args.interval, 0.1))
                print(f"\n--- {time.strftime('%H:%M:%S')} ---")
                lines = render()
        except KeyboardInterrupt:
            pass
    return lines


if __name__ == "__main__":
    main()
