"""Serving driver: batched prefill + decode with per-block caches.

The same prefill/decode step functions the dry-run lowers for the
production mesh, driven for real on local devices (reduced configs on CPU).
Implements a minimal continuous-batching-style server core: a request batch
is prefETCHED together, then decoded lock-step; finished sequences are
masked (their slots keep decoding into a scratch position — the static-shape
SPMD analogue of slot recycling).

Usage::

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..models import model as M
from . import mesh as mesh_mod


def serve(
    cfg,
    *,
    batch: int = 4,
    prompt_len: int = 32,
    gen_tokens: int = 32,
    seed: int = 0,
    mesh=None,
    greedy: bool = True,
) -> Dict:
    if not cfg.supports_decode:
        raise ValueError(f"{cfg.name} is encoder-only; no decode path")
    mesh = mesh or mesh_mod.make_local_mesh()
    S_max = prompt_len + gen_tokens
    rng = np.random.default_rng(seed)
    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)

    prefill = jax.jit(lambda p, b: M.prefill(p, b, cfg, S_max=S_max))
    decode = jax.jit(lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))

    t0 = time.perf_counter()
    with mesh:
        logits, cache = prefill(params, {"tokens": prompts})
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(gen_tokens - 1):
        pos = jnp.full((batch,), prompt_len + i, jnp.int32)
        with mesh:
            logits, cache = decode(params, cache, tok, pos)
        if greedy:
            tok = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        else:
            key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
            tok = jax.random.categorical(key, logits[:, 0, :]).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    generated = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    return {
        "generated": generated,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": batch * (gen_tokens - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get_config(args.arch)
    out = serve(cfg, batch=args.batch, prompt_len=args.prompt_len, gen_tokens=args.gen,
                seed=args.seed)
    print(f"prefill {out['prefill_s']:.2f}s; decode {out['decode_s']:.2f}s; "
          f"{out['tokens_per_s']:.1f} tok/s")
    print("sample:", out["generated"][0][:16])


if __name__ == "__main__":
    main()
