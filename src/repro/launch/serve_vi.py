"""Vanishing-ideal serving driver: registry + engine + micro-batcher.

Stands the :mod:`repro.serving` stack up end to end and replays a request
trace against it, reporting tail latency and throughput — the (FT) analogue
of :mod:`repro.launch.serve`'s LM decode loop:

1. **model** — load a committed checkpoint (``--model-dir``; a
   ``VanishingIdealClassifier`` or single ``VanishingIdealModel``), or fit a
   demo classifier on the paper's Appendix C synthetic data and, when
   ``--model-dir`` is given, save it there first (so the next run exercises
   the load path).
2. **engine** — :class:`~repro.serving.engine.TransformEngine`, local by
   default, row-sharded over all visible devices with ``--sharded``
   (``--data-axes``/``--mesh-shape`` control the mesh).  All row buckets are
   warmed before the trace starts.
3. **traffic** — ``--requests`` synthetic mixed-size requests (log-normal
   row counts around ``--mean-rows``), or a file trace (``--trace``: one
   request size per line).  ``--concurrency`` closed-loop clients submit
   through the :class:`~repro.serving.batcher.MicroBatcher` and wait.
4. **report** — p50/p99 latency, rows/s, coalescing and recompile stats.

Usage::

    PYTHONPATH=src python -m repro.launch.serve_vi --requests 256
    PYTHONPATH=src python -m repro.launch.serve_vi --sharded --kind predict \
        --model-dir runs/served-clf --requests 512 --concurrency 16
"""

from __future__ import annotations

import argparse
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from .. import obs


def build_mesh(data_axes, mesh_shape: Optional[List[int]] = None):
    import jax

    axes = tuple(data_axes)
    if mesh_shape is None:
        mesh_shape = [len(jax.devices())] + [1] * (len(axes) - 1)
    if len(mesh_shape) != len(axes):
        raise ValueError(f"--mesh-shape {mesh_shape} does not match axes {axes}")
    return jax.make_mesh(tuple(mesh_shape), axes)


def demo_classifier(m: int, psi: float, seed: int):
    from ..core.pipeline import PipelineConfig, VanishingIdealClassifier
    from ..data.synthetic import appendix_c

    X, y = appendix_c(m=m, seed=seed)
    clf = VanishingIdealClassifier(
        PipelineConfig(method="oavi:fast", psi=psi, oavi_kw={"cap_terms": 64})
    )
    clf.fit(X, y)
    return clf


def synth_trace(num_requests: int, mean_rows: int, seed: int) -> List[int]:
    """Mixed request sizes: log-normal around ``mean_rows`` (heavy right
    tail, lots of small requests — the shape real inference traffic has)."""
    rng = np.random.default_rng(seed)
    sizes = rng.lognormal(mean=np.log(max(mean_rows, 1)), sigma=0.9, size=num_requests)
    return [int(np.clip(round(s), 1, 16 * mean_rows)) for s in sizes]


def load_trace(path: str) -> List[int]:
    with open(path) as f:
        sizes = [int(line) for line in f if line.strip()]
    if not sizes:
        raise ValueError(f"trace file {path!r} is empty")
    return sizes


def replay(
    batcher,
    payloads: List[np.ndarray],
    *,
    kind: str,
    concurrency: int,
) -> Dict:
    """Closed-loop replay: ``concurrency`` clients each send their share of
    the trace, one in-flight request per client.  Returns latency/throughput
    stats (latencies in ms)."""
    latencies = [0.0] * len(payloads)
    errors: List[BaseException] = []
    next_idx = {"i": 0}
    idx_lock = threading.Lock()

    def client():
        while True:
            with idx_lock:
                i = next_idx["i"]
                if i >= len(payloads):
                    return
                next_idx["i"] = i + 1
            t0 = time.perf_counter()
            try:
                batcher.submit(payloads[i], kind).result()
            except BaseException as e:  # surfaced after the run
                errors.append(e)
                return
            latencies[i] = (time.perf_counter() - t0) * 1e3

    threads = [threading.Thread(target=client) for _ in range(concurrency)]
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t_start
    if errors:
        raise errors[0]
    rows = sum(p.shape[0] for p in payloads)
    # shared sketch-based summary (same rounding rule as every obs report,
    # and p999 for free) instead of a hand-rolled np.percentile block
    lat = obs.percentile_summary(latencies)
    return {
        "requests": len(payloads),
        "rows": rows,
        "wall_s": wall,
        "rows_per_s": rows / max(wall, 1e-9),
        "requests_per_s": len(payloads) / max(wall, 1e-9),
        "lat_p50_ms": lat["p50"],
        "lat_p90_ms": lat["p90"],
        "lat_p99_ms": lat["p99"],
        "lat_p999_ms": lat["p999"],
        "lat_max_ms": lat["max"],
    }


def main(argv=None) -> Dict:
    from ..serving import BatcherConfig, EngineConfig, MicroBatcher, ModelRegistry

    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--model-dir", type=str, default=None,
                    help="checkpoint dir to load (or save the demo fit into)")
    ap.add_argument("--sharded", action="store_true",
                    help="shard_map the engine over all visible devices")
    ap.add_argument("--data-axes", type=str, default="data",
                    help="comma-separated mesh axis names for the row dim")
    ap.add_argument("--mesh-shape", type=str, default=None,
                    help="comma-separated device counts per axis (default: all on first)")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--mean-rows", type=int, default=128)
    ap.add_argument("--trace", type=str, default=None,
                    help="file with one request size per line (overrides synthetic)")
    ap.add_argument("--kind", choices=["transform", "predict"], default="predict")
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--max-batch-rows", type=int, default=8192)
    ap.add_argument("--min-bucket", type=int, default=64)
    ap.add_argument("--max-bucket", type=int, default=16384)
    ap.add_argument("--fit-m", type=int, default=4000,
                    help="demo-fit sample count when no checkpoint exists")
    ap.add_argument("--psi", type=float, default=0.005)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    axes = tuple(a for a in args.data_axes.split(",") if a)
    mesh_shape = (
        [int(s) for s in args.mesh_shape.split(",")] if args.mesh_shape else None
    )
    mesh = build_mesh(axes, mesh_shape) if args.sharded else None

    # -- model: load or demo-fit (+save) ---------------------------------
    from ..checkpoint import store as ckpt_store

    registry = ModelRegistry(
        mesh=mesh,
        data_axes=axes,
        engine_config=EngineConfig(
            min_bucket=args.min_bucket, max_bucket=args.max_bucket
        ),
    )
    t0 = time.perf_counter()
    if args.model_dir and ckpt_store.latest_step(args.model_dir) is not None:
        entry = registry.load("default", args.model_dir)
        print(f"loaded checkpoint {args.model_dir!r}")
    else:
        print(f"fitting demo classifier (m={args.fit_m}, psi={args.psi}) ...")
        clf = demo_classifier(args.fit_m, args.psi, args.seed)
        if args.model_dir:
            clf.save(args.model_dir)
            print(f"saved demo classifier to {args.model_dir!r}")
        entry = registry.register("default", clf, path=args.model_dir)
    t_up = time.perf_counter() - t0
    engine = entry.engine
    if engine is None:
        raise SystemExit("loaded servable has no fused plan (VCA?); nothing to serve")
    print(
        f"serving {entry.name!r} v{entry.version}: {len(entry.models)} models, "
        f"{entry.num_features} features, {engine!r}; warm in {t_up:.2f}s"
    )

    # -- traffic ----------------------------------------------------------
    kind = args.kind if entry.head is not None else "transform"
    if kind != args.kind:
        print(f"(no classifier head — serving {kind!r} requests instead)")
    sizes = load_trace(args.trace) if args.trace else synth_trace(
        args.requests, args.mean_rows, args.seed
    )
    rng = np.random.default_rng(args.seed + 1)
    from ..data.synthetic import appendix_c

    pool, _ = appendix_c(m=max(sizes), seed=args.seed + 2)
    pool = entry.scale(pool)  # scale once; requests are slices of the pool
    payloads = []
    for q in sizes:
        take = rng.integers(0, pool.shape[0] - q + 1)
        payloads.append(pool[take : take + q])

    batcher = MicroBatcher(
        engine,
        head=entry.head,
        config=BatcherConfig(
            max_batch_rows=args.max_batch_rows, max_delay_ms=args.max_delay_ms
        ),
    )
    with batcher:
        report = replay(batcher, payloads, kind=kind, concurrency=args.concurrency)

    # -- report -----------------------------------------------------------
    es, bs = engine.stats, batcher.stats
    report.update(
        recompiles=es["recompiles"],
        device_calls=es["device_calls"],
        padded_rows=es["padded_rows"],
        batches=bs["batches"],
        coalesced_max=bs["coalesced_max"],
        shards=engine.shards,
    )
    print(
        f"{report['requests']} {kind} requests ({report['rows']} rows) in "
        f"{report['wall_s']:.2f}s — {report['rows_per_s']:,.0f} rows/s, "
        f"{report['requests_per_s']:.0f} req/s"
    )
    print(
        f"latency p50 {report['lat_p50_ms']:.2f}ms  p90 {report['lat_p90_ms']:.2f}ms  "
        f"p99 {report['lat_p99_ms']:.2f}ms  max {report['lat_max_ms']:.2f}ms"
    )
    print(
        f"engine: {es['device_calls']} device calls over {bs['batches']} batches "
        f"(max coalesce {bs['coalesced_max']}), {es['padded_rows']} padded rows, "
        f"{es['recompiles']} recompiles after warmup"
    )
    if es["recompiles"]:
        print("WARNING: trace triggered recompiles — widen warmup or buckets")
    return report


if __name__ == "__main__":
    main()
