"""Training driver: mesh + config + data pipeline + AdamW + fault tolerance.

Runs for real on whatever devices exist (reduced configs on CPU; the same
code path drives the production mesh on TPU).  Composes every substrate:

    config -> init params (sharded) -> deterministic token pipeline ->
    jit'd train_step (donated state) -> TrainLoop (async checkpoints,
    resume, straggler detection)

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..data import lm as lm_data
from ..models import model as M
from ..optim import AdamW
from ..runtime import TrainLoop, TrainLoopConfig
from . import mesh as mesh_mod


def make_sharded_train_state(cfg, opt, mesh, seed: int = 0):
    """Init params + optimizer state directly into their shardings."""
    aparams = M.abstract_params(cfg, seed)
    pspecs = M.param_specs(cfg, aparams, mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    init = jax.jit(partial(M.init_params, cfg=cfg), out_shardings=pshard)
    with mesh:
        params = init(jax.random.PRNGKey(seed))
    sspecs = opt.state_specs(pspecs)
    sshard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                          is_leaf=lambda x: isinstance(x, P))
    opt_init = jax.jit(opt.init, out_shardings=sshard)
    with mesh:
        opt_state = opt_init(params)
    return params, opt_state, pshard, sshard


def make_step(cfg, opt, mesh, pshard, sshard):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(M.loss_fn)(params, batch, cfg)
        params, opt_state = opt.update(params, grads, opt_state)
        return loss, params, opt_state

    return jax.jit(
        train_step,
        in_shardings=(pshard, sshard, None),
        out_shardings=(NamedSharding(mesh, P()), pshard, sshard),
        donate_argnums=(0, 1),
    )


def train(
    cfg,
    *,
    steps: int = 100,
    global_batch: int = 8,
    seq_len: int = 128,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    mesh=None,
    seed: int = 0,
    log_every: int = 10,
    opt: Optional[AdamW] = None,
) -> Dict[str, Any]:
    mesh = mesh or mesh_mod.make_local_mesh()
    opt = opt or AdamW(peak_lr=3e-4, warmup_steps=min(50, steps // 10 + 1),
                       total_steps=steps)
    params, opt_state, pshard, sshard = make_sharded_train_state(cfg, opt, mesh, seed)
    step_fn = make_step(cfg, opt, mesh, pshard, sshard)
    pipe = lm_data.PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len + 1,
        global_batch=global_batch, seed=seed,
    )

    losses = []
    state = {"params": params, "opt": opt_state}

    def batch_fn(step: int):
        tokens = lm_data.batch_for_mesh(pipe, step, mesh, M.batch_axes(mesh))
        return {"tokens": tokens}

    def wrapped_step(state, batch):
        with mesh:
            loss, params, opt_state = step_fn(state["params"], state["opt"], batch)
        losses.append(float(loss))
        return {"params": params, "opt": opt_state}, {"loss": float(loss)}

    if ckpt_dir is not None:
        loop = TrainLoop(
            TrainLoopConfig(ckpt_dir=ckpt_dir, ckpt_every=ckpt_every),
            wrapped_step, batch_fn, state,
        )
        loop.try_resume()
        report = loop.run(steps)
        state = loop.state
    else:
        t0 = time.perf_counter()
        for i in range(steps):
            state, m = wrapped_step(state, batch_fn(i))
            if i % log_every == 0:
                print(f"step {i:5d} loss {m['loss']:.4f}", flush=True)
        report = {"final_step": steps, "seconds": time.perf_counter() - t0}
    report["losses"] = losses
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get_config(args.arch)
    report = train(
        cfg, steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, seed=args.seed,
    )
    losses = report["losses"]
    print(f"done: {report.get('final_step')} steps; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
