"""LM substrate: config-driven architectures (dense/MoE/MLA/SSM/hybrid)."""

from . import attention, layers, mla, model, moe, ssm
from .model import ModelConfig, init_params, abstract_params, forward, loss_fn

__all__ = [
    "attention", "layers", "mla", "model", "moe", "ssm",
    "ModelConfig", "init_params", "abstract_params", "forward", "loss_fn",
]
