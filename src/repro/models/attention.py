"""GQA attention block (qk-norm / QKV-bias variants) + KV-cache decode.

Forward uses :func:`repro.kernels.ops.multihead_attention` (Pallas flash
kernel on TPU, jnp reference elsewhere).  Decode is a dense one-token
attention over the cache (no kernel needed — it is bandwidth-bound on the
cache read, which the roofline analysis attributes to the memory term).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import hints, layers


class AttnDims(NamedTuple):
    n_heads: int
    n_kv_heads: int
    d_head: int
    qk_norm: bool
    qkv_bias: bool
    rope_theta: float
    causal: bool
    mrope_sections: Optional[Tuple[int, int, int]] = None  # M-RoPE if set
    impl: str = "reference"  # "reference" | "chunked" (flash-in-XLA)
    chunk: int = 1024
    unroll: bool = False  # cost-extraction: unroll the kv-chunk scan


def init_params(key, d_model: int, dims: AttnDims, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    H, Hkv, dh = dims.n_heads, dims.n_kv_heads, dims.d_head
    p = {
        "norm_scale": layers.init_rms_scale(d_model, dtype),
        "wq": layers.dense_init(ks[0], (d_model, H * dh), dtype),
        "wk": layers.dense_init(ks[1], (d_model, Hkv * dh), dtype),
        "wv": layers.dense_init(ks[2], (d_model, Hkv * dh), dtype),
        "wo": layers.dense_init(ks[3], (H * dh, d_model), dtype),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((Hkv * dh,), dtype)
        p["bv"] = jnp.zeros((Hkv * dh,), dtype)
    if dims.qk_norm:
        p["q_norm"] = layers.init_rms_scale(dh, dtype)
        p["k_norm"] = layers.init_rms_scale(dh, dtype)
    return p


def _project_qkv(p, x, dims: AttnDims, positions):
    B, S, _ = x.shape
    H, Hkv, dh = dims.n_heads, dims.n_kv_heads, dims.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if dims.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Hkv, dh)
    v = v.reshape(B, S, Hkv, dh)
    # keep the batch axes pinned through the head split; shard heads over
    # `model` only where divisible (GSPMD otherwise replicates — see hints.py)
    ba = hints.batch_axes()
    if ba:
        bspec = ba if len(ba) > 1 else ba[0]
        q = hints.constrain(q, bspec, None, ("model?", H), None)
        k = hints.constrain(k, bspec, None, ("model?", Hkv), None)
        v = hints.constrain(v, bspec, None, ("model?", Hkv), None)
    if dims.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    if dims.mrope_sections is not None:
        if positions.ndim == 2:
            positions = layers.text_mrope_positions(positions)
        q = layers.apply_mrope(q, positions, dims.rope_theta, dims.mrope_sections)
        k = layers.apply_mrope(k, positions, dims.rope_theta, dims.mrope_sections)
    else:
        q = layers.apply_rope(q, positions, dims.rope_theta)
        k = layers.apply_rope(k, positions, dims.rope_theta)
    return q, k, v


def _chunked_attention(q, k, v, dims: AttnDims):
    """Online-softmax attention, streaming KV in ``dims.chunk`` blocks via
    lax.scan — the flash-attention schedule expressed in XLA (no Pallas), so
    the (Sq, Sk) score matrix never materializes beyond (Sq, chunk).  Used
    on CPU/dry-run paths; on TPU the Pallas kernel supersedes it.

    q: (B, H, Sq, dh); k, v: (B, Hkv, Sk, dh*).  §Perf Cell C iteration.
    """
    B, H, Sq, dh = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = H // Hkv
    ck = min(dims.chunk, Sk)
    nck = Sk // ck if Sk % ck == 0 else -1
    if nck <= 0:  # ragged: fall back to the reference path
        return ops.multihead_attention(q, k, v, causal=dims.causal)
    scale = 1.0 / (dh**0.5)
    kc = k.reshape(B, Hkv, nck, ck, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, nck, ck, dv).transpose(2, 0, 1, 3, 4)
    q_pos = jax.lax.broadcasted_iota(jnp.int32, (Sq, ck), 0)

    def step(carry, inp):
        m_run, s_run, acc = carry
        k_c, v_c, cidx = inp  # (B, Hkv, ck, dh), ..., scalar
        if group != 1:
            k_c = jnp.repeat(k_c, group, axis=1)
            v_c = jnp.repeat(v_c, group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k_c).astype(jnp.float32) * scale
        if dims.causal:
            kv_pos = cidx * ck + jax.lax.broadcasted_iota(jnp.int32, (Sq, ck), 1)
            s = jnp.where((q_pos >= kv_pos)[None, None], s, -1e30)
        m_c = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_run, m_c)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        s_run = s_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(v_c.dtype), v_c
        ).astype(jnp.float32)
        return (m_new, s_run, acc), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    s0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, dv), jnp.float32)
    (m_f, s_f, acc), _ = jax.lax.scan(
        step, (m0, s0, a0), (kc, vc, jnp.arange(nck, dtype=jnp.int32)),
        unroll=nck if dims.unroll else 1,
    )
    return (acc / jnp.maximum(s_f, 1e-30)[..., None]).astype(q.dtype)


def _attend(q, k, v, dims: AttnDims):
    """(B, H, S, dh) attention dispatch: Pallas kernel on TPU, chunked
    flash-in-XLA when configured, dense reference otherwise."""
    if dims.impl == "chunked":
        return _chunked_attention(q, k, v, dims)
    return ops.multihead_attention(q, k, v, causal=dims.causal)


def forward(p: Dict, x: jax.Array, dims: AttnDims, positions: jax.Array) -> jax.Array:
    """Full-sequence attention (training / prefill).  x: (B, S, d_model)."""
    B, S, _ = x.shape
    h = layers.rms_norm(x, p["norm_scale"])
    q, k, v = _project_qkv(p, h, dims, positions)
    out = _attend(
        q.transpose(0, 2, 1, 3),  # (B, H, S, dh)
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        dims,
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, S, dims.n_heads * dims.d_head)
    out = hints.constrain_batch(out)
    return x + out @ p["wo"]


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, Hkv, dh)
    v: jax.Array  # (B, S_max, Hkv, dh)


def init_cache(B: int, S_max: int, dims: AttnDims, dtype) -> KVCache:
    shape = (B, S_max, dims.n_kv_heads, dims.d_head)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def prefill(
    p: Dict, x: jax.Array, dims: AttnDims, positions: jax.Array, S_max: int
) -> Tuple[jax.Array, KVCache]:
    """Forward + cache fill (cache padded to S_max)."""
    B, S, _ = x.shape
    h = layers.rms_norm(x, p["norm_scale"])
    q, k, v = _project_qkv(p, h, dims, positions)
    out = _attend(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        dims,
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, S, dims.n_heads * dims.d_head)
    pad = S_max - S
    cache = KVCache(
        k=jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        v=jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
    )
    return x + out @ p["wo"], cache


def decode_step(
    p: Dict,
    x: jax.Array,  # (B, 1, d_model) — the new token
    cache: KVCache,
    dims: AttnDims,
    pos: jax.Array,  # (B,) int32 — index of the new token
) -> Tuple[jax.Array, KVCache]:
    """One-token decode against a (B, S_max) KV cache.

    The cache is treated as fully populated up to ``pos`` (entries beyond are
    masked).  Bandwidth-bound: reads the whole cache once.
    """
    B, _, _ = x.shape
    H, Hkv, dh = dims.n_heads, dims.n_kv_heads, dims.d_head
    h = layers.rms_norm(x, p["norm_scale"])
    q, k_new, v_new = _project_qkv(p, h, dims, pos[:, None])
    # write the new kv at position pos
    S_max = cache.k.shape[1]
    onehot = (jnp.arange(S_max)[None, :] == pos[:, None]).astype(cache.k.dtype)
    k = cache.k + onehot[:, :, None, None] * k_new
    v = cache.v + onehot[:, :, None, None] * v_new
    # dense one-token attention over the cache (GQA broadcast via reshape)
    group = H // Hkv
    qg = q.reshape(B, 1, Hkv, group, dh)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg, k).astype(jnp.float32)
    scores = scores / (dh**0.5)
    valid = (jnp.arange(S_max)[None, :] <= pos[:, None])[:, None, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", probs.astype(v.dtype), v)
    out = out.reshape(B, 1, H * dh)
    return x + out @ p["wo"], KVCache(k=k, v=v)
