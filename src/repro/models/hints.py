"""Sharding hints: `with_sharding_constraint` helpers that are no-ops when
no mesh is active (CPU unit tests), and axis-aware when lowering under the
production mesh.

Why these exist: GSPMD propagates shardings through reshapes/transposes
heuristically, and the attention head split (B, S, H*dh) -> (B, S, H, dh)
with H not divisible by the model axis makes it fall back to *replicating*
the tensor ("involuntary full rematerialization") — which silently inflates
per-device FLOPs by the data-parallel degree.  Pinning the batch axes at
block boundaries and the head/feature axes where divisible keeps the
partitioner on the intended plan.  (Measured: qwen2-1.5b train went from
8x over the analytic roofline to ~1x after these hints — EXPERIMENTS.md.)
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
from jax.sharding import PartitionSpec as P


def current_mesh():
    """The mesh this trace is running under, or None."""
    try:
        m = jax._src.mesh.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return am
    except Exception:
        pass
    return None


def axis_sizes() -> Dict[str, int]:
    m = current_mesh()
    if m is None:
        return {}
    return {name: int(size) for name, size in zip(m.axis_names, m.shape.values())} \
        if hasattr(m.shape, "values") else dict(m.shape)


def batch_axes() -> Tuple[str, ...]:
    sizes = axis_sizes()
    return tuple(a for a in ("pod", "data") if a in sizes)


def constrain(x, *spec):
    """with_sharding_constraint if a mesh is active; axes not present in the
    mesh are dropped to None.  ``spec`` entries: None | str | tuple of str |
    ("model?", dim_size) — the '?' form shards over model only if the given
    dimension size is divisible by the model-axis size."""
    sizes = axis_sizes()
    if not sizes:
        return x
    clean = []
    for s in spec:
        if s is None:
            clean.append(None)
        elif isinstance(s, tuple) and len(s) == 2 and s[0] == "model?":
            msz = sizes.get("model", 0)
            clean.append("model" if msz and s[1] % msz == 0 else None)
        elif isinstance(s, tuple):
            kept = tuple(a for a in s if a in sizes)
            clean.append(kept if kept else None)
        else:
            clean.append(s if s in sizes else None)
    return jax.lax.with_sharding_constraint(x, P(*clean))


def constrain_batch(x):
    """Pin the leading dim to the batch axes, rest unconstrained... except we
    explicitly mark them None to stop bad propagation."""
    ba = batch_axes()
    if not ba:
        return x
    rest = [None] * (x.ndim - 1)
    return constrain(x, ba if len(ba) > 1 else ba[0], *rest)
