"""Shared neural blocks for the LM substrate: norms, RoPE / M-RoPE, MLPs,
embeddings.  Pure functions over explicit param pytrees (no flax) so that
pjit sharding rules can be assigned by parameter path (see model.py).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * scale


def init_rms_scale(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(d_head_rot: int, theta: float, dtype=jnp.float32) -> jax.Array:
    """Inverse frequencies for the rotary half-dim (d_head_rot // 2)."""
    half = d_head_rot // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)).astype(
        dtype
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE.  x: (B, S, H, dh) — rotates the full head dim.
    positions: (B, S) int32."""
    B, S, H, dh = x.shape
    inv = rope_freqs(dh, theta)
    ang = positions[..., None].astype(jnp.float32) * inv[None, None, :]  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: Tuple[int, int, int]
) -> jax.Array:
    """M-RoPE (Qwen2-VL): the rotary half-dim is partitioned into
    (temporal, height, width) sections, each rotated by its own position id.

    x: (B, S, H, dh); positions: (B, S, 3) int32; sections sums to dh // 2.
    """
    B, S, H, dh = x.shape
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(dh, theta)  # (half,)
    # section id per frequency slot
    sec_pos = []
    start = 0
    for i, sec in enumerate(sections):
        sec_pos.append(jnp.broadcast_to(positions[..., i : i + 1], (B, S, sec)))
        start += sec
    pos = jnp.concatenate(sec_pos, axis=-1)  # (B, S, half)
    ang = pos.astype(jnp.float32) * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def text_mrope_positions(positions: jax.Array) -> jax.Array:
    """Pure-text M-RoPE: t = h = w = sequence index.  (B, S) -> (B, S, 3)."""
    return jnp.broadcast_to(positions[..., None], (*positions.shape, 3))


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, w_in: jax.Array, w_out: jax.Array) -> jax.Array:
    """Fused-gate SwiGLU: w_in packs [gate | up] along the output dim."""
    h = x @ w_in
    gate, up = jnp.split(h, 2, axis=-1)
    return (jax.nn.silu(gate) * up) @ w_out


def gelu_mlp(x: jax.Array, w_in: jax.Array, w_out: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ w_in, approximate=True) @ w_out


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = 1.0 / (fan_in**0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(
        dtype
    )


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
