"""Multi-head Latent Attention (DeepSeek-V2) — compressed KV cache.

The KV path is factored through a low-rank latent ``c_kv`` of dimension
``kv_lora_rank`` plus a shared rotary key ``k_pe`` of dimension
``rope_head_dim``; only ``(c_kv, k_pe)`` are cached, shrinking the decode
cache by ~an order of magnitude versus GQA.  Implemented in the explicit
(non-absorbed) form for training/prefill; decode uses the same up-projection
per step.  (The absorbed-matmul decode optimization is a recorded
hillclimbing candidate in EXPERIMENTS.md §Perf.)
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from . import layers


class MLADims(NamedTuple):
    n_heads: int
    kv_lora_rank: int  # r
    qk_nope_dim: int  # per-head non-rotary q/k dim
    qk_rope_dim: int  # shared rotary dim
    v_head_dim: int
    rope_theta: float
    causal: bool = True
    impl: str = "reference"  # "reference" | "chunked" (shares attention.py's)
    chunk: int = 1024
    unroll: bool = False


def init_params(key, d_model: int, dims: MLADims, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    H = dims.n_heads
    r = dims.kv_lora_rank
    return {
        "norm_scale": layers.init_rms_scale(d_model, dtype),
        # queries: full-rank projection to per-head (nope + rope) dims
        "wq": layers.dense_init(ks[0], (d_model, H * (dims.qk_nope_dim + dims.qk_rope_dim)), dtype),
        # KV down-projection to the latent + shared rotary key
        "w_dkv": layers.dense_init(ks[1], (d_model, r + dims.qk_rope_dim), dtype),
        "kv_norm": layers.init_rms_scale(r, dtype),
        # up-projections from the latent
        "w_uk": layers.dense_init(ks[2], (r, H * dims.qk_nope_dim), dtype),
        "w_uv": layers.dense_init(ks[3], (r, H * dims.v_head_dim), dtype),
        "wo": layers.dense_init(ks[4], (H * dims.v_head_dim, d_model), dtype),
    }


def _latent(p, h, dims: MLADims, positions):
    """Compressed KV latent and rotary key from the (normed) input."""
    B, S, _ = h.shape
    dkv = h @ p["w_dkv"]
    c_kv, k_pe = jnp.split(dkv, [dims.kv_lora_rank], axis=-1)
    c_kv = layers.rms_norm(c_kv, p["kv_norm"])
    k_pe = layers.apply_rope(
        k_pe.reshape(B, S, 1, dims.qk_rope_dim), positions, dims.rope_theta
    ).reshape(B, S, dims.qk_rope_dim)
    return c_kv, k_pe


def _q_heads(p, h, dims: MLADims, positions):
    B, S, _ = h.shape
    H = dims.n_heads
    q = (h @ p["wq"]).reshape(B, S, H, dims.qk_nope_dim + dims.qk_rope_dim)
    q_nope, q_pe = jnp.split(q, [dims.qk_nope_dim], axis=-1)
    q_pe = layers.apply_rope(q_pe, positions, dims.rope_theta)
    return q_nope, q_pe


def _expand_kv(p, c_kv, dims: MLADims):
    B, S, _ = c_kv.shape
    H = dims.n_heads
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, dims.qk_nope_dim)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, dims.v_head_dim)
    return k_nope, v


def forward(p: Dict, x: jax.Array, dims: MLADims, positions: jax.Array) -> jax.Array:
    B, S, _ = x.shape
    H = dims.n_heads
    h = layers.rms_norm(x, p["norm_scale"])
    c_kv, k_pe = _latent(p, h, dims, positions)
    q_nope, q_pe = _q_heads(p, h, dims, positions)
    k_nope, v = _expand_kv(p, c_kv, dims)
    # concat (nope | rope) per head; rope part of K is shared across heads
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, dims.qk_rope_dim))],
        axis=-1,
    )
    if dims.impl == "chunked":
        from .attention import _chunked_attention

        out = _chunked_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), dims,
        )
    else:
        out = ops.multihead_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=dims.causal,
        )
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * dims.v_head_dim)
    return x + out @ p["wo"]


class MLACache(NamedTuple):
    c_kv: jax.Array  # (B, S_max, r)
    k_pe: jax.Array  # (B, S_max, qk_rope_dim)


def init_cache(B: int, S_max: int, dims: MLADims, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((B, S_max, dims.kv_lora_rank), dtype),
        k_pe=jnp.zeros((B, S_max, dims.qk_rope_dim), dtype),
    )


def prefill(
    p: Dict, x: jax.Array, dims: MLADims, positions: jax.Array, S_max: int
) -> Tuple[jax.Array, MLACache]:
    B, S, _ = x.shape
    out = forward(p, x, dims, positions)
    h = layers.rms_norm(x, p["norm_scale"])
    c_kv, k_pe = _latent(p, h, dims, positions)
    pad = S_max - S
    cache = MLACache(
        c_kv=jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
        k_pe=jnp.pad(k_pe, ((0, 0), (0, pad), (0, 0))),
    )
    return out, cache


def decode_step(
    p: Dict, x: jax.Array, cache: MLACache, dims: MLADims, pos: jax.Array
) -> Tuple[jax.Array, MLACache]:
    """One-token decode: only the latent (r + rope) row is appended; K/V are
    re-expanded from the latent cache (explicit form)."""
    B = x.shape[0]
    H = dims.n_heads
    S_max = cache.c_kv.shape[1]
    h = layers.rms_norm(x, p["norm_scale"])
    c_new, kpe_new = _latent(p, h, dims, pos[:, None])
    onehot = (jnp.arange(S_max)[None, :] == pos[:, None]).astype(cache.c_kv.dtype)
    c_kv = cache.c_kv + onehot[:, :, None] * c_new
    k_pe = cache.k_pe + onehot[:, :, None] * kpe_new
    q_nope, q_pe = _q_heads(p, h, dims, pos[:, None])
    k_nope, v = _expand_kv(p, c_kv, dims)  # (B, S_max, H, ...)
    scores = (
        jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
        + jnp.einsum("bqhd,bsd->bhqs", q_pe, k_pe)
    ).astype(jnp.float32)
    scores = scores / ((dims.qk_nope_dim + dims.qk_rope_dim) ** 0.5)
    valid = (jnp.arange(S_max)[None, :] <= pos[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)
    out = out.reshape(B, 1, H * dims.v_head_dim)
    return x + out @ p["wo"], MLACache(c_kv=c_kv, k_pe=k_pe)
