"""Config-driven model stack: every assigned architecture as one config.

A model is a *period* of sub-block types (``attn``, ``mla``, ``mlp``,
``moe``, ``mamba``, ``mlstm``, ``slstm``) repeated ``n_periods`` times.
Parameters for each period position are stacked over the period axis and the
stack is applied with ``lax.scan`` — HLO size stays O(period), not O(layers),
which keeps 61–72-layer compiles tractable and is remat-friendly.

Three step functions are exposed per config:

* ``forward``        — logits for a full sequence (training / encoder)
* ``loss_fn`` + ``make_train_step``   — next-token (or frame-label) CE
* ``prefill`` / ``decode_step``       — KV/state-cache serving path

Sharding is assigned by parameter *path* (see :func:`param_specs`): a
baseline FSDP×TP scheme — matrix in-dims sharded over ``data``, out-dims /
heads / experts over ``model``, batch over ``(pod?, data)``.  The perf loop
(EXPERIMENTS.md §Perf) iterates on these rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import attention, hints, layers, mla, moe, ssm

BlockParams = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | audio | vlm | hybrid
    n_periods: int
    period: Tuple[str, ...]  # sub-block types applied in order, per period
    d_model: int
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    causal: bool = True
    mrope_sections: Optional[Tuple[int, int, int]] = None
    # dense mlp
    d_ff: int = 0
    # family-specific dims
    moe: Optional[moe.MoEDims] = None
    mla: Optional[mla.MLADims] = None
    mamba: Optional[ssm.MambaDims] = None
    mlstm: Optional[ssm.MLSTMDims] = None
    slstm: Optional[ssm.SLSTMDims] = None
    # io
    frontend: str = "tokens"  # tokens | frames (precomputed embeddings stub)
    tie_embeddings: bool = False
    # numerics / scaling
    dtype: str = "bfloat16"
    remat: bool = True
    # remat_policy: "full" (recompute everything), "dots" (save matmul
    # outputs, recompute elementwise) — §Perf Cell B iteration
    remat_policy: str = "full"
    ssm_chunk: int = 256
    # ce_impl: "plain" materializes (B,S,V) logits; "chunked" scans over
    # vocab chunks with running (max, sum-exp, gold) so logits never
    # materialize — §Perf Cell B iteration
    ce_impl: str = "plain"
    ce_chunk: int = 8192
    # attn_impl: "reference" (dense softmax via kernels.ops fallback) or
    # "chunked" (online-softmax lax.scan over KV blocks — flash-in-XLA,
    # bounds the S^2 working set) — §Perf Cell C iteration
    attn_impl: str = "reference"
    attn_chunk: int = 1024
    # cost-extraction mode: fully unroll the period scan and the SSM inner
    # scans so compiled.cost_analysis() counts every layer (XLA counts a
    # while-loop body ONCE regardless of trip count — see launch/dryrun.py)
    unroll_scan: bool = False
    # capability flags (drive the dry-run cell grid)
    supports_decode: bool = True
    sub_quadratic: bool = False  # can run long_500k

    @property
    def n_layers(self) -> int:
        return self.n_periods * len(self.period)

    def jax_dtype(self):
        return jnp.dtype(self.dtype)

    def attn_dims(self) -> attention.AttnDims:
        return attention.AttnDims(
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            d_head=self.d_head,
            qk_norm=self.qk_norm,
            qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta,
            causal=self.causal,
            mrope_sections=self.mrope_sections,
            impl=self.attn_impl,
            chunk=self.attn_chunk,
            unroll=self.unroll_scan,
        )


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _init_block(key, btype: str, cfg: ModelConfig, dtype) -> BlockParams:
    d = cfg.d_model
    if btype == "attn":
        return attention.init_params(key, d, cfg.attn_dims(), dtype)
    if btype == "mla":
        return mla.init_params(key, d, cfg.mla, dtype)
    if btype == "mlp":
        k1, k2 = jax.random.split(key)
        return {
            "norm_scale": layers.init_rms_scale(d, dtype),
            "w_in": layers.dense_init(k1, (d, 2 * cfg.d_ff), dtype),
            "w_out": layers.dense_init(k2, (cfg.d_ff, d), dtype),
        }
    if btype == "moe":
        return moe.init_params(key, d, cfg.moe, dtype)
    if btype == "mamba":
        return ssm.init_params(key, d, cfg.mamba, dtype)
    if btype == "mlstm":
        return ssm.mlstm_init_params(key, d, cfg.mlstm, dtype)
    if btype == "slstm":
        return ssm.slstm_init_params(key, d, cfg.slstm, dtype)
    raise ValueError(f"unknown block type {btype!r}")


def init_params(key, cfg: ModelConfig) -> Dict:
    dtype = cfg.jax_dtype()
    k_embed, k_head, k_blocks = jax.random.split(key, 3)
    params: Dict[str, Any] = {}
    if cfg.frontend == "tokens":
        params["embed"] = layers.embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dtype)
    else:  # frames: precomputed embeddings -> learned input projection (stub)
        params["embed_proj"] = layers.dense_init(k_embed, (cfg.d_model, cfg.d_model), dtype)
    blocks: Dict[str, Any] = {}
    for idx, btype in enumerate(cfg.period):
        keys = jax.random.split(jax.random.fold_in(k_blocks, idx), cfg.n_periods)
        blocks[f"{idx:02d}_{btype}"] = jax.vmap(
            lambda k: _init_block(k, btype, cfg, dtype)
        )(keys)
    params["blocks"] = blocks
    params["final_norm"] = layers.init_rms_scale(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = layers.dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype)
    return params


def abstract_params(cfg: ModelConfig, seed: int = 0):
    """ShapeDtypeStruct pytree (no allocation) — dry-run input."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(seed), cfg))


# ---------------------------------------------------------------------------
# Forward (full sequence)
# ---------------------------------------------------------------------------


def _apply_block(btype: str, p: BlockParams, x, cfg: ModelConfig, positions, aux):
    if btype == "attn":
        return attention.forward(p, x, cfg.attn_dims(), positions), aux
    if btype == "mla":
        mdims = cfg.mla._replace(
            impl=cfg.attn_impl, chunk=cfg.attn_chunk, unroll=cfg.unroll_scan)
        return mla.forward(p, x, mdims, positions), aux
    if btype == "mlp":
        h = layers.rms_norm(x, p["norm_scale"])
        return x + layers.swiglu(h, p["w_in"], p["w_out"]), aux
    if btype == "moe":
        out, a = moe.forward(p, x, cfg.moe)
        return out, aux + a
    # NOTE: the mamba/mlstm inner chunk scans stay ROLLED even in
    # unroll_scan (cost-extraction) mode: unrolling them makes XLA-CPU
    # compiles pathological (~8 min/cell) while the inner bodies account
    # for <=4% of per-token FLOPs (intra-chunk recurrence vs projections;
    # bound derived in EXPERIMENTS.md §Dry-run) — the roofline terms carry
    # that documented undercount instead.
    if btype == "mamba":
        return ssm.forward(p, x, cfg.mamba, cfg.ssm_chunk), aux
    if btype == "mlstm":
        return ssm.mlstm_forward(p, x, cfg.mlstm, cfg.ssm_chunk), aux
    if btype == "slstm":
        return ssm.slstm_forward(p, x, cfg.slstm, cost_mode=cfg.unroll_scan), aux
    raise ValueError(btype)


def _embed(params, cfg: ModelConfig, batch) -> jax.Array:
    if cfg.frontend == "tokens":
        return jnp.take(params["embed"], batch["tokens"], axis=0)
    return batch["frames"].astype(cfg.jax_dtype()) @ params["embed_proj"]


def _unembed(params, cfg: ModelConfig, x) -> jax.Array:
    x = layers.rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["head"]


def forward(params: Dict, batch: Dict, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence logits.  batch: {'tokens' | 'frames': ...}.
    Returns (logits (B, S, V), moe aux loss scalar)."""
    x = _embed(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, period_params):
        x, aux = carry
        for idx, btype in enumerate(cfg.period):
            p = period_params[f"{idx:02d}_{btype}"]
            x = hints.constrain_batch(x)  # re-pin batch axes every block
            x, aux = _apply_block(btype, p, x, cfg, positions, aux)
        return (x, aux), None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        scan_body = jax.checkpoint(body, policy=policy)
    else:
        scan_body = body
    (x, aux), _ = jax.lax.scan(
        scan_body, (x, jnp.asarray(0.0, jnp.float32)), params["blocks"],
        unroll=cfg.n_periods if cfg.unroll_scan else 1,
    )
    return _unembed(params, cfg, x), aux


# ---------------------------------------------------------------------------
# Loss / train step
# ---------------------------------------------------------------------------


def loss_fn(params: Dict, batch: Dict, cfg: ModelConfig) -> jax.Array:
    """Causal LMs: next-token CE (inputs shifted).  Encoders: frame-label CE.

    The CE is written entirely as *reductions over the vocab axis* (max /
    exp-sum / one-hot dot) rather than ``take_along_axis``: with the vocab
    dimension sharded over ``model``, GSPMD turns each reduction into a
    per-shard partial + an all-reduce of (B, S) scalars, so the full logits
    tensor is never regathered or replicated (a gather over a sharded axis
    forces an all-gather of the (B, S, V) logits — hundreds of GB/device at
    these vocab sizes).
    """
    if cfg.causal and cfg.frontend == "tokens":
        inputs = {"tokens": batch["tokens"][:, :-1]}
        labels = batch["tokens"][:, 1:]
    else:
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        labels = batch["labels"]

    if cfg.ce_impl == "chunked":
        return _chunked_ce(params, inputs, labels, cfg)

    logits, aux = forward(params, inputs, cfg)
    logits = logits.astype(jnp.float32)
    mx = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - mx), axis=-1)) + mx[..., 0]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, logits.shape[-1]), 2
    )
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    ce = jnp.mean(lse - gold)
    return ce + aux


def _final_hidden(params: Dict, batch: Dict, cfg: ModelConfig):
    """Forward up to (and including) the final norm, no unembedding."""
    x = _embed(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, period_params):
        x, aux = carry
        for idx, btype in enumerate(cfg.period):
            p = period_params[f"{idx:02d}_{btype}"]
            x = hints.constrain_batch(x)
            x, aux = _apply_block(btype, p, x, cfg, positions, aux)
        return (x, aux), None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        scan_body = jax.checkpoint(body, policy=policy)
    else:
        scan_body = body
    (x, aux), _ = jax.lax.scan(
        scan_body, (x, jnp.asarray(0.0, jnp.float32)), params["blocks"],
        unroll=cfg.n_periods if cfg.unroll_scan else 1,
    )
    return layers.rms_norm(x, params["final_norm"]), aux


def _chunked_ce(params: Dict, inputs: Dict, labels, cfg: ModelConfig) -> jax.Array:
    """Cross-entropy without materializing (B, S, V) logits (§Perf Cell B).

    The unembedding matmul is streamed over vocab chunks inside a scan that
    carries running (max, sum-exp, gold-logit); per-step live memory is
    (B, S, ce_chunk) instead of (B, S, V).  Exact (online-softmax algebra).
    """
    x, aux = _final_hidden(params, inputs, cfg)
    B, S, _ = x.shape
    head = params["embed"].T if cfg.tie_embeddings else params["head"]  # (d, V)
    V = head.shape[1]
    ck = cfg.ce_chunk
    nck = (V + ck - 1) // ck
    Vpad = nck * ck
    if Vpad != V:
        head = jnp.pad(head, ((0, 0), (0, Vpad - V)))
    head_chunks = head.reshape(head.shape[0], nck, ck).transpose(1, 0, 2)

    def step(carry, inp):
        m_run, s_run, gold = carry
        h_c, cidx = inp
        logit_c = (x @ h_c).astype(jnp.float32)  # (B, S, ck)
        # mask padded vocab entries
        vocab_ids = cidx * ck + jax.lax.broadcasted_iota(jnp.int32, (1, 1, ck), 2)
        logit_c = jnp.where(vocab_ids < V, logit_c, -1e30)
        m_c = jnp.max(logit_c, axis=-1)
        m_new = jnp.maximum(m_run, m_c)
        s_run = s_run * jnp.exp(m_run - m_new) + jnp.sum(
            jnp.exp(logit_c - m_new[..., None]), axis=-1
        )
        hit = labels[..., None] == vocab_ids
        gold = gold + jnp.sum(jnp.where(hit, logit_c, 0.0), axis=-1)
        return (m_new, s_run, gold), None

    m0 = jnp.full((B, S), -1e30, jnp.float32)
    s0 = jnp.zeros((B, S), jnp.float32)
    g0 = jnp.zeros((B, S), jnp.float32)
    (m_fin, s_fin, gold), _ = jax.lax.scan(
        step, (m0, s0, g0), (head_chunks, jnp.arange(nck, dtype=jnp.int32)),
        unroll=nck if cfg.unroll_scan else 1,
    )
    lse = m_fin + jnp.log(jnp.maximum(s_fin, 1e-30))
    return jnp.mean(lse - gold) + aux


def make_train_step(cfg: ModelConfig, optimizer):
    """(params, opt_state, batch) -> (loss, params, opt_state)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return loss, params, opt_state

    return train_step


# ---------------------------------------------------------------------------
# Serving: prefill + one-token decode with per-block caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, B: int, S_max: int) -> Dict:
    """Stacked (n_periods, ...) cache pytree mirroring params['blocks']."""
    dtype = cfg.jax_dtype()
    cache: Dict[str, Any] = {}
    for idx, btype in enumerate(cfg.period):
        key = f"{idx:02d}_{btype}"
        if btype == "attn":
            one = attention.init_cache(B, S_max, cfg.attn_dims(), dtype)
        elif btype == "mla":
            one = mla.init_cache(B, S_max, cfg.mla, dtype)
        elif btype == "mamba":
            one = ssm.init_state(B, cfg.mamba, dtype)
        elif btype == "mlstm":
            one = ssm.mlstm_init_state(B, cfg.mlstm, dtype)
        elif btype == "slstm":
            one = ssm.slstm_init_state(B, cfg.d_model, dtype)
        else:
            one = {}
        cache[key] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_periods, *x.shape)), one
        )
    return cache


def abstract_cache(cfg: ModelConfig, B: int, S_max: int):
    return jax.eval_shape(lambda: init_cache(cfg, B, S_max))


def prefill(params: Dict, batch: Dict, cfg: ModelConfig, S_max: int):
    """Forward over the prompt, filling caches.  Returns (last_logits, cache)."""
    x = _embed(params, cfg, batch)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, period_params):
        caches = {}
        for idx, btype in enumerate(cfg.period):
            key = f"{idx:02d}_{btype}"
            p = period_params[key]
            if btype == "attn":
                x, c = attention.prefill(p, x, cfg.attn_dims(), positions, S_max)
            elif btype == "mla":
                mdims = cfg.mla._replace(
                    impl=cfg.attn_impl, chunk=cfg.attn_chunk, unroll=cfg.unroll_scan)
                x, c = mla.prefill(p, x, mdims, positions, S_max)
            elif btype == "mamba":
                # forward + reconstruct final state via a one-step replay is
                # wasteful; run the chunked scan then a tail decode pass is
                # equivalent — for the dry-run we simply re-run decode_step on
                # the last token after a full forward.  Cheap approximation:
                # full forward; state = zeros (documented serving limitation).
                x = ssm.forward(p, x, cfg.mamba, cfg.ssm_chunk)
                c = ssm.init_state(B, cfg.mamba, x.dtype)
            elif btype == "mlstm":
                x = ssm.mlstm_forward(p, x, cfg.mlstm, cfg.ssm_chunk)
                c = ssm.mlstm_init_state(B, cfg.mlstm, x.dtype)
            elif btype == "slstm":
                x = ssm.slstm_forward(p, x, cfg.slstm, cost_mode=cfg.unroll_scan)
                c = ssm.slstm_init_state(B, cfg.d_model, x.dtype)
            else:
                x, _ = _apply_block(btype, p, x, cfg, positions, jnp.float32(0.0))
                c = {}
            caches[key] = c
        return x, caches

    x, caches = jax.lax.scan(
        body, x, params["blocks"],
        unroll=cfg.n_periods if cfg.unroll_scan else 1,
    )
    logits = _unembed(params, cfg, x[:, -1:, :])
    return logits, caches


def decode_step(params: Dict, cache: Dict, token: jax.Array, pos: jax.Array, cfg: ModelConfig):
    """One decode step.  token: (B,) int32; pos: (B,) int32 (cache length).
    Returns (logits (B, 1, V), new cache)."""
    if cfg.frontend == "tokens":
        x = jnp.take(params["embed"], token[:, None], axis=0)
    else:
        raise ValueError(f"{cfg.name}: encoder-only arch has no decode step")

    def body(x, scanned):
        period_params, period_cache = scanned
        new_cache = {}
        for idx, btype in enumerate(cfg.period):
            key = f"{idx:02d}_{btype}"
            p = period_params[key]
            c = period_cache[key]
            if btype == "attn":
                x, c = attention.decode_step(p, x, c, cfg.attn_dims(), pos)
            elif btype == "mla":
                x, c = mla.decode_step(p, x, c, cfg.mla, pos)
            elif btype == "mamba":
                x, c = ssm.decode_step(p, x, c, cfg.mamba)
            elif btype == "mlstm":
                x, c = ssm.mlstm_decode_step(p, x, c, cfg.mlstm)
            elif btype == "slstm":
                x, c = ssm.slstm_decode_step(p, x, c, cfg.slstm)
            else:
                positions = pos[:, None]
                x, _ = _apply_block(btype, p, x, cfg, positions, jnp.float32(0.0))
            new_cache[key] = c
        return x, new_cache

    x, new_cache = jax.lax.scan(
        body, x, (params["blocks"], cache),
        unroll=cfg.n_periods if cfg.unroll_scan else 1,
    )
    return _unembed(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# Sharding rules (baseline FSDP x TP; iterated in EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

# leaf name -> spec for the *block-local* shape (period axis prepended later)
_RULES: Dict[str, P] = {
    # attention
    "wq": P("data", "model"),
    "wk": P("data", "model"),
    "wv": P("data", "model"),
    "wo": P("model", "data"),
    "bq": P("model"),
    "bk": P("model"),
    "bv": P("model"),
    # mlp
    "w_in": P("data", "model"),
    "w_out": P("model", "data"),
    # moe (expert-major weights override w_in/w_out by rank below)
    "router": P("data", None),
    "sw_in": P("data", "model"),
    "sw_out": P("model", "data"),
    # mla
    "w_dkv": P("data", None),
    "w_uk": P(None, "model"),
    "w_uv": P(None, "model"),
    # mamba
    "conv_w": P(None, "model"),
    "conv_b": P("model"),
    "w_x": P("model", None),
    "w_dt": P(None, "model"),
    "dt_bias": P("model"),
    "A_log": P("model", None),
    "D": P("model"),
    # mlstm / slstm
    "w_up": P("data", "model"),
    "w_if": P("data", None),
    "b_i": P(None),
    "b_f": P(None),
    "w_down": P("model", "data"),
    "w": P("data", "model"),
    "r": P(None),
    "b": P(None),
    # io
    "embed": P("model", "data"),
    "embed_proj": P("data", "model"),
    "head": P("data", "model"),
}

_REPLICATED = {"norm_scale", "q_norm", "k_norm", "kv_norm", "out_norm", "final_norm"}


def _spec_for(path, leaf) -> P:
    names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    leaf_name = names[-1]
    in_blocks = names[0] == "blocks"
    rank = len(leaf.shape) - (1 if in_blocks else 0)
    if leaf_name in _REPLICATED:
        spec = P()
    elif leaf_name in ("w_in", "w_out") and rank == 3:  # MoE expert stacks
        spec = P("model", "data", None) if leaf_name == "w_in" else P("model", None, "data")
    elif leaf_name in ("wq", "wk", "wv") and rank == 3:  # mLSTM per-head (H, dh, dh)
        spec = P(None, "data", "model")
    elif leaf_name in _RULES:
        spec = _RULES[leaf_name]
        # trim over-long specs for low-rank leaves (e.g. biases)
        if len(spec) > rank:
            spec = P(*tuple(spec)[:rank])
    else:
        spec = P()
    if in_blocks:
        spec = P(None, *tuple(spec))
    return spec


def param_specs(cfg: ModelConfig, params_like, mesh=None) -> Any:
    """PartitionSpec pytree matching ``params_like`` (abstract or concrete).

    With ``mesh`` given, axes that do not divide the corresponding dimension
    are dropped (e.g. a 504-class head over a 16-way model axis) — GSPMD
    requires exact divisibility for explicit input shardings.
    """
    specs = jax.tree_util.tree_map_with_path(_spec_for, params_like)
    if mesh is None:
        return specs

    def fix(leaf, spec):
        entries = []
        for dim, entry in enumerate(tuple(spec)):
            if entry is None:
                entries.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= int(mesh.shape[a])
            entries.append(entry if leaf.shape[dim] % size == 0 else None)
        return P(*entries)

    return jax.tree.map(fix, params_like, specs)


def batch_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _mesh_size(mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])
    return n


def _batch_spec_entry(mesh, size: int):
    """Largest prefix of the batch axes that divides ``size`` (P entry)."""
    ba = batch_axes(mesh)
    # try full tuple, then drop leading axes (pod first) until divisible
    for start in range(len(ba) + 1):
        axes = ba[start:]
        if not axes:
            return None
        if size % _mesh_size(mesh, axes) == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


def batch_specs(cfg: ModelConfig, mesh, kind: str, global_batch: int) -> Dict[str, P]:
    """Input shardings for a given step kind ('train'|'prefill'|'decode').
    Batch dims smaller than the data-axis product fall back to replication
    (e.g. the long_500k single-request decode cell)."""
    b = _batch_spec_entry(mesh, global_batch)
    if kind == "decode":
        return {"token": P(b), "pos": P(b)}
    if cfg.frontend == "tokens":
        return {"tokens": P(b, None)}
    out = {"frames": P(b, None, None)}
    if kind == "train":
        out["labels"] = P(b, None)
    return out


def cache_specs(cfg: ModelConfig, cache_like, mesh) -> Any:
    """Baseline cache sharding: batch dim over the data axes where divisible
    (replicated otherwise, e.g. batch-1 long-context decode), sequence and
    head dims left to GSPMD."""

    def spec(path, leaf):
        # leaves are stacked (n_periods, B, ...)
        rank = len(leaf.shape)
        b = _batch_spec_entry(mesh, int(leaf.shape[1]))
        rest = [None] * (rank - 2)
        return P(None, b, *rest)

    return jax.tree_util.tree_map_with_path(spec, cache_like)
