"""Mixture-of-Experts FFN: top-k router + sort-based capacity dispatch.

TPU/pjit adaptation: instead of per-expert ragged batching (GPU style), the
dispatch is expressed as dense, statically-shaped ops that GSPMD shards
cleanly — tokens stay sharded over the ``data`` axes, the expert buffer
``(E, C, d)`` and expert weights ``(E, d, f)`` shard over ``model`` (expert
parallelism); the token->buffer scatter and buffer->token gather become the
all-to-alls of the EP pattern.

Dispatch algorithm (per call, static shapes):
  1. router logits -> softmax -> top-k (gates, expert ids)
  2. flatten (token, choice) pairs; stable-sort by expert id
  3. position-within-expert via cumsum; drop pairs beyond capacity C
  4. scatter kept tokens into the (E*C, d) buffer (one-hot-free `.at[].add`)
  5. grouped GEMM: (E, C, d) x (E, d, f) einsums (MXU-aligned)
  6. gather back per (token, choice), weight by gate, sum over choices

Capacity: C = ceil(T * k / E * capacity_factor), statically derived from the
global token count.  Dropped tokens (beyond capacity) contribute zero — the
standard capacity-dropout semantics.

An auxiliary load-balance loss (Switch-style) is returned for training.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import hints, layers


class MoEDims(NamedTuple):
    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    n_shared: int = 0  # shared (always-on) experts
    capacity_factor: float = 1.25
    aux_coef: float = 0.01
    # dispatch = "global": one global (E, C, d) buffer (paper-faithful naive
    # EP; GSPMD all-reduces the whole buffer across data shards).
    # dispatch = "rowwise": per-data-shard local dispatch with per-shard
    # capacity — the scatter/gather stay device-local and the only cross-
    # device traffic is the expert einsum's (data x model) alignment.
    # Beyond-paper optimization, EXPERIMENTS.md §Perf Cell A.
    dispatch: str = "global"


def init_params(key, d_model: int, dims: MoEDims, dtype) -> Dict:
    ks = jax.random.split(key, 5)
    E, f = dims.num_experts, dims.d_ff
    p = {
        "norm_scale": layers.init_rms_scale(d_model, dtype),
        "router": layers.dense_init(ks[0], (d_model, E), dtype),
        # fused swiglu in-proj: [gate | up]
        "w_in": layers.dense_init(ks[1], (E, d_model, 2 * f), dtype, in_axis=1),
        "w_out": layers.dense_init(ks[2], (E, f, d_model), dtype, in_axis=1),
    }
    if dims.n_shared > 0:
        fs = dims.n_shared * f
        p["sw_in"] = layers.dense_init(ks[3], (d_model, 2 * fs), dtype)
        p["sw_out"] = layers.dense_init(ks[4], (fs, d_model), dtype)
    return p


def capacity(T: int, dims: MoEDims) -> int:
    if dims.capacity_factor <= 0:
        # dropless: every expert can hold every token (C == T), so routing
        # never depends on the batch's token count — one-token decode then
        # reproduces batch-forward logits exactly.
        c = T
    else:
        c = math.ceil(T * dims.top_k / dims.num_experts * dims.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)  # pad to an 8-multiple for layout


def forward(p: Dict, x: jax.Array, dims: MoEDims) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d).  Returns (residual output, aux load-balance loss)."""
    if dims.dispatch == "rowwise":
        return _forward_rowwise(p, x, dims)
    B, S, d = x.shape
    T = B * S
    E, k = dims.num_experts, dims.top_k
    C = capacity(T, dims)
    h = layers.rms_norm(x, p["norm_scale"]).reshape(T, d)

    # 1. route
    logits = (h @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux loss: mean prob per expert x mean routed fraction per expert
    me = probs.mean(axis=0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * k)
    aux = dims.aux_coef * E * jnp.sum(me * ce)

    # 2-3. sort (token, choice) pairs by expert; positions within expert
    flat_e = eidx.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)  # (T*k,)
    sorted_e = flat_e[order]
    tok_of = order // k  # original token per sorted pair
    # position within expert = rank - first rank of that expert (contiguous
    # after the stable sort)
    rank = jnp.arange(T * k, dtype=jnp.int32)
    seg_start = jnp.full((E,), T * k, jnp.int32).at[sorted_e].min(rank)
    pos_in_e = rank - seg_start[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # E*C = drop bin

    # 4. scatter into the expert buffer
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    buf = buf.at[slot].add(h[tok_of])
    buf = buf[: E * C].reshape(E, C, d)

    # 5. grouped GEMM (swiglu)
    mid = jnp.einsum("ecd,edf->ecf", buf, p["w_in"])
    gate_h, up_h = jnp.split(mid, 2, axis=-1)
    act = jax.nn.silu(gate_h) * up_h
    y = jnp.einsum("ecf,efd->ecd", act, p["w_out"]).reshape(E * C, d)

    # 6. combine: gather per sorted pair, weight, sum over the k choices
    pair_out = jnp.where(keep[:, None], y[jnp.minimum(slot, E * C - 1)], 0.0)
    pair_gate = gates.reshape(T * k)[order]
    out = jnp.zeros((T, d), x.dtype).at[tok_of].add(
        pair_out * pair_gate[:, None].astype(x.dtype)
    )

    if dims.n_shared > 0:
        out = out + layers.swiglu(h, p["sw_in"], p["sw_out"])
    return x + out.reshape(B, S, d), aux


def _forward_rowwise(p: Dict, x: jax.Array, dims: MoEDims) -> Tuple[jax.Array, jax.Array]:
    """Row-local dispatch (EXPERIMENTS.md §Perf Cell A).

    Tokens are viewed as (rows, T/rows) with ``rows`` = the data-parallel
    degree; routing/sort/scatter/combine are vmapped over rows so every
    memory-movement op stays *within* a data shard, with a per-row capacity
    C_row = C/rows (per-device capacity — the semantics real MoE systems
    enforce).  The expert einsum carries (rows->data, E->model) sharding on
    both operands, so GSPMD needs no buffer-wide all-reduce — the measured
    collective bytes drop by ~the DP degree (see §Perf).

    On a single device (rows=1) this is numerically identical to the global
    dispatch with the same capacity.
    """
    B, S, d = x.shape
    T = B * S
    E, k = dims.num_experts, dims.top_k
    sizes = hints.axis_sizes()
    rows = 1
    for a in ("pod", "data"):
        rows *= sizes.get(a, 1)
    if T % rows != 0:
        rows = 1
    Tr = T // rows
    Cr = capacity(Tr, dims)
    ba = hints.batch_axes()
    bspec = (ba if len(ba) > 1 else ba[0]) if ba else None

    h = layers.rms_norm(x, p["norm_scale"]).reshape(rows, Tr, d)
    h = hints.constrain(h, bspec, None, None)

    logits = (h @ p["router"]).astype(jnp.float32)  # (rows, Tr, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # (rows, Tr, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * k)
    aux = dims.aux_coef * E * jnp.sum(me * ce)

    def one_row(h_r, gates_r, eidx_r):
        flat_e = eidx_r.reshape(Tr * k)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        tok_of = order // k
        rank = jnp.arange(Tr * k, dtype=jnp.int32)
        seg_start = jnp.full((E,), Tr * k, jnp.int32).at[sorted_e].min(rank)
        pos_in_e = rank - seg_start[sorted_e]
        keep = pos_in_e < Cr
        slot = jnp.where(keep, sorted_e * Cr + pos_in_e, E * Cr)
        buf = jnp.zeros((E * Cr + 1, d), h_r.dtype).at[slot].add(h_r[tok_of])
        return buf[: E * Cr].reshape(E, Cr, d), (order, tok_of, keep, slot)

    buf, meta = jax.vmap(one_row)(h, gates, eidx)  # (rows, E, Cr, d)
    buf = hints.constrain(buf, bspec, "model", None, None)
    # ZeRO-3-style use-site weight gathering: expert weights live FSDP-
    # sharded (E over model, d over data) at rest, but are all-gathered
    # over the data axis here so the expert GEMMs contract locally —
    # gathering ~GBs of weights beats all-reducing ~100 GB of activation
    # partial sums (measured in §Perf Cell A iter3).  The backward pass
    # reduce-scatters the weight grads automatically (GSPMD transpose).
    w_in = hints.constrain(p["w_in"], "model", None, None)
    w_out = hints.constrain(p["w_out"], "model", None, None)
    mid = jnp.einsum("recd,edf->recf", buf, w_in)
    gate_h, up_h = jnp.split(mid, 2, axis=-1)
    act = jax.nn.silu(gate_h) * up_h
    y = jnp.einsum("recf,efd->recd", act, w_out)
    y = hints.constrain(y, bspec, "model", None, None)

    def combine_row(y_r, gates_r, meta_r):
        order, tok_of, keep, slot = meta_r
        flat = y_r.reshape(E * Cr, d)
        pair_out = jnp.where(keep[:, None], flat[jnp.minimum(slot, E * Cr - 1)], 0.0)
        pair_gate = gates_r.reshape(Tr * k)[order]
        return jnp.zeros((Tr, d), y_r.dtype).at[tok_of].add(
            pair_out * pair_gate[:, None].astype(y_r.dtype)
        )

    out = jax.vmap(combine_row)(y, gates, meta)  # (rows, Tr, d)
    out = hints.constrain(out, bspec, None, None)
    out = out.reshape(T, d)
    if dims.n_shared > 0:
        out = out + layers.swiglu(h.reshape(T, d), p["sw_in"], p["sw_out"])
    return x + out.reshape(B, S, d), aux
