"""State-space / recurrent mixers: Mamba, mLSTM, sLSTM.

TPU adaptation notes (DESIGN.md §3):

* **Mamba** (selective SSM, Mamba-1 parameterization) — the GPU reference is
  a fused CUDA scan.  Here the sequence is processed in chunks via
  ``lax.scan`` (inter-chunk recurrence on the (di, ds) state) with an
  associative scan *within* each chunk, so the materialized state tensor is
  (B, chunk, di, ds) instead of (B, T, di, ds): the working set is bounded
  by the chunk size and the scan keeps the HLO compact for 60+ layer stacks.
* **mLSTM** (xLSTM matrix memory) — chunkwise-parallel stabilized form:
  intra-chunk interactions are (c x c) MXU matmuls (quadratic inside the
  chunk), inter-chunk state (H, dk, dv) is carried by ``lax.scan``.  The
  exponential-gating max-stabilizer is tracked exactly across chunks.
* **sLSTM** (scalar memory, exponential gating, block-diagonal recurrence)
  — inherently sequential; a ``lax.scan`` over time with per-head
  block-diagonal recurrent matmuls.  xLSTM-style stacks use few sLSTM
  layers precisely because of this serialization.

All mixers expose ``forward`` (full sequence), ``init_state`` and
``decode_step`` (O(1)-per-token recurrence) — the latter is what makes the
``long_500k`` decode cell runnable for xlstm/jamba.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import layers


# ===========================================================================
# Mamba (selective SSM)
# ===========================================================================


class MambaDims(NamedTuple):
    d_inner: int  # expansion of d_model (typically 2x)
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


def mamba_dt_rank(d_model: int, dims: MambaDims) -> int:
    return dims.dt_rank or math.ceil(d_model / 16)


def init_params(key, d_model: int, dims: MambaDims, dtype) -> Dict:
    ks = jax.random.split(key, 7)
    di, ds = dims.d_inner, dims.d_state
    dtr = mamba_dt_rank(d_model, dims)
    # S4D-real initialization for A
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "norm_scale": layers.init_rms_scale(d_model, dtype),
        "w_in": layers.dense_init(ks[0], (d_model, 2 * di), dtype),  # [x | z]
        "conv_w": (jax.random.normal(ks[1], (dims.d_conv, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_x": layers.dense_init(ks[2], (di, dtr + 2 * ds), dtype),  # dt, B, C
        "w_dt": layers.dense_init(ks[3], (dtr, di), dtype),
        "dt_bias": jnp.asarray(
            jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
                ks[4], (di,), jnp.float32,
                minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))), dtype),
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "w_out": layers.dense_init(ks[5], (di, d_model), dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B, T, di); w: (k, di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(k):  # k is tiny (4): unrolled shift-multiply-add
        out = out + xp[:, j : j + x.shape[1], :] * w[j][None, None, :]
    return out + b


def _ssm_scan_chunked(deltaA, deltaBx, C, chunk: int, unroll: bool = False):
    """h_t = deltaA_t * h_{t-1} + deltaBx_t ;  y_t = (h_t * C_t).sum(-1).

    deltaA, deltaBx: (B, T, di, ds); C: (B, T, ds).  Associative scan within
    chunks, sequential (lax.scan) across chunks.
    """
    B, T, di, ds = deltaA.shape
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    dA = deltaA.reshape(B, nc, chunk, di, ds).transpose(1, 0, 2, 3, 4)
    dBx = deltaBx.reshape(B, nc, chunk, di, ds).transpose(1, 0, 2, 3, 4)
    Cc = C.reshape(B, nc, chunk, ds).transpose(1, 0, 2, 3)

    def combine(a, b):
        (Aa, Ba), (Ab, Bb) = a, b
        return (Aa * Ab, Ba * Ab + Bb)

    def chunk_step(h, inp):
        dA_c, dBx_c, C_c = inp  # (B, chunk, di, ds), ..., (B, chunk, ds)
        # fold the carried state into the first step
        dBx_c = dBx_c.at[:, 0].add(dA_c[:, 0] * h)
        _, hs = jax.lax.associative_scan(combine, (dA_c, dBx_c), axis=1)
        y_c = jnp.einsum("btds,bts->btd", hs, C_c)
        return hs[:, -1], y_c

    h0 = jnp.zeros((B, di, ds), deltaA.dtype)
    _, ys = jax.lax.scan(chunk_step, h0, (dA, dBx, Cc), unroll=nc if unroll else 1)
    return ys.transpose(1, 0, 2, 3).reshape(B, T, di)


def forward(p: Dict, x: jax.Array, dims: MambaDims, chunk: int = 256,
            unroll: bool = False) -> jax.Array:
    """Mamba mixer with residual.  x: (B, T, d_model)."""
    B, T, d = x.shape
    di, ds = dims.d_inner, dims.d_state
    h = layers.rms_norm(x, p["norm_scale"])
    xz = h @ p["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"]))
    dtr = p["w_dt"].shape[0]
    xproj = xin @ p["w_x"]
    dt_low, Bc, Cc = jnp.split(xproj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["w_dt"] + p["dt_bias"])  # (B, T, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, ds)
    deltaA = jnp.exp(dt[..., None].astype(jnp.float32) * A)  # (B,T,di,ds)
    deltaBx = (dt * xin)[..., None] * Bc[:, :, None, :]  # (B,T,di,ds)
    y = _ssm_scan_chunked(deltaA.astype(x.dtype), deltaBx.astype(x.dtype), Cc,
                          min(chunk, T), unroll=unroll)
    y = y + p["D"] * xin
    y = y * jax.nn.silu(z)
    return x + y @ p["w_out"]


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv - 1, di) — trailing inputs
    h: jax.Array  # (B, di, ds)


def init_state(B: int, dims: MambaDims, dtype) -> MambaState:
    return MambaState(
        conv=jnp.zeros((B, dims.d_conv - 1, dims.d_inner), dtype),
        h=jnp.zeros((B, dims.d_inner, dims.d_state), dtype),
    )


def decode_step(
    p: Dict, x: jax.Array, state: MambaState, dims: MambaDims
) -> Tuple[jax.Array, MambaState]:
    """One-token recurrence.  x: (B, 1, d_model)."""
    B = x.shape[0]
    di, ds = dims.d_inner, dims.d_state
    h = layers.rms_norm(x, p["norm_scale"])
    xz = h @ p["w_in"]
    xin, z = jnp.split(xz, 2, axis=-1)  # (B, 1, di)
    window = jnp.concatenate([state.conv, xin], axis=1)  # (B, k, di)
    conv = (window * p["conv_w"][None]).sum(axis=1, keepdims=True) + p["conv_b"]
    xin_c = jax.nn.silu(conv)  # (B, 1, di)
    dtr = p["w_dt"].shape[0]
    xproj = xin_c @ p["w_x"]
    dt_low, Bc, Cc = jnp.split(xproj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["w_dt"] + p["dt_bias"])  # (B, 1, di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)[:, 0]  # (B,di,ds)
    dBx = ((dt * xin_c)[..., None] * Bc[:, :, None, :])[:, 0]
    h_new = dA.astype(x.dtype) * state.h + dBx.astype(x.dtype)
    y = jnp.einsum("bds,bs->bd", h_new, Cc[:, 0])[:, None, :]
    y = y + p["D"] * xin_c
    y = y * jax.nn.silu(z)
    return x + y @ p["w_out"], MambaState(conv=window[:, 1:], h=h_new)


# ===========================================================================
# mLSTM (xLSTM matrix memory, chunkwise-parallel stabilized form)
# ===========================================================================


class MLSTMDims(NamedTuple):
    d_inner: int  # up-projection (typically 2 x d_model)
    n_heads: int
    d_conv: int = 4


def mlstm_init_params(key, d_model: int, dims: MLSTMDims, dtype) -> Dict:
    ks = jax.random.split(key, 8)
    di, H = dims.d_inner, dims.n_heads
    dh = di // H
    # q/k/v are per-head block-diagonal projections (xLSTM design): (H, dh, dh)
    bd = lambda k: (jax.random.normal(k, (H, dh, dh), jnp.float32) / (dh**0.5)).astype(dtype)  # noqa: E731
    return {
        "norm_scale": layers.init_rms_scale(d_model, dtype),
        "w_up": layers.dense_init(ks[0], (d_model, 2 * di), dtype),  # [x | z]
        "conv_w": (jax.random.normal(ks[1], (dims.d_conv, di), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": bd(ks[2]),
        "wk": bd(ks[3]),
        "wv": bd(ks[4]),
        "w_if": layers.dense_init(ks[5], (di, 2 * H), dtype),  # input/forget gates
        "b_i": jnp.zeros((H,), dtype),
        "b_f": jnp.full((H,), 3.0, dtype),  # forget bias ~ sigmoid(3) ≈ 0.95
        "out_norm": layers.init_rms_scale(di, dtype),
        "w_down": layers.dense_init(ks[6], (di, d_model), dtype),
    }


def _headed_proj(x, w, H: int):
    """Block-diagonal per-head projection.  x: (..., di); w: (H, dh, dh)."""
    dh = w.shape[-1]
    xs = x.reshape(*x.shape[:-1], H, dh)
    return jnp.einsum("...hd,hde->...he", xs, w).reshape(x.shape)


def _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk: int, unroll: bool = False):
    """Chunkwise stabilized mLSTM.

    q, k, v: (B, H, T, dh);  log_i, log_f: (B, H, T)  (log input/forget gate).
    Returns h: (B, H, T, dh).
    """
    B, H, T, dh = q.shape
    assert T % chunk == 0
    nc = T // chunk
    c = chunk
    qs = q.reshape(B, H, nc, c, dh).transpose(2, 0, 1, 3, 4)
    ks_ = k.reshape(B, H, nc, c, dh).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, H, nc, c, dh).transpose(2, 0, 1, 3, 4)
    lis = log_i.reshape(B, H, nc, c).transpose(2, 0, 1, 3)
    lfs = log_f.reshape(B, H, nc, c).transpose(2, 0, 1, 3)
    scale = 1.0 / (dh**0.5)

    def chunk_step(carry, inp):
        C_st, n_st, m_st = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qc, kc, vc, li, lf = inp
        b = jnp.cumsum(lf, axis=-1)  # (B,H,c) inclusive log-decay
        u = li - b  # log(i_t) - b_t
        Mrun = jax.lax.associative_scan(jnp.maximum, u, axis=-1)  # running max
        m_j = b + jnp.maximum(m_st[..., None], Mrun)  # stabilizer per position
        # inter-chunk (state) contribution scale
        s_state = jnp.exp(m_st[..., None] + b - m_j)  # (B,H,c)
        # intra-chunk decay matrix D[j,t] = exp(b_j - b_t + li_t - m_j), t <= j
        Dlog = b[..., :, None] + u[..., None, :] - m_j[..., :, None]  # (B,H,c,c)
        tri = jnp.tril(jnp.ones((c, c), bool))
        D = jnp.where(tri, jnp.exp(Dlog), 0.0)
        S = jnp.einsum("bhjd,bhtd->bhjt", qc, kc) * scale * D  # (B,H,c,c)
        num = jnp.einsum("bhjt,bhtd->bhjd", S, vc) + s_state[..., None] * jnp.einsum(
            "bhjd,bhde->bhje", qc * scale, C_st
        )
        den = S.sum(-1) + s_state * jnp.einsum("bhjd,bhd->bhj", qc * scale, n_st)
        h = (num / jnp.maximum(jnp.abs(den), jnp.exp(-m_j))[..., None]).astype(qc.dtype)
        # ---- state update to the end of the chunk
        btot = b[..., -1]  # (B,H)
        u_max = Mrun[..., -1]
        m_new = btot + jnp.maximum(m_st, u_max)
        w_t = jnp.exp(btot[..., None] - b + li - m_new[..., None])  # (B,H,c)
        C_new = jnp.exp(m_st + btot - m_new)[..., None, None] * C_st + jnp.einsum(
            "bht,bhtd,bhte->bhde", w_t, kc, vc
        )
        n_new = jnp.exp(m_st + btot - m_new)[..., None] * n_st + jnp.einsum(
            "bht,bhtd->bhd", w_t, kc
        )
        return (C_new.astype(C_st.dtype), n_new.astype(n_st.dtype), m_new), h

    C0 = jnp.zeros((B, H, dh, dh), q.dtype)
    n0 = jnp.zeros((B, H, dh), q.dtype)
    m0 = jnp.full((B, H), -1e30, jnp.float32)
    _, hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qs, ks_, vs, lis, lfs),
                         unroll=nc if unroll else 1)
    return hs.transpose(1, 2, 0, 3, 4).reshape(B, H, T, dh)


def mlstm_forward(p: Dict, x: jax.Array, dims: MLSTMDims, chunk: int = 128,
                  unroll: bool = False) -> jax.Array:
    B, T, d = x.shape
    di, H = dims.d_inner, dims.n_heads
    dh = di // H
    h = layers.rms_norm(x, p["norm_scale"])
    up = h @ p["w_up"]
    xin, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xin, p["conv_w"], p["conv_b"]))
    q = _headed_proj(xc, p["wq"], H).reshape(B, T, H, dh).transpose(0, 2, 1, 3)
    k = _headed_proj(xc, p["wk"], H).reshape(B, T, H, dh).transpose(0, 2, 1, 3)
    v = _headed_proj(xin, p["wv"], H).reshape(B, T, H, dh).transpose(0, 2, 1, 3)
    gates = (xc @ p["w_if"]).reshape(B, T, 2, H).transpose(0, 3, 2, 1)  # (B,H,2,T)
    log_i = (gates[:, :, 0] + p["b_i"][None, :, None]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gates[:, :, 1] + p["b_f"][None, :, None]).astype(jnp.float32)
    out = _mlstm_chunk_scan(q, k, v, log_i, log_f, min(chunk, T), unroll=unroll)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, di)
    out = layers.rms_norm(out, p["out_norm"]) * jax.nn.silu(z)
    return x + out @ p["w_down"]


class MLSTMState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, di)
    C: jax.Array  # (B, H, dh, dh)
    n: jax.Array  # (B, H, dh)
    m: jax.Array  # (B, H)


def mlstm_init_state(B: int, dims: MLSTMDims, dtype) -> MLSTMState:
    H, dh = dims.n_heads, dims.d_inner // dims.n_heads
    return MLSTMState(
        conv=jnp.zeros((B, dims.d_conv - 1, dims.d_inner), dtype),
        C=jnp.zeros((B, H, dh, dh), dtype),
        n=jnp.zeros((B, H, dh), dtype),
        m=jnp.full((B, H), -1e30, jnp.float32),
    )


def mlstm_decode_step(
    p: Dict, x: jax.Array, state: MLSTMState, dims: MLSTMDims
) -> Tuple[jax.Array, MLSTMState]:
    B = x.shape[0]
    di, H = dims.d_inner, dims.n_heads
    dh = di // H
    h = layers.rms_norm(x, p["norm_scale"])
    up = h @ p["w_up"]
    xin, z = jnp.split(up, 2, axis=-1)  # (B, 1, di)
    window = jnp.concatenate([state.conv, xin], axis=1)
    conv = (window * p["conv_w"][None]).sum(axis=1, keepdims=True) + p["conv_b"]
    xc = jax.nn.silu(conv)
    q = _headed_proj(xc, p["wq"], H).reshape(B, H, dh)
    k = _headed_proj(xc, p["wk"], H).reshape(B, H, dh)
    v = _headed_proj(xin, p["wv"], H).reshape(B, H, dh)
    gates = (xc @ p["w_if"]).reshape(B, 2, H)
    li = (gates[:, 0] + p["b_i"]).astype(jnp.float32)  # (B,H)
    lf = jax.nn.log_sigmoid(gates[:, 1] + p["b_f"]).astype(jnp.float32)
    m_new = jnp.maximum(lf + state.m, li)
    i_p = jnp.exp(li - m_new)[..., None]
    f_p = jnp.exp(lf + state.m - m_new)[..., None]
    scale = 1.0 / (dh**0.5)
    C_new = (f_p[..., None] * state.C + i_p[..., None] * k[..., :, None] * v[..., None, :]).astype(state.C.dtype)
    n_new = (f_p * state.n + i_p * k).astype(state.n.dtype)
    num = jnp.einsum("bhd,bhde->bhe", q * scale, C_new)
    den = jnp.einsum("bhd,bhd->bh", q * scale, n_new)
    hout = (num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]).astype(x.dtype)
    out = hout.reshape(B, 1, di)
    out = layers.rms_norm(out, p["out_norm"]) * jax.nn.silu(z)
    return x + out @ p["w_down"], MLSTMState(conv=window[:, 1:], C=C_new, n=n_new, m=m_new)


# ===========================================================================
# sLSTM (scalar memory, exponential gating, block-diagonal recurrence)
# ===========================================================================


class SLSTMDims(NamedTuple):
    n_heads: int


def slstm_init_params(key, d_model: int, dims: SLSTMDims, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    H = dims.n_heads
    dh = d_model // H
    return {
        "norm_scale": layers.init_rms_scale(d_model, dtype),
        "w": layers.dense_init(ks[0], (d_model, 4 * d_model), dtype),  # i,f,z,o
        # block-diagonal recurrent weights per head: (H, dh, 4*dh)
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32) / (dh**0.5)).astype(dtype),
        "b": jnp.concatenate([
            jnp.zeros((d_model,), dtype),          # i
            jnp.full((d_model,), 3.0, dtype),       # f (forget bias)
            jnp.zeros((2 * d_model,), dtype),       # z, o
        ]),
        "w_out": layers.dense_init(ks[2], (d_model, d_model), dtype),
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, d)
    n: jax.Array  # (B, d)
    h: jax.Array  # (B, d)
    m: jax.Array  # (B, d)


def slstm_init_state(B: int, d_model: int, dtype) -> SLSTMState:
    z = jnp.zeros((B, d_model), dtype)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((B, d_model), -1e30, jnp.float32))


def _slstm_cell(p, xw, state: SLSTMState, H: int) -> SLSTMState:
    """One step.  xw: precomputed x @ w + b, (B, 4d)."""
    B, d4 = xw.shape
    d = d4 // 4
    dh = d // H
    hprev = state.h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hprev, p["r"]).reshape(B, 4 * d)
    # heads own contiguous [i|f|z|o] slices of size 4*dh each: rearrange to
    # match the global [i|f|z|o] layout of xw
    rec = rec.reshape(B, H, 4, dh).transpose(0, 2, 1, 3).reshape(B, 4 * d)
    pre = xw + rec
    li, lf, z_in, o_in = jnp.split(pre, 4, axis=-1)
    li = li.astype(jnp.float32)
    lf = jax.nn.log_sigmoid(lf.astype(jnp.float32))
    m_new = jnp.maximum(lf + state.m, li)
    i_p = jnp.exp(li - m_new)
    f_p = jnp.exp(lf + state.m - m_new)
    z_t = jnp.tanh(z_in)
    o_t = jax.nn.sigmoid(o_in)
    c_new = f_p * state.c + i_p * z_t
    n_new = f_p * state.n + i_p
    h_new = o_t * (c_new / jnp.maximum(n_new, 1e-6))
    return SLSTMState(c=c_new.astype(state.c.dtype), n=n_new.astype(state.n.dtype),
                      h=h_new.astype(state.h.dtype), m=m_new)


def slstm_forward(p: Dict, x: jax.Array, dims: SLSTMDims, cost_mode: bool = False) -> jax.Array:
    B, T, d = x.shape
    h = layers.rms_norm(x, p["norm_scale"])
    xw = h @ p["w"] + p["b"]  # (B, T, 4d)

    if cost_mode:
        # FLOP-equivalent parallel form for cost extraction (dry-run only):
        # XLA counts a while-loop body ONCE, so the true sequential scan
        # under-reports by ~T x.  Here the recurrent h_{t-1} dependency in
        # the gates is replaced by the (shape/FLOP-identical) normed input,
        # which makes the c/n recurrences linear in precomputed gates and
        # lets an associative scan stand in for the time loop.  Per-step op
        # counts (the per-head recurrent matmul + gate elementwise) match
        # the sequential cell exactly; only the log-depth scan combine
        # differs (negligible vs the matmuls).
        H = dims.n_heads
        dh = d // H
        h_proxy = h.reshape(B, T, H, dh)
        rec = jnp.einsum("bthd,hde->bthe", h_proxy, p["r"]).reshape(B, T, H, 4, dh)
        rec = rec.transpose(0, 1, 3, 2, 4).reshape(B, T, 4 * d)
        pre = xw + rec
        li, lf, z_in, o_in = jnp.split(pre, 4, axis=-1)
        lf = jax.nn.log_sigmoid(lf.astype(jnp.float32))
        i_p = jnp.exp(li.astype(jnp.float32) - jnp.max(li))
        f_p = jnp.exp(lf)
        z_t = jnp.tanh(z_in)
        o_t = jax.nn.sigmoid(o_in)

        def combine(a, b):
            (fa, xa), (fb, xb) = a, b
            return (fa * fb, xa * fb + xb)

        _, c_all = jax.lax.associative_scan(
            combine, (f_p, (i_p * z_t.astype(jnp.float32))), axis=1)
        _, n_all = jax.lax.associative_scan(combine, (f_p, i_p), axis=1)
        hs = (o_t * (c_all / jnp.maximum(n_all, 1e-6)).astype(x.dtype))
        return x + hs @ p["w_out"]

    def step(state, xw_t):
        new = _slstm_cell(p, xw_t, state, dims.n_heads)
        return new, new.h

    state0 = slstm_init_state(B, d, x.dtype)
    _, hs = jax.lax.scan(step, state0, xw.transpose(1, 0, 2))
    out = hs.transpose(1, 0, 2)  # (B, T, d)
    return x + out @ p["w_out"]


def slstm_decode_step(
    p: Dict, x: jax.Array, state: SLSTMState, dims: SLSTMDims
) -> Tuple[jax.Array, SLSTMState]:
    B = x.shape[0]
    h = layers.rms_norm(x, p["norm_scale"])
    xw = (h @ p["w"] + p["b"])[:, 0]  # (B, 4d)
    new = _slstm_cell(p, xw, state, dims.n_heads)
    return x + new.h[:, None, :] @ p["w_out"], new
