"""repro.obs — tracing, metrics, and profiling for the OAVI stack.

Quick tour::

    from repro import obs

    with obs.span("fit/degree", d=3):
        ...                                # timed, nested, thread-safe
    obs.event("fit/recompile", degree=3)   # instant marker

    h = obs.registry().histogram("serve.latency_seconds", engine="vi")
    h.observe(0.004)
    h.summary()["p999"]

    obs.export_trace("results/trace.json")     # open in ui.perfetto.dev
    obs.export_metrics("results/metrics.jsonl")
    print("\n".join(obs.report_lines()))

Spans and events are gated by ``OBS_ENABLED`` (default on) and are true
no-ops when disabled; metric objects are always live because the repo's
public ``stats`` dicts are views over them.  See ``core.py`` for the full
contract and the ``OBS_*`` env toggles.
"""

from . import baseline, device, slo  # noqa: F401
from .core import (  # noqa: F401
    configure,
    counter_event,
    current_stack,
    disable,
    disabled,
    enable,
    enabled,
    event,
    export_metrics,
    export_trace,
    registry,
    report_lines,
    reset,
    snapshot,
    span,
    trace_document,
    trace_events,
)
from .metrics import (  # noqa: F401
    BUCKETS_PER_OCTAVE,
    Counter,
    Gauge,
    Histogram,
    Registry,
    bucket_relative_error,
    percentile_summary,
)
from .trace import (  # noqa: F401
    chrome_trace,
    export_chrome_trace,
    merge_traces,
    validate_chrome_trace,
)

__all__ = [
    "baseline", "device", "slo",
    "configure", "counter_event", "current_stack", "disable", "disabled",
    "enable", "enabled", "event", "export_metrics", "export_trace",
    "registry", "report_lines", "reset", "snapshot", "span",
    "trace_document", "trace_events",
    "BUCKETS_PER_OCTAVE", "Counter", "Gauge", "Histogram", "Registry",
    "bucket_relative_error", "percentile_summary",
    "chrome_trace", "export_chrome_trace", "merge_traces",
    "validate_chrome_trace",
]
