"""Rolling perf baseline + noise-aware regression gate over BENCH history.

``benchmarks/run.py`` appends one record per invocation to
``results/history.jsonl`` (see ``benchmarks/history.py`` for the writer):
git SHA, an environment fingerprint, every flattened ``BENCH_*.json``
headline number, and serialized histogram-sketch snapshots of the run's
timing series.  This module is the *pure* half of the gate — parsing and
the regression decision — so it is unit-testable without running a single
benchmark.

The decision rule per timing metric (keys whose leaf field looks like a
duration) is spread-aware rather than mean-based:

- baseline = the **minimum** across history (best observed — timing noise is
  one-sided, the min is the closest to the true cost);
- the allowance is ``baseline * max(1 + tolerance, observed_spread *
  (1 + spread_margin))`` where ``observed_spread = max/min`` over history —
  a metric that historically wobbles 1.4x is allowed to wobble 1.4x, while a
  stable one gets the flat tolerance;
- metrics faster than ``min_time_s`` are skipped (they time the clock, not
  the code), and metrics with fewer than ``min_records`` history points are
  reported but never failed.

Sketch snapshots give a second, distribution-level band: history sketches
for a series merge exactly (bucket counts add), and the current run's p99
must stay within the merged baseline's p99 times the same tolerance plus
two sketch bucket widths.  ``benchmarks/history.py`` layers the
``BENCH_SOFT`` escalation idiom and the zero-overhead control-run noise
detector from ``bench_obs`` on top of this module's verdict.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

from .metrics import Histogram, bucket_relative_error

__all__ = [
    "RECORD_SCHEMA",
    "check_regression",
    "is_time_metric",
    "load_history",
    "merge_sketches",
]

RECORD_SCHEMA = "bench-history.v1"

# leaf-field suffixes that mark a flattened BENCH number as a duration
_TIME_SUFFIXES = ("_s", "_ms", "_us", "_seconds", "_sec")
_TIME_FIELDS = {"seconds", "time_total", "wall_s"}


def is_time_metric(key: str) -> bool:
    """Whether a flattened metric key (``bench/section:field``) is a duration
    (only durations are gated — counts and bytes regress differently)."""
    field = key.rsplit(":", 1)[-1]
    return field in _TIME_FIELDS or field.endswith(_TIME_SUFFIXES)


def load_history(path: str) -> Tuple[List[Dict], List[str]]:
    """Parse a history JSONL file; returns (records, warnings).

    A torn/truncated **last** line (the writer died mid-append) is skipped
    with a warning — the same contract as the resilience journal's torn-tail
    handling.  Malformed JSON *before* the tail means real corruption and
    raises ``ValueError`` loudly.  Records with a foreign schema tag are
    skipped with a warning so future schema bumps stay readable.
    """
    records: List[Dict] = []
    warnings: List[str] = []
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return [], [f"{path}: no history yet"]
    stripped = [(i, ln.strip()) for i, ln in enumerate(lines)]
    stripped = [(i, ln) for i, ln in stripped if ln]
    for pos, (lineno, line) in enumerate(stripped):
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            if pos == len(stripped) - 1:
                warnings.append(
                    f"{path}:{lineno + 1}: torn tail skipped ({e.msg})")
                break
            raise ValueError(
                f"{path}:{lineno + 1}: corrupt history record mid-file: {e}"
            ) from e
        if rec.get("schema") != RECORD_SCHEMA:
            warnings.append(
                f"{path}:{lineno + 1}: skipping schema "
                f"{rec.get('schema')!r} (want {RECORD_SCHEMA!r})")
            continue
        records.append(rec)
    return records, warnings


def merge_sketches(records: List[Dict], series: str) -> Optional[Histogram]:
    """Exact merge of one series' sketch snapshots across history records."""
    merged: Optional[Histogram] = None
    for rec in records:
        state = (rec.get("sketches") or {}).get(series)
        if not state:
            continue
        h = Histogram.from_state(state)
        merged = h if merged is None else merged.merge(h)
    return merged


def check_regression(
    current: Dict,
    baseline_records: List[Dict],
    *,
    tolerance: float = 0.25,
    spread_margin: float = 0.05,
    min_records: int = 2,
    min_time_s: float = 0.005,
    min_sketch_count: int = 20,
) -> Dict:
    """Compare one history record against the rolling baseline.

    Returns ``{"status": "pass" | "fail" | "insufficient", "findings": [...],
    "checked": int, "skipped": [...], "warnings": [...]}``.  ``findings`` are
    dicts naming the metric, the current value, the baseline, and the
    allowance that was exceeded.  ``insufficient`` means no metric had
    enough history to gate — a vacuous pass the caller should surface.
    """
    findings: List[Dict] = []
    skipped: List[str] = []
    checked = 0

    metrics = current.get("metrics") or {}
    for key in sorted(metrics):
        if not is_time_metric(key):
            continue
        try:
            cur = float(metrics[key])
        except (TypeError, ValueError):
            continue
        vals = []
        for rec in baseline_records:
            v = (rec.get("metrics") or {}).get(key)
            if isinstance(v, (int, float)):
                vals.append(float(v))
        if len(vals) < min_records:
            skipped.append(f"{key}: only {len(vals)} history point(s)")
            continue
        best, worst = min(vals), max(vals)
        if best < min_time_s:
            skipped.append(f"{key}: baseline {best:.3g}s below timing floor")
            continue
        spread = worst / best
        allowed = best * max(1.0 + tolerance, spread * (1.0 + spread_margin))
        checked += 1
        if cur > allowed:
            findings.append({
                "kind": "metric",
                "key": key,
                "current": cur,
                "baseline_best": best,
                "baseline_worst": worst,
                "allowed": allowed,
                "ratio": cur / best,
            })

    cur_sketches = current.get("sketches") or {}
    band_pad = 2.0 * bucket_relative_error()
    for series in sorted(cur_sketches):
        merged = merge_sketches(baseline_records, series)
        if merged is None or merged.count < min_sketch_count:
            skipped.append(f"sketch {series}: insufficient baseline samples")
            continue
        cur_h = Histogram.from_state(cur_sketches[series])
        cur_p99 = cur_h.quantile(0.99)
        base_p99 = merged.quantile(0.99)
        if cur_p99 is None or base_p99 is None or base_p99 < min_time_s:
            skipped.append(f"sketch {series}: below timing floor or empty")
            continue
        allowed = base_p99 * (1.0 + tolerance + band_pad)
        checked += 1
        if cur_p99 > allowed:
            findings.append({
                "kind": "sketch",
                "key": series,
                "current": cur_p99,
                "baseline_best": base_p99,
                "allowed": allowed,
                "ratio": cur_p99 / base_p99,
            })

    if checked == 0:
        status = "insufficient"
    else:
        status = "fail" if findings else "pass"
    return {
        "status": status,
        "findings": findings,
        "checked": checked,
        "skipped": skipped,
        "tolerance": tolerance,
    }
