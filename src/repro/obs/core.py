"""Process-global observability state: spans, events, snapshot/export.

Contract (asserted by ``tests/test_obs.py`` and ``benchmarks/bench_obs.py``):

- ``span()``/``event()`` when obs is disabled are true no-ops: they return a
  shared singleton and allocate nothing on the hot path.
- Enabling obs never changes numerics — instrumentation only reads clocks
  and appends to buffers; fitted models are bit-identical either way.
- Metric objects (see :mod:`repro.obs.metrics`) are *not* gated: the public
  ``stats`` dicts around the repo are views over them and must keep working
  with tracing off.

Env toggles (read once at import, overridable via :func:`configure`):

- ``OBS_ENABLED``      default 1 — master switch for spans/events.
- ``OBS_TRACE_EVENTS`` default 100000 — trace ring-buffer capacity.
- ``OBS_SAMPLE_EVERY`` default 1 — keep every Nth span per span name
  (deterministic counter-based sampling, no randomness).
- ``OBS_JAX_TRACE``    default 0 — additionally wrap each span in
  ``jax.profiler.TraceAnnotation`` so obs spans line up with XLA timelines
  when a jax profile is being captured.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional

from .metrics import Registry
from .trace import TraceBuffer, chrome_trace, export_chrome_trace

__all__ = [
    "span", "event", "counter_event", "enabled", "enable", "disable",
    "disabled", "configure", "reset", "registry", "trace_events", "snapshot",
    "export_trace", "export_metrics", "report_lines",
]


def _env_int(name: str, default: int) -> int:
    try:
        return int(os.environ.get(name, default))
    except ValueError:
        return default


class _State:
    def __init__(self) -> None:
        self.enabled = _env_int("OBS_ENABLED", 1) != 0
        self.sample_every = max(1, _env_int("OBS_SAMPLE_EVERY", 1))
        self.jax_trace = _env_int("OBS_JAX_TRACE", 0) != 0
        self.buffer = TraceBuffer(maxlen=max(16, _env_int("OBS_TRACE_EVENTS", 100_000)))
        self.registry = Registry()
        self.epoch = time.perf_counter()
        self._sample_lock = threading.Lock()
        self._sample_counts: Dict[str, int] = {}

    def now_us(self) -> float:
        return (time.perf_counter() - self.epoch) * 1e6

    def sampled(self, name: str) -> bool:
        """Deterministic per-name sampling: keep every Nth occurrence."""
        if self.sample_every == 1:
            return True
        with self._sample_lock:
            n = self._sample_counts.get(name, 0)
            self._sample_counts[name] = n + 1
        return n % self.sample_every == 0


_STATE = _State()
_LOCAL = threading.local()


def _jax_annotation(name: str):
    try:  # deferred so obs imports without jax (e.g. standalone tooling)
        from jax.profiler import TraceAnnotation
    except Exception:
        return None
    return TraceAnnotation(name)


class Span:
    """A recorded span.  Use via ``with obs.span("fit/degree", d=3): ...``."""

    __slots__ = ("name", "args", "_t0", "_jax_ctx", "duration_s")

    def __init__(self, name: str, args: Optional[dict]) -> None:
        self.name = name
        self.args = args
        self._t0 = 0.0
        self._jax_ctx = None
        self.duration_s = 0.0

    def __enter__(self) -> "Span":
        stack = getattr(_LOCAL, "stack", None)
        if stack is None:
            stack = _LOCAL.stack = []
        stack.append(self.name)
        if _STATE.jax_trace:
            self._jax_ctx = _jax_annotation(self.name)
            if self._jax_ctx is not None:
                self._jax_ctx.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        dur = self.duration_s = t1 - self._t0
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(exc_type, exc, tb)
        _LOCAL.stack.pop()
        st = _STATE
        # inline the sample_every == 1 fast path: this exit runs on serving's
        # per-request hot path, where even one extra call shows up in the
        # bench_obs overhead budget.  Durations live in the trace buffer
        # only; aggregate latencies belong to the components' own always-on
        # histograms (``fit.seconds``, ``serve.transform_seconds``, ...)
        if st.sample_every == 1 or st.sampled(self.name):
            st.buffer.add_complete(
                self.name, (self._t0 - st.epoch) * 1e6, dur * 1e6, self.args)


class _NoopSpan:
    """Shared do-nothing span returned when obs is disabled."""

    __slots__ = ()
    name = ""
    args = None
    duration_s = 0.0

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None


_NOOP_SPAN = _NoopSpan()


def span(name: str, **args):
    """Open a (nested, thread-safe) span.  No-op singleton when disabled."""
    if not _STATE.enabled:
        return _NOOP_SPAN
    return Span(name, args or None)


def event(name: str, **args) -> None:
    """Record an instant event (compile, recompile, activation...)."""
    if not _STATE.enabled:
        return
    _STATE.buffer.add_instant(name, _STATE.now_us(), args or None)


def counter_event(name: str, **values) -> None:
    """Record a counter sample (``ph: "C"``) on the trace timeline.

    Values must be numbers; Perfetto renders them as a stacked counter track
    (the live-memory timeline).  No-op when obs is disabled.
    """
    if not _STATE.enabled:
        return
    _STATE.buffer.add_counter(name, _STATE.now_us(), values)


def current_stack() -> List[str]:
    """Names of the open spans on this thread, outermost first."""
    return list(getattr(_LOCAL, "stack", ()))


def enabled() -> bool:
    return _STATE.enabled


def enable() -> None:
    _STATE.enabled = True


def disable() -> None:
    _STATE.enabled = False


class disabled:
    """Context manager that temporarily disables span/event recording."""

    def __enter__(self):
        self._prev = _STATE.enabled
        _STATE.enabled = False
        return self

    def __exit__(self, *exc):
        _STATE.enabled = self._prev


def configure(enabled: Optional[bool] = None,
              sample_every: Optional[int] = None,
              jax_trace: Optional[bool] = None,
              trace_capacity: Optional[int] = None) -> None:
    """Override env-derived settings at runtime."""
    if enabled is not None:
        _STATE.enabled = enabled
    if sample_every is not None:
        _STATE.sample_every = max(1, int(sample_every))
    if jax_trace is not None:
        _STATE.jax_trace = jax_trace
    if trace_capacity is not None:
        _STATE.buffer = TraceBuffer(maxlen=max(16, int(trace_capacity)))


def registry() -> Registry:
    """The process-global metric registry."""
    return _STATE.registry


def trace_events() -> List[dict]:
    return _STATE.buffer.events()


def reset(metrics: bool = True, trace: bool = True) -> None:
    """Clear recorded state (tests / between bench trials)."""
    if trace:
        _STATE.buffer.clear()
    if metrics:
        _STATE.registry.clear()
    with _STATE._sample_lock:
        _STATE._sample_counts.clear()


def snapshot() -> dict:
    """Point-in-time view of all metrics plus trace-buffer counters."""
    return {
        "metrics": _STATE.registry.snapshot(),
        "trace": {
            "events": len(_STATE.buffer),
            "dropped": _STATE.buffer.dropped,
        },
        "enabled": _STATE.enabled,
    }


def export_trace(path: str, process_name: str = "repro") -> str:
    """Write the trace buffer as Chrome-trace JSON; returns the path."""
    return export_chrome_trace(_STATE.buffer.events(), path,
                               process_name=process_name)


def trace_document(process_name: str = "repro") -> dict:
    return chrome_trace(_STATE.buffer.events(), process_name=process_name)


def export_metrics(path: str) -> str:
    """Write one JSONL line per metric series; returns the path."""
    rows = _STATE.registry.snapshot()
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")
    os.replace(tmp, path)
    return path


def report_lines(snap: Optional[dict] = None) -> List[str]:
    """Render a metric snapshot as an aligned human-readable table."""
    snap = snap or snapshot()
    rows = []
    for m in snap["metrics"]:
        labels = ",".join(f"{k}={v}" for k, v in sorted(m["labels"].items()))
        name = f"{m['name']}{{{labels}}}" if labels else m["name"]
        if m["type"] == "counter":
            rows.append((name, "counter", f"{m['value']}"))
        elif m["type"] == "gauge":
            rows.append((name, "gauge", f"{m['value']:g}"))
        else:
            fmt = lambda v: "-" if v is None else f"{v:.6g}"  # noqa: E731
            rows.append((
                name, "histogram",
                f"n={m['count']} mean={fmt(m['mean'])} p50={fmt(m['p50'])} "
                f"p99={fmt(m['p99'])} p999={fmt(m['p999'])} max={fmt(m['max'])}",
            ))
    if not rows:
        return ["(no metrics recorded)"]
    w_name = max(len(r[0]) for r in rows)
    w_type = max(len(r[1]) for r in rows)
    lines = [f"{n:<{w_name}}  {t:<{w_type}}  {v}" for n, t, v in rows]
    tr = snap.get("trace", {})
    lines.append(
        f"trace: {tr.get('events', 0)} events buffered, "
        f"{tr.get('dropped', 0)} dropped"
    )
    return lines
