"""Device-level observability: compile time, HLO cost, memory, profiles.

Four capabilities, all best-effort and all safe without jax installed:

- **XLA compile accounting** — a process-global listener on jax's internal
  event-duration channel accumulates ``backend_compile`` seconds, and
  :class:`CompileWindow` attributes the delta over a code region (a fit, an
  engine warmup).  This measures the *actual* XLA compile, not the Python
  call that happened to trigger it.
- **Per-step HLO cost analysis** — :func:`step_cost` lowers a jitted
  callable for one argument signature and reads ``cost_analysis()``
  (flops / bytes accessed / output bytes).  Lowering traces but does not
  XLA-compile, so the capture is a one-time host cost per signature, cached
  alongside the degree-step cache's own signature set — warm steps pay a
  dict lookup, cold steps pay one extra trace on a path that is about to
  compile anyway.
- **Live-memory timeline** — :func:`sample_memory` unifies the allocator
  high-water mark (TPU/GPU) and live-array accounting (CPU) into one
  sampling point that updates fit-stats peaks, sets registry gauges, and
  emits a Chrome counter event so traces show memory over time.
- **Profiler windows** — :func:`profile_window` opens a ``jax.profiler``
  trace when ``OBS_JAX_PROFILE=<dir>`` is set, so XLA device timelines
  interleave with obs spans (which already carry ``TraceAnnotation`` under
  ``OBS_JAX_TRACE=1``).

Gating: everything here is additionally gated by ``OBS_DEVICE`` (default
on) AND :func:`repro.obs.enabled` — ``obs.disabled()`` therefore yields the
same zero-instrumentation path the overhead benchmarks compare against.
None of it ever changes what a fit or transform computes.
"""

from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict
from typing import Dict, Optional, Tuple

from .core import counter_event, enabled, event, registry

__all__ = [
    "CompileWindow",
    "compile_snapshot",
    "device_enabled",
    "capture_stats",
    "device_memory_stats",
    "live_buffer_bytes",
    "profile_window",
    "sample_memory",
    "step_cost",
]

_BACKEND_COMPILE_SUFFIX = "backend_compile_duration"


def _env_flag(name: str, default: int) -> bool:
    try:
        return int(os.environ.get(name, default)) != 0
    except ValueError:
        return default != 0


def device_enabled() -> bool:
    """Device-level capture is on: ``OBS_DEVICE`` (default 1) and obs enabled."""
    return enabled() and _env_flag("OBS_DEVICE", 1)


# ---------------------------------------------------------------------------
# XLA compile accounting
# ---------------------------------------------------------------------------

_COMPILE_LOCK = threading.Lock()
_COMPILE = {"seconds": 0.0, "count": 0}
_LISTENER = {"state": None}  # None = not yet tried, True = live, False = n/a


def _on_event_duration(name: str, secs: float, **_kw) -> None:
    if not name.endswith(_BACKEND_COMPILE_SUFFIX):
        return
    with _COMPILE_LOCK:
        _COMPILE["seconds"] += secs
        _COMPILE["count"] += 1
    event("device/xla_compile", seconds=round(secs, 6))


def _ensure_listener() -> bool:
    if _LISTENER["state"] is None:
        try:  # jax._src.monitoring is semi-private; degrade to "unavailable"
            from jax._src import monitoring

            monitoring.register_event_duration_secs_listener(_on_event_duration)
            _LISTENER["state"] = True
        except Exception:
            _LISTENER["state"] = False
    return bool(_LISTENER["state"])


def compile_snapshot() -> Tuple[float, int]:
    """Cumulative (seconds, count) of XLA backend compiles this process."""
    _ensure_listener()
    with _COMPILE_LOCK:
        return _COMPILE["seconds"], _COMPILE["count"]


class CompileWindow:
    """Delta of XLA backend-compile time over a ``with`` region.

    The listener is process-global, so compiles triggered concurrently by
    *other* threads land in every open window — single-fit attribution is
    exact in the (usual) single-threaded fit case and an upper bound
    otherwise.  ``seconds``/``count`` are 0 until exit, and stay 0 when the
    monitoring channel is unavailable.
    """

    __slots__ = ("seconds", "count", "_s0", "_c0")

    def __init__(self) -> None:
        self.seconds = 0.0
        self.count = 0

    def __enter__(self) -> "CompileWindow":
        self._s0, self._c0 = compile_snapshot()
        return self

    def __exit__(self, *exc) -> None:
        s1, c1 = compile_snapshot()
        self.seconds = s1 - self._s0
        self.count = c1 - self._c0


# ---------------------------------------------------------------------------
# Per-step HLO cost analysis
# ---------------------------------------------------------------------------

_COST_LOCK = threading.Lock()
_COST_CACHE: "OrderedDict[Tuple, Optional[Dict]]" = OrderedDict()
_COST_CACHE_CAP = 512
_CAPTURE = {"captures": 0, "failures": 0, "seconds": 0.0}


def capture_stats() -> Dict:
    """Cost-capture telemetry: captures, failures, cumulative capture time."""
    with _COST_LOCK:
        return dict(_CAPTURE)


def _capture_cost(fn, args, kwargs) -> Optional[Dict]:
    t0 = time.perf_counter()
    try:
        analysis = fn.lower(*args, **kwargs).cost_analysis()
    except Exception:
        with _COST_LOCK:
            _CAPTURE["failures"] += 1
        return None
    if isinstance(analysis, (list, tuple)):  # some backends: one per device
        analysis = analysis[0] if analysis else {}
    if not isinstance(analysis, dict):
        analysis = {}
    dt = time.perf_counter() - t0
    cost = {
        "flops": float(analysis.get("flops", 0.0) or 0.0),
        "bytes_accessed": float(analysis.get("bytes accessed", 0.0) or 0.0),
        "bytes_out": float(analysis.get("bytes accessedout{}", 0.0) or 0.0),
        "capture_s": round(dt, 6),
    }
    with _COST_LOCK:
        _CAPTURE["captures"] += 1
        _CAPTURE["seconds"] += dt
    registry().histogram("device.cost_capture_seconds").observe(dt)
    event("device/cost_capture", flops=cost["flops"],
          bytes_accessed=cost["bytes_accessed"], capture_s=cost["capture_s"])
    return cost


def step_cost(fn, sig, args, kwargs: Optional[dict] = None) -> Optional[Dict]:
    """HLO cost estimate for jitted ``fn`` at one argument signature.

    Returns ``{"flops", "bytes_accessed", "bytes_out", "capture_s"}`` or
    None (capture off, or the backend exposes no cost model).  ``sig`` must
    identify the trace signature the caller would use for compile counting —
    the result is cached per ``(fn, sig)`` so repeat calls are a dict hit.
    """
    if not device_enabled():
        return None
    key = (id(fn), sig)
    with _COST_LOCK:
        if key in _COST_CACHE:
            return _COST_CACHE[key]
    cost = _capture_cost(fn, args, kwargs or {})
    with _COST_LOCK:
        _COST_CACHE[key] = cost
        while len(_COST_CACHE) > _COST_CACHE_CAP:
            _COST_CACHE.popitem(last=False)
    return cost


# ---------------------------------------------------------------------------
# Live-memory timeline
# ---------------------------------------------------------------------------


def device_memory_stats() -> Dict:
    """Best-effort ``memory_stats()`` of the first local device.  TPU/GPU
    runtimes report allocator counters (``peak_bytes_in_use``); CPU returns
    nothing — callers must treat every key as optional."""
    try:
        import jax

        stats = jax.local_devices()[0].memory_stats()
    except Exception:
        return {}
    return dict(stats or {})


def live_buffer_bytes() -> Optional[int]:
    """Total bytes of all live device arrays — the measured fallback for the
    memory benchmarks on backends without allocator stats (this container's
    CPU).  Dominated by the persistent fit buffers (A, IHB state), which is
    exactly the footprint the streaming fit is built to flatten."""
    try:
        import jax

        return int(sum(x.nbytes for x in jax.live_arrays()))
    except Exception:
        return None


def sample_memory(stats: Optional[Dict] = None) -> Dict:
    """One memory-timeline sample: gauges, a trace counter, and stats peaks.

    Updates ``stats["peak_bytes"]`` (allocator high-water, where available)
    and ``stats["live_bytes_peak"]`` (live-array accounting) in place when a
    stats dict is given — the unified replacement for the ad-hoc
    ``peak_bytes`` plumbing the fit loops used to carry.  Always refreshes
    the ``device.live_bytes`` / ``device.peak_bytes`` registry gauges and,
    when obs recording is on, appends a ``device/memory`` counter event so
    exported traces show the memory timeline.  Returns the raw sample.
    """
    out: Dict = {}
    live = live_buffer_bytes()
    if live is not None:
        out["live_bytes"] = live
        if stats is not None:
            stats["live_bytes_peak"] = max(live, int(stats.get("live_bytes_peak") or 0))
    peak = device_memory_stats().get("peak_bytes_in_use")
    if peak is not None:
        out["peak_bytes"] = int(peak)
        if stats is not None:
            stats["peak_bytes"] = max(int(peak), int(stats.get("peak_bytes") or 0))
    if not out:
        return out
    reg = registry()
    if live is not None:
        reg.gauge("device.live_bytes").set(float(live))
        reg.gauge("device.live_bytes_peak").set_max(float(live))
    if peak is not None:
        reg.gauge("device.peak_bytes").set(float(peak))
    if device_enabled():
        # counter args must stay numeric: Perfetto stacks them as series
        counter_event("device/memory", **{k: float(v) for k, v in out.items()})
    return out


# ---------------------------------------------------------------------------
# jax.profiler trace windows
# ---------------------------------------------------------------------------

_PROFILE_LOCK = threading.Lock()
_PROFILE_ACTIVE = {"on": False}


class _ProfileWindow:
    """One ``jax.profiler`` capture window; inner/overlapping windows no-op
    (the profiler cannot nest).  Emits obs instant events at both edges so
    the obs trace shows where the device profile interleaves."""

    __slots__ = ("_dir", "_name", "_started")

    def __init__(self, log_dir: str, name: str) -> None:
        self._dir = log_dir
        self._name = name
        self._started = False

    def __enter__(self) -> "_ProfileWindow":
        with _PROFILE_LOCK:
            if _PROFILE_ACTIVE["on"]:
                return self
            _PROFILE_ACTIVE["on"] = True
        try:
            import jax.profiler

            os.makedirs(self._dir, exist_ok=True)
            jax.profiler.start_trace(self._dir)
            self._started = True
            event("device/profile_start", name=self._name, dir=self._dir)
        except Exception:
            with _PROFILE_LOCK:
                _PROFILE_ACTIVE["on"] = False
        return self

    def __exit__(self, *exc) -> None:
        if not self._started:
            return
        try:
            import jax.profiler

            jax.profiler.stop_trace()
            event("device/profile_stop", name=self._name)
        except Exception:
            pass
        finally:
            with _PROFILE_LOCK:
                _PROFILE_ACTIVE["on"] = False


class _NoopWindow:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NOOP_WINDOW = _NoopWindow()


def profile_window(name: str):
    """Env-gated device profiler window: ``OBS_JAX_PROFILE=<dir>`` turns the
    returned context manager into a real ``jax.profiler`` capture written
    under ``<dir>``; otherwise it is a shared no-op.  Safe to nest — only
    the outermost window captures."""
    log_dir = os.environ.get("OBS_JAX_PROFILE", "")
    if not log_dir or not enabled():
        return _NOOP_WINDOW
    return _ProfileWindow(log_dir, name)
