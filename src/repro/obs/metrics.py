"""Metric primitives: counters, gauges, and mergeable streaming histograms.

These are the always-on building blocks of ``repro.obs``.  Unlike spans and
trace events (which are gated by :func:`repro.obs.enabled`), metric objects
are plain thread-safe accumulators that components own directly — the public
``stats`` dicts across the repo are views over them, so they must keep
working even when tracing is disabled.

The histogram is a fixed log-bucket sketch: values land in geometric buckets
with ``BUCKETS_PER_OCTAVE`` buckets per factor of 2, so any quantile is
recoverable to within one bucket (a multiplicative error of at most
``2**(1/BUCKETS_PER_OCTAVE) ~ 4.4%``) without storing samples.  Sketches
merge by adding bucket counts, which makes the merge exact and associative —
per-thread or per-process sketches can be combined in any order.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "BUCKETS_PER_OCTAVE",
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "bucket_relative_error",
]

# Bucket resolution of the log sketch.  16 buckets per octave keeps the
# worst-case quantile error under 4.4% while a full lognormal latency
# distribution still fits in a few dozen sparse buckets.
BUCKETS_PER_OCTAVE = 16

_LOG2 = math.log(2.0)


def bucket_relative_error() -> float:
    """Worst-case multiplicative quantile error of the sketch (one bucket)."""
    return 2.0 ** (1.0 / BUCKETS_PER_OCTAVE) - 1.0


def _bucket_index(value: float) -> int:
    """Map a positive value to its geometric bucket index.

    Bucket ``i`` covers ``(2**((i-1)/B), 2**(i/B)]`` so the bucket's upper
    edge is an upper bound for every sample in it.
    """
    return math.ceil(math.log(value) / _LOG2 * BUCKETS_PER_OCTAVE)


def _bucket_upper(index: int) -> float:
    return 2.0 ** (index / BUCKETS_PER_OCTAVE)


class Counter:
    """Monotonic thread-safe counter."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, amount: int = 1) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> int:
        return self._value

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-write-wins value with an optional high-water helper."""

    __slots__ = ("_lock", "_value")

    def __init__(self, value: float = 0.0) -> None:
        self._lock = threading.Lock()
        self._value = value

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value

    def set_max(self, value: float) -> None:
        with self._lock:
            if value > self._value:
                self._value = value

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Mergeable streaming histogram over positive values (log-bucket sketch).

    Tracks exact ``count``/``sum``/``min``/``max`` alongside sparse geometric
    bucket counts.  Non-positive values are legal and land in a dedicated
    underflow bucket (they count toward ``count`` and quantile rank but
    report as 0.0).
    """

    __slots__ = ("_lock", "_buckets", "_underflow", "count", "sum", "min", "max")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._buckets: Dict[int, int] = {}
        self._underflow = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            self.count += 1
            self.sum += value
            if value < self.min:
                self.min = value
            if value > self.max:
                self.max = value
            if value <= 0.0:
                self._underflow += 1
            else:
                idx = _bucket_index(value)
                self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def observe_many(self, values: Iterable[float]) -> None:
        for v in values:
            self.observe(v)

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into ``self`` (exact: bucket counts add)."""
        with other._lock:
            buckets = dict(other._buckets)
            underflow = other._underflow
            count, total = other.count, other.sum
            lo, hi = other.min, other.max
        with self._lock:
            for idx, c in buckets.items():
                self._buckets[idx] = self._buckets.get(idx, 0) + c
            self._underflow += underflow
            self.count += count
            self.sum += total
            if lo < self.min:
                self.min = lo
            if hi > self.max:
                self.max = hi
        return self

    def quantile(self, q: float) -> Optional[float]:
        """Upper-edge quantile estimate, clamped to the observed [min, max].

        Returns None on an empty sketch — there is no sample to estimate, and
        a fabricated 0.0 would read as a real (excellent) latency downstream.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            if self.count == 0:
                return None
            rank = q * (self.count - 1)  # np.percentile-style rank
            seen = self._underflow
            if rank < seen:
                return max(self.min, 0.0) if self.min <= 0.0 else self.min
            for idx in sorted(self._buckets):
                seen += self._buckets[idx]
                if rank < seen:
                    est = _bucket_upper(idx)
                    return min(max(est, self.min), self.max)
            return self.max

    def count_above(self, threshold: float) -> int:
        """Samples strictly above ``threshold`` (to sketch resolution).

        Counts every bucket whose upper edge exceeds the threshold, so values
        in the threshold's own bucket are attributed as "above" — the estimate
        errs pessimistic by at most one bucket (~4.4%).  Used by the SLO
        monitor to turn a latency sketch into a bad-event count.
        """
        with self._lock:
            above = sum(c for idx, c in self._buckets.items()
                        if _bucket_upper(idx) > threshold)
            if threshold < 0.0:
                above += self._underflow
            return above

    def to_state(self) -> dict:
        """Serializable sketch state; exact round-trip via :meth:`from_state`.

        Bucket keys are stringified for JSON; ``min``/``max`` are None when
        empty (the inf sentinels are not JSON-representable).
        """
        with self._lock:
            return {
                "buckets": {str(i): c for i, c in self._buckets.items()},
                "underflow": self._underflow,
                "count": self.count,
                "sum": self.sum,
                "min": None if self.count == 0 else self.min,
                "max": None if self.count == 0 else self.max,
            }

    @classmethod
    def from_state(cls, state: dict) -> "Histogram":
        h = cls()
        h._buckets = {int(i): int(c) for i, c in state.get("buckets", {}).items()}
        h._underflow = int(state.get("underflow", 0))
        h.count = int(state.get("count", 0))
        h.sum = float(state.get("sum", 0.0))
        h.min = math.inf if state.get("min") is None else float(state["min"])
        h.max = -math.inf if state.get("max") is None else float(state["max"])
        return h

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def num_buckets(self) -> int:
        """Occupied sketch buckets — the histogram's actual state size."""
        with self._lock:
            return len(self._buckets) + (1 if self._underflow else 0)

    def summary(self) -> dict:
        """Point-in-time summary with SLO quantiles.

        An empty sketch returns a None-valued summary (``count`` 0, ``sum``
        0.0, every statistic None) rather than NaN or a divide-by-zero — the
        consumer can tell "no data" from "observed zeros".
        """
        with self._lock:
            count, total = self.count, self.sum
            lo = self.min if count else None
            hi = self.max if count else None
        return {
            "count": count,
            "sum": total,
            "mean": total / count if count else None,
            "min": lo,
            "max": hi,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
            "p999": self.quantile(0.999),
        }

    def snapshot(self) -> dict:
        snap = self.summary()
        snap["type"] = "histogram"
        return snap


_METRIC_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """Name + label-set keyed metric store.

    Keys are ``(name, frozenset(labels.items()))`` so label order never
    matters.  ``counter``/``gauge``/``histogram`` are get-or-create and the
    type of an existing name+labels pair is sticky (mismatches raise).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, frozenset], object] = {}

    def _get(self, kind: str, name: str, labels: Dict[str, object]):
        key = (name, frozenset((k, str(v)) for k, v in labels.items()))
        cls = _METRIC_TYPES[kind]
        with self._lock:
            metric = self._metrics.get(key)
            if metric is None:
                metric = cls()
                self._metrics[key] = metric
            elif not isinstance(metric, cls):
                raise TypeError(
                    f"metric {name!r} with labels {dict(labels)!r} already "
                    f"registered as {type(metric).__name__}, not {kind}"
                )
            return metric

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    def find(self, name: str) -> List[Tuple[dict, object]]:
        """All (labels, metric) pairs registered under ``name``."""
        with self._lock:
            items = list(self._metrics.items())
        return [(dict(key[1]), m) for key, m in items if key[0] == name]

    def percentile_summary(self, name: str, **labels) -> Optional[dict]:
        """Merged histogram summary across every series under ``name``.

        Series are filtered to those whose labels are a superset of the given
        ``labels``.  Returns None for an unknown metric name, for a name with
        no matching histogram series, or when every matching sketch is empty
        — never a NaN-valued dict.
        """
        want = {k: str(v) for k, v in labels.items()}
        merged = Histogram()
        matched = False
        for got, metric in self.find(name):
            if not isinstance(metric, Histogram):
                continue
            if any(got.get(k) != v for k, v in want.items()):
                continue
            matched = True
            merged.merge(metric)
        if not matched or merged.count == 0:
            return None
        return merged.summary()

    def snapshot(self) -> List[dict]:
        """Stable-ordered list of metric snapshots (one dict per series)."""
        with self._lock:
            items = list(self._metrics.items())
        rows = []
        for (name, labelset), metric in items:
            row = {"name": name, "labels": dict(sorted(labelset))}
            row.update(metric.snapshot())  # type: ignore[attr-defined]
            rows.append(row)
        rows.sort(key=lambda r: (r["name"], sorted(r["labels"].items())))
        return rows

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


def percentile_summary(values, unit_scale: float = 1.0) -> Optional[dict]:
    """Build a Histogram from raw samples and return its summary.

    Shared replacement for the hand-rolled ``np.percentile`` reporters in
    ``launch/serve_vi.py`` and ``launch/continuous_vi.py``: one sketch, one
    rounding rule, and p999 for free.  Returns None for an empty sample set.
    """
    vals = [float(v) * unit_scale for v in values]
    if not vals:
        return None
    h = Histogram()
    h.observe_many(vals)
    return h.summary()
