"""SLO objectives and multi-window burn-rate alerting over the registry.

An :class:`Objective` defines a service-level target as a *bad-event
fraction budget* evaluated against metrics that already exist in the
:mod:`repro.obs.metrics` registry:

- a **latency** objective reads a latency histogram and counts samples above
  a threshold as bad (``p99 <= 25ms`` becomes ``budget_frac=0.01`` over
  ``threshold_s=0.025`` — at most 1% of requests may exceed the threshold);
- an **events** objective reads a bad/total counter pair (update failures
  over update attempts).

:class:`SLOMonitor` snapshots the cumulative metrics on every :meth:`tick`
and evaluates *burn rates* over sliding windows by subtracting snapshots —
exact, because sketch bucket counts and counters are cumulative.  The burn
rate is the observed bad fraction divided by the budget fraction: burn 1.0
consumes the error budget exactly at the sustainable rate, burn 14.4 on a
5%-of-period window is the classic page-now threshold.  An objective alerts
when BOTH the long and the short window of any :class:`BurnWindow` pair
exceed that pair's threshold — the long window provides evidence, the short
window confirms the problem is still happening (so recovered incidents stop
alerting as soon as the short window drains).

``launch/continuous_vi.py`` drives its health state from this monitor
(alert -> ``degraded`` long before ``--max-failures`` would kill the loop)
and exports :meth:`SLOMonitor.state` as ``slo.json`` for
``launch/obs_report.py``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .core import registry as _global_registry
from .metrics import Counter, Histogram, Registry

__all__ = [
    "BurnWindow",
    "DEFAULT_WINDOWS",
    "Objective",
    "SLOMonitor",
    "error_objective",
    "latency_objective",
]


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """One (long, short) burn-rate window pair with its alert threshold."""

    long_s: float
    short_s: float
    max_burn: float


# Scaled-down versions of the classic 1h/5m + 6h/30m pairs: the continuous
# loop's whole lifetime is minutes, so windows are seconds here.  Callers
# with real uptime pass their own.
DEFAULT_WINDOWS: Tuple[BurnWindow, ...] = (
    BurnWindow(long_s=60.0, short_s=5.0, max_burn=14.4),
    BurnWindow(long_s=300.0, short_s=30.0, max_burn=6.0),
)


@dataclasses.dataclass(frozen=True)
class Objective:
    """A bad-fraction budget over registry metrics (build via the helpers)."""

    name: str
    budget_frac: float
    kind: str  # "latency" | "events"
    metric: Optional[str] = None          # latency: histogram name
    threshold_s: float = 0.0              # latency: bad above this
    labels: Tuple[Tuple[str, str], ...] = ()
    bad_metric: Optional[str] = None      # events: numerator counter
    total_metric: Optional[str] = None    # events: denominator counter

    def describe(self) -> Dict:
        d = {"name": self.name, "kind": self.kind,
             "budget_frac": self.budget_frac}
        if self.kind == "latency":
            d["metric"] = self.metric
            d["threshold_s"] = self.threshold_s
            if self.labels:
                d["labels"] = dict(self.labels)
        else:
            d["bad_metric"] = self.bad_metric
            d["total_metric"] = self.total_metric
        return d


def latency_objective(name: str, metric: str, threshold_s: float,
                      budget_frac: float = 0.01, **labels) -> Objective:
    """At most ``budget_frac`` of samples in ``metric`` above ``threshold_s``
    (``budget_frac=0.01`` == a p99 target at the threshold)."""
    if not 0.0 < budget_frac < 1.0:
        raise ValueError(f"budget_frac must be in (0, 1), got {budget_frac}")
    return Objective(
        name=name, budget_frac=budget_frac, kind="latency", metric=metric,
        threshold_s=float(threshold_s),
        labels=tuple(sorted((k, str(v)) for k, v in labels.items())),
    )


def error_objective(name: str, bad_metric: str, total_metric: str,
                    budget_frac: float = 0.01) -> Objective:
    """At most ``budget_frac`` of ``total_metric`` events in ``bad_metric``."""
    if not 0.0 < budget_frac < 1.0:
        raise ValueError(f"budget_frac must be in (0, 1), got {budget_frac}")
    return Objective(name=name, budget_frac=budget_frac, kind="events",
                     bad_metric=bad_metric, total_metric=total_metric)


class SLOMonitor:
    """Evaluate objectives by differencing cumulative metric snapshots.

    ``tick()`` is cheap (a registry scan plus O(windows) subtraction) and is
    meant to run once per control-loop iteration.  ``now`` is injectable for
    deterministic tests; it defaults to ``time.monotonic``.
    """

    def __init__(self, objectives: Sequence[Objective],
                 windows: Sequence[BurnWindow] = DEFAULT_WINDOWS,
                 registry: Optional[Registry] = None,
                 now: Callable[[], float] = time.monotonic) -> None:
        if not objectives:
            raise ValueError("SLOMonitor needs at least one objective")
        self._objectives = list(objectives)
        self._windows = tuple(windows)
        self._registry = registry
        self._now = now
        self._horizon = max(w.long_s for w in self._windows)
        # per objective: cumulative (t, total, bad) snapshots, oldest first
        self._history: Dict[str, List[Tuple[float, float, float]]] = {
            o.name: [] for o in self._objectives
        }
        self._state: Dict = {"objectives": [], "alerting": False, "ticks": 0}

    def _reg(self) -> Registry:
        return self._registry if self._registry is not None else _global_registry()

    def _totals(self, o: Objective) -> Tuple[float, float]:
        """Cumulative (total, bad) event counts for an objective, now."""
        reg = self._reg()
        if o.kind == "latency":
            want = dict(o.labels)
            total = bad = 0.0
            for got, metric in reg.find(o.metric or ""):
                if not isinstance(metric, Histogram):
                    continue
                if any(got.get(k) != v for k, v in want.items()):
                    continue
                total += metric.count
                bad += metric.count_above(o.threshold_s)
            return total, bad
        bad = sum(m.value for _, m in reg.find(o.bad_metric or "")
                  if isinstance(m, Counter))
        total = sum(m.value for _, m in reg.find(o.total_metric or "")
                    if isinstance(m, Counter))
        return float(total), float(bad)

    @staticmethod
    def _window_burn(hist: List[Tuple[float, float, float]], t: float,
                     window_s: float, budget_frac: float) -> Dict:
        """Burn rate over [t - window_s, t] from cumulative snapshots."""
        cur = hist[-1]
        base = hist[0]
        for rec in hist:  # latest snapshot at or before the window start
            if rec[0] <= t - window_s:
                base = rec
            else:
                break
        d_total = cur[1] - base[1]
        d_bad = cur[2] - base[2]
        frac = (d_bad / d_total) if d_total > 0 else 0.0
        return {
            "window_s": window_s,
            "events": d_total,
            "bad": d_bad,
            "bad_frac": frac,
            "burn": frac / budget_frac,
        }

    def tick(self, now: Optional[float] = None) -> List[Dict]:
        """Record one snapshot and re-evaluate; returns active alerts."""
        t = self._now() if now is None else float(now)
        alerts: List[Dict] = []
        obj_states: List[Dict] = []
        for o in self._objectives:
            total, bad = self._totals(o)
            hist = self._history[o.name]
            hist.append((t, total, bad))
            # keep one snapshot older than the horizon as the window base
            while len(hist) > 2 and hist[1][0] <= t - self._horizon:
                hist.pop(0)
            windows = []
            alerting = False
            for w in self._windows:
                long_b = self._window_burn(hist, t, w.long_s, o.budget_frac)
                short_b = self._window_burn(hist, t, w.short_s, o.budget_frac)
                fired = (long_b["burn"] >= w.max_burn
                         and short_b["burn"] >= w.max_burn)
                alerting = alerting or fired
                windows.append({
                    "max_burn": w.max_burn,
                    "long": long_b,
                    "short": short_b,
                    "alerting": fired,
                })
            state = dict(o.describe())
            state.update({
                "total": total,
                "bad": bad,
                "windows": windows,
                "alerting": alerting,
            })
            obj_states.append(state)
            if alerting:
                worst = max(
                    (w for w in windows if w["alerting"]),
                    key=lambda w: w["long"]["burn"],
                )
                alerts.append({
                    "objective": o.name,
                    "burn": worst["long"]["burn"],
                    "max_burn": worst["max_burn"],
                    "bad_frac": worst["long"]["bad_frac"],
                    "budget_frac": o.budget_frac,
                })
        self._state = {
            "objectives": obj_states,
            "alerting": bool(alerts),
            "ticks": self._state.get("ticks", 0) + 1,
            "t": t,
        }
        return alerts

    def alerting(self) -> bool:
        return bool(self._state.get("alerting"))

    def state(self) -> Dict:
        """JSON-serializable view of the last evaluation (``slo.json``)."""
        return self._state
