"""Chrome-trace event recording and export.

Span/instant events accumulate in a bounded ring buffer and export as the
Chrome trace-event JSON format (``{"traceEvents": [...]}``) that loads
directly in ``ui.perfetto.dev`` or ``chrome://tracing``.

Event vocabulary (the subset of the spec we emit):

- ``ph: "X"`` — complete event: a span with ``ts``/``dur`` in microseconds.
- ``ph: "i"`` — instant event (compile, recompile, regrowth, activation...).
- ``ph: "M"`` — metadata (process/thread names), emitted at export time.

``pid`` is the real process id; ``tid`` is a stable small integer per Python
thread so nested spans from one thread stack correctly in the timeline.
"""

from __future__ import annotations

import json
import os
import threading
from collections import deque
from typing import Dict, List, Optional

__all__ = [
    "TraceBuffer",
    "chrome_trace",
    "export_chrome_trace",
    "validate_chrome_trace",
]

_REQUIRED_KEYS = {"name", "ph", "ts", "pid", "tid"}
_KNOWN_PHASES = {"X", "i", "B", "E", "M", "C"}


class TraceBuffer:
    """Thread-safe bounded buffer of Chrome-trace events."""

    def __init__(self, maxlen: int = 100_000) -> None:
        self._lock = threading.Lock()
        # hot path is lock-free: deque.append with maxlen is itself
        # thread-safe and lossless under the GIL; ``_added`` is a telemetry
        # counter (racy increments may undercount drops, never events).
        # Records are plain tuples — building the Chrome-trace dict (7 keys
        # hashed, cache-cold between device calls) costs several times the
        # tuple append, so it is deferred to :meth:`events` at export time:
        #   ("X", name, ts_us, dur_us, tid, args)   complete (span)
        #   ("i", name, ts_us, tid, args)           instant
        self._events: deque = deque(maxlen=maxlen)
        self._tids: Dict[int, int] = {}
        self._pid = os.getpid()
        self._added = 0

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids) + 1)
        return tid

    def add_complete(self, name: str, ts_us: float, dur_us: float,
                     args: Optional[dict] = None) -> None:
        self._added += 1
        self._events.append(("X", name, ts_us, dur_us, self._tid(), args))

    def add_instant(self, name: str, ts_us: float,
                    args: Optional[dict] = None) -> None:
        self._added += 1
        self._events.append(("i", name, ts_us, self._tid(), args))

    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped(self) -> int:
        return max(0, self._added - len(self._events))

    def events(self) -> List[dict]:
        """Materialize the buffered records as Chrome-trace event dicts."""
        while True:
            try:
                raw = list(self._events)
                break
            except RuntimeError:
                continue  # deque mutated mid-copy by a concurrent append
        pid = self._pid
        out = []
        for rec in raw:
            if rec[0] == "X":
                _, name, ts, dur, tid, args = rec
                out.append({
                    "name": name, "ph": "X", "ts": ts, "dur": dur,
                    "pid": pid, "tid": tid, "args": args or {},
                })
            else:
                _, name, ts, tid, args = rec
                out.append({
                    "name": name, "ph": "i", "ts": ts, "pid": pid,
                    "tid": tid, "s": "t",  # thread-scoped instant
                    "args": args or {},
                })
        return out

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._added = 0


def chrome_trace(events: List[dict], process_name: str = "repro") -> dict:
    """Wrap raw events in the Chrome trace-event container format."""
    pid = os.getpid()
    rounded = []
    for e in events:  # rounding deferred off the recording hot path
        e = dict(e)
        e["ts"] = round(e["ts"], 3)
        if "dur" in e:
            e["dur"] = round(e["dur"], 3)
        rounded.append(e)
    events = rounded
    meta = [{
        "name": "process_name",
        "ph": "M",
        "ts": 0,
        "pid": pid,
        "tid": 0,
        "args": {"name": process_name},
    }]
    tids = sorted({e["tid"] for e in events})
    for tid in tids:
        meta.append({
            "name": "thread_name",
            "ph": "M",
            "ts": 0,
            "pid": pid,
            "tid": tid,
            "args": {"name": f"thread-{tid}"},
        })
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def export_chrome_trace(events: List[dict], path: str,
                        process_name: str = "repro") -> str:
    """Write events as a Chrome-trace JSON file; returns the path."""
    doc = chrome_trace(events, process_name=process_name)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return path


def validate_chrome_trace(doc: dict) -> List[dict]:
    """Validate a trace document against the Chrome trace-event schema.

    Raises ``ValueError`` on the first malformed event; returns the list of
    non-metadata events on success.  Used by tests and ``bench_obs`` so an
    unloadable trace.json fails loudly instead of silently in the viewer.
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace document must be a dict with 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    payload = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        missing = _REQUIRED_KEYS - set(ev)
        if missing:
            raise ValueError(f"event {i} missing keys {sorted(missing)}: {ev}")
        if ev["ph"] not in _KNOWN_PHASES:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} has bad ts: {ev['ts']!r}")
        if not isinstance(ev["pid"], int) or not isinstance(ev["tid"], int):
            raise ValueError(f"event {i} pid/tid must be ints: {ev}")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"complete event {i} has bad dur: {dur!r}")
        if ev["ph"] != "M":
            payload.append(ev)
    return payload
