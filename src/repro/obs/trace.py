"""Chrome-trace event recording and export.

Span/instant events accumulate in a bounded ring buffer and export as the
Chrome trace-event JSON format (``{"traceEvents": [...]}``) that loads
directly in ``ui.perfetto.dev`` or ``chrome://tracing``.

Event vocabulary (the subset of the spec we emit):

- ``ph: "X"`` — complete event: a span with ``ts``/``dur`` in microseconds.
- ``ph: "i"`` — instant event (compile, recompile, regrowth, activation...).
- ``ph: "C"`` — counter sample (live-memory timeline gauges).
- ``ph: "M"`` — metadata (process/thread names), emitted at export time.

``pid`` is the real process id; ``tid`` is a stable small integer per Python
thread so nested spans from one thread stack correctly in the timeline.
"""

from __future__ import annotations

import json
import os
import threading
from collections import deque
from typing import Dict, List, Optional

__all__ = [
    "TraceBuffer",
    "chrome_trace",
    "export_chrome_trace",
    "merge_traces",
    "validate_chrome_trace",
]

_REQUIRED_KEYS = {"name", "ph", "ts", "pid", "tid"}
_KNOWN_PHASES = {"X", "i", "B", "E", "M", "C"}


class TraceBuffer:
    """Thread-safe bounded buffer of Chrome-trace events."""

    def __init__(self, maxlen: int = 100_000) -> None:
        self._lock = threading.Lock()
        # hot path is lock-free: deque.append with maxlen is itself
        # thread-safe and lossless under the GIL; ``_added`` is a telemetry
        # counter (racy increments may undercount drops, never events).
        # Records are plain tuples — building the Chrome-trace dict (7 keys
        # hashed, cache-cold between device calls) costs several times the
        # tuple append, so it is deferred to :meth:`events` at export time:
        #   ("X", name, ts_us, dur_us, tid, args)   complete (span)
        #   ("i", name, ts_us, tid, args)           instant
        #   ("C", name, ts_us, tid, values)         counter sample
        self._events: deque = deque(maxlen=maxlen)
        self._tids: Dict[int, int] = {}
        self._pid = os.getpid()
        self._added = 0

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids) + 1)
        return tid

    def add_complete(self, name: str, ts_us: float, dur_us: float,
                     args: Optional[dict] = None) -> None:
        self._added += 1
        self._events.append(("X", name, ts_us, dur_us, self._tid(), args))

    def add_instant(self, name: str, ts_us: float,
                    args: Optional[dict] = None) -> None:
        self._added += 1
        self._events.append(("i", name, ts_us, self._tid(), args))

    def add_counter(self, name: str, ts_us: float, values: dict) -> None:
        """Record a Chrome counter sample (``ph: "C"``).

        ``values`` maps series name -> number; Perfetto renders one stacked
        counter track per (pid, name).  Used for the live-memory timeline.
        """
        self._added += 1
        self._events.append(("C", name, ts_us, self._tid(), values))

    def __len__(self) -> int:
        return len(self._events)

    @property
    def dropped(self) -> int:
        return max(0, self._added - len(self._events))

    def events(self) -> List[dict]:
        """Materialize the buffered records as Chrome-trace event dicts."""
        while True:
            try:
                raw = list(self._events)
                break
            except RuntimeError:
                continue  # deque mutated mid-copy by a concurrent append
        pid = self._pid
        out = []
        for rec in raw:
            if rec[0] == "X":
                _, name, ts, dur, tid, args = rec
                out.append({
                    "name": name, "ph": "X", "ts": ts, "dur": dur,
                    "pid": pid, "tid": tid, "args": args or {},
                })
            elif rec[0] == "C":
                _, name, ts, tid, args = rec
                out.append({
                    "name": name, "ph": "C", "ts": ts, "pid": pid,
                    "tid": tid, "args": args or {},
                })
            else:
                _, name, ts, tid, args = rec
                out.append({
                    "name": name, "ph": "i", "ts": ts, "pid": pid,
                    "tid": tid, "s": "t",  # thread-scoped instant
                    "args": args or {},
                })
        return out

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._added = 0


def chrome_trace(events: List[dict], process_name: str = "repro") -> dict:
    """Wrap raw events in the Chrome trace-event container format."""
    pid = os.getpid()
    rounded = []
    for e in events:  # rounding deferred off the recording hot path
        e = dict(e)
        e["ts"] = round(e["ts"], 3)
        if "dur" in e:
            e["dur"] = round(e["dur"], 3)
        rounded.append(e)
    events = rounded
    meta = [{
        "name": "process_name",
        "ph": "M",
        "ts": 0,
        "pid": pid,
        "tid": 0,
        "args": {"name": process_name},
    }]
    tids = sorted({e["tid"] for e in events})
    for tid in tids:
        meta.append({
            "name": "thread_name",
            "ph": "M",
            "ts": 0,
            "pid": pid,
            "tid": tid,
            "args": {"name": f"thread-{tid}"},
        })
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def export_chrome_trace(events: List[dict], path: str,
                        process_name: str = "repro") -> str:
    """Write events as a Chrome-trace JSON file; returns the path."""
    doc = chrome_trace(events, process_name=process_name)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return path


def merge_traces(docs: List[dict], markers: Optional[List[dict]] = None,
                 gap_us: float = 1_000.0,
                 harness_name: str = "harness") -> dict:
    """Merge per-process trace documents onto one sequential timeline.

    Each document keeps its own process track (its real ``pid``; a synthetic
    one on collision) but is shifted so document ``i`` begins after document
    ``i-1`` ends — per-process ``perf_counter`` epochs share no origin, so
    only within-process ordering is meaningful and a sequential layout is the
    honest rendering of e.g. a killed controller followed by its resume.

    ``markers`` inject instant events from the merging (harness) process onto
    a dedicated track: ``{"name": ..., "after_doc": i, "args": {...}}`` lands
    on the merged timeline at the boundary after document ``i`` (``-1`` = the
    very start).  Used by ``launch/chaos_vi.py`` for kill/recovery markers.
    """
    merged: List[dict] = []
    seen_pids: set = set()
    boundaries: Dict[int, float] = {-1: 0.0}
    cursor = 0.0
    for i, doc in enumerate(docs):
        events = doc.get("traceEvents", [])
        payload = [e for e in events if e.get("ph") != "M"]
        meta = [e for e in events if e.get("ph") == "M"]
        pids = {e["pid"] for e in payload} | {e["pid"] for e in meta}
        pid_map = {}
        for pid in sorted(pids):
            new = pid
            while new in seen_pids:
                new += 100_000  # same-pid docs still get distinct tracks
            pid_map[pid] = new
            seen_pids.add(new)
        t0 = min((e["ts"] for e in payload), default=0.0)
        end = cursor
        for e in meta:
            e = dict(e)
            e["pid"] = pid_map[e["pid"]]
            merged.append(e)
        for e in payload:
            e = dict(e)
            e["pid"] = pid_map[e["pid"]]
            e["ts"] = e["ts"] - t0 + cursor
            end = max(end, e["ts"] + e.get("dur", 0.0))
            merged.append(e)
        boundaries[i] = end
        cursor = end + gap_us
    harness_pid = os.getpid()
    while harness_pid in seen_pids:
        harness_pid += 100_000
    if markers:
        merged.append({
            "name": "process_name", "ph": "M", "ts": 0,
            "pid": harness_pid, "tid": 0, "args": {"name": harness_name},
        })
        last = max(boundaries.values())
        for k, m in enumerate(markers):
            ts = boundaries.get(m.get("after_doc", -1), last) + gap_us * 0.5
            merged.append({
                "name": m["name"], "ph": "i", "ts": round(ts, 3) + k * 1e-3,
                "pid": harness_pid, "tid": 1, "s": "g",  # global-scoped
                "args": m.get("args") or {},
            })
    return {"traceEvents": merged, "displayTimeUnit": "ms"}


def validate_chrome_trace(doc: dict) -> List[dict]:
    """Validate a trace document against the Chrome trace-event schema.

    Raises ``ValueError`` on the first malformed event; returns the list of
    non-metadata events on success.  Used by tests and ``bench_obs`` so an
    unloadable trace.json fails loudly instead of silently in the viewer.
    """
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace document must be a dict with 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    payload = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        missing = _REQUIRED_KEYS - set(ev)
        if missing:
            raise ValueError(f"event {i} missing keys {sorted(missing)}: {ev}")
        if ev["ph"] not in _KNOWN_PHASES:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} has bad ts: {ev['ts']!r}")
        if not isinstance(ev["pid"], int) or not isinstance(ev["tid"], int):
            raise ValueError(f"event {i} pid/tid must be ints: {ev}")
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"complete event {i} has bad dur: {dur!r}")
        if ev["ph"] != "M":
            payload.append(ev)
    return payload
