"""Incremental OAVI: batch fitting turned into continuous fitting.

The Gram sufficient statistics that drive the streaming degree step are
additive over rows and bit-reproducible under the blocked-reduction carry-in
contract, so a fit over continuously-arriving data is a *fold*: persist the
per-degree accumulators (:class:`FitState`), fold new chunks in
(:func:`update` — bit-identical to a full streaming refit on the
concatenated data), re-run the m-independent statistics-only degree steps
(zero recompiles warm), and gate the whole thing on cheap one-pass drift
signals (:class:`DriftMonitor`).  The ingest→refit→activate serving loop
lives in ``launch/continuous_vi.py``.
"""

from .drift import DriftConfig, DriftMonitor
from .state import FIT_STATE_FORMAT, DegreeRecord, FitState
from .update import UpdateResult, fit, update

__all__ = [
    "DegreeRecord",
    "DriftConfig",
    "DriftMonitor",
    "FIT_STATE_FORMAT",
    "FitState",
    "UpdateResult",
    "fit",
    "update",
]
