"""Drift detection from one-pass statistics — the refit trigger.

The continuous loop should not refit on every arrival (an update is cheap
but not free) nor serve a stale model once the data moved.  The
:class:`DriftMonitor` decides, from exactly the statistics the fit already
keeps — per-feature first/second moments and the frozen min-max range — and
nothing else: observing a chunk is O(rows * n) host adds, no device work.

Signals, all computed in the *scaled* space the models are fitted in:

* **mean shift** — per-feature ``|mean_window - mean_ref| / std_ref``: the
  distribution moved.
* **mse0 ratio** — per-feature windowed variance over reference variance
  (either direction).  The per-feature variance is the closed-form MSE of
  the best degree-0 fit — the ``mse0`` every OAVI degree step starts from —
  so a blown-up ratio means polynomials that used to vanish on the data no
  longer do (or vice versa): the vanishing structure itself changed.
* **out-of-range fraction** — share of values outside ``[0, 1]`` under the
  *frozen* scaler: new data escaped the min-max box the scaler was fitted
  on, the one failure the frozen-scaler design cannot absorb (the loop
  should refit with a fresh scaler).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from .state import FitState


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Refit-trigger thresholds (see module docstring for the signals)."""

    mean_shift: float = 0.25  # max per-feature |mean shift| / ref std
    mse0_ratio: float = 2.0  # max per-feature var ratio (either direction)
    range_frac: float = 1e-3  # tolerated fraction of out-of-[0,1] values
    min_rows: int = 512  # don't judge drift on fewer window rows

    def __post_init__(self):
        if self.mean_shift <= 0 or self.mse0_ratio <= 1.0 or self.range_frac < 0:
            raise ValueError(
                "need mean_shift > 0, mse0_ratio > 1, range_frac >= 0; got "
                f"({self.mean_shift}, {self.mse0_ratio}, {self.range_frac})"
            )


class DriftMonitor:
    """Fold incoming (scaled) chunks into window statistics; compare against
    a reference (typically the fitted data's own moments).

    Usage::

        monitor = DriftMonitor.from_fit_state(state)   # or set_reference()
        monitor.observe(scaled_chunk)                  # per arrival
        if monitor.should_refit()[0]:
            ...run the update, then monitor.rebase()
    """

    def __init__(self, config: DriftConfig = DriftConfig()):
        self.config = config
        self._ref: Optional[Tuple[np.ndarray, np.ndarray, int]] = None
        self.reset_window()

    # -- reference ----------------------------------------------------------

    def set_reference(self, s1: np.ndarray, sq: np.ndarray, rows: int) -> None:
        """Reference from one-pass sums: ``s1[j] = sum x_j``,
        ``sq[j] = sum x_j^2`` over ``rows`` scaled rows."""
        if rows <= 1:
            raise ValueError(f"reference needs > 1 rows, got {rows}")
        self._ref = (
            np.asarray(s1, np.float64).copy(),
            np.asarray(sq, np.float64).copy(),
            int(rows),
        )

    @classmethod
    def from_fit_state(
        cls, state: FitState, config: DriftConfig = DriftConfig()
    ) -> "DriftMonitor":
        """Reference = the Pearson moment snapshot the fit already paid for
        (``s1`` and ``diag(s2)`` over ``moment_rows`` rows).  Requires a
        state fitted with a Pearson ordering (otherwise no moments exist —
        use :meth:`set_reference` with your own pass)."""
        if state.moments is None or state.moment_rows <= 1:
            raise ValueError(
                "FitState carries no moment statistics (ordering='none'?); "
                "seed the monitor with set_reference() instead"
            )
        mon = cls(config)
        s1, s2 = state.moments
        mon.set_reference(s1, np.diagonal(s2), state.moment_rows)
        return mon

    # -- window -------------------------------------------------------------

    def reset_window(self) -> None:
        self._w_s1: Optional[np.ndarray] = None
        self._w_sq: Optional[np.ndarray] = None
        self._w_rows = 0
        self._w_oob = 0
        self._w_vals = 0

    def observe(self, chunk) -> None:
        """Fold one chunk of *scaled* rows into the drift window."""
        rows = np.asarray(chunk, np.float64)
        if rows.ndim != 2 or rows.shape[0] == 0:
            return
        if self._w_s1 is None:
            self._w_s1 = np.zeros((rows.shape[1],), np.float64)
            self._w_sq = np.zeros((rows.shape[1],), np.float64)
        self._w_s1 += rows.sum(axis=0)
        self._w_sq += (rows * rows).sum(axis=0)
        self._w_rows += rows.shape[0]
        self._w_oob += int(((rows < 0.0) | (rows > 1.0)).sum())
        self._w_vals += rows.size

    def rebase(self) -> None:
        """After a refit absorbed the window: fold it into the reference and
        start a fresh window (the new normal includes the observed data)."""
        if self._ref is not None and self._w_rows:
            s1, sq, rows = self._ref
            self._ref = (s1 + self._w_s1, sq + self._w_sq, rows + self._w_rows)
        self.reset_window()

    # -- signals ------------------------------------------------------------

    @property
    def window_rows(self) -> int:
        return self._w_rows

    def signals(self) -> Dict:
        """Current drift signals (NaN-free; zeros while the window or the
        reference is empty)."""
        out = {
            "window_rows": self._w_rows,
            "mean_shift": 0.0,
            "mse0_ratio": 1.0,
            "oob_frac": 0.0,
        }
        if self._ref is None or self._w_rows == 0:
            return out
        s1, sq, rows = self._ref
        mean_r = s1 / rows
        var_r = np.maximum(sq / rows - mean_r**2, 0.0)
        mean_w = self._w_s1 / self._w_rows
        var_w = np.maximum(self._w_sq / self._w_rows - mean_w**2, 0.0)
        eps = 1e-12
        std_r = np.sqrt(np.maximum(var_r, eps))
        out["mean_shift"] = float(np.max(np.abs(mean_w - mean_r) / std_r))
        ratio = np.maximum(var_w, eps) / np.maximum(var_r, eps)
        out["mse0_ratio"] = float(np.max(np.maximum(ratio, 1.0 / ratio)))
        out["oob_frac"] = float(self._w_oob / max(self._w_vals, 1))
        return out

    def should_refit(self) -> Tuple[bool, Dict]:
        """(trigger, signals-with-verdict).  Never triggers before
        ``min_rows`` window rows (tiny windows are all variance)."""
        sig = self.signals()
        cfg = self.config
        triggered = []
        if self._w_rows >= cfg.min_rows:
            if sig["mean_shift"] > cfg.mean_shift:
                triggered.append("mean_shift")
            if sig["mse0_ratio"] > cfg.mse0_ratio:
                triggered.append("mse0_ratio")
            if sig["oob_frac"] > cfg.range_frac:
                triggered.append("oob_frac")
        sig["triggered"] = triggered
        return bool(triggered), sig
