"""Persisted fit state for incremental OAVI.

The streaming fit's only O(m) work is folding row chunks into per-degree
Gram accumulators ``(accQL, accC) = (A^T B, B^T B)``; everything downstream
(the statistics-only degree step, the IHB factors) is m-independent.  Those
accumulators are additive over rows *and* bit-reproducible under the
:func:`repro.kernels.ops.gram_accumulate` carry-in contract — its fp32
reduction runs strictly left-to-right over fixed :data:`GRAM_BLOCK`-row
blocks, so statistics over rows ``[0, r)`` extended with rows ``[r, m)``
equal a one-shot pass over ``[0, m)`` exactly, *provided* ``r`` sits on a
block boundary.  A :class:`FitState` therefore snapshots each degree's
accumulators over the block-aligned prefix ``aligned_rows = (m // B) * B``;
the (< B-row) unaligned tail is re-read from the source at update time.

A degree's snapshot is only reusable while the fit's decision history up to
that degree is unchanged: the term book is built prefix-append-only, so a
record is valid iff the stored book prefix of length ``ell`` (the |O| at
that degree's start) matches the book the new fit has built so far, at the
same capacities.  Once new data flips one accept/reject decision, that
degree and all later ones replay from row 0 — :mod:`repro.online.update`
handles both cases degree by degree.

Serialized via :func:`repro.api.save_state_dict` under the versioned format
tag :data:`FIT_STATE_FORMAT` (``repro.online_fit_state.v1``), through the
same atomic :mod:`repro.checkpoint.store` manifest machinery as models.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import terms as terms_mod
from ..core.oavi import OAVIConfig
from ..core.oracles import OracleConfig

FIT_STATE_FORMAT = "repro.online_fit_state.v1"


def config_to_dict(config: OAVIConfig) -> Dict:
    """JSON-safe dict of an :class:`OAVIConfig` (nested solver included)."""
    return dataclasses.asdict(config)


def config_from_dict(d: Dict) -> OAVIConfig:
    d = dict(d)
    d["solver"] = OracleConfig(**d["solver"])
    return OAVIConfig(**d)


@dataclasses.dataclass
class DegreeRecord:
    """One degree's Gram statistics over the block-aligned row prefix.

    ``ell`` is |O| when the degree started (the occupied accQL rows), ``K``
    the border size; ``Lcap`` / ``Kcap`` the capacity buckets the
    accumulators were shaped with — all four must match for the record to be
    foldable (capacity changes the padding, and padded fp32 shapes are part
    of the bit contract).
    """

    degree: int
    ell: int
    K: int
    Lcap: int
    Kcap: int
    accQL: np.ndarray  # (Lcap, Kcap) fp32 = A^T B over rows [0, aligned_rows)
    accC: np.ndarray  # (Kcap, Kcap) fp32 = B^T B over rows [0, aligned_rows)


@dataclasses.dataclass
class FitState:
    """Everything an :func:`repro.online.update` needs besides the source.

    ``book_parents`` / ``book_vars`` are the FINAL term book of the fit that
    produced this state; a :class:`DegreeRecord` for degree ``d`` validates
    against their length-``ell`` prefix.  ``moments`` is the float64 Pearson
    one-pass state ``(s1, s2)`` (present iff the config orders features), so
    an update folds only the new rows before re-deriving the permutation.
    ``scaler_lo`` / ``scaler_hi`` record the frozen min-max statistics the
    source was scaled with — reference material for drift monitoring; the
    update itself never rescales.  ``probe_first`` / ``probe_last`` are raw
    copies of rows ``0`` and ``num_rows - 1``: an update re-reads them to
    catch the unrecoverable error of feeding a source whose prefix is not
    the data this state accumulated.
    """

    n: int
    num_rows: int
    aligned_rows: int
    chunk_rows: int
    config: OAVIConfig
    book_parents: np.ndarray  # (L,) int32 — final book, prefix-validates records
    book_vars: np.ndarray  # (L,) int32
    records: List[DegreeRecord]
    feature_perm: Optional[np.ndarray] = None
    moments: Optional[Tuple[np.ndarray, np.ndarray]] = None  # (s1, s2) float64
    moment_rows: int = 0  # rows covered by ``moments`` (chunk-grid aligned)
    scaler_lo: Optional[np.ndarray] = None
    scaler_hi: Optional[np.ndarray] = None
    probe_first: Optional[np.ndarray] = None
    probe_last: Optional[np.ndarray] = None

    def record_for(self, degree: int) -> Optional[DegreeRecord]:
        for rec in self.records:
            if rec.degree == degree:
                return rec
        return None

    def record_matches(
        self, degree: int, book: terms_mod.TermBook, K: int, Lcap: int, Kcap: int
    ) -> Optional[DegreeRecord]:
        """The stored record for ``degree`` iff it was accumulated under the
        identical decision history (book prefix) and capacities — the exact
        condition under which folding new rows into it is bit-identical to a
        full pass.  The book is append-only, so a prefix match at length
        ``ell`` pins every prior degree's decisions."""
        rec = self.record_for(degree)
        if rec is None:
            return None
        ell = len(book)
        if (rec.ell, rec.K, rec.Lcap, rec.Kcap) != (ell, K, Lcap, Kcap):
            return None
        if not (
            np.array_equal(self.book_parents[:ell], np.asarray(book.parents))
            and np.array_equal(self.book_vars[:ell], np.asarray(book.vars))
        ):
            return None
        return rec

    # -- serialization ------------------------------------------------------

    def to_state_dict(self) -> Tuple[Dict[str, np.ndarray], Dict]:
        arrays: Dict[str, np.ndarray] = {
            "book_parents": np.asarray(self.book_parents, np.int32),
            "book_vars": np.asarray(self.book_vars, np.int32),
        }
        if self.feature_perm is not None:
            arrays["feature_perm"] = np.asarray(self.feature_perm, np.int64)
        if self.moments is not None:
            arrays["moment_s1"] = np.asarray(self.moments[0], np.float64)
            arrays["moment_s2"] = np.asarray(self.moments[1], np.float64)
        if self.scaler_lo is not None:
            arrays["scaler_lo"] = np.asarray(self.scaler_lo, np.float64)
        if self.scaler_hi is not None:
            arrays["scaler_hi"] = np.asarray(self.scaler_hi, np.float64)
        if self.probe_first is not None:
            arrays["probe_first"] = np.asarray(self.probe_first)
        if self.probe_last is not None:
            arrays["probe_last"] = np.asarray(self.probe_last)
        recs_meta = []
        for rec in self.records:
            arrays[f"deg{rec.degree:03d}_accQL"] = np.asarray(rec.accQL, np.float32)
            arrays[f"deg{rec.degree:03d}_accC"] = np.asarray(rec.accC, np.float32)
            recs_meta.append(
                {
                    "degree": rec.degree,
                    "ell": rec.ell,
                    "K": rec.K,
                    "Lcap": rec.Lcap,
                    "Kcap": rec.Kcap,
                }
            )
        meta = {
            "kind": "online_fit_state",
            "n": int(self.n),
            "num_rows": int(self.num_rows),
            "aligned_rows": int(self.aligned_rows),
            "chunk_rows": int(self.chunk_rows),
            "moment_rows": int(self.moment_rows),
            "config": config_to_dict(self.config),
            "records": recs_meta,
        }
        return arrays, meta

    @classmethod
    def from_state_dict(cls, arrays: Dict, meta: Dict) -> "FitState":
        records = [
            DegreeRecord(
                degree=int(r["degree"]),
                ell=int(r["ell"]),
                K=int(r["K"]),
                Lcap=int(r["Lcap"]),
                Kcap=int(r["Kcap"]),
                accQL=np.asarray(arrays[f"deg{int(r['degree']):03d}_accQL"]),
                accC=np.asarray(arrays[f"deg{int(r['degree']):03d}_accC"]),
            )
            for r in meta["records"]
        ]
        moments = None
        if "moment_s1" in arrays:
            moments = (
                np.asarray(arrays["moment_s1"]),
                np.asarray(arrays["moment_s2"]),
            )
        get = lambda k: np.asarray(arrays[k]) if k in arrays else None  # noqa: E731
        return cls(
            n=int(meta["n"]),
            num_rows=int(meta["num_rows"]),
            aligned_rows=int(meta["aligned_rows"]),
            chunk_rows=int(meta["chunk_rows"]),
            config=config_from_dict(meta["config"]),
            book_parents=np.asarray(arrays["book_parents"]),
            book_vars=np.asarray(arrays["book_vars"]),
            records=records,
            feature_perm=get("feature_perm"),
            moments=moments,
            moment_rows=int(meta.get("moment_rows", 0)),
            scaler_lo=get("scaler_lo"),
            scaler_hi=get("scaler_hi"),
            probe_first=get("probe_first"),
            probe_last=get("probe_last"),
        )

    def save(self, path: str, step: int = 0) -> str:
        """Persist atomically (committed checkpoint manifest) at ``path``.

        ``step`` versions successive snapshots inside one directory so a
        corrupted head (detected by the manifest-v2 leaf checksums) falls
        back to the previous committed state on :meth:`load` — pair with
        :func:`repro.checkpoint.store.cleanup` to bound retention."""
        from .. import api

        arrays, meta = self.to_state_dict()
        return api.save_state_dict(path, arrays, meta, FIT_STATE_FORMAT, step=step)

    @classmethod
    def load(cls, path: str) -> "FitState":
        """Load the newest *verifiable* persisted state at ``path``: every
        Gram snapshot leaf is checksum-verified first, and a corrupt head
        checkpoint falls back to the newest older committed one (an
        :class:`~repro.resilience.integrity.IntegrityError` naming the bad
        file propagates only when nothing under ``path`` verifies)."""
        from .. import api

        arrays, metadata = api.load_state_dict(path, FIT_STATE_FORMAT)
        return cls.from_state_dict(arrays, metadata["meta"])
