"""Incremental OAVI: fold new rows into persisted Gram state.

:func:`update` takes a fitted model, its :class:`~repro.online.state.FitState`
and the *grown* source (old rows first, new rows appended) and produces the
model of the grown data — without re-reading the old rows, degree by degree:

* a degree whose stored :class:`DegreeRecord` still matches the (new) fit's
  decision history folds only rows ``[aligned_rows, m_new)`` into the saved
  accumulators — the per-degree data work drops from O(m) to O(new rows);
* a degree whose border changed (new data flipped an accept/reject upstream,
  growing or shrinking the book prefix) replays rows ``[0, m_new)`` — border
  growth is handled by replaying only the affected degrees, never the whole
  fit, because the book is prefix-append-only: degrees before the first
  changed decision keep folding.

Bit-exactness: both paths produce accumulators bit-identical to a full
streaming refit over the concatenated source at matched capacity.  The fold
resumes on a :data:`~repro.kernels.ops.GRAM_BLOCK` boundary
(``FitState.aligned_rows``), so the blocked fp32 reduction sees the exact
same block partition as a one-shot pass (the ``gram_accumulate`` carry-in
contract); the m-independent statistics-only degree step then runs on
bit-equal inputs.  The Pearson moment fold keeps the same guarantee by
snapshotting moments on the ``chunk_rows`` grid (the one-shot pass's own
chunk partition).

Zero recompiles warm: the degree loop reuses the streaming fit's global
chunk-accumulator and stats-step caches, so an update after any warm
streaming fit (or prior update) of the same config and book sequence
compiles nothing.

The degree step re-runs for *every* degree — folded or replayed — because
the IHB factors are rebuilt from the new statistics as the degrees advance;
that work is O(Lcap^2) per degree, independent of ``m``, which is exactly
why folding wins.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core import ihb as ihb_mod
from ..core import terms as terms_mod
from ..core.oavi import (
    FitScope,
    Generator,
    OAVIConfig,
    OAVIModel,
    _np_dtype,
    border_index_arrays,
    collect_degree,
    init_fit_stats,
    pow2_bucket,
)
from ..core.ordering import pearson_order_from_moments
from ..kernels import ops as kernel_ops
from ..streaming.fit import (
    DEFAULT_CHUNK_ROWS,
    _check_chunk_rows,
    _chunk_accumulator,
    _streaming_stats_entry,
    accumulate_source_range,
    pearson_moments,
)
from ..streaming.source import DataSource, as_source
from .state import DegreeRecord, FitState


@dataclasses.dataclass
class UpdateResult:
    """What :func:`update` hands back: the refreshed model (a new version,
    bit-identical to a full refit on the grown data), the new fit state for
    the *next* update, and update-level accounting."""

    model: OAVIModel
    state: FitState
    stats: Dict


def _probe_row(source: DataSource, row: int) -> np.ndarray:
    return np.array(source.read(row, row + 1)[0])


def _pearson_perm(
    source: DataSource,
    chunk_rows: int,
    config: OAVIConfig,
    base: Optional[FitState],
) -> Tuple[np.ndarray, Tuple[np.ndarray, np.ndarray], int]:
    """Feature permutation of the (grown) source + the chunk-aligned moment
    snapshot for the next state.

    Moments are snapshotted at ``(m // chunk_rows) * chunk_rows`` — a chunk
    boundary of the one-shot pass — so folding new full chunks on top of the
    snapshot reproduces :func:`streaming_pearson_order`'s float64 sums bit
    for bit (same chunk partition, same summation order).  A base state with
    a different ``chunk_rows`` cannot reuse its snapshot (different
    partition): moments recompute from scratch, still matching the one-shot
    pass at the *new* chunk size."""
    m = source.num_rows
    aligned = (m // chunk_rows) * chunk_rows
    if (
        base is not None
        and base.moments is not None
        and base.chunk_rows == chunk_rows
        and base.moment_rows <= aligned
    ):
        s1, s2 = pearson_moments(
            source,
            chunk_rows,
            start=base.moment_rows,
            stop=aligned,
            s1=base.moments[0],
            s2=base.moments[1],
        )
    else:
        s1, s2 = pearson_moments(source, chunk_rows, stop=aligned)
    s1f, s2f = pearson_moments(source, chunk_rows, start=aligned, s1=s1, s2=s2)
    perm = pearson_order_from_moments(
        s1f, s2f, m, reverse=(config.ordering == "reverse_pearson")
    )
    return perm, (s1, s2), aligned


def _scaler_stats(scaler) -> Tuple[Optional[np.ndarray], Optional[np.ndarray]]:
    if scaler is None or getattr(scaler, "lo", None) is None:
        return None, None
    lo = np.asarray(scaler.lo, np.float64)
    hi = getattr(scaler, "hi", None)
    if hi is None and getattr(scaler, "scale", None) is not None:
        # plain MinMaxScaler keeps (lo, scale); recover hi where the range
        # was non-degenerate, else hi = lo
        scale = np.asarray(scaler.scale, np.float64)
        hi = np.where(scale > 0, lo + 1.0 / np.where(scale > 0, scale, 1.0), lo)
    return lo, (None if hi is None else np.asarray(hi, np.float64))


def _drive(
    source: DataSource,
    config: OAVIConfig,
    chunk_rows: int,
    state_in: Optional[FitState],
    perm: Optional[np.ndarray],
    moments: Optional[Tuple[np.ndarray, np.ndarray]],
    moment_rows: int,
    scaler,
    prefetch: bool,
) -> Tuple[OAVIModel, FitState]:
    """The shared degree loop behind :func:`fit` (``state_in=None``: every
    degree streams all rows) and :func:`update` (matching degrees fold only
    rows past the snapshot).  Local path only — an update is O(new rows) of
    data work, which a serving-side host handles without a mesh; sharded
    *full* fits stay with :func:`repro.streaming.fit`."""
    dtype = config.jax_dtype()
    np_dtype = _np_dtype(config.dtype)
    m, n = source.num_rows, source.num_features
    aligned_new = (m // kernel_ops.GRAM_BLOCK) * kernel_ops.GRAM_BLOCK
    base_rows = state_in.num_rows if state_in is not None else 0

    stats = init_fit_stats(
        m,
        n,
        streaming={"chunk_rows": chunk_rows, "num_chunks": 0, "passes": 0},
        online={
            "base_rows": base_rows,
            "new_rows": m - base_rows,
            "folded_degrees": 0,
            "replayed_degrees": [],
        },
    )
    with FitScope(stats, backend="online") as scope:
        book = terms_mod.TermBook(n=n)
        generators: List[Generator] = []
        Lcap = pow2_bucket(config.cap_terms)
        ihb_state = ihb_mod.init_state(
            Lcap, jnp.asarray(1.0, dtype), dtype, factors=config.ihb_factors()
        )
        ell = 1
        entry = _streaming_stats_entry(config, None, ("data",))
        m_total = jnp.asarray(float(m), dtype)
        records_out: List[DegreeRecord] = []

        d = 0
        while True:
            d += 1
            if d > config.max_degree:
                stats["termination"] = f"max_degree={config.max_degree}"
                break
            border = book.border(d)
            if not border:
                stats["termination"] = "empty_border"
                break
            K = len(border)
            stats["border_sizes"].append(K)
            stats["degrees"].append(d)

            while ell + K > Lcap:
                Lcap *= 2
                scope.regrowth(Lcap)
                ihb_state = ihb_mod.grow_state(ihb_state, Lcap)

            Kcap = max(config.cap_border, pow2_bucket(K))
            parents, vars_, valid = border_index_arrays(book, border, Kcap)

            acc_fn, acc_seen, acc_new = _chunk_accumulator(
                book, config, Lcap, chunk_rows, None, ("data",)
            )
            acc_sig = (Kcap, chunk_rows, n, str(dtype))
            scope.note_signature(acc_seen, acc_sig, kind="fit/compile_accumulator")
            scope.note_signature(entry.seen, (Lcap, Kcap, str(dtype)))

            with scope.degree(d, K=K):
                parents_d = jnp.asarray(parents)
                vars_d = jnp.asarray(vars_)
                rec = (
                    state_in.record_matches(d, book, K, Lcap, Kcap)
                    if state_in is not None
                    else None
                )
                if rec is not None:
                    # resume the fold where the snapshot ends — a GRAM_BLOCK
                    # boundary, so the remaining blocks land exactly where a
                    # one-shot pass would put them
                    accQL = jnp.asarray(rec.accQL)
                    accC = jnp.asarray(rec.accC)
                    start_row = state_in.aligned_rows
                    stats["online"]["folded_degrees"] += 1
                    obs.event("online/fold", degree=d, start_row=start_row)
                else:
                    accQL = jnp.zeros((Lcap, Kcap), jnp.float32)
                    accC = jnp.zeros((Kcap, Kcap), jnp.float32)
                    start_row = 0
                    stats["online"]["replayed_degrees"].append(d)
                    obs.event("online/replay", degree=d)

                accQL, accC, nc = accumulate_source_range(
                    acc_fn,
                    source,
                    start_row,
                    aligned_new,
                    chunk_rows,
                    (accQL, accC),
                    parents_d,
                    vars_d,
                    perm=perm,
                    np_dtype=np_dtype,
                    prefetch=prefetch,
                )
                # snapshot BEFORE the unaligned tail: the record must cover
                # exactly [0, aligned_new) so the next update can resume on a
                # block boundary (np.asarray forces + copies to host before
                # acc_fn donates the device buffers again)
                records_out.append(
                    DegreeRecord(
                        degree=d,
                        ell=ell,
                        K=K,
                        Lcap=Lcap,
                        Kcap=Kcap,
                        accQL=np.asarray(accQL),
                        accC=np.asarray(accC),
                    )
                )
                if aligned_new < m:
                    accQL, accC, nc2 = accumulate_source_range(
                        acc_fn,
                        source,
                        aligned_new,
                        m,
                        chunk_rows,
                        (accQL, accC),
                        parents_d,
                        vars_d,
                        perm=perm,
                        np_dtype=np_dtype,
                        prefetch=prefetch,
                    )
                    nc += nc2
                stats["streaming"]["num_chunks"] += nc
                stats["streaming"]["passes"] += 1

                st = entry.fn(
                    accQL,
                    accC,
                    ihb_state,
                    jnp.asarray(ell, jnp.int32),
                    jnp.asarray(valid),
                    m_total,
                )
                ihb_state = st.ihb
                accepted = np.asarray(st.accepted)
                mses = np.asarray(st.mses)
                coeffs = np.asarray(st.coeffs)
                iters = np.asarray(st.iters)
            stats["solver_iters"].append(int(iters[:K].sum()))

            ell = collect_degree(book, border, accepted, mses, coeffs, generators)

        scope.finalize(book, generators, Lcap, config)
    scaler_lo, scaler_hi = _scaler_stats(scaler)
    model = OAVIModel(
        n=n,
        psi=config.psi,
        book=book,
        generators=generators,
        feature_perm=perm,
        stats=stats,
        dtype=config.dtype,
    )
    new_state = FitState(
        n=n,
        num_rows=m,
        aligned_rows=aligned_new,
        chunk_rows=chunk_rows,
        config=config,
        book_parents=np.asarray(book.parents, np.int32),
        book_vars=np.asarray(book.vars, np.int32),
        records=records_out,
        feature_perm=None if perm is None else np.asarray(perm),
        moments=moments,
        moment_rows=moment_rows,
        scaler_lo=scaler_lo,
        scaler_hi=scaler_hi,
        probe_first=_probe_row(source, 0) if m else None,
        probe_last=_probe_row(source, m - 1) if m else None,
    )
    return model, new_state


def fit(
    source,
    config: OAVIConfig = OAVIConfig(),
    *,
    chunk_rows: int = DEFAULT_CHUNK_ROWS,
    scaler=None,
    prefetch: bool = True,
) -> Tuple[OAVIModel, FitState]:
    """Streaming OAVI fit that also captures the incremental
    :class:`FitState` — bit-identical model to :func:`repro.streaming.fit`
    on the same source at the same ``chunk_rows`` (it is the same chunk
    accumulator and stats step, driven through the same caches).

    ``scaler`` (optional, a fitted min-max scaler) is recorded in the state
    as the drift-monitoring reference; pass the frozen scaler the source is
    composed with."""
    source = as_source(source)
    chunk_rows = _check_chunk_rows(chunk_rows)
    perm = moments = None
    moment_rows = 0
    if config.ordering in ("pearson", "reverse_pearson"):
        perm, moments, moment_rows = _pearson_perm(source, chunk_rows, config, None)
    return _drive(
        source, config, chunk_rows, None, perm, moments, moment_rows, scaler, prefetch
    )


def update(
    model: Optional[OAVIModel],
    state: FitState,
    source,
    *,
    chunk_rows: Optional[int] = None,
    scaler=None,
    prefetch: bool = True,
    check_probes: bool = True,
) -> UpdateResult:
    """Refit on a grown source, folding instead of re-reading where possible.

    ``source`` must be the FULL grown dataset: rows ``[0, state.num_rows)``
    bit-identical to the data the state accumulated (same scaler, same
    order), new rows appended after.  Full access — not just the delta — is
    required because a flipped degree decision forces a full-range replay of
    the affected degrees; unchanged degrees never touch the old rows.

    Returns an :class:`UpdateResult` whose model is bit-identical to
    ``streaming.fit`` (or :func:`fit`) on the same source at the same
    capacity and chunk size, for every engine the streaming fit supports.
    """
    t0 = time.perf_counter()
    source = as_source(source)
    config = state.config
    chunk_rows = (
        state.chunk_rows if chunk_rows is None else _check_chunk_rows(chunk_rows)
    )
    m_new = source.num_rows
    if source.num_features != state.n:
        raise ValueError(
            f"source has {source.num_features} features, state was built on "
            f"{state.n}"
        )
    if m_new < state.num_rows:
        raise ValueError(
            f"source shrank: {m_new} rows < state.num_rows={state.num_rows}; "
            "update() only supports appended data"
        )
    if model is not None:
        mp = np.asarray(model.book.parents, np.int32)
        mv = np.asarray(model.book.vars, np.int32)
        if not (
            np.array_equal(mp, state.book_parents)
            and np.array_equal(mv, state.book_vars)
        ):
            raise ValueError(
                "model/state mismatch: the FitState does not belong to this "
                "model (different term books)"
            )
    if check_probes and state.probe_first is not None and state.num_rows:
        same_first = np.array_equal(_probe_row(source, 0), state.probe_first)
        same_last = state.probe_last is None or np.array_equal(
            _probe_row(source, state.num_rows - 1), state.probe_last
        )
        if not (same_first and same_last):
            raise ValueError(
                "source prefix mismatch: rows the state already accumulated "
                "changed (different data, ordering, or scaler); incremental "
                "statistics would be silently wrong — refit from scratch"
            )

    refit_reason = None
    perm = moments = None
    moment_rows = 0
    state_eff: Optional[FitState] = state
    if chunk_rows != state.chunk_rows:
        # a different chunk grid re-partitions the Pearson moment sums; the
        # Gram records themselves stay foldable (their alignment is
        # GRAM_BLOCK, not chunk_rows)
        refit_reason = "chunk_rows_changed"
    if config.ordering in ("pearson", "reverse_pearson"):
        perm, moments, moment_rows = _pearson_perm(source, chunk_rows, config, state)
        if state.feature_perm is None or not np.array_equal(
            perm, np.asarray(state.feature_perm)
        ):
            # the permutation relabels every book column: no record survives
            state_eff = None
            refit_reason = "feature_order_changed"
    elif state.feature_perm is not None:
        state_eff = None
        refit_reason = "feature_order_changed"

    with obs.span(
        "online/update",
        base_rows=state.num_rows,
        new_rows=m_new - state.num_rows,
        refit_reason=refit_reason,
    ):
        new_model, new_state = _drive(
            source,
            config,
            chunk_rows,
            state_eff,
            perm,
            moments,
            moment_rows,
            scaler,
            prefetch,
        )
    if scaler is None:
        # carry the drift reference forward unless the caller replaces it
        new_state.scaler_lo = state.scaler_lo
        new_state.scaler_hi = state.scaler_hi
    online = new_model.stats["online"]
    online["base_rows"] = state.num_rows  # even when records were dropped
    online["new_rows"] = m_new - state.num_rows
    if refit_reason is not None:
        online["refit_reason"] = refit_reason
    up_stats = {
        "base_rows": state.num_rows,
        "new_rows": m_new - state.num_rows,
        "folded_degrees": online["folded_degrees"],
        "replayed_degrees": list(online["replayed_degrees"]),
        "refit_reason": refit_reason,
        "recompiles": new_model.stats["recompiles"],
        "chunks": new_model.stats["streaming"]["num_chunks"],
        "time_update": time.perf_counter() - t0,
    }
    return UpdateResult(model=new_model, state=new_state, stats=up_stats)
