"""Optimizer substrate: AdamW (fp32 / 8-bit states), schedules, compression."""

from .adamw import AdamW, AdamWState, warmup_cosine, compress_grads, decompress_grads, init_residuals

__all__ = ["AdamW", "AdamWState", "warmup_cosine", "compress_grads", "decompress_grads", "init_residuals"]
