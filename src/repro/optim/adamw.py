"""AdamW with sharded states, LR schedules, clipping, and (beyond-paper)
8-bit block-quantized moments for HBM headroom at the 1T-param scale.

States inherit the parameter sharding (the moment pytrees mirror params, so
the same PartitionSpecs apply) — FSDP for optimizer state comes for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)


# ---------------------------------------------------------------------------
# 8-bit block quantization (per-block absmax scaling)
# ---------------------------------------------------------------------------

_QBLOCK = 256


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, shape, dtype=jnp.float32) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any  # first moment (fp32 pytree, or (int8, scale) pairs)
    nu: Any  # second moment


@dataclasses.dataclass(frozen=True)
class AdamW:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    quantize_states: bool = False  # 8-bit moments (beyond-paper)

    def init(self, params) -> AdamWState:
        if self.quantize_states:
            qz = lambda p: _quantize(jnp.zeros(p.shape, jnp.float32))  # noqa: E731
            return AdamWState(
                step=jnp.zeros((), jnp.int32),
                mu=jax.tree.map(qz, params),
                nu=jax.tree.map(qz, params),
            )
        z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                          nu=jax.tree.map(jnp.copy, z))

    def _lr(self, step):
        return warmup_cosine(step, peak_lr=self.peak_lr,
                             warmup_steps=self.warmup_steps,
                             total_steps=self.total_steps)

    def update(self, params, grads, state: AdamWState):
        step = state.step + 1
        lr = self._lr(step)
        if self.clip_norm is not None:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gn, 1e-12))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        bc1 = 1.0 - self.b1**step.astype(jnp.float32)
        bc2 = 1.0 - self.b2**step.astype(jnp.float32)

        if self.quantize_states:
            is_q = lambda x: isinstance(x, tuple) and len(x) == 2  # noqa: E731

            def upd(p, g, mq, nq):
                g32 = g.astype(jnp.float32)
                m = _dequantize(mq[0], mq[1], p.shape)
                # second moment stored in sqrt-space: int8 linear quantization
                # of sqrt(n) keeps the *relative* error of the denominator
                # bounded (linear int8 on n itself diverges: n spans ~12
                # orders of magnitude and blocks collapse to zero).
                n = jnp.square(_dequantize(nq[0], nq[1], p.shape))
                m = self.b1 * m + (1 - self.b1) * g32
                n = self.b2 * n + (1 - self.b2) * g32 * g32
                u = (m / bc1) / (jnp.sqrt(n / bc2) + self.eps)
                new_p = (p.astype(jnp.float32) - lr * (u + self.weight_decay * p.astype(jnp.float32))).astype(p.dtype)
                return new_p, _quantize(m), _quantize(jnp.sqrt(n))

            out = jax.tree.map(upd, params, grads, state.mu, state.nu, is_leaf=is_q)
            # out leaves are 3-tuples at param positions; unzip
            treedef = jax.tree.structure(params)
            flat = treedef.flatten_up_to(out)
            new_p = treedef.unflatten([t[0] for t in flat])
            mu = treedef.unflatten([t[1] for t in flat])
            nu = treedef.unflatten([t[2] for t in flat])
            return new_p, AdamWState(step=step, mu=mu, nu=nu)

        def upd(p, g, m, n):
            g32 = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g32
            n = self.b2 * n + (1 - self.b2) * g32 * g32
            u = (m / bc1) / (jnp.sqrt(n / bc2) + self.eps)
            new_p = (p.astype(jnp.float32) - lr * (u + self.weight_decay * p.astype(jnp.float32))).astype(p.dtype)
            return new_p, m, n

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        treedef = jax.tree.structure(params)
        flat = treedef.flatten_up_to(out)
        new_p = treedef.unflatten([t[0] for t in flat])
        mu = treedef.unflatten([t[1] for t in flat])
        nu = treedef.unflatten([t[2] for t in flat])
        return new_p, AdamWState(step=step, mu=mu, nu=nu)

    def state_specs(self, param_specs) -> AdamWState:
        """PartitionSpecs for the optimizer state, mirroring the params."""
        from jax.sharding import PartitionSpec as P

        if self.quantize_states:
            # quantized leaves are (int8 blocks, scales): shard is data-dependent
            # on flattening; replicate scales, keep blocks replicated too
            # (quantized states are small enough that this is acceptable for
            # the baseline; a packed-sharded layout is a §Perf candidate).
            q = jax.tree.map(lambda s: (P(), P()), param_specs)
            return AdamWState(step=P(), mu=q, nu=q)
        return AdamWState(
            step=P(),
            mu=jax.tree.map(lambda s: s, param_specs),
            nu=jax.tree.map(lambda s: s, param_specs),
        )


# ---------------------------------------------------------------------------
# Error-feedback int8 gradient compression (optional DP all-reduce wrapper)
# ---------------------------------------------------------------------------


def compress_grads(grads, residuals):
    """Quantize grads to int8 blocks with error feedback.  Returns
    (quantized pytree of (q, scale), new residuals).  Used by the optional
    compressed-DP path in launch/train.py; OFF by default."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = _quantize(g32)
        back = _dequantize(q, s, g.shape)
        return (q, s), g32 - back

    out = jax.tree.map(one, grads, residuals)
    treedef = jax.tree.structure(grads)
    flat = treedef.flatten_up_to(out)
    qs = treedef.unflatten([t[0] for t in flat])
    res = treedef.unflatten([t[1] for t in flat])
    return qs, res


def decompress_grads(qs, shapes, dtype=jnp.float32):
    return jax.tree.map(
        lambda q_s, ref: _dequantize(q_s[0], q_s[1], ref.shape, dtype),
        qs, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2 and not hasattr(x, "shape"),
    )


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
