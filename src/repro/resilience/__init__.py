"""Failure-survival layer for the continuous OAVI stack.

Scale guarantees (linear in m, near-instant IHB refits) are worthless if the
first torn shard write, flipped checkpoint bit, poison request, or controller
SIGKILL takes the service down or — worse — lets it keep serving silently
wrong polynomials.  This package is the robustness substrate the streaming /
online / serving layers are threaded through:

* :mod:`~repro.resilience.integrity` — CRC32 content checksums for every
  checkpoint leaf (manifest v2), every shard file, and the persisted
  ``FitState`` Gram snapshots; corruption raises :class:`IntegrityError`
  naming the offending file instead of producing confidently-wrong
  generators (the spurious-vanishing failure mode).
* :mod:`~repro.resilience.journal` — an fsync'd append-only controller
  journal with per-record CRCs; a SIGKILL'd ``launch/continuous_vi`` resumes
  exactly where it died (last-good state + re-fold of un-journaled rows,
  bit-identical under the ``gram_accumulate`` carry-in contract).
* :mod:`~repro.resilience.chaos` — a seeded, deterministic
  :class:`FaultPlan` (flip-leaf-bit, raise-on-Nth-engine-call, hang,
  fail-activation, SIGKILL-at-phase) injected through ``chaos.fire`` hooks
  in the store / source / engine / registry / controller, driving the
  ``make chaos-smoke`` harness.
"""

from .chaos import (
    Fault,
    FaultPlan,
    InjectedFault,
    PoisonRequestError,
    TransientEngineError,
    fire,
    install,
    installed,
    uninstall,
)
from .integrity import (
    IntegrityError,
    checksum_bytes,
    checksum_file,
    flip_bit,
    truncate_file,
    verify_file,
)
from .journal import Journal, JournalError

__all__ = [
    "Fault",
    "FaultPlan",
    "InjectedFault",
    "IntegrityError",
    "Journal",
    "JournalError",
    "PoisonRequestError",
    "TransientEngineError",
    "checksum_bytes",
    "checksum_file",
    "fire",
    "flip_bit",
    "truncate_file",
    "install",
    "installed",
    "uninstall",
    "verify_file",
]
