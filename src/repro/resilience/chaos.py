"""Deterministic fault injection: a seeded plan, fired through fixed hooks.

Production failure modes — a transient device error, a hung accelerator, a
corrupted checkpoint leaf, an operator SIGKILL mid-update — are injected
through named **sites** instrumented in the store / source / engine /
registry / controller.  Each site calls :func:`fire` with a little context;
when no plan is installed that is one global ``is None`` check, so the happy
path pays nothing measurable.

A :class:`FaultPlan` is a list of :class:`Fault` records, each bound to a
site and an occurrence index (``at`` = fire on the Nth event at that site,
for ``times`` consecutive events).  Plans are plain JSON, so the chaos
harness can pass one to a subprocess (``--chaos plan.json``) and every run
of the same plan injects the identical schedule — failures are part of the
test's seed, not of its luck.

Instrumented sites (context keys in parentheses):

====================== ====================================================
``engine.transform``    every :meth:`TransformEngine.transform` (``Z``)
``registry.activate``   every :meth:`ModelRegistry.activate` (``name``,
                        ``version``)
``store.committed``     every committed :func:`checkpoint.store.save`
                        (``path`` — corrupt-after-commit faults)
``shards.committed``    every :func:`write_shards` meta commit (``path``)
``shards.shard_written``each shard file written, BEFORE the meta commit
                        (``path`` — a SIGKILL here is a torn shard write)
``controller.*``        continuous-loop phase transitions
                        (``update_start``, ``state_saved``, ``staged``,
                        ``activated``)
====================== ====================================================

Actions: ``raise`` (a :class:`InjectedFault`), ``raise_transient``
(:class:`TransientEngineError` — the batcher's retry path), ``poison``
(raise :class:`PoisonRequestError` iff the request payload contains the
poison sentinel — content-bound, so batch bisection isolates exactly the
poison request), ``hang`` (sleep ``hang_ms``), ``sigkill`` (the process
dies mid-phase, no cleanup — the crash-recovery path), ``flip_bit`` /
``truncate`` (corrupt the file named by the event's context in place).
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from .integrity import flip_bit, truncate_file

#: Requests carrying this value in any cell are "poison": they deterministically
#: fail the device call they ride in, whatever batch they were coalesced into.
POISON_SENTINEL = 1.0e30


class InjectedFault(RuntimeError):
    """A chaos-plan fault (non-transient: retries must NOT paper over it)."""


class TransientEngineError(RuntimeError):
    """A transient engine/device failure — safe and expected to retry."""


class PoisonRequestError(RuntimeError):
    """A request whose *content* deterministically fails the device call."""


@dataclasses.dataclass
class Fault:
    """One scheduled fault: fires at occurrences ``[at, at + times)`` of
    ``site`` (1-based).  ``poison`` faults ignore ``at`` — they are bound to
    request content, not to event order."""

    site: str
    at: int = 1
    action: str = "raise"  # raise|raise_transient|poison|hang|sigkill|flip_bit|truncate
    times: int = 1
    hang_ms: float = 0.0
    byte_offset: int = 0
    bit: int = 0
    truncate_to: int = 0

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


class FaultPlan:
    """A deterministic fault schedule + per-site occurrence counters."""

    def __init__(self, faults: List[Fault]):
        self.faults = list(faults)
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()
        self.fired: List[Dict] = []  # audit log: what actually triggered

    # -- (de)serialization --------------------------------------------------

    def to_json(self) -> str:
        return json.dumps({"faults": [f.to_dict() for f in self.faults]}, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        return cls([Fault(**f) for f in json.loads(text)["faults"]])

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- firing -------------------------------------------------------------

    def _matches(self, fault: Fault, count: int, ctx: Dict) -> bool:
        if fault.action == "poison":
            Z = ctx.get("Z")
            return Z is not None and bool(np.any(np.asarray(Z) == POISON_SENTINEL))
        return fault.at <= count < fault.at + fault.times

    def fire(self, site: str, **ctx) -> None:
        with self._lock:
            count = self._counts.get(site, 0) + 1
            self._counts[site] = count
            hits = [f for f in self.faults if f.site == site and self._matches(f, count, ctx)]
            for f in hits:
                self.fired.append({"site": site, "count": count, "action": f.action})
        for f in hits:
            self._execute(f, ctx)

    def _execute(self, fault: Fault, ctx: Dict) -> None:
        if fault.action == "raise":
            raise InjectedFault(f"injected fault at {fault.site} (#{fault.at})")
        if fault.action == "raise_transient":
            raise TransientEngineError(
                f"injected transient failure at {fault.site} (#{fault.at})"
            )
        if fault.action == "poison":
            raise PoisonRequestError(
                f"poison request payload at {fault.site} (sentinel {POISON_SENTINEL:g})"
            )
        if fault.action == "hang":
            time.sleep(fault.hang_ms / 1e3)
            return
        if fault.action == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)
            return  # pragma: no cover - unreachable
        path = ctx.get("path")
        if path is None:
            raise ValueError(
                f"fault action {fault.action!r} at site {fault.site!r} needs a "
                "path in the event context"
            )
        if fault.action == "flip_bit":
            target = _pick_file(path)
            flip_bit(target, fault.byte_offset, fault.bit)
            return
        if fault.action == "truncate":
            target = _pick_file(path)
            truncate_file(target, fault.truncate_to)
            return
        raise ValueError(f"unknown fault action {fault.action!r}")


def _pick_file(path: str) -> str:
    """File-corruption faults may point at a checkpoint *directory*; corrupt
    its largest payload file (the Gram accumulators, not the manifest)."""
    if os.path.isfile(path):
        return path
    candidates = [
        os.path.join(path, n) for n in sorted(os.listdir(path)) if n.endswith(".npy")
    ]
    if not candidates:
        raise ValueError(f"no corruptible payload files under {path!r}")
    return max(candidates, key=os.path.getsize)


# ---------------------------------------------------------------------------
# Global installation point (one process, one plan)
# ---------------------------------------------------------------------------

_PLAN: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> FaultPlan:
    """Install ``plan`` as the process-wide fault schedule."""
    global _PLAN
    _PLAN = plan
    return plan


def uninstall() -> None:
    global _PLAN
    _PLAN = None


def installed() -> Optional[FaultPlan]:
    return _PLAN


def fire(site: str, **ctx) -> None:
    """Hook entry: a no-op (one global load + ``is None``) without a plan."""
    plan = _PLAN
    if plan is not None:
        plan.fire(site, **ctx)
