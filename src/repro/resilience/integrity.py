"""Content checksums: corruption is never silent.

A flipped bit in a Gram accumulator or a truncated leaf file does not crash
OAVI — it produces confidently-wrong polynomials (the spurious-vanishing
failure mode).  The only defense is end-to-end content verification: every
persisted payload (checkpoint leaves, shard files, journal records) carries a
CRC32 of its exact bytes, and every load verifies before the bytes reach a
kernel.

CRC32 (``zlib.crc32``) is the right tool here: it is in the stdlib (no new
dependency), runs at memory bandwidth, and — being a linear code — detects
**every** single-bit flip and every burst error up to 32 bits, which covers
the physically plausible corruption modes (bit rot, torn page, truncation;
truncation additionally changes the recorded byte length, checked first so
the error says "truncated" rather than "mismatch").  It is *not* a defense
against an adversary; these files are trusted-writer state, not inputs.

Checksums are serialized as ``"crc32:%08x"`` so a future algorithm switch
(xxhash when available, sha256 for untrusted sources) is a new prefix, not a
format break.
"""

from __future__ import annotations

import os
import zlib
from typing import Optional, Tuple

_PREFIX = "crc32:"
_CHUNK = 1 << 20  # stream files in 1 MiB pieces: O(1) memory at any size


class IntegrityError(ValueError):
    """A persisted payload failed content verification.

    ``path`` names the offending file — the one piece of information an
    operator needs to decide between restore-from-replica and delete.
    Subclasses :class:`ValueError` so pre-existing callers that treat load
    problems as value errors keep working.
    """

    def __init__(self, message: str, path: Optional[str] = None):
        super().__init__(message)
        self.path = path


def checksum_bytes(data: bytes) -> str:
    """Serialized CRC32 of ``data`` (``"crc32:%08x"``)."""
    return f"{_PREFIX}{zlib.crc32(data) & 0xFFFFFFFF:08x}"


def checksum_file(path: str) -> Tuple[str, int]:
    """``(checksum, num_bytes)`` of a file, streamed in bounded memory."""
    crc = 0
    size = 0
    with open(path, "rb") as f:
        while True:
            block = f.read(_CHUNK)
            if not block:
                break
            crc = zlib.crc32(block, crc)
            size += len(block)
    return f"{_PREFIX}{crc & 0xFFFFFFFF:08x}", size


def verify_file(path: str, expected: str, expected_bytes: Optional[int] = None) -> None:
    """Raise :class:`IntegrityError` unless ``path`` matches its recorded
    checksum (and byte length, when recorded).  Length is checked first so a
    truncated file reports *truncation*, not a generic mismatch."""
    if not os.path.exists(path):
        raise IntegrityError(f"{path}: missing (expected {expected})", path=path)
    if expected_bytes is not None:
        actual_bytes = os.path.getsize(path)
        if actual_bytes != expected_bytes:
            raise IntegrityError(
                f"{path}: truncated or grown — {actual_bytes} bytes on disk, "
                f"{expected_bytes} recorded",
                path=path,
            )
    actual, _ = checksum_file(path)
    if actual != expected:
        raise IntegrityError(
            f"{path}: checksum mismatch — {actual} on disk, {expected} recorded "
            "(corrupt payload; falling back to an older checkpoint if one exists)",
            path=path,
        )


def flip_bit(path: str, byte_offset: int, bit: int = 0) -> None:
    """Flip one bit of a file in place — the canonical corruption injector
    used by the chaos plans and the property tests.  ``byte_offset`` may be
    negative (from the end)."""
    size = os.path.getsize(path)
    if size == 0:
        raise ValueError(f"cannot flip a bit in empty file {path!r}")
    offset = byte_offset % size
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ (1 << (bit % 8))]))
        f.flush()
        os.fsync(f.fileno())


def truncate_file(path: str, num_bytes: int) -> None:
    """Truncate a file to ``num_bytes`` (a torn write, frozen mid-flight)."""
    with open(path, "r+b") as f:
        f.truncate(num_bytes)
        f.flush()
        os.fsync(f.fileno())
