"""Fsync'd append-only controller journal with per-record CRCs.

The continuous controller's version lineage — which rows are folded into
which persisted :class:`~repro.online.FitState`, which versions were staged
and activated — lives only in process memory today; a SIGKILL loses it and
the loop refits from scratch.  The journal makes every transition durable
*before* its effects matter, so a restarted controller replays the record
stream and resumes exactly where the dead process stopped: re-load the
last-good state checkpoint it names, re-fold only rows past it (bit-exact
under the ``gram_accumulate`` carry-in contract), re-stage anything that was
in flight.

Format: one JSON object per line, ``{"seq": N, "kind": ..., **fields,
"crc": "crc32:..."}`` where the CRC covers the record serialized *without*
its own crc field.  Appends write + flush + fsync before returning — a
record that :meth:`append` returned for is durable.

Crash semantics on replay:

* a **torn tail** (partial last line, no trailing newline, half-written
  JSON, bad CRC on the final record) is exactly what a crash mid-append
  leaves behind — it is dropped silently and recovery proceeds from the
  previous record;
* a bad CRC / unparsable line **before** the tail is not a crash artifact,
  it is corruption of committed history — that raises
  :class:`JournalError` (an :class:`~repro.resilience.integrity.IntegrityError`)
  naming the file and line rather than resuming from a lie.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Dict, List, Optional, Sequence

from .. import obs
from .integrity import IntegrityError, checksum_bytes


class JournalError(IntegrityError):
    """Committed journal history failed verification."""


def _record_crc(rec: Dict) -> str:
    body = {k: v for k, v in rec.items() if k != "crc"}
    return checksum_bytes(json.dumps(body, sort_keys=True).encode())


class Journal:
    """Append-only journal at ``path`` (created on first append)."""

    def __init__(self, path: str):
        self.path = path
        self._fh = None
        self._seq = 0
        self._lock = threading.Lock()  # ingest + controller threads both append
        # resume the sequence counter past existing committed records, and
        # truncate any torn tail NOW: appending after an uncommitted partial
        # record would bury it mid-history, turning a benign crash artifact
        # into (apparent) corruption of committed lineage on the next replay
        if os.path.exists(path):
            records, committed = self._scan()
            if committed < os.path.getsize(path):
                with open(path, "r+b") as f:
                    f.truncate(committed)
            if records:
                self._seq = records[-1]["seq"] + 1

    # -- writing ------------------------------------------------------------

    def append(self, kind: str, **fields) -> Dict:
        """Durably append one record; returns it (with seq + crc).
        Thread-safe: concurrent appenders serialize, records never interleave."""
        with self._lock:
            rec = {"seq": self._seq, "kind": kind, **fields}
            rec["crc"] = _record_crc(rec)
            if self._fh is None:
                os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
                self._fh = open(self.path, "a")
            self._fh.write(json.dumps(rec, sort_keys=True) + "\n")
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._seq += 1
            obs.registry().counter("journal.appends", kind=kind).inc()
            return rec

    def compact(
        self,
        anchor_kind: str = "activated",
        keep_kinds: Sequence[str] = ("base_fitted",),
    ) -> int:
        """Drop committed history older than the newest ``anchor_kind`` record.

        Keeps the newest ``anchor_kind`` record and everything after it, plus
        (for each kind in ``keep_kinds``) the newest earlier record of that
        kind — by default the last ``base_fitted``, which the continuous
        controller's resume gate reads via :meth:`last`.  Records keep their
        original ``seq`` and CRC (both cover only the record body, which is
        unchanged), so replay semantics and :meth:`last` lookups are
        indistinguishable from the uncompacted journal for every surviving
        kind.

        The rewrite is crash-safe: surviving records are CRC-re-verified and
        written to ``<path>.tmp``, fsync'd, then renamed over the journal
        (plus a directory fsync) — a crash mid-compact leaves either the old
        or the new journal, never a torn mix.  Returns the number of records
        dropped (0 when there is no anchor or nothing precedes it).
        """
        with self._lock:
            records, _ = self._scan()
            cut = 0
            for i, rec in enumerate(records):
                if rec["kind"] == anchor_kind:
                    cut = i
            prefix: List[Dict] = []
            for kind in keep_kinds:
                newest = None
                for rec in records[:cut]:
                    if rec["kind"] == kind:
                        newest = rec
                if newest is not None:
                    prefix.append(newest)
            prefix.sort(key=lambda r: r["seq"])
            kept = prefix + records[cut:]
            dropped = len(records) - len(kept)
            if dropped <= 0:
                return 0
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                for rec in kept:
                    if rec.get("crc") != _record_crc(rec):
                        raise JournalError(
                            f"{self.path}: record seq={rec.get('seq')} failed CRC "
                            "re-verification during compaction; aborting rewrite",
                            path=self.path,
                        )
                    f.write(json.dumps(rec, sort_keys=True) + "\n")
                f.flush()
                os.fsync(f.fileno())
            if self._fh is not None:
                self._fh.close()
                self._fh = None  # reopened lazily by the next append
            os.replace(tmp, self.path)
            dirfd = os.open(os.path.dirname(self.path) or ".", os.O_RDONLY)
            try:
                os.fsync(dirfd)
            finally:
                os.close(dirfd)
            obs.event("journal/compact", dropped=dropped, kept=len(kept))
            return dropped

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- reading ------------------------------------------------------------

    def replay(self) -> List[Dict]:
        """All committed records, oldest first (torn tail dropped)."""
        return self._scan()[0]

    def _scan(self) -> "tuple[List[Dict], int]":
        """(committed records, byte length of the committed prefix)."""
        if not os.path.exists(self.path):
            return [], 0
        with open(self.path, "rb") as f:
            raw = f.read()
        lines = raw.split(b"\n")
        # anything after the last newline is an in-flight append at crash time
        tail_torn = bool(lines and lines[-1] != b"")
        body = lines[:-1]
        records: List[Dict] = []
        committed = offset = 0
        for i, line in enumerate(body):
            end = offset + len(line) + 1  # +1: the newline
            if not line.strip():
                offset = committed = end
                continue
            is_tail = not tail_torn and i == len(body) - 1
            rec = self._parse(line, i, is_tail=is_tail)
            if rec is None:
                break  # verified-bad final record: crash mid-fsync, drop it
            records.append(rec)
            offset = committed = end
        return records, committed

    def _parse(self, line: bytes, lineno: int, is_tail: bool) -> Optional[Dict]:
        try:
            rec = json.loads(line)
            ok = isinstance(rec, dict) and rec.get("crc") == _record_crc(rec)
        except (json.JSONDecodeError, TypeError):
            rec, ok = None, False
        if ok:
            return rec
        if is_tail:
            return None
        raise JournalError(
            f"{self.path}: journal record at line {lineno + 1} failed CRC "
            "verification mid-history — committed records were corrupted "
            "(not a torn tail); refusing to resume from damaged lineage",
            path=self.path,
        )

    def last(self, kind: str) -> Optional[Dict]:
        """Newest committed record of ``kind`` (None when absent)."""
        for rec in reversed(self.replay()):
            if rec["kind"] == kind:
                return rec
        return None
