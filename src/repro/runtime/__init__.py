"""Runtime substrate: fault tolerance (checkpoint-restart, stragglers, elasticity)."""
from . import fault_tolerance
from .fault_tolerance import TrainLoop, TrainLoopConfig, StepFailure, reshard_tree
__all__ = ["fault_tolerance", "TrainLoop", "TrainLoopConfig", "StepFailure", "reshard_tree"]
