"""Fault-tolerant training runtime: checkpoint-restart, stragglers, elasticity.

Synchronous SPMD on TPU pods has a specific failure model: any chip/host
failure kills the whole step, and the *only* recovery primitive is
checkpoint-restart onto a (possibly re-provisioned) slice.  This module
implements the machinery around that model:

* :class:`TrainLoop` — the driver loop with periodic async checkpointing,
  automatic resume-from-latest, bounded retry on step failure, and a
  failure-injection hook used by the tests.
* **straggler mitigation** — in synchronous SPMD the slowest chip sets the
  step time; at-scale mitigation is (a) replacing the slow host (hot spares)
  and (b) *detecting* the straggler.  We implement detection: a step-time
  EWMA with a configurable multiple threshold; on trigger the loop logs and
  (optionally) checkpoints so the scheduler can swap the host.  Data-level
  mitigation (skip-and-log the slow batch) is deterministic: the pipeline is
  keyed by (seed, step), so skipping a step is reproducible across restarts.
* **elastic scaling** — checkpoints are unsharded at rest (see
  checkpoint/store.py); :func:`reshard_tree` re-device_puts a restored tree
  under the shardings of a *new* mesh, so resume works across device-count
  changes (tested: save on 1 device topology, restore on 8, and vice versa).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax

from ..checkpoint import store
from ..resilience.integrity import IntegrityError

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class TrainLoopConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    max_retries_per_step: int = 2
    straggler_ewma: float = 0.9
    straggler_factor: float = 3.0  # step slower than factor x EWMA -> flag
    max_steps: int = 1000


class StepFailure(RuntimeError):
    pass


class TrainLoop:
    """Drives ``step_fn(state, batch) -> (state, metrics)`` with fault
    tolerance.  ``state`` is any pytree (params + optimizer + pipeline step).
    """

    def __init__(
        self,
        config: TrainLoopConfig,
        step_fn: Callable,
        batch_fn: Callable[[int], Any],
        state: Any,
        failure_injector: Optional[Callable[[int], None]] = None,
    ):
        self.config = config
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.state = state
        self.failure_injector = failure_injector
        self.saver = store.AsyncSaver()
        self.step = 0
        self.metrics_history: list = []
        self.straggler_events: list = []
        self.restarts = 0
        self.integrity_fallbacks = 0
        self._ewma: Optional[float] = None

    # -- checkpoint-restart ------------------------------------------------

    def try_resume(self, shardings=None) -> bool:
        """Resume from the newest *verifiable* committed checkpoint.

        A head checkpoint corrupted after commit (bit rot, torn page) is
        detected by the manifest-v2 leaf checksums and skipped: resume lands
        on the previous committed step instead of either crashing or —
        before checksums existed — silently training on damaged weights.
        ``integrity_fallbacks`` counts how many steps were skipped."""
        steps = store.committed_steps(self.config.ckpt_dir)
        for latest in reversed(steps):
            try:
                self.state, meta = store.restore(
                    self.config.ckpt_dir, latest, self.state, shardings
                )
            except IntegrityError as e:
                self.integrity_fallbacks += 1
                log.warning("checkpoint step %d is corrupt (%s); trying older", latest, e)
                continue
            self.step = latest
            log.info("resumed from step %d", latest)
            return True
        return False

    def _checkpoint(self):
        self.saver.save(self.config.ckpt_dir, self.step, self.state)
        store.cleanup(self.config.ckpt_dir, self.config.keep_last)

    # -- main loop -----------------------------------------------------------

    def run(self, num_steps: Optional[int] = None) -> Dict:
        target = self.step + (num_steps or self.config.max_steps)
        while self.step < target:
            batch = self.batch_fn(self.step)
            retries = 0
            while True:
                t0 = time.perf_counter()
                try:
                    if self.failure_injector is not None:
                        self.failure_injector(self.step)
                    self.state, metrics = self.step_fn(self.state, batch)
                    jax.block_until_ready(jax.tree.leaves(self.state)[0])
                    break
                except StepFailure:
                    retries += 1
                    self.restarts += 1
                    if retries > self.config.max_retries_per_step:
                        # unrecoverable in-process: resume from checkpoint
                        log.warning("step %d failed %d times; restoring", self.step, retries)
                        if not self.try_resume():
                            raise
                        batch = self.batch_fn(self.step)
                        retries = 0
            dt = time.perf_counter() - t0
            self._track_straggler(dt)
            self.metrics_history.append(metrics)
            self.step += 1
            if self.step % self.config.ckpt_every == 0:
                self._checkpoint()
        self._checkpoint()
        self.saver.wait()
        return {
            "final_step": self.step,
            "restarts": self.restarts,
            "straggler_events": self.straggler_events,
        }

    def _track_straggler(self, dt: float):
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.config.straggler_factor * self._ewma:
            self.straggler_events.append({"step": self.step, "dt": dt, "ewma": self._ewma})
            log.warning("straggler at step %d: %.3fs vs EWMA %.3fs", self.step, dt, self._ewma)
        a = self.config.straggler_ewma
        self._ewma = a * self._ewma + (1 - a) * dt


def reshard_tree(tree, shardings):
    """Re-device_put a (host or differently-sharded) tree under new shardings
    — the elastic-resume primitive."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
