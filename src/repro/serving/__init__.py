"""Sharded, request-batched serving of vanishing-ideal feature transforms.

The paper's payoff is cheap inference: once the generators are constructed,
the (FT) feeding Algorithm 2's linear SVM is polynomial evaluation.  This
package turns the fused transform of :mod:`repro.api` into a service:

* :class:`~repro.serving.engine.TransformEngine` — one compiled plan per
  model set, executed locally or row-sharded over a mesh via ``shard_map``,
  with pow2 query-size buckets so varying request shapes never recompile.
* :class:`~repro.serving.batcher.MicroBatcher` — coalesces concurrent
  transform / predict requests into one padded device call and scatters the
  results back to each caller.
* :class:`~repro.serving.registry.ModelRegistry` — loads models and
  classifiers from :mod:`repro.checkpoint.store` paths, warms their engines,
  and hot-swaps versions.

``python -m repro.launch.serve_vi`` stands the whole stack up and replays a
request trace.
"""

from .batcher import BatcherConfig, DeadlineExceeded, MicroBatcher, ShutdownError
from .engine import EngineConfig, TransformEngine, UnsupportedModelError
from .registry import ModelRegistry, RegistryEntry, load_servable

__all__ = [
    "BatcherConfig",
    "DeadlineExceeded",
    "EngineConfig",
    "MicroBatcher",
    "ModelRegistry",
    "RegistryEntry",
    "ShutdownError",
    "TransformEngine",
    "UnsupportedModelError",
    "load_servable",
]
