"""MicroBatcher: coalesce concurrent (FT) requests into one device call.

The (FT) analogue of ``launch/serve.py``'s continuous-batching decode loop:
callers submit variable-size transform / predict requests from any thread;
a single worker thread drains the queue, concatenates the pending rows into
one padded call through the :class:`~repro.serving.engine.TransformEngine`,
and scatters the result rows back to each caller's future.

Coalescing policy: the worker sleeps until a request arrives, then keeps
collecting until either ``max_batch_rows`` is reached or ``max_delay_ms``
has elapsed since the first queued request — classic micro-batching: tiny
added latency bound, large throughput win (one dispatch + one pad instead
of one per request).

Because the engine's evaluation is row-independent and the engine pads to
its row buckets anyway, a coalesced call is bit-identical to per-request
calls — batching is purely a throughput optimization.  That same
row-independence is what makes **failure isolation** sound: when a coalesced
call fails, the batch is bisected and each half re-dispatched, so a poison
request (one whose *content* deterministically fails the device call) ends
up failing alone while every innocent rider succeeds with bit-identical
output.  Transient engine failures
(:class:`~repro.resilience.chaos.TransientEngineError`) are retried with
exponential backoff and deterministic, seeded jitter before isolation kicks
in.  Per-request deadlines bound how long a request may sit behind a
retrying batch: an expired request fails with :class:`DeadlineExceeded`
instead of holding its caller forever.  None of this touches the happy
path — with no faults, the dispatch sequence (and therefore every output
bit) is identical to the pre-resilience batcher.

``predict`` requests ride the same queue: they share the batched feature
transform and apply the (cheap, host-side) classifier head per request.

Shutdown is loss-free: ``stop()`` drains queued requests by default, and
anything still undrained (``drain=False``, or racing submitters) fails with
:class:`ShutdownError` — no future is ever silently dropped.  ``submit``
after ``stop()`` raises :class:`ShutdownError` instead of enqueueing into a
dead queue.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable, Deque, List, Optional, Sequence

import numpy as np

from .. import obs
from ..resilience.chaos import TransientEngineError
from .engine import TransformEngine


class ShutdownError(RuntimeError):
    """The batcher is (or went) stopped; the request was not served."""


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before it could be dispatched."""


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    max_batch_rows: int = 8192  # flush when this many rows are queued
    max_delay_ms: float = 2.0  # ... or this long after the first request
    max_queue: int = 4096  # pending-request backpressure bound
    # -- degrade-don't-die ---------------------------------------------------
    max_retries: int = 2  # transient-failure retries per batch
    backoff_ms: float = 1.0  # base of the exponential retry backoff
    backoff_jitter: float = 0.5  # jitter fraction on top (deterministic, seeded)
    retry_seed: int = 0  # seeds the backoff jitter: replays are exact
    isolate_failures: bool = True  # bisect failed batches to isolate poison
    default_deadline_ms: Optional[float] = None  # per-request default (None: none)

    def __post_init__(self):
        if self.max_batch_rows < 1:
            raise ValueError(f"max_batch_rows must be >= 1, got {self.max_batch_rows}")
        if self.max_delay_ms < 0:
            raise ValueError(f"max_delay_ms must be >= 0, got {self.max_delay_ms}")
        if self.max_queue < 1:
            # 0 would deadlock: submit waits for space the worker can never
            # create (it only notifies _not_full after popping a request)
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_ms < 0:
            raise ValueError(f"backoff_ms must be >= 0, got {self.backoff_ms}")


@dataclasses.dataclass
class _Request:
    Z: np.ndarray
    kind: str  # 'transform' | 'predict'
    future: Future
    t_submit: float
    deadline: Optional[float] = None  # absolute perf_counter time


class MicroBatcher:
    """Request-coalescing front of a :class:`TransformEngine`.

    ``head`` (optional) maps a feature block ``(q, F)`` to predictions for
    ``kind='predict'`` requests — e.g. ``classifier.head`` (SVM argmax).

    Start the background worker with ``start()`` (or use the context
    manager); ``submit`` returns a ``concurrent.futures.Future``.  For
    deterministic in-process use (tests, benchmark replay without threads)
    ``run_once()`` drains the current queue synchronously in coalesced
    batches.
    """

    def __init__(
        self,
        engine: TransformEngine,
        *,
        head: Optional[Callable[[np.ndarray], np.ndarray]] = None,
        config: BatcherConfig = BatcherConfig(),
    ):
        self.engine = engine
        self.head = head
        self.config = config
        self._queue: Deque[_Request] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._stopped = False
        self._batch_seq = 0  # keys the deterministic retry jitter
        # obs metric primitives (always live — ``stats`` is a view over them)
        self._requests = obs.Counter()
        self._batches = obs.Counter()
        self._rows = obs.Counter()
        self._coalesced_max = obs.Gauge()
        self._retries = obs.Counter()
        self._bisections = obs.Counter()
        self._isolated_failures = obs.Counter()
        self._deadline_expired = obs.Counter()
        self._shutdown_failed = obs.Counter()
        # queue-wait sketch replaces the single running wait_ms_total scalar;
        # the view keeps the historical key as ``sum`` of the sketch
        self.wait_ms = obs.Histogram()

    @property
    def stats(self) -> dict:
        """Point-in-time metric view (same keys as the historical dict)."""
        return {
            "requests": self._requests.value,
            "batches": self._batches.value,
            "rows": self._rows.value,
            "coalesced_max": int(self._coalesced_max.value),
            "wait_ms_total": self.wait_ms.sum,
            "retries": self._retries.value,
            "bisections": self._bisections.value,
            "isolated_failures": self._isolated_failures.value,
            "deadline_expired": self._deadline_expired.value,
            "shutdown_failed": self._shutdown_failed.value,
            "wait_ms": self.wait_ms.summary(),
        }

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "MicroBatcher":
        if self._thread is not None:
            return self
        self._stopped = False
        self._running = True
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True):
        """Stop the worker.  ``drain=True`` (default) serves queued requests
        synchronously first; any future still pending afterwards — or every
        queued future under ``drain=False`` — fails with
        :class:`ShutdownError` rather than being lost forever."""
        with self._lock:
            self._running = False
            self._stopped = True
            self._not_empty.notify_all()
            self._not_full.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            self.run_once()  # serve stragglers synchronously
        with self._lock:
            leftovers = list(self._queue)
            self._queue.clear()
        for r in leftovers:
            self._shutdown_failed.inc()
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(
                    ShutdownError("MicroBatcher stopped before serving this request")
                )

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- client API --------------------------------------------------------

    def submit(self, Z, kind: str = "transform", *, deadline_ms: Optional[float] = None) -> Future:
        """Enqueue one request; the future resolves to (q, F) features for
        ``kind='transform'`` or head outputs for ``kind='predict'``.

        ``deadline_ms`` (default ``config.default_deadline_ms``) bounds the
        time from submit to dispatch: a request still queued past its
        deadline fails with :class:`DeadlineExceeded` instead of waiting out
        a retry storm."""
        if kind not in ("transform", "predict"):
            raise ValueError(f"unknown request kind {kind!r}")
        if kind == "predict" and self.head is None:
            raise ValueError("predict requests need a head= callable")
        Z = np.asarray(Z)
        n = self.engine.consts.n
        if Z.ndim != 2 or Z.shape[1] != n:
            # reject malformed requests HERE: once coalesced, a bad request
            # would fail the whole batch and poison innocent callers' futures
            raise ValueError(f"expected (q, {n}) request rows, got {Z.shape}")
        t_submit = time.perf_counter()
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        deadline = None if deadline_ms is None else t_submit + deadline_ms / 1e3
        fut: Future = Future()
        req = _Request(Z=Z, kind=kind, future=fut, t_submit=t_submit, deadline=deadline)
        with self._lock:
            while (
                not self._stopped
                and self._running
                and len(self._queue) >= self.config.max_queue
            ):
                self._not_full.wait()
            if self._stopped:
                # after stop()'s final drain nothing empties the queue;
                # enqueueing would leave the caller blocked on a future that
                # never resolves (including submitters woken from the
                # backpressure wait above by stop())
                raise ShutdownError("MicroBatcher is stopped; start() it again")
            self._queue.append(req)
            self._requests.inc()
            self._not_empty.notify()
        return fut

    def transform(self, Z, *, deadline_ms: Optional[float] = None) -> np.ndarray:
        """Synchronous convenience: submit + wait."""
        fut = self.submit(Z, "transform", deadline_ms=deadline_ms)
        if self._thread is None:
            self.run_once()
        return fut.result()

    def predict(self, Z, *, deadline_ms: Optional[float] = None) -> np.ndarray:
        fut = self.submit(Z, "predict", deadline_ms=deadline_ms)
        if self._thread is None:
            self.run_once()
        return fut.result()

    # -- batching core -----------------------------------------------------

    def _take_batch(self, block: bool) -> List[_Request]:
        """Pop a coalesced batch: up to ``max_batch_rows`` rows, waiting at
        most ``max_delay_ms`` after the first pending request."""
        with self._lock:
            if block:
                while not self._queue and self._running:
                    self._not_empty.wait()
            if not self._queue:
                return []
            # anchor the flush deadline at the OLDEST pending request, so a
            # request that already waited while the previous batch was being
            # processed is not taxed another full delay window
            deadline = self._queue[0].t_submit + self.config.max_delay_ms / 1e3
            # collection window: give concurrent submitters a bounded chance
            # to join this batch.  A timed condition wait (woken by submit)
            # rather than a sleep/poll loop — the worker stays off the GIL
            # while it waits.
            while self._running:
                rows = sum(r.Z.shape[0] for r in self._queue)
                if rows >= self.config.max_batch_rows:
                    break
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                self._not_empty.wait(timeout=remaining)
            batch: List[_Request] = []
            rows = 0
            while self._queue:
                nxt = self._queue[0]
                if batch and rows + nxt.Z.shape[0] > self.config.max_batch_rows:
                    break
                batch.append(self._queue.popleft())
                rows += nxt.Z.shape[0]
            self._not_full.notify_all()
        return batch

    def _backoff_s(self, attempt: int) -> float:
        """Exponential backoff with deterministic jitter: replaying the same
        fault schedule reproduces the same retry timing, so chaos runs are
        seeds, not dice."""
        base = self.config.backoff_ms * (2.0 ** attempt) / 1e3
        rng = np.random.default_rng(
            np.random.SeedSequence([self.config.retry_seed, self._batch_seq, attempt])
        )
        return base * (1.0 + self.config.backoff_jitter * float(rng.uniform()))

    def _fail(self, batch: Sequence[_Request], err: BaseException):
        for r in batch:
            if r.future.set_running_or_notify_cancel():
                r.future.set_exception(err)

    def _scatter(self, batch: Sequence[_Request], Z: np.ndarray, feats: np.ndarray, t0: float):
        self._batches.inc()
        self._rows.inc(int(Z.shape[0]))
        self._coalesced_max.set_max(len(batch))
        self.wait_ms.observe((t0 - batch[0].t_submit) * 1e3)
        start = 0
        for r in batch:
            stop = start + r.Z.shape[0]
            block = feats[start:stop]
            if len(batch) > 1:
                # own the rows: a view would pin the whole coalesced batch
                # buffer in memory for as long as any caller keeps its result
                block = np.ascontiguousarray(block)
            start = stop
            if not r.future.set_running_or_notify_cancel():
                continue
            try:
                if r.kind == "predict":
                    r.future.set_result(self.head(block))
                else:
                    r.future.set_result(block)
            except Exception as e:
                r.future.set_exception(e)

    def _execute(self, batch: Sequence[_Request]):
        """Dispatch one coalesced batch: transient failures retry with
        backoff; a persistent failure bisects the batch so the offending
        request(s) fail alone.  Single-request batches fail directly — the
        recursion's base case, depth <= ceil(log2(len(batch)))."""
        t0 = time.perf_counter()
        Z = (
            np.concatenate([r.Z for r in batch], axis=0)
            if len(batch) > 1
            else batch[0].Z
        )
        with obs.span("batcher/execute", requests=len(batch), rows=int(Z.shape[0])):
            attempt = 0
            while True:
                try:
                    feats = self.engine.transform(Z)
                    break
                except TransientEngineError as e:
                    if attempt >= self.config.max_retries:
                        # the engine, not a request, is sick: isolation cannot
                        # help, and hammering it further only extends the outage
                        self._fail(batch, e)
                        return
                    self._retries.inc()
                    obs.event("batcher/retry", attempt=attempt, rows=int(Z.shape[0]))
                    time.sleep(self._backoff_s(attempt))
                    attempt += 1
                except Exception as e:
                    if self.config.isolate_failures and len(batch) > 1:
                        # bisect: row-independence means re-dispatching halves is
                        # bit-identical for every non-poison request in them
                        self._bisections.inc()
                        obs.event("batcher/bisect", requests=len(batch))
                        mid = len(batch) // 2
                        self._execute(batch[:mid])
                        self._execute(batch[mid:])
                    else:
                        if len(batch) == 1:
                            self._isolated_failures.inc()
                            obs.event("batcher/isolated_failure")
                        self._fail(batch, e)
                    return
            self._scatter(batch, Z, feats, t0)

    def _process(self, batch: Sequence[_Request]):
        if not batch:
            return
        now = time.perf_counter()
        live: List[_Request] = []
        for r in batch:
            if r.deadline is not None and now > r.deadline:
                self._deadline_expired.inc()
                obs.event("batcher/deadline_expired")
                if r.future.set_running_or_notify_cancel():
                    r.future.set_exception(
                        DeadlineExceeded(
                            f"request waited {(now - r.t_submit) * 1e3:.1f}ms, "
                            "past its deadline, before dispatch"
                        )
                    )
                continue
            live.append(r)
        if live:
            self._batch_seq += 1
            self._execute(live)

    def run_once(self) -> int:
        """Synchronously drain the queue in coalesced batches (no worker
        thread needed).  Returns the number of requests processed."""
        done = 0
        while True:
            batch = self._take_batch(block=False)
            if not batch:
                return done
            self._process(batch)
            done += len(batch)

    def _loop(self):
        while True:
            with self._lock:
                if not self._running and not self._queue:
                    return
            batch = self._take_batch(block=True)
            self._process(batch)
