"""MicroBatcher: coalesce concurrent (FT) requests into one device call.

The (FT) analogue of ``launch/serve.py``'s continuous-batching decode loop:
callers submit variable-size transform / predict requests from any thread;
a single worker thread drains the queue, concatenates the pending rows into
one padded call through the :class:`~repro.serving.engine.TransformEngine`,
and scatters the result rows back to each caller's future.

Coalescing policy: the worker sleeps until a request arrives, then keeps
collecting until either ``max_batch_rows`` is reached or ``max_delay_ms``
has elapsed since the first queued request — classic micro-batching: tiny
added latency bound, large throughput win (one dispatch + one pad instead
of one per request).

Because the engine's evaluation is row-independent and the engine pads to
its row buckets anyway, a coalesced call is bit-identical to per-request
calls — batching is purely a throughput optimization.

``predict`` requests ride the same queue: they share the batched feature
transform and apply the (cheap, host-side) classifier head per request.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable, Deque, List, Optional, Sequence

import numpy as np

from .engine import TransformEngine


@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    max_batch_rows: int = 8192  # flush when this many rows are queued
    max_delay_ms: float = 2.0  # ... or this long after the first request
    max_queue: int = 4096  # pending-request backpressure bound

    def __post_init__(self):
        if self.max_batch_rows < 1:
            raise ValueError(f"max_batch_rows must be >= 1, got {self.max_batch_rows}")
        if self.max_delay_ms < 0:
            raise ValueError(f"max_delay_ms must be >= 0, got {self.max_delay_ms}")
        if self.max_queue < 1:
            # 0 would deadlock: submit waits for space the worker can never
            # create (it only notifies _not_full after popping a request)
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")


@dataclasses.dataclass
class _Request:
    Z: np.ndarray
    kind: str  # 'transform' | 'predict'
    future: Future
    t_submit: float


class MicroBatcher:
    """Request-coalescing front of a :class:`TransformEngine`.

    ``head`` (optional) maps a feature block ``(q, F)`` to predictions for
    ``kind='predict'`` requests — e.g. ``classifier.head`` (SVM argmax).

    Start the background worker with ``start()`` (or use the context
    manager); ``submit`` returns a ``concurrent.futures.Future``.  For
    deterministic in-process use (tests, benchmark replay without threads)
    ``run_once()`` drains the current queue synchronously in coalesced
    batches.
    """

    def __init__(
        self,
        engine: TransformEngine,
        *,
        head: Optional[Callable[[np.ndarray], np.ndarray]] = None,
        config: BatcherConfig = BatcherConfig(),
    ):
        self.engine = engine
        self.head = head
        self.config = config
        self._queue: Deque[_Request] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._stopped = False
        self.stats = {
            "requests": 0,
            "batches": 0,
            "rows": 0,
            "coalesced_max": 0,
            "wait_ms_total": 0.0,
        }

    # -- lifecycle ---------------------------------------------------------

    def start(self) -> "MicroBatcher":
        if self._thread is not None:
            return self
        self._stopped = False
        self._running = True
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        with self._lock:
            self._running = False
            self._stopped = True
            self._not_empty.notify_all()
            self._not_full.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.run_once()  # drain stragglers synchronously

    def __enter__(self) -> "MicroBatcher":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- client API --------------------------------------------------------

    def submit(self, Z, kind: str = "transform") -> Future:
        """Enqueue one request; the future resolves to (q, F) features for
        ``kind='transform'`` or head outputs for ``kind='predict'``."""
        if kind not in ("transform", "predict"):
            raise ValueError(f"unknown request kind {kind!r}")
        if kind == "predict" and self.head is None:
            raise ValueError("predict requests need a head= callable")
        Z = np.asarray(Z)
        n = self.engine.consts.n
        if Z.ndim != 2 or Z.shape[1] != n:
            # reject malformed requests HERE: once coalesced, a bad request
            # would fail the whole batch and poison innocent callers' futures
            raise ValueError(f"expected (q, {n}) request rows, got {Z.shape}")
        fut: Future = Future()
        req = _Request(Z=Z, kind=kind, future=fut, t_submit=time.perf_counter())
        with self._lock:
            while (
                not self._stopped
                and self._running
                and len(self._queue) >= self.config.max_queue
            ):
                self._not_full.wait()
            if self._stopped:
                # after stop()'s final drain nothing empties the queue;
                # enqueueing would leave the caller blocked on a future that
                # never resolves (including submitters woken from the
                # backpressure wait above by stop())
                raise RuntimeError("MicroBatcher is stopped; start() it again")
            self._queue.append(req)
            self.stats["requests"] += 1
            self._not_empty.notify()
        return fut

    def transform(self, Z) -> np.ndarray:
        """Synchronous convenience: submit + wait."""
        fut = self.submit(Z, "transform")
        if self._thread is None:
            self.run_once()
        return fut.result()

    def predict(self, Z) -> np.ndarray:
        fut = self.submit(Z, "predict")
        if self._thread is None:
            self.run_once()
        return fut.result()

    # -- batching core -----------------------------------------------------

    def _take_batch(self, block: bool) -> List[_Request]:
        """Pop a coalesced batch: up to ``max_batch_rows`` rows, waiting at
        most ``max_delay_ms`` after the first pending request."""
        with self._lock:
            if block:
                while not self._queue and self._running:
                    self._not_empty.wait()
            if not self._queue:
                return []
            # anchor the flush deadline at the OLDEST pending request, so a
            # request that already waited while the previous batch was being
            # processed is not taxed another full delay window
            deadline = self._queue[0].t_submit + self.config.max_delay_ms / 1e3
            # collection window: give concurrent submitters a bounded chance
            # to join this batch.  A timed condition wait (woken by submit)
            # rather than a sleep/poll loop — the worker stays off the GIL
            # while it waits.
            while self._running:
                rows = sum(r.Z.shape[0] for r in self._queue)
                if rows >= self.config.max_batch_rows:
                    break
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                self._not_empty.wait(timeout=remaining)
            batch: List[_Request] = []
            rows = 0
            while self._queue:
                nxt = self._queue[0]
                if batch and rows + nxt.Z.shape[0] > self.config.max_batch_rows:
                    break
                batch.append(self._queue.popleft())
                rows += nxt.Z.shape[0]
            self._not_full.notify_all()
        return batch

    def _process(self, batch: Sequence[_Request]):
        if not batch:
            return
        t0 = time.perf_counter()
        try:
            Z = (
                np.concatenate([r.Z for r in batch], axis=0)
                if len(batch) > 1
                else batch[0].Z
            )
            feats = self.engine.transform(Z)
        except Exception as e:  # propagate to every caller in the batch
            for r in batch:
                if not r.future.set_running_or_notify_cancel():
                    continue
                r.future.set_exception(e)
            return
        self.stats["batches"] += 1
        self.stats["rows"] += int(Z.shape[0])
        self.stats["coalesced_max"] = max(self.stats["coalesced_max"], len(batch))
        self.stats["wait_ms_total"] += (t0 - batch[0].t_submit) * 1e3
        start = 0
        for r in batch:
            stop = start + r.Z.shape[0]
            block = feats[start:stop]
            if len(batch) > 1:
                # own the rows: a view would pin the whole coalesced batch
                # buffer in memory for as long as any caller keeps its result
                block = np.ascontiguousarray(block)
            start = stop
            if not r.future.set_running_or_notify_cancel():
                continue
            try:
                if r.kind == "predict":
                    r.future.set_result(self.head(block))
                else:
                    r.future.set_result(block)
            except Exception as e:
                r.future.set_exception(e)

    def run_once(self) -> int:
        """Synchronously drain the queue in coalesced batches (no worker
        thread needed).  Returns the number of requests processed."""
        done = 0
        while True:
            batch = self._take_batch(block=False)
            if not batch:
                return done
            self._process(batch)
            done += len(batch)

    def _loop(self):
        while True:
            with self._lock:
                if not self._running and not self._queue:
                    return
            batch = self._take_batch(block=True)
            self._process(batch)
