"""TransformEngine: compiled, shape-bucketed, optionally sharded (FT) serving.

One engine owns one model set (the per-class models of a classifier, or a
single model) and the fused evaluation plan built from it by
:func:`repro.api.plan_constants` — the same hoisted trace constants the
direct :func:`repro.api.feature_transform` path uses, so both paths are
bit-identical at matched dtype.

Request shapes never recompile: a query of ``q`` rows is zero-padded up to a
**pow2 row bucket** (clamped to ``[min_bucket, max_bucket]`` and rounded up
to the data-shard count), mirroring the zero-recompile ``(Lcap, Kcap)``
capacity buckets of the fit path.  Every row of the fused transform is
independent (the whole evaluation is row-parallel matmuls with a fixed
contraction order), so padding rows changes nothing about real rows and the
sliced result is bit-identical to evaluating at the exact shape.

Sharded execution reuses :mod:`repro.core.distributed`'s mesh helpers: rows
are data-parallel over the mesh's ``data_axes`` (``shard_map`` with the same
row spec as the distributed fit), plan constants are replicated (closed
over), and no collectives are needed — the transform is embarrassingly
row-parallel, so multi-host serving scales linearly in devices.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core.distributed import (
    SHARD_MAP_KW,
    data_spec,
    num_data_shards,
    shard_map_compat,
)
from ..core.oavi import pow2_bucket
from ..resilience import chaos


class UnsupportedModelError(TypeError):
    """The model set has no fused term-book plan (e.g. VCA) — serve those
    through the legacy per-model loop instead."""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Row-bucket policy of a :class:`TransformEngine`.

    ``min_bucket`` bounds the padding waste of tiny requests from below
    (every request costs at least one ``min_bucket``-row device call);
    ``max_bucket`` bounds device memory from above — larger queries stream
    through in full ``max_bucket`` chunks (which are already-warm buckets,
    so chunking never recompiles either).
    """

    min_bucket: int = 64
    max_bucket: int = 16_384  # larger requests chunk through warm buckets

    def __post_init__(self):
        if self.min_bucket < 1 or self.max_bucket < self.min_bucket:
            raise ValueError(
                f"need 1 <= min_bucket <= max_bucket, got "
                f"({self.min_bucket}, {self.max_bucket})"
            )


class TransformEngine:
    """Serve the fused feature transform of one model set.

    Parameters
    ----------
    models : the per-class model set (term-book models only — OAVI / ABM).
    mesh : optional ``jax.sharding.Mesh``; when given, every device call is
        ``shard_map``-sharded with rows data-parallel over ``data_axes`` and
        plan constants replicated.  ``mesh=None`` runs locally.
    data_axes : mesh axes the row dimension is sharded over.
    config : row-bucket policy (:class:`EngineConfig`).
    """

    def __init__(
        self,
        models: Sequence,
        *,
        mesh=None,
        data_axes: Sequence[str] = ("data",),
        config: EngineConfig = EngineConfig(),
    ):
        from .. import api

        self.models: Tuple = tuple(models)
        self._model_key = tuple(id(m) for m in self.models)
        plan = api._fuse(self.models)
        if plan is None:
            raise UnsupportedModelError(
                "TransformEngine needs term-book models (OAVI/ABM); got a "
                "model set with no fused plan (e.g. VCA or mixed dtypes) — "
                "use repro.api.feature_transform's per-model fallback"
            )
        self.plan = plan
        self.consts = api.plan_constants(plan)
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.config = config
        self.shards = 1 if mesh is None else num_data_shards(mesh, self.data_axes)
        # every bucket must split evenly over the data shards AND leave every
        # shard >= 2 rows: a 1-row local shard hits XLA's single-row gemv
        # lowering, whose accumulation order differs from the gemm path and
        # would break bit-identity with the local/direct evaluation
        self.min_bucket = self._round_to_shards(
            max(pow2_bucket(config.min_bucket), 2 * self.shards)
        )
        self.max_bucket = self._round_to_shards(
            max(pow2_bucket(config.max_bucket), self.min_bucket)
        )
        self._fn = self._build_fn()
        self._seen_buckets: set = set()
        self._lock = threading.Lock()
        self.backend = "local" if mesh is None else "sharded"
        # obs metric primitives (always live — ``stats`` is a view over them;
        # the span/trace layer is what OBS_ENABLED gates)
        self._requests = obs.Counter()
        self._rows = obs.Counter()
        self._device_calls = obs.Counter()
        self._padded_rows = obs.Counter()
        self._recompiles = obs.Counter()
        self._warmup_compiles = obs.Counter()
        self._bucket_calls: Dict[int, obs.Counter] = {}
        # per-engine request latency sketch (p50/p99/p999 via stats view);
        # also folded into the process-global serve SLO histogram by label
        self.latency = obs.Histogram()
        self._slo = obs.registry().histogram(
            "serve.transform_seconds", backend=self.backend
        )
        # device-level accounting: HLO flop estimate per bucket (captured
        # once per bucket via lowering, no XLA compile), cumulative flops
        # actually dispatched, and XLA backend-compile seconds attributed to
        # this engine's warmup/first-call compiles
        self._bucket_flops: Dict[int, Optional[float]] = {}
        self._flops_dispatched = 0.0
        self._compile_seconds = 0.0

    @property
    def stats(self) -> Dict:
        """Point-in-time counter view (same keys as the historical dict)."""
        lat = self.latency.summary()
        achieved = None
        if self._flops_dispatched > 0.0 and lat["sum"] > 0.0:
            achieved = round(self._flops_dispatched / lat["sum"] / 1e9, 3)
        return {
            "requests": self._requests.value,
            "rows": self._rows.value,
            "device_calls": self._device_calls.value,
            "padded_rows": self._padded_rows.value,
            "recompiles": self._recompiles.value,
            "warmup_compiles": self._warmup_compiles.value,
            "buckets": {b: c.value for b, c in sorted(self._bucket_calls.items())},
            "latency": lat,
            "flops_per_bucket": dict(sorted(self._bucket_flops.items())),
            "flops_dispatched": self._flops_dispatched,
            "compile_seconds": round(self._compile_seconds, 6),
            "achieved_gflops": achieved,
        }

    # -- plan / shape machinery -------------------------------------------

    def _round_to_shards(self, b: int) -> int:
        return ((b + self.shards - 1) // self.shards) * self.shards

    def _build_fn(self):
        consts = self.consts
        from .. import api

        def eval_fn(Z):
            return api.eval_with_constants(consts, Z)

        if self.mesh is None:
            return jax.jit(eval_fn)
        dspec = data_spec(self.data_axes)
        sharded = shard_map_compat(
            eval_fn,
            mesh=self.mesh,
            in_specs=(dspec,),
            out_specs=dspec,
            **SHARD_MAP_KW,
        )
        return jax.jit(sharded)

    def matches(self, models: Sequence) -> bool:
        """True when this engine serves exactly ``models`` (by identity)."""
        return tuple(id(m) for m in models) == self._model_key

    def bucket_for(self, q: int) -> int:
        """Row bucket a ``q``-row request pads to (pow2, clamped, shard-even)."""
        b = min(max(pow2_bucket(max(q, 1)), self.min_bucket), self.max_bucket)
        return self._round_to_shards(b)

    def buckets(self) -> Tuple[int, ...]:
        """Every bucket this engine can dispatch (smallest to largest)."""
        out = []
        b = self.min_bucket
        while b < self.max_bucket:
            out.append(b)
            b = self._round_to_shards(pow2_bucket(b + 1))
        out.append(self.max_bucket)
        return tuple(out)

    # -- execution ---------------------------------------------------------

    def _bucket_cost(self, b: int) -> Optional[float]:
        """Flop estimate of one ``b``-row device call (HLO cost analysis,
        captured once per bucket — lowering traces without XLA-compiling)."""
        if not obs.device.device_enabled():
            return None
        with self._lock:
            if b in self._bucket_flops:
                return self._bucket_flops[b]
        aval = jax.ShapeDtypeStruct((b, self.consts.n), self.plan.dtype)
        cost = obs.device.step_cost(self._fn, ("serve", b), (aval,))
        flops = None if cost is None else cost["flops"]
        with self._lock:
            self._bucket_flops.setdefault(b, flops)
        return flops

    def warmup(self, max_rows: Optional[int] = None) -> int:
        """Trace-and-compile every bucket up to ``max_rows`` (default: all).

        Returns the number of compiles triggered.  After a full warmup a
        request trace of any shape mix runs with ``stats["recompiles"] == 0``.
        """
        top = self.max_bucket if max_rows is None else self.bucket_for(max_rows)
        compiled = 0
        with obs.device.profile_window("serve/warmup"):
            for b in self.buckets():
                if b > top:
                    break
                with self._lock:
                    if b in self._seen_buckets:
                        continue
                    self._seen_buckets.add(b)
                self._bucket_cost(b)
                Zb = np.zeros((b, self.consts.n), self.plan.dtype)
                with obs.span(
                    "serve/warmup_compile", bucket=b, backend=self.backend
                ), obs.device.CompileWindow() as cw:
                    jax.block_until_ready(self._fn(jnp.asarray(Zb)))
                with self._lock:
                    self._compile_seconds += cw.seconds
                compiled += 1
        self._warmup_compiles.inc(compiled)
        return compiled

    def _dispatch(self, Zp: np.ndarray) -> np.ndarray:
        """One padded device call at a bucket shape; updates compile stats."""
        b = Zp.shape[0]
        fresh = False
        with self._lock:
            if b not in self._seen_buckets:
                self._seen_buckets.add(b)
                self._recompiles.inc()
                fresh = True
                obs.event("serve/recompile", bucket=b, backend=self.backend)
            bucket = self._bucket_calls.get(b)
            if bucket is None:
                bucket = self._bucket_calls.setdefault(b, obs.Counter())
        self._device_calls.inc()
        bucket.inc()
        flops = self._bucket_cost(b)
        if flops:
            with self._lock:
                self._flops_dispatched += flops
        if not fresh:
            return np.asarray(self._fn(jnp.asarray(Zp)))
        # cold bucket outside warmup: attribute the XLA compile to the engine
        with obs.device.CompileWindow() as cw:
            out = np.asarray(self._fn(jnp.asarray(Zp)))
        with self._lock:
            self._compile_seconds += cw.seconds
        return out

    def transform(self, Z) -> np.ndarray:
        """(FT) features for one request: (q, num_features) in plan dtype.

        Bit-identical to ``api.feature_transform(self.models, Z)`` at the
        plan dtype for any q; rows beyond ``max_bucket`` stream through in
        full already-warm chunks.
        """
        Z = np.asarray(Z)
        if Z.ndim != 2 or Z.shape[1] != self.consts.n:
            raise ValueError(
                f"expected (q, {self.consts.n}) queries, got {Z.shape}"
            )
        # chaos hook: transient/poison/hang faults fire HERE, the device-call
        # boundary — the batcher's retry and bisection paths see exactly what
        # a failing accelerator call would look like (no-op without a plan)
        chaos.fire("engine.transform", Z=Z)
        q = Z.shape[0]
        self._requests.inc()
        self._rows.inc(q)
        out_dtype = self.plan.dtype
        if q == 0 or self.consts.num_features == 0:
            return np.zeros((q, self.consts.num_features), out_dtype)
        t0 = time.perf_counter()
        with obs.span("serve/transform", rows=q, backend=self.backend):
            Zd = Z.astype(self.plan.dtype, copy=False)
            out = np.empty((q, self.consts.num_features), out_dtype)
            start = 0
            while start < q:
                stop = min(start + self.max_bucket, q)
                chunk = Zd[start:stop]
                b = self.bucket_for(chunk.shape[0])
                if chunk.shape[0] < b:
                    Zp = np.zeros((b, self.consts.n), self.plan.dtype)
                    Zp[: chunk.shape[0]] = chunk
                    self._padded_rows.inc(b - chunk.shape[0])
                else:
                    Zp = chunk
                out[start:stop] = self._dispatch(Zp)[: chunk.shape[0]]
                start = stop
        dur = time.perf_counter() - t0
        self.latency.observe(dur)
        self._slo.observe(dur)
        return out

    def __repr__(self) -> str:
        where = (
            "local"
            if self.mesh is None
            else f"sharded(shards={self.shards}, axes={self.data_axes})"
        )
        return (
            f"TransformEngine(models={len(self.models)}, "
            f"features={self.consts.num_features}, {where}, "
            f"buckets=[{self.min_bucket}..{self.max_bucket}])"
        )
