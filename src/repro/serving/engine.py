"""TransformEngine: compiled, shape-bucketed, optionally sharded (FT) serving.

One engine owns one model set (the per-class models of a classifier, or a
single model) and the fused evaluation plan built from it by
:func:`repro.api.plan_constants` — the same hoisted trace constants the
direct :func:`repro.api.feature_transform` path uses, so both paths are
bit-identical at matched dtype.

Request shapes never recompile: a query of ``q`` rows is zero-padded up to a
**pow2 row bucket** (clamped to ``[min_bucket, max_bucket]`` and rounded up
to the data-shard count), mirroring the zero-recompile ``(Lcap, Kcap)``
capacity buckets of the fit path.  Every row of the fused transform is
independent (the whole evaluation is row-parallel matmuls with a fixed
contraction order), so padding rows changes nothing about real rows and the
sliced result is bit-identical to evaluating at the exact shape.

Sharded execution reuses :mod:`repro.core.distributed`'s mesh helpers: rows
are data-parallel over the mesh's ``data_axes`` (``shard_map`` with the same
row spec as the distributed fit), plan constants are replicated (closed
over), and no collectives are needed — the transform is embarrassingly
row-parallel, so multi-host serving scales linearly in devices.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distributed import (
    SHARD_MAP_KW,
    data_spec,
    num_data_shards,
    shard_map_compat,
)
from ..core.oavi import pow2_bucket
from ..resilience import chaos


class UnsupportedModelError(TypeError):
    """The model set has no fused term-book plan (e.g. VCA) — serve those
    through the legacy per-model loop instead."""


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Row-bucket policy of a :class:`TransformEngine`.

    ``min_bucket`` bounds the padding waste of tiny requests from below
    (every request costs at least one ``min_bucket``-row device call);
    ``max_bucket`` bounds device memory from above — larger queries stream
    through in full ``max_bucket`` chunks (which are already-warm buckets,
    so chunking never recompiles either).
    """

    min_bucket: int = 64
    max_bucket: int = 16_384  # larger requests chunk through warm buckets

    def __post_init__(self):
        if self.min_bucket < 1 or self.max_bucket < self.min_bucket:
            raise ValueError(
                f"need 1 <= min_bucket <= max_bucket, got "
                f"({self.min_bucket}, {self.max_bucket})"
            )


class TransformEngine:
    """Serve the fused feature transform of one model set.

    Parameters
    ----------
    models : the per-class model set (term-book models only — OAVI / ABM).
    mesh : optional ``jax.sharding.Mesh``; when given, every device call is
        ``shard_map``-sharded with rows data-parallel over ``data_axes`` and
        plan constants replicated.  ``mesh=None`` runs locally.
    data_axes : mesh axes the row dimension is sharded over.
    config : row-bucket policy (:class:`EngineConfig`).
    """

    def __init__(
        self,
        models: Sequence,
        *,
        mesh=None,
        data_axes: Sequence[str] = ("data",),
        config: EngineConfig = EngineConfig(),
    ):
        from .. import api

        self.models: Tuple = tuple(models)
        self._model_key = tuple(id(m) for m in self.models)
        plan = api._fuse(self.models)
        if plan is None:
            raise UnsupportedModelError(
                "TransformEngine needs term-book models (OAVI/ABM); got a "
                "model set with no fused plan (e.g. VCA or mixed dtypes) — "
                "use repro.api.feature_transform's per-model fallback"
            )
        self.plan = plan
        self.consts = api.plan_constants(plan)
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.config = config
        self.shards = 1 if mesh is None else num_data_shards(mesh, self.data_axes)
        # every bucket must split evenly over the data shards AND leave every
        # shard >= 2 rows: a 1-row local shard hits XLA's single-row gemv
        # lowering, whose accumulation order differs from the gemm path and
        # would break bit-identity with the local/direct evaluation
        self.min_bucket = self._round_to_shards(
            max(pow2_bucket(config.min_bucket), 2 * self.shards)
        )
        self.max_bucket = self._round_to_shards(
            max(pow2_bucket(config.max_bucket), self.min_bucket)
        )
        self._fn = self._build_fn()
        self._seen_buckets: set = set()
        self._lock = threading.Lock()
        self.stats: Dict = {
            "requests": 0,
            "rows": 0,
            "device_calls": 0,
            "padded_rows": 0,
            "recompiles": 0,
            "warmup_compiles": 0,
            "buckets": {},  # bucket -> device calls
        }

    # -- plan / shape machinery -------------------------------------------

    def _round_to_shards(self, b: int) -> int:
        return ((b + self.shards - 1) // self.shards) * self.shards

    def _build_fn(self):
        consts = self.consts
        from .. import api

        def eval_fn(Z):
            return api.eval_with_constants(consts, Z)

        if self.mesh is None:
            return jax.jit(eval_fn)
        dspec = data_spec(self.data_axes)
        sharded = shard_map_compat(
            eval_fn,
            mesh=self.mesh,
            in_specs=(dspec,),
            out_specs=dspec,
            **SHARD_MAP_KW,
        )
        return jax.jit(sharded)

    def matches(self, models: Sequence) -> bool:
        """True when this engine serves exactly ``models`` (by identity)."""
        return tuple(id(m) for m in models) == self._model_key

    def bucket_for(self, q: int) -> int:
        """Row bucket a ``q``-row request pads to (pow2, clamped, shard-even)."""
        b = min(max(pow2_bucket(max(q, 1)), self.min_bucket), self.max_bucket)
        return self._round_to_shards(b)

    def buckets(self) -> Tuple[int, ...]:
        """Every bucket this engine can dispatch (smallest to largest)."""
        out = []
        b = self.min_bucket
        while b < self.max_bucket:
            out.append(b)
            b = self._round_to_shards(pow2_bucket(b + 1))
        out.append(self.max_bucket)
        return tuple(out)

    # -- execution ---------------------------------------------------------

    def warmup(self, max_rows: Optional[int] = None) -> int:
        """Trace-and-compile every bucket up to ``max_rows`` (default: all).

        Returns the number of compiles triggered.  After a full warmup a
        request trace of any shape mix runs with ``stats["recompiles"] == 0``.
        """
        top = self.max_bucket if max_rows is None else self.bucket_for(max_rows)
        compiled = 0
        for b in self.buckets():
            if b > top:
                break
            with self._lock:
                if b in self._seen_buckets:
                    continue
                self._seen_buckets.add(b)
            Zb = np.zeros((b, self.consts.n), self.plan.dtype)
            jax.block_until_ready(self._fn(jnp.asarray(Zb)))
            compiled += 1
        with self._lock:
            self.stats["warmup_compiles"] += compiled
        return compiled

    def _dispatch(self, Zp: np.ndarray) -> np.ndarray:
        """One padded device call at a bucket shape; updates compile stats."""
        b = Zp.shape[0]
        with self._lock:
            if b not in self._seen_buckets:
                self._seen_buckets.add(b)
                self.stats["recompiles"] += 1
            self.stats["device_calls"] += 1
            self.stats["buckets"][b] = self.stats["buckets"].get(b, 0) + 1
        return np.asarray(self._fn(jnp.asarray(Zp)))

    def transform(self, Z) -> np.ndarray:
        """(FT) features for one request: (q, num_features) in plan dtype.

        Bit-identical to ``api.feature_transform(self.models, Z)`` at the
        plan dtype for any q; rows beyond ``max_bucket`` stream through in
        full already-warm chunks.
        """
        Z = np.asarray(Z)
        if Z.ndim != 2 or Z.shape[1] != self.consts.n:
            raise ValueError(
                f"expected (q, {self.consts.n}) queries, got {Z.shape}"
            )
        # chaos hook: transient/poison/hang faults fire HERE, the device-call
        # boundary — the batcher's retry and bisection paths see exactly what
        # a failing accelerator call would look like (no-op without a plan)
        chaos.fire("engine.transform", Z=Z)
        q = Z.shape[0]
        with self._lock:
            self.stats["requests"] += 1
            self.stats["rows"] += q
        out_dtype = self.plan.dtype
        if q == 0 or self.consts.num_features == 0:
            return np.zeros((q, self.consts.num_features), out_dtype)
        Zd = Z.astype(self.plan.dtype, copy=False)
        out = np.empty((q, self.consts.num_features), out_dtype)
        start = 0
        while start < q:
            stop = min(start + self.max_bucket, q)
            chunk = Zd[start:stop]
            b = self.bucket_for(chunk.shape[0])
            if chunk.shape[0] < b:
                Zp = np.zeros((b, self.consts.n), self.plan.dtype)
                Zp[: chunk.shape[0]] = chunk
                with self._lock:
                    self.stats["padded_rows"] += b - chunk.shape[0]
            else:
                Zp = chunk
            out[start:stop] = self._dispatch(Zp)[: chunk.shape[0]]
            start = stop
        return out

    def __repr__(self) -> str:
        where = (
            "local"
            if self.mesh is None
            else f"sharded(shards={self.shards}, axes={self.data_axes})"
        )
        return (
            f"TransformEngine(models={len(self.models)}, "
            f"features={self.consts.num_features}, {where}, "
            f"buckets=[{self.min_bucket}..{self.max_bucket}])"
        )
