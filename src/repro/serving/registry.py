"""ModelRegistry: versioned, warmed, hot-swappable serving entries.

A serving process holds one registry.  Each ``register``/``load`` call
builds a :class:`~repro.serving.engine.TransformEngine` for the servable's
model set (and warms its shape buckets so live traffic never compiles),
then files it under ``(name, version)``.  ``activate`` flips the active
version pointer atomically — hot-swap: in-flight requests finish on the old
engine object, new requests resolve the new one.

Servables come from :mod:`repro.checkpoint.store` paths written by either
:func:`repro.api.save` (a single :class:`VanishingIdealModel`) or
:meth:`VanishingIdealClassifier.save` (scaler + per-class models + SVM
head); :func:`load_servable` dispatches on the manifest format tag.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from ..checkpoint import store as ckpt_store
from ..resilience import chaos
from .engine import EngineConfig, TransformEngine, UnsupportedModelError


def load_servable(path: str):
    """Load whatever committed checkpoint lives at ``path``: a
    :class:`VanishingIdealModel` (``repro.api.save``) or a
    :class:`VanishingIdealClassifier` (``classifier.save``)."""
    from .. import api
    from ..core import pipeline

    metadata, _ = ckpt_store.read_metadata(path)
    fmt = metadata.get("format")
    if fmt == api._FORMAT:
        return api.load(path)
    if fmt == pipeline.CLASSIFIER_FORMAT:
        return pipeline.VanishingIdealClassifier.load(path)
    raise ValueError(f"{path!r} has unknown checkpoint format {fmt!r}")


@dataclasses.dataclass
class RegistryEntry:
    """One servable version: the loaded object, its warmed engine, and the
    request-path helpers the driver / batcher need."""

    name: str
    version: int
    servable: object  # VanishingIdealModel or VanishingIdealClassifier
    models: Tuple  # the engine's model set
    engine: Optional[TransformEngine]  # None -> per-model fallback (VCA)
    head: Optional[Callable[[np.ndarray], np.ndarray]]  # features -> labels
    scaler: Optional[object]  # MinMaxScaler for raw request inputs
    path: Optional[str]
    loaded_at: float
    ever_activated: bool = False  # has this version ever carried traffic?

    @property
    def num_features(self) -> int:
        if self.engine is not None:
            return self.engine.consts.num_features
        return sum(m.num_G for m in self.models)

    def scale(self, Z) -> np.ndarray:
        """Raw request rows -> the [0,1]^n inputs the models were fitted on
        (identity for model-only entries, which carry no scaler)."""
        return Z if self.scaler is None else self.scaler.transform(Z)

    def transform(self, Z, *, scaled: bool = False) -> np.ndarray:
        """(FT) features through the warmed engine (or the per-model
        fallback when the model set has no fused plan)."""
        from .. import api

        Z = np.asarray(Z)
        if not scaled:
            Z = self.scale(Z)
        if self.engine is not None:
            return self.engine.transform(Z)
        return np.asarray(api.feature_transform(list(self.models), Z))

    def predict(self, Z, *, scaled: bool = False) -> np.ndarray:
        if self.head is None:
            raise ValueError(
                f"{self.name!r} v{self.version} is a bare model set; predict "
                "needs a classifier servable (with an SVM head)"
            )
        return self.head(self.transform(Z, scaled=scaled))


class ModelRegistry:
    """Thread-safe (name, version) -> warmed engine store with hot-swap."""

    def __init__(
        self,
        *,
        mesh=None,
        data_axes: Sequence[str] = ("data",),
        engine_config: EngineConfig = EngineConfig(),
        warmup: bool = True,
        warmup_rows: Optional[int] = None,
    ):
        self.mesh = mesh
        self.data_axes = tuple(data_axes)
        self.engine_config = engine_config
        self.warmup = warmup
        self.warmup_rows = warmup_rows
        self._entries: Dict[str, Dict[int, RegistryEntry]] = {}
        self._active: Dict[str, int] = {}
        self._lock = threading.Lock()

    # -- registration ------------------------------------------------------

    def _model_set(self, servable) -> Tuple[Tuple, Optional[Callable], Optional[object]]:
        models = getattr(servable, "models", None)
        if models is not None:  # classifier: per-class models + head + scaler
            return tuple(models), servable.head, getattr(servable, "scaler", None)
        return (servable,), None, None

    def register(
        self,
        name: str,
        servable,
        *,
        version: Optional[int] = None,
        activate: bool = True,
        path: Optional[str] = None,
    ) -> RegistryEntry:
        """File ``servable`` under ``(name, version)`` with a warmed engine.

        ``version`` defaults to one past the newest registered version.
        ``activate=False`` stages the version without flipping traffic to it
        (finish warmup, run shadow checks, then :meth:`activate`).
        """
        if version is not None:
            with self._lock:  # cheap duplicate check BEFORE paying warmup
                if version in self._entries.get(name, {}):
                    raise ValueError(f"{name!r} v{version} is already registered")
        models, head, scaler = self._model_set(servable)
        try:
            engine = TransformEngine(
                models,
                mesh=self.mesh,
                data_axes=self.data_axes,
                config=self.engine_config,
            )
            if self.warmup:
                engine.warmup(self.warmup_rows)
        except UnsupportedModelError:
            engine = None  # VCA & co: per-model fallback path
        with self._lock:
            versions = self._entries.setdefault(name, {})
            if version is None:
                version = max(versions, default=0) + 1
            if version in versions:
                raise ValueError(f"{name!r} v{version} is already registered")
            entry = RegistryEntry(
                name=name,
                version=version,
                servable=servable,
                models=models,
                engine=engine,
                head=head,
                scaler=scaler,
                path=path,
                loaded_at=time.time(),
                ever_activated=activate,
            )
            versions[version] = entry
            if activate:
                self._active[name] = version
        return entry

    def load(self, name: str, path: str, **register_kw) -> RegistryEntry:
        """:func:`load_servable` + :meth:`register` in one step."""
        return self.register(name, load_servable(path), path=path, **register_kw)

    # -- lookup / hot-swap -------------------------------------------------

    def get(self, name: str, version: Optional[int] = None) -> RegistryEntry:
        with self._lock:
            versions = self._entries.get(name)
            if not versions:
                raise KeyError(f"no servable registered under {name!r}")
            if version is None:
                version = self._active.get(name)
                if version is None:
                    raise KeyError(
                        f"{name!r} has only staged versions "
                        f"({sorted(versions)}); activate() one first"
                    )
            entry = versions.get(version)
            if entry is None:
                raise KeyError(
                    f"{name!r} has no version {version}; "
                    f"available: {sorted(versions)}"
                )
            return entry

    def activate(self, name: str, version: int) -> RegistryEntry:
        """Hot-swap: atomically point ``name`` at ``version``.

        The chaos hook fires *before* the pointer moves: an injected
        activation failure leaves the previous version serving — the
        degrade-don't-die contract the continuous controller relies on."""
        chaos.fire("registry.activate", name=name, version=version)
        with self._lock:
            versions = self._entries.get(name, {})
            if version not in versions:
                raise KeyError(
                    f"cannot activate {name!r} v{version}; "
                    f"available: {sorted(versions)}"
                )
            self._active[name] = version
            versions[version].ever_activated = True
            return versions[version]

    def active_version(self, name: str) -> Optional[int]:
        """Version traffic resolves to, or None while all versions are staged."""
        with self._lock:
            if name not in self._entries:
                raise KeyError(f"no servable registered under {name!r}")
            return self._active.get(name)

    def versions(self, name: str) -> Tuple[int, ...]:
        with self._lock:
            return tuple(sorted(self._entries.get(name, {})))

    def names(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._entries))

    def remove(self, name: str, version: Optional[int] = None):
        """Drop one version (or the whole name).  Removing the active
        version re-points traffic at the newest survivor that has carried
        traffic before; if only staged versions remain, the active pointer
        clears (serve nothing rather than an unvalidated staged model)."""
        with self._lock:
            versions = self._entries.get(name)
            if not versions:
                raise KeyError(f"no servable registered under {name!r}")
            if version is None:
                del self._entries[name]
                self._active.pop(name, None)
                return
            if version not in versions:
                raise KeyError(f"{name!r} has no version {version}")
            del versions[version]
            if not versions:
                del self._entries[name]
                self._active.pop(name, None)
            elif self._active.get(name) == version:
                trusted = [v for v, e in versions.items() if e.ever_activated]
                if trusted:
                    self._active[name] = max(trusted)
                else:
                    del self._active[name]
