"""Out-of-core OAVI: chunked data sources, one-pass scaling, and a streaming
fit driver that rematerializes the evaluation matrix per degree and reduces
it to Gram sufficient statistics on the fly — ``m`` is bounded by storage (or
by nothing at all, for generator-backed sources), not device memory, and the
result is bit-identical to the in-memory fit at matched capacity."""

from .fit import (
    DEFAULT_CHUNK_ROWS,
    accumulate_source_range,
    fit,
    fit_classes,
    pearson_moments,
    prefetch_map,
    streaming_pearson_order,
)
from .scaler import StreamingMinMaxScaler
from .source import (
    ArraySource,
    DataSource,
    ScaledSource,
    ShardDirSource,
    SyntheticSource,
    as_source,
    is_source,
    iter_chunks,
)

__all__ = [
    "ArraySource",
    "DEFAULT_CHUNK_ROWS",
    "DataSource",
    "ScaledSource",
    "ShardDirSource",
    "StreamingMinMaxScaler",
    "SyntheticSource",
    "accumulate_source_range",
    "as_source",
    "fit",
    "fit_classes",
    "is_source",
    "iter_chunks",
    "pearson_moments",
    "prefetch_map",
    "streaming_pearson_order",
]
