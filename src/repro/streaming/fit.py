"""Out-of-core OAVI: fit over data that never fully resides on device.

The paper's central scaling observation is that every degree-step decision of
OAVI reduces to ``O(|O| * |border|)`` Gram sufficient statistics — the
``(m, Lcap)`` evaluation matrix A only ever enters through ``A^T B`` and
``B^T B``.  The in-memory fit still materializes A (capping ``m`` at device
memory); this driver does not:

* **Per-degree A rematerialization** — a column of A is exactly the
  evaluation of an O term, so for each fixed-size row chunk of X the A-block
  is rebuilt from scratch with the degree-wavefront term evaluator
  (:func:`repro.core.oavi.apply_wavefronts`, bit-identical to the
  incrementally-built A: both multiply parent column by variable column in
  the same association order).
* **Streaming Gram accumulation** — each chunk's Gram blocks fold into
  running ``(Lcap, Kcap)`` / ``(Kcap, Kcap)`` fp32 accumulators through
  :func:`repro.kernels.ops.gram_accumulate`, whose ``GRAM_BLOCK``-row
  sequential reduction makes the accumulated statistics *bit-identical* to
  the in-memory degree step's single call — for any chunk size that is a
  multiple of ``GRAM_BLOCK`` — so the streamed fit reproduces the in-memory
  fit exactly at matched capacity.
* **Statistics-only degree step** — the acceptance loop runs on the
  accumulated statistics alone (:func:`repro.core.oavi._make_stats_degree_step`,
  hoisted out of the in-memory step), covering both the closed-form ``fast``
  engine and the convex-oracle configs (their IHB/AtA state is Gram-only).
* **Sharding** — with a ``mesh``, each data shard streams the chunks of its
  contiguous row span (the same row partition as
  :func:`repro.core.distributed.fit`) into per-shard accumulators held
  device-side under ``shard_map``; ONE psum of the accumulated statistics
  per degree — the same collective count as the in-memory sharded fit, and
  bit-identical to it at matched capacity.

Peak device memory is O(chunk_rows * Lcap) + O(Lcap^2) regardless of ``m``:
the half of the paper's "linear in m" claim that device memory previously
denied us.
"""

from __future__ import annotations

from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import obs
from ..kernels import ops as kernel_ops
from ..core import ihb as ihb_mod
from ..core import terms as terms_mod
from ..core.distributed import (
    SHARD_MAP_KW,
    data_spec,
    num_data_shards,
    shard_map_compat,
    shard_probe,
)
from ..core.oavi import (
    FitScope,
    Generator,
    OAVIConfig,
    OAVIModel,
    _kernel_kwargs,
    _make_stats_degree_step,
    _np_dtype,
    apply_wavefronts,
    border_index_arrays,
    class_batchable,
    collect_degree,
    degree_step_entry,
    init_fit_stats,
    pow2_bucket,
    wavefront_schedule,
)
from ..core.ordering import pearson_order_from_moments
from .source import DataSource, as_source, iter_chunks

DEFAULT_CHUNK_ROWS = 4096


def _check_chunk_rows(chunk_rows: int) -> int:
    chunk_rows = int(chunk_rows)
    if chunk_rows < kernel_ops.GRAM_BLOCK or chunk_rows & (chunk_rows - 1):
        raise ValueError(
            f"chunk_rows must be a power of two >= {kernel_ops.GRAM_BLOCK} "
            f"(the canonical Gram block), got {chunk_rows}"
        )
    return chunk_rows


def pearson_moments(
    source: DataSource,
    chunk_rows: int,
    start: int = 0,
    stop: Optional[int] = None,
    s1: Optional[np.ndarray] = None,
    s2: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Fold rows ``[start, stop)`` of ``source`` into the float64 Pearson
    sufficient statistics ``(s1, s2) = (sum x, sum x x^T)`` — the one-pass
    moment state behind :func:`streaming_pearson_order`, exposed so an
    online fit can persist it and fold only *new* rows on update."""
    n = source.num_features
    s1 = np.zeros((n,), np.float64) if s1 is None else np.array(s1, np.float64)
    s2 = np.zeros((n, n), np.float64) if s2 is None else np.array(s2, np.float64)
    for chunk, valid in iter_chunks(source, chunk_rows, start=start, stop=stop):
        rows = np.asarray(chunk[:valid], np.float64)
        s1 += rows.sum(axis=0)
        s2 += rows.T @ rows
    return s1, s2


def streaming_pearson_order(
    source: DataSource, chunk_rows: int, reverse: bool = False
) -> np.ndarray:
    """One streaming pass of float64 sufficient statistics -> Pearson feature
    order (Algorithm 5).  See :func:`pearson_scores_from_moments` for the
    (ulp-level, tie-only) caveat vs the in-memory two-pass formula."""
    s1, s2 = pearson_moments(source, chunk_rows)
    return pearson_order_from_moments(s1, s2, source.num_rows, reverse=reverse)


def prefetch_map(stage, items: Iterable, enabled: bool = True):
    """Yield ``stage(item)`` for each item, keeping ONE staged result in
    flight ahead of the consumer (host->device double buffering).

    While the consumer runs the jitted accumulator on chunk ``i``, a single
    worker thread assembles and device-puts chunk ``i+1`` — the host-side
    read/pad/transfer work overlaps the device work instead of serializing
    with it.  Order is preserved and every item is staged exactly once, so
    the values the consumer folds are identical with prefetching on or off
    (bit-identity is a pure function of the fold order, which this never
    changes)."""
    if not enabled:
        for item in items:
            yield stage(item)
        return
    with ThreadPoolExecutor(max_workers=1) as pool:
        pending = None
        for item in items:
            nxt = pool.submit(stage, item)
            if pending is not None:
                yield pending.result()
            pending = nxt
        if pending is not None:
            yield pending.result()


# ---------------------------------------------------------------------------
# Chunk accumulator: jitted (rematerialize A-block, fold Gram blocks) per book
# ---------------------------------------------------------------------------

# LRU-bounded like the wavefront cache: one entry per (book, config, shapes);
# a warm refit of the same data replays the same book sequence and compiles
# nothing.
_ACC_CACHE: "OrderedDict[Tuple, Tuple]" = OrderedDict()
_ACC_CACHE_SIZE = 64


def _chunk_accumulator(
    book: terms_mod.TermBook,
    cfg: OAVIConfig,
    Lcap: int,
    chunk_rows: int,
    mesh: Optional[Mesh],
    data_axes: Tuple[str, ...],
):
    """Jitted ``(accQL, accC, Xc, mask, parents, vars_) -> (accQL, accC)``
    for one term book: rematerialize the chunk's A-block with the wavefront
    evaluator, fold its Gram blocks into the running accumulators (donated,
    so the buffers are reused in place).  Returns ``(fn, seen, is_new)``;
    ``seen`` mirrors the jit trace cache for recompile accounting."""
    parents_np = np.asarray(book.parents, np.int32)
    vars_np = np.asarray(book.vars, np.int32)
    key = (
        parents_np.tobytes(),
        vars_np.tobytes(),
        cfg,
        Lcap,
        chunk_rows,
        mesh,
        data_axes,
    )
    cached = _ACC_CACHE.get(key)
    if cached is not None:
        _ACC_CACHE.move_to_end(key)
        return cached[0], cached[1], False

    waves, wperm = wavefront_schedule(parents_np, vars_np)
    ell_book = len(book)
    gram_kw = _kernel_kwargs(cfg)

    def body(accQL, accC, Xc, mask, parents, vars_):
        # A-block = O-term evaluations of this chunk: bit-identical to the
        # incrementally built A (same parent-times-variable association).
        cols = apply_wavefronts(Xc, waves, wperm)
        # padded chunk rows must be zero in EVERY column (the constant column
        # doubles as the row mask, like the sharded path); real rows multiply
        # by exactly 1.0
        cols = cols * mask[:, None]
        A = jnp.pad(cols, ((0, 0), (0, Lcap - ell_book)))
        return kernel_ops.gram_accumulate(
            A, Xc, parents, vars_, acc=(accQL, accC), **gram_kw
        )

    if mesh is None:
        fn = jax.jit(body, donate_argnums=(0, 1))
    else:
        dspec2 = data_spec(data_axes)
        dspec1 = P(data_axes if len(data_axes) > 1 else data_axes[0])
        aspec = P(data_axes if len(data_axes) > 1 else data_axes[0], None, None)
        rep = P()

        def per_shard(accQL, accC, Xc, mask, parents, vars_):
            ql, c = body(accQL[0], accC[0], Xc, mask, parents, vars_)
            return ql[None], c[None]

        fn = jax.jit(
            shard_map_compat(
                per_shard,
                mesh=mesh,
                in_specs=(aspec, aspec, dspec2, dspec1, rep, rep),
                out_specs=(aspec, aspec),
                **SHARD_MAP_KW,
            ),
            donate_argnums=(0, 1),
        )
    entry = (fn, set())
    _ACC_CACHE[key] = entry
    if len(_ACC_CACHE) > _ACC_CACHE_SIZE:
        _ACC_CACHE.popitem(last=False)
    return fn, entry[1], True


def accumulate_source_range(
    acc_fn,
    source: DataSource,
    start: int,
    stop: int,
    chunk_rows: int,
    acc: Tuple[jax.Array, jax.Array],
    parents_d: jax.Array,
    vars_d: jax.Array,
    perm: Optional[np.ndarray] = None,
    np_dtype=np.float32,
    prefetch: bool = True,
) -> Tuple[jax.Array, jax.Array, int]:
    """Fold rows ``[start, stop)`` of ``source`` into the Gram accumulators
    through one jitted chunk accumulator (local path).

    ``start`` must sit on a :data:`~repro.kernels.ops.GRAM_BLOCK` boundary of
    the *global* row index: every chunk then covers whole GRAM_BLOCK blocks
    (trailing zero-padding is a bitwise no-op), so the block partition — and
    therefore every fp32 partial — is identical to a single pass over
    ``[0, stop)`` no matter where the range is split.  This is what lets an
    online update resume accumulation exactly where a previous fit's
    statistics end (:mod:`repro.online`).  Returns
    ``(accQL, accC, num_chunks)``."""
    if start % kernel_ops.GRAM_BLOCK:
        raise ValueError(
            f"range start {start} is not a multiple of the Gram block "
            f"({kernel_ops.GRAM_BLOCK}); the blocked fp32 reduction would "
            "not match a one-shot pass bit for bit"
        )
    n = source.num_features

    def stage(lo: int):
        hi = min(lo + chunk_rows, stop)
        rows = np.zeros((chunk_rows, n), np_dtype)
        mask = np.zeros((chunk_rows,), np_dtype)
        block = np.asarray(source.read(lo, hi))
        if perm is not None:
            block = block[:, perm]
        rows[: hi - lo] = block
        mask[: hi - lo] = 1.0
        return jnp.asarray(rows), jnp.asarray(mask)

    accQL, accC = acc
    num_chunks = 0
    steps = range(start, stop, chunk_rows)
    with obs.span("streaming/accumulate", start=start, stop=stop,
                  chunk_rows=chunk_rows):
        for rows_d, mask_d in prefetch_map(stage, steps, enabled=prefetch):
            accQL, accC = acc_fn(accQL, accC, rows_d, mask_d, parents_d, vars_d)
            num_chunks += 1
    return accQL, accC, num_chunks


def _streaming_stats_entry(
    config: OAVIConfig, mesh: Optional[Mesh], data_axes: Tuple[str, ...]
):
    """Cached jitted statistics-only degree step — replicated stats loop
    locally; under ``shard_map`` with ONE psum of the accumulators per degree
    when sharded."""
    if mesh is None:
        return degree_step_entry(
            config,
            backend_key="streaming",
            jitted_builder=lambda: jax.jit(_make_stats_degree_step(config)),
        )

    def build():
        axes = tuple(data_axes)
        reduce_fn = lambda x: jax.lax.psum(x, axes)  # noqa: E731
        stats_step = _make_stats_degree_step(config, reduce_fn=reduce_fn)
        aspec = P(axes if len(axes) > 1 else axes[0], None, None)
        rep = P()

        def per_shard(accQL, accC, state, ell0, valid, m_total):
            return stats_step(accQL[0], accC[0], state, ell0, valid, m_total)

        # per-shard instant marker, once per degree (NOT on the per-chunk
        # accumulator hot path) — the sharded streaming half of the PR 8
        # span-coverage remainder
        per_shard = shard_probe(per_shard, mesh, axes, "fit/shard_step")

        return jax.jit(
            shard_map_compat(
                per_shard,
                mesh=mesh,
                in_specs=(aspec, aspec, rep, rep, rep, rep),
                out_specs=rep,
                **SHARD_MAP_KW,
            )
        )

    return degree_step_entry(
        config, backend_key=("streaming", mesh, tuple(data_axes)), jitted_builder=build
    )


# ---------------------------------------------------------------------------
# The streaming fit driver
# ---------------------------------------------------------------------------


def fit(
    source,
    config: OAVIConfig = OAVIConfig(),
    *,
    chunk_rows: int = DEFAULT_CHUNK_ROWS,
    mesh: Optional[Mesh] = None,
    data_axes: Sequence[str] = ("data",),
    prefetch: bool = True,
) -> OAVIModel:
    """Run OAVI over a chunked :class:`~repro.streaming.source.DataSource`
    (or array-like) without ever materializing the evaluation matrix.

    Same semantics as :func:`repro.core.oavi.fit` — bit-exact against it at
    matched capacity for any power-of-two ``chunk_rows`` that is a multiple
    of :data:`repro.kernels.ops.GRAM_BLOCK` (and against
    :func:`repro.core.distributed.fit` on the same ``mesh`` when sharded).
    ``source`` must yield data in ``[0, 1]^n`` (compose with
    :class:`~repro.streaming.source.ScaledSource`).

    ``prefetch`` double-buffers the host->device pipeline: chunk ``i+1`` is
    read, permuted, padded and transferred by a worker thread while chunk
    ``i``'s jitted accumulator runs (:func:`prefetch_map`).  The fold order
    is unchanged, so the result is bit-identical with it on or off.
    """
    source = as_source(source)
    chunk_rows = _check_chunk_rows(chunk_rows)
    dtype = config.jax_dtype()
    np_dtype = _np_dtype(config.dtype)
    m, n = source.num_rows, source.num_features
    axes = tuple(data_axes)
    stats = init_fit_stats(
        m, n, streaming={"chunk_rows": chunk_rows, "num_chunks": 0, "passes": 0}
    )
    if mesh is not None:
        stats["mesh"] = {a: int(mesh.shape[a]) for a in mesh.axis_names}
        stats["data_axes"] = list(axes)
    backend = "streaming" if mesh is None else "streaming_sharded"

    with FitScope(stats, backend=backend) as scope:
        perm = None
        if config.ordering in ("pearson", "reverse_pearson"):
            perm = streaming_pearson_order(
                source, chunk_rows, reverse=(config.ordering == "reverse_pearson")
            )

        book = terms_mod.TermBook(n=n)
        generators: List[Generator] = []

        Lcap = pow2_bucket(config.cap_terms)
        state = ihb_mod.init_state(
            Lcap, jnp.asarray(1.0, dtype), dtype, factors=config.ihb_factors()
        )
        ell = 1

        # sharded layout: the SAME contiguous per-shard row spans as the
        # in-memory distributed fit, so per-shard partials (and their psum)
        # are bit-identical to it
        if mesh is not None:
            shards = num_data_shards(mesh, axes)
            m_pad = ((m + shards - 1) // shards) * shards
            span = m_pad // shards
            dspec = data_spec(axes)
            chunk_sharding = NamedSharding(mesh, dspec)
            mask_sharding = NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))
            acc_sharding = NamedSharding(
                mesh, P(axes if len(axes) > 1 else axes[0], None, None)
            )
            rep_sharding = NamedSharding(mesh, P())
            state = jax.device_put(state, rep_sharding)
            stats["m_padded"] = m_pad
        else:
            shards = 1
            span = m

        entry = _streaming_stats_entry(config, mesh, axes)
        m_total = jnp.asarray(float(m), dtype)
        steps_per_pass = max((span + chunk_rows - 1) // chunk_rows, 1)

        def load_step(i: int) -> Tuple[np.ndarray, np.ndarray]:
            """Host-side chunk assembly for global step ``i``: each shard's
            rows ``[s*span + i*c, ...)`` of its span, zero-padded, plus the
            row mask."""
            c = chunk_rows
            rows = np.zeros((shards * c, n), np_dtype)
            mask = np.zeros((shards * c,), np_dtype)
            for s in range(shards):
                lo = s * span + i * c
                hi = min(lo + c, (s + 1) * span, m)
                if lo >= hi:
                    continue
                block = np.asarray(source.read(lo, hi))
                if perm is not None:
                    block = block[:, perm]
                rows[s * c : s * c + hi - lo] = block
                mask[s * c : s * c + hi - lo] = 1.0
            return rows, mask

        d = 0
        while True:
            d += 1
            if d > config.max_degree:
                stats["termination"] = f"max_degree={config.max_degree}"
                break
            border = book.border(d)
            if not border:
                stats["termination"] = "empty_border"
                break
            K = len(border)
            stats["border_sizes"].append(K)
            stats["degrees"].append(d)

            # capacity management: only the O(Lcap^2) state grows — there is
            # no (m, Lcap) buffer to regrow, which is the whole point
            while ell + K > Lcap:
                Lcap *= 2
                scope.regrowth(Lcap)
                state = ihb_mod.grow_state(state, Lcap)
                if mesh is not None:
                    state = jax.device_put(state, rep_sharding)

            Kcap = max(config.cap_border, pow2_bucket(K))
            parents, vars_, valid = border_index_arrays(book, border, Kcap)

            acc_fn, acc_seen, acc_new = _chunk_accumulator(
                book, config, Lcap, chunk_rows, mesh, axes
            )
            # a fresh accumulator fn (acc_new) starts with an empty ``seen``,
            # so its first signature always counts — same rule as before
            acc_sig = (Kcap, chunk_rows, n, str(dtype))
            scope.note_signature(acc_seen, acc_sig, kind="fit/compile_accumulator")
            sig = (Lcap, Kcap, str(dtype))
            scope.note_signature(entry.seen, sig)

            # HLO cost of the degree = accumulator flops x chunk count plus
            # the stats step, lowered from abstract shapes (the real buffers
            # only exist inside the degree window).  The accumulator re-lowers
            # each degree because its jitted fn is book-specific — the same
            # degree already pays a full jit trace + compile for it, so the
            # extra lowering rides an inherently cold path.
            sample_chunks = obs.device.device_enabled()
            if sample_chunks:
                aval = jax.ShapeDtypeStruct
                f32 = jnp.float32
                rows_cap = shards * chunk_rows if mesh is not None else chunk_rows
                if mesh is None:
                    acc_shapes = ((Lcap, Kcap), (Kcap, Kcap))
                else:
                    acc_shapes = ((shards, Lcap, Kcap), (shards, Kcap, Kcap))
                idx_aval = aval((Kcap,), jnp.int32)
                acc_avals = (
                    aval(acc_shapes[0], f32), aval(acc_shapes[1], f32),
                    aval((rows_cap, n), dtype), aval((rows_cap,), dtype),
                    idx_aval, idx_aval,
                )
                state_avals = jax.tree_util.tree_map(
                    lambda x: aval(jnp.shape(x), x.dtype), state
                )
                step_avals = (
                    aval(acc_shapes[0], f32), aval(acc_shapes[1], f32),
                    state_avals, aval((), jnp.int32),
                    aval((Kcap,), jnp.bool_), aval((), dtype),
                )
                acc_cost = obs.device.step_cost(
                    acc_fn, ("acc", len(book), shards) + acc_sig, acc_avals
                )
                st_cost = obs.device.step_cost(entry.fn, sig, step_avals)
                flops = None
                if acc_cost is not None or st_cost is not None:
                    flops = (
                        (acc_cost["flops"] if acc_cost else 0.0) * steps_per_pass
                        + (st_cost["flops"] if st_cost else 0.0)
                    )
                scope.record_flops(flops)
            else:
                scope.record_flops(None)

            with scope.degree(d, K=K):
                parents_d = jnp.asarray(parents)
                vars_d = jnp.asarray(vars_)
                if mesh is None:
                    accQL = jnp.zeros((Lcap, Kcap), jnp.float32)
                    accC = jnp.zeros((Kcap, Kcap), jnp.float32)
                else:
                    accQL = jax.device_put(
                        jnp.zeros((shards, Lcap, Kcap), jnp.float32), acc_sharding
                    )
                    accC = jax.device_put(
                        jnp.zeros((shards, Kcap, Kcap), jnp.float32), acc_sharding
                    )

                def stage(i: int):
                    rows, mask = load_step(i)
                    if mesh is None:
                        return jnp.asarray(rows), jnp.asarray(mask)
                    return (
                        jax.device_put(rows, chunk_sharding),
                        jax.device_put(mask, mask_sharding),
                    )

                with obs.span("streaming/accumulate", d=d, chunks=steps_per_pass):
                    for rows_d, mask_d in prefetch_map(
                        stage, range(steps_per_pass), enabled=prefetch
                    ):
                        accQL, accC = acc_fn(
                            accQL, accC, rows_d, mask_d, parents_d, vars_d
                        )
                        if sample_chunks:
                            # chunk-boundary memory timeline (gauges + trace
                            # counter); intra-degree peaks are invisible to
                            # the per-degree sample alone
                            obs.device.sample_memory(stats)
                stats["streaming"]["num_chunks"] += steps_per_pass
                stats["streaming"]["passes"] += 1

                st = entry.fn(
                    accQL,
                    accC,
                    state,
                    jnp.asarray(ell, jnp.int32),
                    jnp.asarray(valid),
                    m_total,
                )
                state = st.ihb
                accepted = np.asarray(st.accepted)
                mses = np.asarray(st.mses)
                coeffs = np.asarray(st.coeffs)
                iters = np.asarray(st.iters)
            stats["solver_iters"].append(int(iters[:K].sum()))

            ell = collect_degree(book, border, accepted, mses, coeffs, generators)

        scope.finalize(book, generators, Lcap, config)
    return OAVIModel(
        n=n,
        psi=config.psi,
        book=book,
        generators=generators,
        feature_perm=perm,
        stats=stats,
        dtype=config.dtype,
    )


# ---------------------------------------------------------------------------
# Class-batched streaming fit: k out-of-core fits, ONE vmapped stats step
# ---------------------------------------------------------------------------


def _streaming_class_entry(config: OAVIConfig, schedule):
    """Cached jitted ``vmap`` of the statistics-only degree step over a class
    axis; ``schedule`` (oracle/WIHB configs) is part of the cache key so each
    escalation level is its own compiled step."""
    return degree_step_entry(
        config,
        backend_key=("streaming_class_batch", schedule),
        jitted_builder=lambda: jax.jit(
            jax.vmap(_make_stats_degree_step(config, schedule=schedule))
        ),
    )


def fit_classes(
    sources: Sequence,
    config: OAVIConfig = OAVIConfig(),
    *,
    chunk_rows: int = DEFAULT_CHUNK_ROWS,
    prefetch: bool = True,
) -> List[OAVIModel]:
    """Fit one OAVI model per class out-of-core, with every class's
    accept/reject decisions batched through ONE vmapped statistics-only
    degree step per degree.

    Unlike the in-memory class batch (:mod:`repro.core.class_batch`) there is
    no shared row bucket and no row padding at all: each class streams its
    own rows through its own chunk accumulator (the per-degree O(m_c) work is
    inherently per-class), and only the m-independent acceptance loops — the
    dispatch-bound part of a streaming fit — are stacked into ``(k, Lcap,
    Kcap)`` statistics and decided in one dispatch.  Finished classes ride
    along with all-``False`` validity masks (their zeroed accumulators make
    the slice a bitwise no-op); oracle/WIHB configs run the fixed-schedule
    solvers with the same budget-escalation protocol as the in-memory batch
    (the stats step donates nothing, so re-dispatch is safe).

    Bit-exact against per-class :func:`fit` calls at matched capacity (the
    shared ``Lcap`` growth schedule — the accumulated statistics themselves
    are per-class and identical by construction).  Local backend only; the
    sharded streaming path stays per-class.
    """
    from ..core import class_batch as class_batch_mod
    from ..core import oracles as oracles_mod

    sources = [as_source(s) for s in sources]
    chunk_rows = _check_chunk_rows(chunk_rows)
    if not class_batchable(config):
        raise ValueError(
            "config is not class-batchable (inverse_engine='chol' batched "
            "triangular solves are not vmap-bit-stable); use sequential fits"
        )
    if len(sources) == 0:
        return []
    if len(sources) == 1:
        # mirror class_batch.fit_classes: a lone class rides with a discarded
        # duplicate so results are independent of batch composition at k=1
        return fit_classes(
            [sources[0], sources[0]], config,
            chunk_rows=chunk_rows, prefetch=prefetch,
        )[:1]
    k = len(sources)
    n = sources[0].num_features
    if any(s.num_features != n for s in sources):
        raise ValueError("all classes must share one feature count n")
    ms = [s.num_rows for s in sources]
    dtype = config.jax_dtype()
    np_dtype = _np_dtype(config.dtype)

    group = next(class_batch_mod._GROUP_IDS)
    batch = {
        "group": group,
        "size": k,
        "recompiles": 0,
        "regrowths": 0,
        "degree_times": [],
        "m": int(sum(ms)),
        "n": n,
    }
    scope = FitScope(batch, backend="streaming_class_batch")
    with scope:
        perms: List[Optional[np.ndarray]] = []
        for s in sources:
            perm = None
            if config.ordering in ("pearson", "reverse_pearson"):
                perm = streaming_pearson_order(
                    s, chunk_rows, reverse=(config.ordering == "reverse_pearson")
                )
            perms.append(perm)

        books = [terms_mod.TermBook(n=n) for _ in range(k)]
        generators: List[List[Generator]] = [[] for _ in range(k)]
        ells = [1] * k
        active = [True] * k

        Lcap = pow2_bucket(config.cap_terms)
        state = ihb_mod.batch_state(
            ihb_mod.init_state(
                Lcap, jnp.asarray(1.0, dtype), dtype, factors=config.ihb_factors()
            ),
            k,
        )
        schedule = (
            oracles_mod.schedule_budget(config.solver)
            if class_batch_mod.needs_solver_schedule(config)
            else None
        )
        batch["solver_escalations"] = 0

        m_total = jnp.asarray([float(m) for m in ms], dtype)
        per_class = [
            init_fit_stats(
                ms[c], n,
                streaming={"chunk_rows": chunk_rows, "num_chunks": 0, "passes": 0},
            )
            for c in range(k)
        ]

        d = 0
        while any(active):
            d += 1
            if d > config.max_degree:
                for c in range(k):
                    if active[c]:
                        per_class[c]["termination"] = f"max_degree={config.max_degree}"
                break
            borders: List[List] = []
            for c in range(k):
                b = books[c].border(d) if active[c] else []
                if active[c] and not b:
                    active[c] = False
                    per_class[c]["termination"] = "empty_border"
                borders.append(b)
            if not any(active):
                break
            Ks = [len(b) for b in borders]
            for c in range(k):
                if borders[c]:
                    per_class[c]["border_sizes"].append(Ks[c])
                    per_class[c]["degrees"].append(d)

            while max(ells[c] + Ks[c] for c in range(k)) > Lcap:
                Lcap *= 2
                scope.regrowth(Lcap)
                state = ihb_mod.grow_state(state, Lcap)
            Kcap = max(config.cap_border, pow2_bucket(max(Ks)))
            valid = np.zeros((k, Kcap), bool)

            with scope.degree(d, K=int(max(Ks)), k=k):
                # per-class accumulation: each class streams its own rows
                # through its own (book-keyed) chunk accumulator — identical
                # statistics to its single-class streaming fit
                accQLs = []
                accCs = []
                for c in range(k):
                    if not borders[c]:
                        accQLs.append(jnp.zeros((Lcap, Kcap), jnp.float32))
                        accCs.append(jnp.zeros((Kcap, Kcap), jnp.float32))
                        continue
                    parents_c, vars_c, valid[c] = border_index_arrays(
                        books[c], borders[c], Kcap
                    )
                    acc_fn, acc_seen, _ = _chunk_accumulator(
                        books[c], config, Lcap, chunk_rows, None, ()
                    )
                    scope.note_signature(
                        acc_seen, (Kcap, chunk_rows, n, str(dtype)),
                        kind="fit/compile_accumulator",
                    )
                    accQL, accC, nchunks = accumulate_source_range(
                        acc_fn,
                        sources[c],
                        0,
                        ms[c],
                        chunk_rows,
                        (
                            jnp.zeros((Lcap, Kcap), jnp.float32),
                            jnp.zeros((Kcap, Kcap), jnp.float32),
                        ),
                        jnp.asarray(parents_c),
                        jnp.asarray(vars_c),
                        perm=perms[c],
                        np_dtype=np_dtype,
                        prefetch=prefetch,
                    )
                    per_class[c]["streaming"]["num_chunks"] += nchunks
                    per_class[c]["streaming"]["passes"] += 1
                    accQLs.append(accQL)
                    accCs.append(accC)

                accQL_b = jnp.stack(accQLs)
                accC_b = jnp.stack(accCs)
                ells_d = jnp.asarray(ells, jnp.int32)
                valid_d = jnp.asarray(valid)

                # ONE vmapped stats step for all classes; escalate the solver
                # schedule while any valid lane's budget was cut short
                while True:
                    entry = _streaming_class_entry(config, schedule)
                    csig = (k, Lcap, Kcap, str(dtype), schedule)
                    cargs = (accQL_b, accC_b, state, ells_d, valid_d, m_total)
                    scope.note_signature(entry.seen, csig)
                    scope.step_cost(entry.fn, csig, cargs)
                    st = entry.fn(*cargs)
                    if schedule is None or not bool(
                        np.any(jax.device_get(st.unconverged))
                    ):
                        break
                    if schedule >= oracles_mod.max_schedule(config.solver):
                        break
                    schedule = oracles_mod.escalate_schedule(config.solver, schedule)
                    batch["solver_escalations"] += 1
                state = st.ihb
                accepted, mses, coeffs, iters = jax.device_get(
                    (st.accepted, st.mses, st.coeffs, st.iters)
                )

            for c in range(k):
                if not borders[c]:
                    continue
                per_class[c]["solver_iters"].append(int(iters[c, : Ks[c]].sum()))
                ells[c] = collect_degree(
                    books[c], borders[c], accepted[c], mses[c], coeffs[c],
                    generators[c],
                )

        batch["solver_schedule_len"] = schedule
        if schedule is not None:
            obs.registry().gauge(
                "fit.solver_schedule_len", backend="streaming_class_batch"
            ).set(float(schedule))
        if batch["solver_escalations"]:
            obs.registry().counter(
                "fit.solver_escalations", backend="streaming_class_batch"
            ).inc(batch["solver_escalations"])
        models: List[OAVIModel] = []
        for c in range(k):
            stats = per_class[c]
            stats["recompiles"] = batch["recompiles"]
            stats["regrowths"] = batch["regrowths"]
            stats["degree_times"] = list(batch["degree_times"])
            stats["flops_per_degree"] = list(batch.get("flops_per_degree", []))
            stats["solver_schedule_len"] = schedule
            stats["solver_escalations"] = batch["solver_escalations"]
            stats["class_batch"] = {
                "group": batch["group"],
                "size": k,
                "index": c,
                "m_cap": None,  # streaming: no shared row bucket, no row padding
                "streaming": True,
                "recompiles": batch["recompiles"],
                "regrowths": batch["regrowths"],
            }
            scope.finalize(books[c], generators[c], Lcap, config, stats=stats)
            models.append(
                OAVIModel(
                    n=n,
                    psi=config.psi,
                    book=books[c],
                    generators=generators[c],
                    feature_perm=perms[c],
                    stats=stats,
                    dtype=config.dtype,
                )
            )
    return models
