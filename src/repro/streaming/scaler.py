"""One-pass streaming min-max scaling — bit-exact vs the in-memory scaler.

Min and max are exactly associative and commutative reductions (no rounding
ever occurs), so accumulating per-chunk extrema in any chunking produces the
*identical* ``lo`` / ``scale`` statistics as
:meth:`repro.core.transform.MinMaxScaler.fit` on the materialized array; the
(inherited) elementwise ``transform`` is then bit-identical row for row in
every output dtype it threads (f32 / bf16 / f16 / f64).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core.transform import MinMaxScaler
from .source import DataSource, as_source, iter_chunks

DEFAULT_CHUNK_ROWS = 4096


@dataclasses.dataclass
class StreamingMinMaxScaler(MinMaxScaler):
    """Min-max scaling fitted one chunk at a time.

    ``partial_fit`` folds a chunk's extrema into the running statistics and
    refreshes ``lo`` / ``scale``, so the scaler is usable (and serializable,
    via the inherited fields) after any prefix of the stream; ``fit_source``
    drives one full pass over a :class:`~repro.streaming.source.DataSource`.
    The in-memory ``fit(X)`` still works and resets the stream state.
    """

    hi: Optional[np.ndarray] = None

    def reset(self) -> "StreamingMinMaxScaler":
        self.lo = self.hi = self.scale = None
        return self

    def partial_fit(self, chunk) -> "StreamingMinMaxScaler":
        chunk = np.asarray(chunk, dtype=np.float64)
        if chunk.shape[0] == 0:
            return self
        lo = chunk.min(axis=0)
        hi = chunk.max(axis=0)
        if self.hi is None or self.lo is None:
            self.lo, self.hi = lo, hi
        else:
            self.lo = np.minimum(self.lo, lo)
            self.hi = np.maximum(self.hi, hi)
        rng = self.hi - self.lo
        self.scale = np.where(rng > 0, 1.0 / np.maximum(rng, 1e-300), 0.0)
        return self

    def fit(self, X) -> "StreamingMinMaxScaler":
        return self.reset().partial_fit(X)

    def fit_source(
        self, source: DataSource, chunk_rows: int = DEFAULT_CHUNK_ROWS
    ) -> "StreamingMinMaxScaler":
        """One pass over ``source``; only the padded trailing chunk's valid
        rows enter the statistics."""
        self.reset()
        for chunk, valid in iter_chunks(as_source(source), chunk_rows):
            self.partial_fit(chunk[:valid])
        if self.lo is None:
            raise ValueError("cannot fit a scaler on an empty source")
        return self
