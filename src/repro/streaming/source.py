"""Chunked data sources for out-of-core OAVI.

A :class:`DataSource` exposes random-access row reads over a dataset whose
rows may live anywhere — an in-memory array, a directory of memory-mapped
``.npy`` shards (written by :func:`repro.data.synthetic.write_shards`), or a
deterministic generator that synthesizes rows on demand.  The streaming fit
driver (:mod:`repro.streaming.fit`) only ever touches a source through
:func:`iter_chunks`, which yields fixed-size power-of-two row chunks (the
trailing chunk zero-padded with its valid-row count), so device buffers stay
O(chunk) no matter how large ``num_rows`` is.

All sources yield *raw* rows; compose with :class:`ScaledSource` (wrapping a
fitted :class:`repro.core.transform.MinMaxScaler` or its streaming twin) to
feed the fit the ``[0, 1]^n`` data OAVI expects.
"""

from __future__ import annotations

import json
import os
from typing import Callable, Dict, Iterator, Optional, Protocol, Set, Tuple, runtime_checkable

import numpy as np

from ..resilience.integrity import IntegrityError, verify_file

SHARD_FORMAT = "repro.shards.v1"
SHARD_META = "meta.json"


def _npy_rows(fname: str) -> int:
    """Row count of a ``.npy`` file from its header alone (mmap: no data
    is actually read).  A zero-length or header-mangled file — the residue
    of a torn write — raises :class:`IntegrityError` naming it instead of
    whatever parse error numpy hits first."""
    if os.path.getsize(fname) == 0:
        raise IntegrityError(
            f"{fname}: zero-length shard file (torn write?)", path=fname
        )
    try:
        arr = np.load(fname, mmap_mode="r")
    except Exception as e:
        # np.load surfaces header damage as ValueError/OSError/EOFError but
        # also as SyntaxError/TokenError out of its header ast parse — any
        # failure to read an existing non-empty .npy file is corruption
        raise IntegrityError(
            f"{fname}: unreadable shard file ({e}) — torn or corrupt write",
            path=fname,
        ) from e
    return int(arr.shape[0]) if arr.ndim else 0


@runtime_checkable
class DataSource(Protocol):
    """Random-access row reads; the whole streaming subsystem's data contract."""

    num_rows: int
    num_features: int

    def read(self, start: int, stop: int) -> np.ndarray:
        """Rows ``[start, stop)`` as a ``(stop - start, num_features)`` array."""
        ...


def is_source(obj) -> bool:
    """Duck-typed source check (used by :func:`repro.api.fit` dispatch)."""
    return (
        hasattr(obj, "read")
        and hasattr(obj, "num_rows")
        and hasattr(obj, "num_features")
    )


def as_source(obj) -> DataSource:
    """Pass sources through; wrap array-likes in :class:`ArraySource`."""
    if is_source(obj):
        return obj
    return ArraySource(np.asarray(obj))


def iter_chunks(
    source: DataSource,
    chunk_rows: int,
    start: int = 0,
    stop: Optional[int] = None,
) -> Iterator[Tuple[np.ndarray, int]]:
    """Fixed-size chunks over ``source`` rows ``[start, stop)``.

    Yields ``(chunk, valid)`` where ``chunk`` is always exactly
    ``(chunk_rows, n)`` — the trailing chunk is zero-padded — and ``valid``
    is the number of real rows in it.  Zero padding composes with the
    blocked Gram reduction as a bitwise no-op (see
    :func:`repro.kernels.ops.gram_accumulate`).
    """
    stop = source.num_rows if stop is None else stop
    n = source.num_features
    for lo in range(start, stop, chunk_rows):
        hi = min(lo + chunk_rows, stop)
        rows = source.read(lo, hi)
        valid = hi - lo
        if valid < chunk_rows:
            padded = np.zeros((chunk_rows, n), rows.dtype)
            padded[:valid] = rows
            rows = padded
        yield rows, valid


class ArraySource:
    """In-memory array as a source (views, no copies)."""

    def __init__(self, X):
        self.X = np.asarray(X)
        if self.X.ndim != 2:
            raise ValueError(f"expected (m, n) data, got shape {self.X.shape}")
        self.num_rows = int(self.X.shape[0])
        self.num_features = int(self.X.shape[1])

    def read(self, start: int, stop: int) -> np.ndarray:
        return self.X[start:stop]


class ShardDirSource:
    """A directory of ``shard_%05d.npy`` files + ``meta.json``, opened with
    ``mmap_mode='r'`` so reads touch only the requested rows — the on-disk
    layout written by :func:`repro.data.synthetic.write_shards`.

    The directory may *grow* while the source is open
    (``write_shards(..., append=True)`` adds shard files and then atomically
    rewrites ``meta.json``): :meth:`refresh` re-reads the metadata and picks
    up the new rows in place, validating that every shard file the new
    metadata promises actually exists with the advertised row count — a
    partial write (shards without a committed meta, or a meta naming missing
    shards) fails loudly instead of serving truncated data.

    **Content integrity**: ``meta.json`` written by current ``write_shards``
    carries a CRC32 + byte length per shard; with ``verify_checksums=True``
    (the default) each shard file is verified against them once, right
    before its first rows are served — a flipped bit or truncation raises
    :class:`~repro.resilience.integrity.IntegrityError` naming the file.
    Lazy (first-read) verification keeps opening a huge directory O(1);
    :meth:`verify_all` forces the full pass (operator audit / chaos
    harness).  Shards whose recorded checksum is ``None`` (pre-checksum
    directories) are tolerated unverified.
    """

    def __init__(self, path: str, verify_checksums: bool = True):
        self.path = path
        self.verify_checksums = verify_checksums
        self._mmaps: Dict[int, np.ndarray] = {}
        self._verified: Set[int] = set()
        self._load_meta(validate=True)

    def _load_meta(self, validate: bool) -> None:
        with open(os.path.join(self.path, SHARD_META)) as f:
            meta = json.load(f)
        if meta.get("format") != SHARD_FORMAT:
            raise ValueError(
                f"{self.path!r} is not a {SHARD_FORMAT} shard directory "
                f"(format={meta.get('format')!r})"
            )
        self.meta: Dict = meta
        self.num_rows = int(meta["num_rows"])
        self.num_features = int(meta["num_features"])
        self.shard_rows = int(meta["shard_rows"])
        self.num_shards = int(meta["num_shards"])
        self.checksums = list(meta.get("checksums") or [])
        self.shard_bytes = list(meta.get("shard_bytes") or [])
        if validate:
            self._validate_meta()

    def _validate_meta(self) -> None:
        """meta.json row-count consistency: every promised shard exists and
        the per-shard row counts add up to ``num_rows`` (all shards full
        except possibly the last)."""
        expect_shards = max(
            (self.num_rows + self.shard_rows - 1) // self.shard_rows, 1
        )
        if self.num_shards != expect_shards:
            raise ValueError(
                f"{self.path!r}: meta.json is inconsistent — num_shards="
                f"{self.num_shards} but num_rows={self.num_rows} at "
                f"shard_rows={self.shard_rows} needs {expect_shards} shards "
                "(partial write?)"
            )
        total = 0
        for idx in range(self.num_shards):
            fname = os.path.join(self.path, f"shard_{idx:05d}.npy")
            if not os.path.exists(fname):
                raise ValueError(
                    f"{self.path!r}: meta.json promises shard_{idx:05d}.npy "
                    "but the file is missing (partial write?)"
                )
            rows = _npy_rows(fname)
            expect = min(self.shard_rows, self.num_rows - idx * self.shard_rows)
            if rows < expect:
                raise ValueError(
                    f"{self.path!r}: shard_{idx:05d}.npy has {rows} rows, "
                    f"meta.json needs {expect} (partial write?)"
                )
            total += min(rows, expect)
        if total != self.num_rows:
            raise ValueError(
                f"{self.path!r}: shard files cover {total} rows, meta.json "
                f"says num_rows={self.num_rows} (partial write?)"
            )

    def refresh(self) -> int:
        """Re-read ``meta.json`` and pick up rows appended since the source
        was opened (no re-open needed: existing shard mmaps stay valid, new
        ``shard_%05d.npy`` files are mapped on first read).  Returns the
        number of new rows.  A shard that grew in place (the previously-last,
        partial shard rewritten fuller) is remapped."""
        old_rows, old_shards = self.num_rows, self.num_shards
        self._load_meta(validate=True)
        if self.num_rows < old_rows:
            raise ValueError(
                f"{self.path!r}: refresh() saw num_rows shrink "
                f"{old_rows} -> {self.num_rows}; shard dirs may only grow"
            )
        # the old trailing shard may have been rewritten with more rows
        # (append into a partial shard): drop its cached mmap and its
        # verified mark — the rewritten file has a new checksum
        if self.num_rows > old_rows and old_shards >= 1:
            self._mmaps.pop(old_shards - 1, None)
            self._verified.discard(old_shards - 1)
        return self.num_rows - old_rows

    def _verify_shard(self, idx: int) -> None:
        """Checksum-verify shard ``idx`` once, before its rows are served.
        No-op when disabled, already verified, or unrecorded (None entry)."""
        if not self.verify_checksums or idx in self._verified:
            return
        expected = self.checksums[idx] if idx < len(self.checksums) else None
        if expected is not None:
            nbytes = self.shard_bytes[idx] if idx < len(self.shard_bytes) else None
            verify_file(
                os.path.join(self.path, f"shard_{idx:05d}.npy"), expected, nbytes
            )
        self._verified.add(idx)

    def verify_all(self) -> int:
        """Checksum-verify every shard now (full data read); returns the
        number of shards with recorded checksums that were checked."""
        checked = 0
        for idx in range(self.num_shards):
            had = idx < len(self.checksums) and self.checksums[idx] is not None
            self._verify_shard(idx)
            checked += int(had)
        return checked

    def _shard(self, idx: int) -> np.ndarray:
        mm = self._mmaps.get(idx)
        if mm is None:
            self._verify_shard(idx)
            fname = os.path.join(self.path, f"shard_{idx:05d}.npy")
            mm = np.load(fname, mmap_mode="r")
            self._mmaps[idx] = mm
        return mm

    def read(self, start: int, stop: int) -> np.ndarray:
        if not (0 <= start <= stop <= self.num_rows):
            raise IndexError(f"rows [{start}, {stop}) out of range {self.num_rows}")
        out = np.empty((stop - start, self.num_features), np.dtype(self.meta["dtype"]))
        pos = start
        while pos < stop:
            idx = pos // self.shard_rows
            lo = pos - idx * self.shard_rows
            hi = min(self.shard_rows, lo + (stop - pos))
            out[pos - start : pos - start + hi - lo] = self._shard(idx)[lo:hi]
            pos += hi - lo
        return out


class SyntheticSource:
    """Generator-backed source: rows are synthesized on demand from a
    deterministic per-tile generator, so arbitrarily large datasets occupy no
    storage at all.

    ``tile_fn(tile_idx)`` must return the full ``(tile_rows, n)`` tile for
    its index, deterministically — reads slice tiles, so any chunking of the
    row range sees the identical values (the chunk-size-invariance the
    bit-exactness guarantees rest on).  The last produced tile is cached,
    which makes sequential chunk scans at any ``chunk_rows <= tile_rows`` (or
    multiples) cheap.
    """

    def __init__(
        self,
        tile_fn: Callable[[int], np.ndarray],
        num_rows: int,
        num_features: int,
        tile_rows: int = 4096,
    ):
        self.tile_fn = tile_fn
        self.num_rows = int(num_rows)
        self.num_features = int(num_features)
        self.tile_rows = int(tile_rows)
        self._cache: Optional[Tuple[int, np.ndarray]] = None

    def _tile(self, idx: int) -> np.ndarray:
        if self._cache is not None and self._cache[0] == idx:
            return self._cache[1]
        tile = np.asarray(self.tile_fn(idx))
        if tile.shape != (self.tile_rows, self.num_features):
            raise ValueError(
                f"tile_fn({idx}) returned shape {tile.shape}, expected "
                f"({self.tile_rows}, {self.num_features})"
            )
        self._cache = (idx, tile)
        return tile

    def read(self, start: int, stop: int) -> np.ndarray:
        if not (0 <= start <= stop <= self.num_rows):
            raise IndexError(f"rows [{start}, {stop}) out of range {self.num_rows}")
        parts = []
        pos = start
        while pos < stop:
            idx = pos // self.tile_rows
            lo = pos - idx * self.tile_rows
            hi = min(self.tile_rows, lo + (stop - pos))
            parts.append(self._tile(idx)[lo:hi])
            pos += hi - lo
        if len(parts) == 1:
            return parts[0]
        return np.concatenate(parts, axis=0)


class ScaledSource:
    """A source composed with a fitted min-max scaler: reads are transformed
    chunk-by-chunk.  The transform is elementwise, so the scaled stream is
    bit-identical to scaling the materialized array."""

    def __init__(self, source: DataSource, scaler):
        if scaler.lo is None or scaler.scale is None:
            raise ValueError(
                "ScaledSource needs a *fitted* scaler; fit it first (e.g. "
                "StreamingMinMaxScaler.fit_source)"
            )
        self.source = as_source(source)
        self.scaler = scaler

    # delegate, don't cache: a growing wrapped source (ShardDirSource after
    # refresh()) must propagate its new row count through the wrapper
    @property
    def num_rows(self) -> int:
        return self.source.num_rows

    @property
    def num_features(self) -> int:
        return self.source.num_features

    def read(self, start: int, stop: int) -> np.ndarray:
        return self.scaler.transform(self.source.read(start, stop))
