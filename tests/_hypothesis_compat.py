"""Stand-in for ``hypothesis`` so test modules collect without it.

Seven test modules use hypothesis property tests.  The container does not
ship hypothesis, so a bare ``from hypothesis import given, ...`` aborts
*collection* of the whole module and takes every non-property test down with
it.  When the real package is importable this module is a no-op; otherwise it
installs a minimal fake into ``sys.modules`` whose ``@given`` replaces the
test body with ``pytest.skip(...)``, so property tests skip individually and
the rest of each module still runs.
"""

from __future__ import annotations

import sys
import types


class _Strategy:
    """Inert placeholder for any ``st.<name>(...)`` strategy expression."""

    def __init__(self, name: str = "strategy"):
        self._name = name

    def __call__(self, *args, **kwargs):
        return _Strategy(self._name)

    def __getattr__(self, name):  # st.integers(0, 5).filter(...), etc.
        return _Strategy(f"{self._name}.{name}")

    def __repr__(self):
        return f"<fake hypothesis {self._name}>"


def _given(*_args, **_kwargs):
    def decorate(fn):
        def skipper(*a, **k):
            import pytest

            pytest.skip("hypothesis is not installed")

        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        skipper.__module__ = fn.__module__
        skipper.is_hypothesis_test = True
        return skipper

    return decorate


def _settings(*_args, **_kwargs):
    # usable both as decorator factory and bare decorator
    if len(_args) == 1 and callable(_args[0]) and not _kwargs:
        return _args[0]
    return lambda fn: fn


def install() -> None:
    try:
        import hypothesis  # noqa: F401  (real package wins)

        return
    except ImportError:
        pass

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _Strategy(name)  # type: ignore[attr-defined]

    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    mod.assume = lambda *a, **k: True
    mod.note = lambda *a, **k: None
    mod.example = lambda *a, **k: (lambda fn: fn)
    mod.HealthCheck = types.SimpleNamespace(
        too_slow="too_slow", data_too_large="data_too_large", all=lambda: []
    )
    mod.strategies = strategies
    mod.__fake__ = True

    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
