import _hypothesis_compat
import numpy as np
import pytest

_hypothesis_compat.install()


@pytest.fixture(scope="session")
def appc_small():
    """Small Appendix-C synthetic dataset (train/test split)."""
    from repro.data import synthetic
    X, y = synthetic.appendix_c(m=3000, seed=0)
    return synthetic.train_test_split(X, y, test_frac=0.4, seed=0)


@pytest.fixture(scope="session")
def planted_cube():
    """[0,1]^4 points with one planted algebraic relation."""
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (1200, 4))
    X[:, 3] = X[:, 0] * X[:, 1] + rng.normal(0, 0.01, 1200)
    X[:, 3] = np.clip(X[:, 3], 0, 1)
    return X
