"""Unified estimator API tests: registry, dispatch, serialization, fused FT."""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.transform import MinMaxScaler, feature_transform as legacy_transform


@pytest.fixture(scope="module")
def planted():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (900, 4)).astype(np.float32)
    X[:, 3] = np.clip(X[:, 0] * X[:, 1] + rng.normal(0, 0.01, 900), 0, 1)
    return X


@pytest.fixture(scope="module")
def fitted_models(planted):
    """One model per registered family, fitted on the planted-cube data."""
    return {
        "oavi": api.fit(planted, method="oavi:fast", psi=0.005, cap_terms=64),
        "abm": api.fit(planted, method="abm", psi=0.005, cap_terms=64),
        "vca": api.fit(planted, method="vca", psi=0.005),
    }


# -- registry ---------------------------------------------------------------


def test_available_methods_lists_all_families():
    specs = api.available_methods()
    assert {"oavi", "abm", "vca"} <= set(specs)
    assert "oavi:cgavi-ihb" in specs and "oavi:bpcgavi-wihb" in specs


def test_resolve_spec_strings():
    entry, variant = api.resolve("oavi:bpcgavi-wihb")
    assert entry.name == "oavi" and variant == "bpcgavi-wihb"
    entry, variant = api.resolve("oavi")
    assert entry.name == "oavi" and variant == entry.default_variant
    entry, variant = api.resolve("abm")
    assert entry.name == "abm" and variant is None


def test_resolve_legacy_bare_variant_names():
    for legacy in ("fast", "cgavi-ihb"):
        entry, variant = api.resolve(legacy)
        assert entry.name == "oavi" and variant == legacy


def test_resolve_unknown_method_errors():
    with pytest.raises(ValueError, match="unknown method"):
        api.resolve("nope")
    with pytest.raises(ValueError, match="unknown variant"):
        api.resolve("oavi:nope")
    with pytest.raises(ValueError, match="unknown method"):
        api.resolve("nope:fast")
    with pytest.raises(TypeError):
        api.resolve(123)


def test_register_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        api.register("oavi")(lambda X, **kw: None)


def test_variants_alias_matches_api():
    from repro.core import pipeline

    assert pipeline.VARIANTS is api.OAVI_VARIANTS


# -- fit + protocol -----------------------------------------------------------


def test_fit_returns_protocol_models(fitted_models):
    for name, model in fitted_models.items():
        assert isinstance(model, api.VanishingIdealModel), name
        assert model.num_G > 0
        assert model.stats["api"]["method"].startswith(name)
        assert model.stats["api"]["backend"] == "local"  # 1 device, small m
        feats = model.transform(np.asarray([[0.5, 0.5, 0.5, 0.25]]))
        assert feats.shape == (1, model.num_G)
        assert (feats >= 0).all()


def test_fit_unknown_backend_errors(planted):
    with pytest.raises(ValueError, match="unknown backend"):
        api.fit(planted, method="oavi:fast", backend="gpu-cluster")


def test_sharded_backend_rejected_for_non_oavi(planted):
    for method in ("abm", "vca"):
        with pytest.raises(ValueError, match="does not support"):
            api.fit(planted, method=method, backend="sharded")


def test_fit_with_prebuilt_config(planted):
    from repro.core.oavi import OAVIConfig

    cfg = OAVIConfig(psi=0.01, engine="fast", cap_terms=64, ordering="none")
    model = api.fit(planted, method="oavi", config=cfg)
    assert model.psi == 0.01


# -- backend dispatch on a fake 8-device CPU mesh ----------------------------


def _run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_backend_auto_dispatch_8_devices_subprocess():
    """auto + mesh routes to sharded; leading terms identical to local."""
    out = _run_sub("""
        import numpy as np, jax
        from repro import api
        rng = np.random.default_rng(0)
        X = rng.uniform(0, 1, (1003, 4))  # not divisible by 8 -> padding path
        X[:, 3] = np.clip(X[:, 0] * X[:, 1] + rng.normal(0, 0.01, 1003), 0, 1)
        mesh = jax.make_mesh((8,), ("data",))
        kw = dict(psi=0.005, cap_terms=64, ordering="none")
        local = api.fit(X, method="oavi:fast", backend="local", **kw)
        autod = api.fit(X, method="oavi:fast", backend="auto", mesh=mesh, **kw)
        shard = api.fit(X, method="oavi:fast", backend="sharded", **kw)  # default mesh
        assert autod.stats["api"]["backend"] == "sharded", autod.stats["api"]
        assert shard.stats["mesh"] == {"data": 8}, shard.stats["mesh"]
        # auto without a mesh on small m stays local even with 8 devices
        small = api.fit(X[:200], method="oavi:fast", backend="auto", **kw)
        assert small.stats["api"]["backend"] == "local", small.stats["api"]
        for dist in (autod, shard):
            assert [g.term for g in dist.generators] == \
                   [g.term for g in local.generators]
        print("DISPATCH-OK")
    """)
    assert "DISPATCH-OK" in out


# -- save / load round trip ---------------------------------------------------


@pytest.mark.parametrize("kind", ["oavi", "abm", "vca"])
def test_save_load_bit_identical_transform(fitted_models, planted, kind, tmp_path):
    model = fitted_models[kind]
    path = str(tmp_path / kind)
    committed = api.save(model, path)
    assert os.path.exists(os.path.join(committed, "COMMITTED"))
    restored = api.load(path)
    assert type(restored) is type(model)
    assert restored.num_G == model.num_G
    Z = np.linspace(0, 1, 4 * 257).reshape(257, 4).astype(np.float32)
    a, b = model.transform(Z), restored.transform(Z)
    assert a.dtype == b.dtype
    assert np.array_equal(a, b), "round trip must be bit-identical"


def test_model_save_method_and_load(fitted_models, tmp_path):
    model = fitted_models["oavi"]
    model.save(str(tmp_path / "m"))
    restored = api.load(str(tmp_path / "m"))
    assert [g.term for g in restored.generators] == \
           [g.term for g in model.generators]


@pytest.mark.parametrize("dtype", ["bfloat16", "float16"])
def test_save_load_roundtrip_non_float32_dtype(planted, dtype, tmp_path):
    """Extension dtypes ("bfloat16" is not a plain-numpy name) must survive
    generator_arrays, the checkpoint store, and the transform bit-exactly."""
    model = api.fit(
        planted, method="oavi:fast", psi=0.005, cap_terms=64, dtype=dtype
    )
    assert model.num_G > 0
    C, gp, gv = model.generator_arrays()
    assert C.dtype == np.dtype(jnp.dtype(dtype))
    path = str(tmp_path / f"m_{dtype}")
    api.save(model, path)
    restored = api.load(path)
    assert restored.dtype == dtype
    for gm, gr in zip(model.generators, restored.generators):
        assert np.array_equal(
            np.asarray(gm.coeffs, np.float32), np.asarray(gr.coeffs, np.float32)
        )
    Z = planted[:200]
    a, b = model.transform(Z), restored.transform(Z)
    assert a.dtype == b.dtype == np.dtype(jnp.dtype(dtype))
    assert np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_load_missing_and_foreign_checkpoints(tmp_path):
    with pytest.raises(FileNotFoundError):
        api.load(str(tmp_path / "nothing"))
    from repro.checkpoint import store

    store.save(str(tmp_path / "foreign"), 0, {"w": np.zeros(3)})
    with pytest.raises(ValueError, match="not a repro.vanishing_ideal_model"):
        api.load(str(tmp_path / "foreign"))


# -- fused batched transform --------------------------------------------------


def test_fused_transform_matches_legacy(fitted_models, planted):
    models = [fitted_models["oavi"], fitted_models["abm"]]
    rng = np.random.default_rng(3)
    Z = rng.uniform(0, 1, (777, 4)).astype(np.float32)
    ref = legacy_transform(models, Z)
    fused = api.feature_transform(models, Z)
    assert fused.shape == ref.shape and fused.dtype == ref.dtype
    np.testing.assert_allclose(fused, ref, rtol=1e-5, atol=1e-6)


def test_fused_transform_batching_exact(fitted_models):
    models = [fitted_models["oavi"], fitted_models["abm"]]
    rng = np.random.default_rng(4)
    Z = rng.uniform(0, 1, (1001, 4)).astype(np.float32)  # uneven trailing chunk
    whole = api.feature_transform(models, Z)
    chunked = api.feature_transform(models, Z, batch_size=256)
    assert np.array_equal(np.asarray(whole), np.asarray(chunked))


def test_fused_transform_batch_size_one_bit_identical(fitted_models):
    """batch_size=1 must not drop chunks into XLA's single-row gemv lowering
    (different accumulation order): output stays bit-identical to direct."""
    models = [fitted_models["oavi"]]
    Z = np.random.default_rng(8).uniform(0, 1, (5, 4)).astype(np.float32)
    whole = api.feature_transform(models, Z)
    one = api.feature_transform(models, Z, batch_size=1)
    assert np.array_equal(np.asarray(whole), np.asarray(one))
    single = api.feature_transform(models, Z[:1])
    assert np.array_equal(np.asarray(single), np.asarray(whole)[:1])


def test_fused_transform_vca_fallback(fitted_models):
    """VCA has no term book: feature_transform falls back to the loop."""
    models = [fitted_models["vca"]]
    rng = np.random.default_rng(5)
    Z = rng.uniform(0, 1, (128, 4)).astype(np.float32)
    np.testing.assert_array_equal(
        api.feature_transform(models, Z), legacy_transform(models, Z)
    )


def test_fused_transform_empty_models():
    Z = np.zeros((7, 4), np.float32)
    out = api.feature_transform([], Z)
    assert out.shape == (7, 0)


def test_fused_transform_respects_pearson_permutation(planted):
    """Models fitted with feature reordering must evaluate new points in
    ORIGINAL coordinates — the fused plan folds each model's permutation in."""
    m1 = api.fit(planted, method="oavi:fast", psi=0.005, cap_terms=64,
                 ordering="pearson")
    m2 = api.fit(planted, method="oavi:fast", psi=0.005, cap_terms=64,
                 ordering="reverse_pearson")
    rng = np.random.default_rng(6)
    Z = rng.uniform(0, 1, (333, 4)).astype(np.float32)
    ref = legacy_transform([m1, m2], Z)
    fused = api.feature_transform([m1, m2], Z)
    np.testing.assert_allclose(fused, ref, rtol=1e-5, atol=1e-6)


# -- dtype consistency --------------------------------------------------------


def test_minmax_scaler_dtype_threading():
    X = np.random.default_rng(0).normal(size=(50, 3))
    assert MinMaxScaler().fit_transform(X).dtype == np.float64  # legacy default
    assert MinMaxScaler(dtype="float32").fit_transform(X).dtype == np.float32


def test_feature_transform_dtype_matches_model(fitted_models):
    Z = np.random.default_rng(1).uniform(0, 1, (64, 4))
    for name, model in fitted_models.items():
        legacy = legacy_transform([model], Z)
        fused = np.asarray(api.feature_transform([model], Z))
        assert legacy.dtype == np.dtype(model.dtype), name
        assert fused.dtype == np.dtype(model.dtype), name


def test_pipeline_dtype_consistency(planted):
    from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier

    y = (planted[:, 0] > 0.5).astype(int)
    clf = VanishingIdealClassifier(
        PipelineConfig(method="oavi:fast", psi=0.005, oavi_kw={"cap_terms": 64})
    )
    clf.fit(planted, y)
    assert clf.scaler.transform(planted).dtype == np.float32
    assert clf.transform(planted).dtype == np.float32
