"""Class-batched OAVI tests: bit-exactness vs the sequential path, done
masking, bucket grouping, classifier integration, stats aggregation, and the
sharded (vmap-inside-shard_map) composition.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import api
from repro.core import class_batch, oavi
from repro.core.class_batch import class_buckets, fit_classes
from repro.core.oavi import OAVIConfig, class_batchable
from repro.core.pipeline import PipelineConfig, VanishingIdealClassifier
from repro.data.synthetic import _planted_class, random_cube, uci_like, train_test_split

CFG = OAVIConfig(psi=0.005, engine="fast", cap_terms=64)


def _classes(k, m, n=4, seed=0):
    return [
        np.clip(
            _planted_class(np.random.default_rng(seed + c), m, n, degree=2 + (c % 2)),
            0,
            1,
        ).astype(np.float32)
        for c in range(k)
    ]


def _assert_bit_exact(a: oavi.OAVIModel, b: oavi.OAVIModel):
    assert a.book.terms == b.book.terms
    assert [g.term for g in a.generators] == [g.term for g in b.generators]
    for ga, gb in zip(a.generators, b.generators):
        assert np.array_equal(ga.coeffs, gb.coeffs), ga.term
        assert ga.mse == gb.mse, ga.term


def _assert_structure(a: oavi.OAVIModel, b: oavi.OAVIModel, tol=1e-4):
    assert a.book.terms == b.book.terms
    assert [g.term for g in a.generators] == [g.term for g in b.generators]
    for ga, gb in zip(a.generators, b.generators):
        np.testing.assert_allclose(ga.coeffs, gb.coeffs, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# core: batched vs sequential
# ---------------------------------------------------------------------------


def test_batchable_gate():
    assert class_batchable(CFG)
    # oracle and WIHB configs batch through the fixed-schedule solvers now;
    # only the Cholesky inverse (batched triangular solves are not
    # vmap-bit-stable) stays sequential
    assert class_batchable(OAVIConfig(engine="oracle"))
    assert class_batchable(OAVIConfig(engine="fast", wihb=True))
    assert not class_batchable(OAVIConfig(engine="fast", inverse_engine="chol"))
    assert not class_batchable(OAVIConfig(engine="oracle", inverse_engine="chol"))
    with pytest.raises(ValueError):
        fit_classes([np.zeros((4, 2))], OAVIConfig(engine="fast", inverse_engine="chol"))


def test_batched_equals_sequential_bit_exact_equal_sizes():
    """Equal pow2 class sizes: no row padding, so the batched fit must
    reproduce the plain sequential fit bit for bit."""
    Xs = _classes(k=4, m=512)
    seq = [oavi.fit(X, CFG) for X in Xs]
    bat = fit_classes(Xs, CFG)
    assert all(m.num_G > 0 for m in bat)
    for s, b in zip(seq, bat):
        _assert_bit_exact(s, b)


def test_batched_uneven_sizes_matched_capacity():
    """Uneven sizes: structure-exact vs the unpadded sequential fit, and
    bit-exact vs the matched-capacity reference (same m_cap, k=1)."""
    sizes = [300, 500, 1003, 2048]
    Xs = [
        np.clip(_planted_class(np.random.default_rng(7 + i), m, 4), 0, 1).astype(
            np.float32
        )
        for i, m in enumerate(sizes)
    ]
    bat = fit_classes(Xs, CFG)
    m_cap = bat[0].stats["class_batch"]["m_cap"]
    assert m_cap == 2048
    for i, (X, b) in enumerate(zip(Xs, bat)):
        # vs unpadded sequential: structure exact; coefficients carry the fp
        # drift of the zero-extended Gram reduction amplified through
        # (A^T A)^{-1} (cf. the distributed psum tolerance)
        _assert_structure(oavi.fit(X, CFG), b, tol=5e-2)
        ref = fit_classes([X], CFG, m_cap=m_cap)[0]  # matched-capacity ref
        _assert_bit_exact(ref, b)


def test_single_class_equals_sequential():
    """k=1 (internally ridden with a discarded copy): bit-exact vs
    sequential when m is already the bucket size."""
    X = _classes(k=1, m=256)[0]
    _assert_bit_exact(oavi.fit(X, CFG), fit_classes([X], CFG)[0])


def test_done_masking_early_vs_late_termination():
    """One class terminates at degree 1 (all candidates vanish) while the
    other runs to max_degree: the finished class's lanes are no-ops and both
    results match their sequential fits exactly."""
    rng = np.random.default_rng(0)
    cfg = OAVIConfig(psi=1e-5, engine="fast", cap_terms=64, max_degree=3)
    X_const = (0.5 + 1e-4 * rng.standard_normal((256, 3))).astype(np.float32)
    X_deep = random_cube(m=256, n=3, seed=1)
    bat = fit_classes([X_const, X_deep], cfg)
    assert bat[0].stats["termination"] == "empty_border"
    assert bat[0].stats["degrees"] == [1]
    assert bat[1].stats["termination"] == "max_degree=3"
    assert bat[1].stats["degrees"] == [1, 2, 3]
    for X, b in zip([X_const, X_deep], bat):
        _assert_bit_exact(oavi.fit(X, cfg), b)


def test_batched_warm_refit_zero_recompiles():
    Xs = _classes(k=3, m=256, seed=11)
    cold = fit_classes(Xs, CFG)
    assert cold[0].stats["recompiles"] >= 0  # may be warm from other tests
    warm = fit_classes(Xs, CFG)
    assert warm[0].stats["recompiles"] == 0
    assert all(m.stats["recompiles"] == 0 for m in warm)


def test_class_buckets_grouping():
    # greedy largest-first, padding <= 2x within a bucket
    assert class_buckets([512, 512, 512]) == {512: [0, 1, 2]}
    assert class_buckets([64, 70, 800]) == {1024: [2], 128: [0, 1]}
    assert class_buckets([100, 3000, 120, 2500]) == {4096: [1, 3], 128: [0, 2]}
    # every index appears exactly once
    buckets = class_buckets([5, 9, 17, 33, 65, 129])
    got = sorted(i for idxs in buckets.values() for i in idxs)
    assert got == list(range(6))


# ---------------------------------------------------------------------------
# api layer
# ---------------------------------------------------------------------------


def test_api_fit_classes_mixed_buckets_and_straggler():
    """Stragglers are folded into the nearest warm bucket, never sequential:
    [256, 250, 17] plans as ONE padded group, and every model reports its
    padding bill in stats['class_batch_padding']."""
    sizes = [256, 250, 17]
    Xs = [
        np.clip(_planted_class(np.random.default_rng(i), m, 4), 0, 1).astype(
            np.float32
        )
        for i, m in enumerate(sizes)
    ]
    models = api.fit_classes(Xs, "oavi:fast", psi=0.005)
    kinds = ["batched" if m.stats.get("class_batch") else "seq" for m in models]
    assert kinds == ["batched", "batched", "batched"]
    # class order is preserved
    for X, m in zip(Xs, models):
        assert m.stats["m"] == X.shape[0]
    for m in models:
        pad = m.stats["class_batch_padding"]
        assert pad["m_cap"] == 256
        assert pad["padded_rows"] == 256 - m.stats["m"]
        assert pad["group_rows"] == sum(sizes)
        assert 0.0 <= pad["waste"] < 1.0
    agg = api.aggregate_fit_stats(models)
    assert agg["class_batched"] == 3
    assert agg["class_batch_groups"] == 1
    # one shared group: its recompile count is counted exactly once
    assert agg["recompiles"] == models[0].stats["recompiles"]


def test_plan_class_groups():
    from repro.core.class_batch import plan_class_groups

    # near-boundary buckets merge within the padding budget
    assert plan_class_groups([256, 250, 17]) == [(256, [0, 1, 2])]
    # a lone class still gets folded (never a size-1 group)
    plans = plan_class_groups([1000, 900, 400, 40, 3])
    assert all(len(idxs) >= 2 for _, idxs in plans)
    assert sorted(i for _, idxs in plans for i in idxs) == list(range(5))
    # single class: one group is fine (fit_classes rides it with a copy)
    assert plan_class_groups([128]) == [(128, [0])]
    # far-apart buckets stay separate when merging would blow the pad limit
    plans = plan_class_groups([4096, 4000, 100, 90, 80])
    assert len(plans) == 2
    assert plans[0][1] == [0, 1] and plans[1][1] == [2, 3, 4]


def test_api_fit_list_dispatch_and_off():
    Xs = _classes(k=2, m=128, seed=3)
    models = api.fit(Xs, "oavi:fast", psi=0.005)
    assert len(models) == 2 and models[0].stats["api"]["class_batch"] is True
    off = api.fit_classes(Xs, "oavi:fast", psi=0.005, class_batch="off")
    assert all(m.stats.get("class_batch") is None for m in off)
    for a, b in zip(models, off):
        _assert_bit_exact(a, b)
    with pytest.raises(ValueError):
        api.fit_classes(Xs, "oavi:fast", class_batch="always")


def test_api_fit_classes_oracle_batched_and_abm_fallback():
    """Oracle-engine configs now ride the batched path (fixed-schedule
    solvers) bit-exactly; non-OAVI methods (abm) still fall back to
    sequential fits with identical results."""
    Xs = _classes(k=2, m=128, seed=5)
    auto = api.fit_classes(Xs, "oavi:cgavi-ihb", psi=0.005, cap_terms=64)
    off = api.fit_classes(
        Xs, "oavi:cgavi-ihb", psi=0.005, cap_terms=64, class_batch="off"
    )
    assert all(m.stats.get("class_batch") for m in auto)
    assert all(m.stats.get("class_batch") is None for m in off)
    assert all(m.stats["solver_schedule_len"] is not None for m in auto)
    assert all(m.stats["solver_escalations"] >= 0 for m in auto)
    for a, b in zip(auto, off):
        _assert_bit_exact(a, b)  # equal pow2 sizes: no row padding

    for method in ("abm",):
        auto = api.fit_classes(Xs, method, psi=0.005, cap_terms=64)
        off = api.fit_classes(Xs, method, psi=0.005, cap_terms=64, class_batch="off")
        assert all(m.stats.get("class_batch") is None for m in auto)
        for a, b in zip(auto, off):
            assert np.array_equal(
                np.asarray(a.transform(Xs[0])), np.asarray(b.transform(Xs[0]))
            )


def test_batched_oracle_engines_bit_exact():
    """Every oracle engine (and WIHB) through the batched path, bit-exact vs
    its sequential while_loop-ref fit at matched (pow2, padding-free) sizes."""
    from repro.core.oracles import OracleConfig

    Xs = _classes(k=3, m=256, seed=21)
    configs = [
        OAVIConfig(psi=0.005, engine="oracle",
                   solver=OracleConfig(name="bpcg"), ihb=True, cap_terms=64),
        OAVIConfig(psi=0.005, engine="oracle",
                   solver=OracleConfig(name="cg"), ihb=False, cap_terms=64),
        OAVIConfig(psi=0.005, engine="oracle",
                   solver=OracleConfig(name="agd"), ihb=True, cap_terms=64),
        OAVIConfig(psi=0.005, engine="fast", wihb=True, cap_terms=64),
    ]
    for cfg in configs:
        seq = [oavi.fit(X, cfg) for X in Xs]
        bat = fit_classes(Xs, cfg)
        for s, b in zip(seq, bat):
            _assert_bit_exact(s, b)
        assert bat[0].stats["solver_schedule_len"] is not None
        warm = fit_classes(Xs, cfg)
        assert warm[0].stats["recompiles"] == 0, cfg


# ---------------------------------------------------------------------------
# classifier integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def seeds_data():
    X, y = uci_like("seeds", seed=0)
    return train_test_split(X, y)


def test_classifier_class_batch_bit_identical(seeds_data):
    Xtr, ytr, Xte, yte = seeds_data
    on = VanishingIdealClassifier(
        PipelineConfig(method="fast", psi=0.005, class_batch="auto")
    ).fit(Xtr, ytr)
    off = VanishingIdealClassifier(
        PipelineConfig(method="fast", psi=0.005, class_batch="off")
    ).fit(Xtr, ytr)
    assert on.stats["class_batched"] == len(on.models)
    assert off.stats["class_batched"] == 0
    for a, b in zip(on.models, off.models):
        _assert_bit_exact(a, b)
    assert np.array_equal(on.predict(Xte), off.predict(Xte))


def test_classifier_phase_timings_and_aggregated_stats(seeds_data):
    Xtr, ytr, _, _ = seeds_data
    clf = VanishingIdealClassifier(PipelineConfig(method="fast", psi=0.005))
    clf.fit(Xtr, ytr)
    s = clf.stats
    for key in ("time_generators", "time_transform", "time_svm", "time_total"):
        assert s[key] >= 0.0
    assert s["time_total"] >= s["time_generators"] + s["time_transform"] + s["time_svm"] - 1e-6
    assert "recompiles" in s and "regrowths" in s
    assert len(s["per_class"]) == len(clf.models)


def test_classifier_warm_refit_zero_recompiles(seeds_data):
    """Regression: a warm multi-class refit through the batched path must
    compile nothing (shared global degree-step cache)."""
    Xtr, ytr, _, _ = seeds_data
    VanishingIdealClassifier(PipelineConfig(method="fast", psi=0.005)).fit(Xtr, ytr)
    warm = VanishingIdealClassifier(PipelineConfig(method="fast", psi=0.005)).fit(
        Xtr, ytr
    )
    assert warm.stats["class_batched"] == len(warm.models)
    assert warm.stats["recompiles"] == 0


def test_classifier_save_load_roundtrip_with_class_batch(seeds_data, tmp_path):
    Xtr, ytr, Xte, _ = seeds_data
    clf = VanishingIdealClassifier(
        PipelineConfig(method="fast", psi=0.005, class_batch="auto")
    ).fit(Xtr, ytr)
    path = str(tmp_path / "clf")
    clf.save(path)
    loaded = VanishingIdealClassifier.load(path)
    assert loaded.config.class_batch == "auto"
    assert np.array_equal(clf.predict(Xte), loaded.predict(Xte))


# ---------------------------------------------------------------------------
# sharded composition (subprocess so XLA fake devices don't leak)
# ---------------------------------------------------------------------------


def _run_sub(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_class_batched_sharded_4_devices_subprocess():
    """vmap-inside-shard_map: class-batched fit over a 4-device data mesh
    matches the local class-batched fit (structure exact, coefficients to
    psum reduction-order noise) with zero recompiles when warm."""
    out = _run_sub("""
        import numpy as np, jax
        from repro.core import class_batch
        from repro.core.oavi import OAVIConfig
        from repro.data.synthetic import _planted_class
        cfg = OAVIConfig(psi=0.005, engine="fast", cap_terms=64)
        Xs = [np.clip(_planted_class(np.random.default_rng(c), 1003, 4), 0, 1)
              .astype(np.float32) for c in range(3)]
        local = class_batch.fit_classes(Xs, cfg)
        mesh = jax.make_mesh((4,), ("data",))
        shard = class_batch.fit_classes(Xs, cfg, mesh=mesh)
        for ml, ms in zip(local, shard):
            assert ml.book.terms == ms.book.terms
            assert [g.term for g in ml.generators] == [g.term for g in ms.generators]
            for gl, gs in zip(ml.generators, ms.generators):
                np.testing.assert_allclose(gl.coeffs, gs.coeffs, rtol=5e-3, atol=2e-3)
        warm = class_batch.fit_classes(Xs, cfg, mesh=mesh)
        assert warm[0].stats["recompiles"] == 0, warm[0].stats
        print("OK", [m.num_G for m in shard])
    """)
    assert "OK" in out


def test_api_fit_classes_sharded_backend_subprocess():
    out = _run_sub("""
        import numpy as np, jax
        from repro import api
        from repro.data.synthetic import _planted_class
        Xs = [np.clip(_planted_class(np.random.default_rng(c), 512, 4), 0, 1)
              .astype(np.float32) for c in range(2)]
        mesh = jax.make_mesh((4,), ("data",))
        models = api.fit_classes(Xs, "oavi:fast", psi=0.005,
                                 backend="sharded", mesh=mesh)
        assert all(m.stats["api"]["backend"] == "sharded" for m in models)
        assert all(m.stats.get("class_batch") for m in models)
        local = api.fit_classes(Xs, "oavi:fast", psi=0.005, backend="local")
        for ml, ms in zip(local, models):
            assert ml.book.terms == ms.book.terms
        print("OK")
    """)
    assert "OK" in out
