"""Distributed OAVI (shard_map) and elastic checkpoint tests.

Multi-device cases run in a subprocess so the XLA fake-device flag does not
leak into the main pytest session (which must see 1 CPU device).
"""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import distributed, oavi
from repro.core.oavi import OAVIConfig


def _run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.abspath("src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_equals_single_device_one_shard():
    """mesh=(1,) exercises the full shard_map/psum path on one device."""
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (500, 4))
    X[:, 3] = np.clip(X[:, 0] * X[:, 1], 0, 1)
    cfg = OAVIConfig(psi=0.005, engine="fast", cap_terms=64, ordering="none")
    ref = oavi.fit(X, cfg)
    mesh = jax.make_mesh((1,), ("data",))
    dist = distributed.fit(X, cfg, mesh=mesh)
    assert [g.term for g in ref.generators] == [g.term for g in dist.generators]
    for gr, gd in zip(ref.generators, dist.generators):
        np.testing.assert_allclose(gr.coeffs, gd.coeffs, rtol=1e-4, atol=1e-5)


def test_distributed_8_shards_subprocess():
    out = _run_sub("""
        import numpy as np, jax
        from repro.core import oavi, distributed
        from repro.core.oavi import OAVIConfig
        rng = np.random.default_rng(0)
        X = rng.uniform(0, 1, (1003, 4))  # not divisible by 8 -> padding path
        X[:, 3] = np.clip(X[:, 0] * X[:, 1] + rng.normal(0, 0.01, 1003), 0, 1)
        cfg = OAVIConfig(psi=0.005, engine="fast", cap_terms=64, ordering="none")
        ref = oavi.fit(X, cfg)
        mesh = jax.make_mesh((8,), ("data",))
        dist = distributed.fit(X, cfg, mesh=mesh)
        assert [g.term for g in ref.generators] == [g.term for g in dist.generators]
        assert ref.book.terms == dist.book.terms
        # fp32 psum reduction-order noise amplified through (A^T A)^{-1}:
        # structure (terms) is exact; near-zero coefficients agree to ~1e-3
        for gr, gd in zip(ref.generators, dist.generators):
            np.testing.assert_allclose(gr.coeffs, gd.coeffs, rtol=5e-3, atol=2e-3)
        print("OK", dist.num_G, dist.num_O)
    """)
    assert "OK" in out


def test_distributed_2d_mesh_subprocess():
    """Samples sharded over BOTH mesh axes (pure-OAVI 2-axis run)."""
    out = _run_sub("""
        import numpy as np, jax
        from repro.core import oavi, distributed
        from repro.core.oavi import OAVIConfig
        rng = np.random.default_rng(1)
        X = rng.uniform(0, 1, (640, 3))
        cfg = OAVIConfig(psi=0.01, engine="fast", cap_terms=64, ordering="none")
        ref = oavi.fit(X, cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        dist = distributed.fit(X, cfg, mesh=mesh, data_axes=("data", "model"))
        assert [g.term for g in ref.generators] == [g.term for g in dist.generators]
        print("OK")
    """)
    assert "OK" in out


def test_elastic_checkpoint_resume_different_device_count(tmp_path):
    """Save on 1 device, restore on 4 (and back) — elastic re-shard."""
    ckpt = str(tmp_path / "ckpt")
    _run_sub(f"""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import store
        mesh = jax.make_mesh((4,), ("data",))
        x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                           NamedSharding(mesh, P("data", None)))
        store.save({ckpt!r}, 7, {{"w": x}})
        print("saved")
    """, devices=4)
    out = _run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import store
        mesh = jax.make_mesh((2,), ("data",))
        like = {{"w": jnp.zeros((8, 8))}}
        shardings = {{"w": NamedSharding(mesh, P("data", None))}}
        tree, meta = store.restore({ckpt!r}, 7, like, shardings)
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.arange(64.0).reshape(8, 8))
        assert len(tree["w"].sharding.device_set) == 2
        print("restored-elastic")
    """, devices=2)
    assert "restored-elastic" in out


def test_train_driver_multidevice_subprocess():
    """launch.train on a 4-device local mesh: loss decreases, checkpoints."""
    out = _run_sub("""
        import tempfile, os
        from repro import configs
        from repro.launch.train import train
        from repro.launch import mesh as mesh_mod
        from repro.optim import AdamW
        cfg = configs.get_reduced("qwen2-1.5b")
        mesh = mesh_mod.make_local_mesh(model_parallel=2)
        # the synthetic LCG grammar needs ~100 steps before the transition
        # map becomes visible in the loss (see repro.data.lm._grammar)
        opt = AdamW(peak_lr=3e-3, warmup_steps=10, total_steps=120)
        with tempfile.TemporaryDirectory() as d:
            report = train(cfg, steps=120, global_batch=4, seq_len=32,
                           ckpt_dir=os.path.join(d, "ck"), ckpt_every=40,
                           mesh=mesh, opt=opt)
            losses = report["losses"]
            head = sum(losses[:3]) / 3
            tail = sum(losses[-3:]) / 3
            assert tail < head, (head, tail)
            print("OK", round(head, 3), "->", round(tail, 3))
    """, devices=4)
    assert "OK" in out
