"""Unit tests for dry-run machinery that don't need 512 devices:
HLO collective parsing, batch/cache spec divisibility, shape registry."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch.hlo_analysis import _shape_bytes, collective_bytes
from repro.models import model as M


HLO_SAMPLE = """
HloModule test
  %x = bf16[256,4096]{1,0} parameter(0)
  %ar = bf16[256,4096]{1,0} all-reduce(bf16[256,4096]{1,0} %x), replica_groups={}
  %ag = f32[512,128]{1,0} all-gather(f32[256,128]{1,0} %y), dimensions={0}
  %rs = f32[128]{0} reduce-scatter(f32[512]{0} %z), dimensions={0}
  %a2a = (s32[64]{0}, s32[64]{0}) all-to-all(s32[64]{0} %a, s32[64]{0} %b)
  %cp-start = bf16[32,32]{1,0} collective-permute-start(bf16[32,32]{1,0} %c)
  %cp-done = bf16[32,32]{1,0} collective-permute-done(bf16[32,32]{1,0} %cp-start)
  %not-a-collective = f32[1024]{0} add(f32[1024]{0} %p, f32[1024]{0} %q)
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[256,4096]") == 256 * 4096 * 2
    assert _shape_bytes("f32[128]") == 512
    assert _shape_bytes("(s32[64], s32[64])") == 512
    assert _shape_bytes("pred[]") == 1  # scalar: one element


def test_collective_parser():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 256 * 4096 * 2
    assert out["all-gather"] == 512 * 128 * 4
    assert out["reduce-scatter"] == 128 * 4
    assert out["all-to-all"] == 64 * 4 * 2
    assert out["collective-permute"] == 32 * 32 * 2  # -start counted, -done not
    assert out["total"] == sum(
        out[k] for k in ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"))


@pytest.fixture()
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_batch_specs_divisibility(mesh11):
    cfg = configs.get_config("qwen3-8b")
    # batch 1 cannot shard over data=1? (divides trivially) — use a fake mesh
    # shape check via the helper directly
    assert M._batch_spec_entry(mesh11, 4) is not None
    specs = M.batch_specs(cfg, mesh11, "decode", 1)
    assert set(specs) == {"token", "pos"}


def test_cell_registry_counts():
    cells = configs.all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 31  # 9 documented skips
    skips = {(c[0], c[1]) for c in cells if not c[2]}
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("xlstm-1.3b", "long_500k") not in skips
    assert ("jamba-1.5-large-398b", "long_500k") not in skips


def test_input_specs_shapes():
    cfg = configs.get_config("qwen3-8b")
    sp = configs.input_specs(cfg, configs.SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4097)
    sp = configs.input_specs(cfg, configs.SHAPES["decode_32k"])
    assert sp["token"].shape == (128,)
    enc = configs.get_config("hubert-xlarge")
    sp = configs.input_specs(enc, configs.SHAPES["train_4k"])
    assert sp["frames"].shape == (256, 4096, 1280)
    assert sp["labels"].shape == (256, 4096)


def test_abstract_cache_shapes():
    cfg = configs.get_config("deepseek-v2-lite-16b")
    cache = M.abstract_cache(cfg, B=4, S_max=128)
    mla_leaf = cache["00_mla"]
    assert mla_leaf.c_kv.shape == (cfg.n_periods, 4, 128, 512)
    assert mla_leaf.k_pe.shape == (cfg.n_periods, 4, 128, 64)
    jam = configs.get_config("jamba-1.5-large-398b")
    cache = M.abstract_cache(jam, B=2, S_max=64)
    # mamba state cache: conv window + (di, ds) state
    key = [k for k in cache if "mamba" in k][0]
    assert cache[key].h.shape == (jam.n_periods, 2, 16384, 16)


def test_param_spec_rules_moe_expert_major():
    cfg = configs.get_config("kimi-k2-1t-a32b")
    ap = M.abstract_params(cfg)
    specs = M.param_specs(cfg, ap)
    moe_key = [k for k in specs["blocks"] if "moe" in k][0]
    assert tuple(specs["blocks"][moe_key]["w_in"]) == (None, "model", "data", None)
    attn_key = [k for k in specs["blocks"] if "attn" in k][0]
    assert tuple(specs["blocks"][attn_key]["wq"]) == (None, "data", "model")
    assert tuple(specs["final_norm"]) == ()
