"""Flight-recorder stack tests: device capture, SLO burn rates, the perf
baseline, the bench-history gate, and the report/aggregation plumbing.

Covers the contracts behind the device-level observability layer and the
regression gate:

* ``step_cost`` captures HLO cost once per (fn, signature) and returns None
  (without poisoning its cache) while device capture is disabled;
* ``CompileWindow`` attributes real XLA backend-compile seconds to a region;
* ``sample_memory`` feeds stats peaks and registry gauges from one sample;
* fit stats carry the device fields (``flops_per_degree`` /
  ``compile_seconds`` / ``achieved_gflops``);
* ``SLOMonitor`` fires when BOTH burn windows exceed the threshold and
  stops as soon as the short window drains;
* ``baseline.load_history`` tolerates a torn tail but refuses mid-file
  corruption; ``check_regression`` passes an unchanged tree and fails an
  injected 2x slowdown (metric and sketch bands);
* ``benchmarks.history`` flattens bench docs deterministically and
  ``run_gate`` applies the noise-floor and ``BENCH_SOFT`` escapes;
* ``obs_report`` keeps rendering over torn metric tails and emits
  machine-readable JSON;
* ``merge_traces`` produces a Perfetto-valid document with per-process
  tracks and harness markers (the chaos-export shape);
* solver-discipline stats survive ``api.aggregate_fit_stats`` into the
  classifier-level view and the metric registry.
"""

import json
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from benchmarks import history as bench_history
from repro import api, obs
from repro.obs import baseline, device, slo
from repro.obs.metrics import Histogram, Registry
from repro.launch import obs_report


@pytest.fixture(autouse=True)
def _clean_obs(monkeypatch):
    """Enabled, unsampled, empty recorder state; no soft-fail env leakage."""
    monkeypatch.delenv("BENCH_SOFT", raising=False)
    monkeypatch.delenv("OBS_DEVICE", raising=False)
    obs.configure(enabled=True, sample_every=1, jax_trace=False)
    obs.reset()
    yield
    obs.configure(enabled=True, sample_every=1)
    obs.reset()


# ---------------------------------------------------------------------------
# device: cost capture, compile windows, memory sampling, fit-stats contract


def test_step_cost_captured_once_per_signature():
    fn = jax.jit(lambda a: a @ a.T)
    x = jnp.ones((16, 4), dtype=jnp.float32)
    before = device.capture_stats()["captures"]
    cost = device.step_cost(fn, ("t", 16), (x,))
    assert cost is not None
    assert cost["flops"] > 0
    assert cost["bytes_accessed"] > 0
    assert cost["capture_s"] >= 0
    again = device.step_cost(fn, ("t", 16), (x,))
    assert again == cost
    assert device.capture_stats()["captures"] == before + 1  # cache hit


def test_step_cost_disabled_does_not_poison_cache():
    fn = jax.jit(lambda a: a * 2.0)
    x = jnp.ones((8,), dtype=jnp.float32)
    obs.configure(enabled=False)
    try:
        assert device.step_cost(fn, ("d", 8), (x,)) is None
    finally:
        obs.configure(enabled=True)
    # the disabled call must not have cached None for this signature
    cost = device.step_cost(fn, ("d", 8), (x,))
    assert cost is not None and cost["flops"] >= 0


def test_step_cost_accepts_shape_structs():
    # the serving engine captures per-bucket cost from avals, no real array
    fn = jax.jit(lambda a: jnp.tanh(a).sum(axis=1))
    aval = jax.ShapeDtypeStruct((32, 5), jnp.float32)
    cost = device.step_cost(fn, ("serve", 32), (aval,))
    assert cost is not None and cost["flops"] > 0


def test_compile_window_attributes_backend_compile():
    if not device._ensure_listener():
        pytest.skip("jax monitoring channel unavailable")
    fn = jax.jit(lambda a: jnp.sin(a) + jnp.cos(a))
    x = jnp.linspace(0.0, 1.0, 37)
    with device.CompileWindow() as cw:
        fn(x).block_until_ready()
    assert cw.count >= 1
    assert cw.seconds > 0.0
    with device.CompileWindow() as warm:
        fn(x).block_until_ready()
    assert warm.count == 0
    assert warm.seconds == 0.0


def test_sample_memory_updates_stats_and_gauges():
    keep = jnp.ones((64, 64), dtype=jnp.float32)
    keep.block_until_ready()
    stats = {}
    out = device.sample_memory(stats)
    assert out.get("live_bytes", 0) >= keep.nbytes
    assert stats["live_bytes_peak"] >= keep.nbytes
    snap = {r["name"] for r in obs.registry().snapshot()}
    assert "device.live_bytes" in snap
    assert "device.live_bytes_peak" in snap
    # peaks are monotone: a smaller later sample never lowers them
    peak = stats["live_bytes_peak"]
    device.sample_memory(stats)
    assert stats["live_bytes_peak"] >= peak


def test_fit_stats_carry_device_fields():
    rng = np.random.default_rng(0)
    X = rng.uniform(0.0, 1.0, (120, 3))
    model = api.fit(X, method="oavi", psi=0.1, max_degree=2)
    assert "flops_per_degree" in model.stats
    assert "compile_seconds" in model.stats
    assert "achieved_gflops" in model.stats
    assert model.stats["xla_compiles"] >= 0


def test_profile_window_noop_without_env(monkeypatch):
    monkeypatch.delenv("OBS_JAX_PROFILE", raising=False)
    w = device.profile_window("test")
    assert w is device._NOOP_WINDOW
    with w:
        pass  # no profiler started, no events emitted
    assert not [e for e in obs.trace_events()
                if e.get("name") == "device/profile_start"]


# ---------------------------------------------------------------------------
# SLO: burn-rate windows over the registry


def _slo_windows():
    return (slo.BurnWindow(long_s=60.0, short_s=5.0, max_burn=10.0),)


def test_error_objective_alerts_and_recovers():
    reg = Registry()
    bad = reg.counter("loop.update_failures")
    total = reg.counter("loop.updates_total")
    mon = slo.SLOMonitor(
        [slo.error_objective("errs", "loop.update_failures",
                             "loop.updates_total", budget_frac=0.01)],
        windows=_slo_windows(), registry=reg, now=lambda: 0.0,
    )
    assert mon.tick(now=0.0) == []
    for _ in range(100):
        total.inc()
    for _ in range(50):
        bad.inc()
    alerts = mon.tick(now=1.0)
    assert len(alerts) == 1
    assert alerts[0]["objective"] == "errs"
    assert alerts[0]["burn"] >= 10.0
    assert mon.alerting()
    # healthy traffic drains the short window -> alert clears even though
    # the long window still remembers the incident
    for _ in range(400):
        total.inc()
    assert mon.tick(now=10.0) == []
    assert not mon.alerting()
    state = mon.state()
    assert state["ticks"] == 3
    json.dumps(state)  # slo.json must serialize


def test_latency_objective_counts_samples_above_threshold():
    reg = Registry()
    h = reg.histogram("serve.seconds", backend="local")
    mon = slo.SLOMonitor(
        [slo.latency_objective("lat", "serve.seconds", threshold_s=0.1,
                               budget_frac=0.01, backend="local")],
        windows=_slo_windows(), registry=reg, now=lambda: 0.0,
    )
    mon.tick(now=0.0)  # baseline snapshot: burn rates need a delta
    for _ in range(90):
        h.observe(0.001)
    for _ in range(10):
        h.observe(0.5)  # 10% above threshold vs a 1% budget
    assert mon.tick(now=1.0)
    assert mon.alerting()
    obj = mon.state()["objectives"][0]
    assert obj["total"] == 100
    assert obj["bad"] == 10


def test_slo_requires_valid_budget():
    with pytest.raises(ValueError):
        slo.latency_objective("x", "m", threshold_s=0.1, budget_frac=0.0)
    with pytest.raises(ValueError):
        slo.error_objective("x", "b", "t", budget_frac=1.0)
    with pytest.raises(ValueError):
        slo.SLOMonitor([])


# ---------------------------------------------------------------------------
# baseline: history parsing + the regression decision


def _record(metrics=None, sketches=None):
    return {"schema": baseline.RECORD_SCHEMA,
            "metrics": metrics or {}, "sketches": sketches or {}}


def test_load_history_tolerates_torn_tail(tmp_path):
    p = tmp_path / "history.jsonl"
    p.write_text(json.dumps(_record({"a:t_s": 1.0})) + "\n"
                 + json.dumps(_record({"a:t_s": 1.1})) + "\n"
                 + '{"schema": "bench-history.v1", "metr')
    records, warnings = baseline.load_history(str(p))
    assert len(records) == 2
    assert any("torn tail" in w for w in warnings)


def test_load_history_raises_on_midfile_corruption(tmp_path):
    p = tmp_path / "history.jsonl"
    p.write_text('{"not json\n' + json.dumps(_record()) + "\n")
    with pytest.raises(ValueError, match="mid-file"):
        baseline.load_history(str(p))


def test_load_history_skips_foreign_schema(tmp_path):
    p = tmp_path / "history.jsonl"
    p.write_text(json.dumps({"schema": "bench-history.v99"}) + "\n"
                 + json.dumps(_record({"a:t_s": 1.0})) + "\n")
    records, warnings = baseline.load_history(str(p))
    assert len(records) == 1
    assert any("schema" in w for w in warnings)
    missing, warnings = baseline.load_history(str(tmp_path / "nope.jsonl"))
    assert missing == [] and warnings


def test_is_time_metric_recognizes_duration_leaves():
    assert baseline.is_time_metric("fit.quick/rows/0:t_fit_s")
    assert baseline.is_time_metric("obs/device/1:mean_capture_ms")
    assert baseline.is_time_metric("x/y/0:seconds")
    assert not baseline.is_time_metric("fit.quick/rows/0:flops")
    assert not baseline.is_time_metric("serve/rows/0:bytes")


def test_check_regression_passes_unchanged_and_fails_2x():
    key = "fit.quick/rows/0:t_fit_s"
    base = [_record({key: 1.0}), _record({key: 1.05})]
    ok = baseline.check_regression(_record({key: 1.02}), base)
    assert ok["status"] == "pass"
    assert ok["checked"] == 1 and not ok["findings"]
    bad = baseline.check_regression(_record({key: 2.0}), base)
    assert bad["status"] == "fail"
    (finding,) = bad["findings"]
    assert finding["kind"] == "metric" and finding["key"] == key
    assert finding["ratio"] == pytest.approx(2.0)
    assert finding["current"] > finding["allowed"]


def test_check_regression_spread_widens_allowance():
    key = "a/b/0:t_s"
    wobbly = [_record({key: 1.0}), _record({key: 1.6})]
    # 1.5x is over the flat 25% tolerance but inside the observed 1.6x
    # spread (times its margin) — a historically noisy metric must not flap
    verdict = baseline.check_regression(_record({key: 1.5}), wobbly)
    assert verdict["status"] == "pass"


def test_check_regression_skips_fast_and_thin_metrics():
    fast = "a/b/0:t_s"
    thin = "c/d/0:t_s"
    count = "a/b/0:rows"
    base = [_record({fast: 1e-4, count: 50.0}),
            _record({fast: 1e-4, count: 50.0})]
    base[0]["metrics"][thin] = 1.0  # only one history point
    verdict = baseline.check_regression(
        _record({fast: 1.0, thin: 9.9, count: 5000.0}), base)
    assert verdict["status"] == "insufficient"
    assert verdict["checked"] == 0
    assert any("timing floor" in s for s in verdict["skipped"])
    assert any("history point" in s for s in verdict["skipped"])


def test_check_regression_sketch_band():
    def sketch(scale):
        h = Histogram()
        for i in range(200):
            h.observe(scale * (0.05 + 0.001 * (i % 10)))
        return h.to_state()

    series = "serve.transform_seconds{backend=local}"
    base = [_record(sketches={series: sketch(1.0)}),
            _record(sketches={series: sketch(1.0)})]
    ok = baseline.check_regression(_record(sketches={series: sketch(1.02)}), base)
    assert ok["status"] == "pass"
    bad = baseline.check_regression(_record(sketches={series: sketch(2.0)}), base)
    assert bad["status"] == "fail"
    assert bad["findings"][0]["kind"] == "sketch"
    assert bad["findings"][0]["key"] == series


def test_merge_sketches_is_exact():
    h1, h2 = Histogram(), Histogram()
    for v in (0.01, 0.02, 0.04):
        h1.observe(v)
    for v in (0.08, 0.16):
        h2.observe(v)
    merged = baseline.merge_sketches(
        [_record(sketches={"s": h1.to_state()}),
         _record(sketches={"s": h2.to_state()}), _record()], "s")
    assert merged.count == 5
    assert merged.sum == pytest.approx(h1.sum + h2.sum)
    assert baseline.merge_sketches([_record()], "s") is None


# ---------------------------------------------------------------------------
# benchmarks.history: flattening, record collection, the gate CLI


def test_flatten_bench_keys_are_deterministic():
    doc = {"bench": "fit", "meta": {"quick": True},
           "rows": [{"section": "rows", "t_fit_s": 1.5, "m": 100,
                     "ok": True, "label": "x"},
                    {"section": "rows", "t_fit_s": 2.5, "m": 200}]}
    flat = bench_history.flatten_bench(doc)
    assert flat == {"fit.quick/rows/0:t_fit_s": 1.5,
                    "fit.quick/rows/0:m": 100.0,
                    "fit.quick/rows/1:t_fit_s": 2.5,
                    "fit.quick/rows/1:m": 200.0}
    doc["meta"]["quick"] = False
    assert all(k.startswith("fit.full/")
               for k in bench_history.flatten_bench(doc))


def test_collect_and_append_record_roundtrip(tmp_path):
    doc = {"bench": "fit", "schema": "bench.v1", "created_unix": 1.0,
           "meta": {"quick": True},
           "rows": [{"section": "rows", "t_fit_s": 1.0}]}
    (tmp_path / "BENCH_fit.json").write_text(json.dumps(doc))
    (tmp_path / "BENCH_torn.json").write_text('{"bench": "to')  # ignored
    obs.registry().histogram("fit.seconds", backend="t").observe(0.25)
    rec = bench_history.collect_record(str(tmp_path))
    assert rec["schema"] == baseline.RECORD_SCHEMA
    assert rec["benches"] == {
        "fit": {"created_unix": 1.0, "rows": 1, "meta": {"quick": True}}}
    assert rec["metrics"]["fit.quick/rows/0:t_fit_s"] == 1.0
    assert "fit.seconds{backend=t}" in rec["sketches"]
    assert rec["env"]["python"]
    path = tmp_path / "history.jsonl"
    bench_history.append_record(rec, str(path))
    bench_history.append_record(rec, str(path))
    records, warnings = baseline.load_history(str(path))
    assert len(records) == 2 and not warnings
    assert records[0]["metrics"] == rec["metrics"]


def _write_history(tmp_path, values):
    key = "fit.quick/rows/0:t_fit_s"
    path = tmp_path / "history.jsonl"
    with open(path, "w") as f:
        for v in values:
            f.write(json.dumps(_record({key: v})) + "\n")
    return str(path)


def test_run_gate_fails_injected_2x_slowdown(tmp_path, monkeypatch, capsys):
    monkeypatch.setattr(bench_history, "measure_noise_floor", lambda: 0.0)
    good = _write_history(tmp_path, [1.0, 1.05, 1.02])
    assert bench_history.run_gate(good) == 0
    slow = _write_history(tmp_path, [1.0, 1.05, 2.0])
    assert bench_history.run_gate(slow) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "FAILED" in out


def test_run_gate_escapes(tmp_path, monkeypatch, capsys):
    slow = _write_history(tmp_path, [1.0, 1.05, 2.0])
    # escape 1: the machine's noise floor cannot resolve the tolerance
    monkeypatch.setattr(bench_history, "measure_noise_floor", lambda: 0.5)
    assert bench_history.run_gate(slow) == 0
    assert "cannot resolve" in capsys.readouterr().out
    # escape 2: BENCH_SOFT downgrades the failure on constrained CI
    monkeypatch.setattr(bench_history, "measure_noise_floor", lambda: 0.0)
    monkeypatch.setenv("BENCH_SOFT", "1")
    assert bench_history.run_gate(slow) == 0
    assert "BENCH_SOFT" in capsys.readouterr().out


def test_run_gate_vacuous_pass_below_two_records(tmp_path, capsys):
    assert bench_history.run_gate(_write_history(tmp_path, [1.0])) == 0
    assert "vacuous" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# obs_report: torn-tail tolerance + machine-readable output


def test_report_tolerates_torn_metrics_tail(tmp_path):
    p = tmp_path / "metrics.jsonl"
    p.write_text(json.dumps({"name": "a", "type": "counter", "value": 1}) + "\n"
                 + '{"name": "b", "ty')
    rows, warnings = obs_report.load_metric_rows(str(p))
    assert [r["name"] for r in rows] == ["a"]
    assert any("torn tail" in w for w in warnings)


def test_report_raises_on_midfile_metrics_corruption(tmp_path):
    p = tmp_path / "metrics.jsonl"
    p.write_text('{"broken\n'
                 + json.dumps({"name": "a", "type": "counter", "value": 1})
                 + "\n")
    with pytest.raises(ValueError, match="corrupt"):
        obs_report.load_metric_rows(str(p))


def test_report_json_format(tmp_path, capsys):
    d = tmp_path / "obs"
    d.mkdir()
    (d / "metrics.jsonl").write_text(
        json.dumps({"name": "loop.updates_total", "labels": {},
                    "type": "counter", "value": 3}) + "\n")
    (d / "slo.json").write_text(json.dumps(
        {"objectives": [], "alerting": False, "ticks": 4, "t": 1.0}))
    obs.registry().counter("x").inc()
    with obs.span("work"):
        pass
    obs.export_trace(str(d / "trace.json"))
    obs_report.main(["--obs-dir", str(d), "--format", "json"])
    payload = json.loads(capsys.readouterr().out)
    assert payload["slo"]["ticks"] == 4
    assert payload["metrics"][0]["name"] == "loop.updates_total"
    assert payload["trace"]["events"] >= 1
    # absent slo.json (or a torn mid-replace read) degrades to None
    assert obs_report.load_slo(str(d / "missing.json")) is None
    (d / "torn.json").write_text('{"alert')
    assert obs_report.load_slo(str(d / "torn.json")) is None


# ---------------------------------------------------------------------------
# metrics: empty-sketch None semantics + sketch state round-trips


def test_empty_histogram_quantile_is_none():
    h = Histogram()
    assert h.quantile(0.99) is None
    assert h.count_above(0.0) == 0
    s = h.summary()
    assert s["count"] == 0 and s["sum"] == 0.0
    h.observe(0.5)
    assert h.quantile(0.99) is not None


def test_histogram_state_roundtrip_exact():
    h = Histogram()
    for v in (-1.0, 0.0, 0.001, 0.5, 12.0):
        h.observe(v)
    clone = Histogram.from_state(h.to_state())
    assert clone.count == h.count
    assert clone.sum == pytest.approx(h.sum)
    assert clone.min == h.min and clone.max == h.max
    for q in (0.0, 0.5, 0.99, 1.0):
        assert clone.quantile(q) == h.quantile(q)
    empty = Histogram.from_state(Histogram().to_state())
    assert empty.count == 0
    assert empty.min == math.inf and empty.max == -math.inf
    json.dumps(h.to_state())  # history.jsonl must serialize it


def test_merge_with_empty_operand_is_identity():
    h = Histogram()
    for v in (0.01, 0.5):
        h.observe(v)
    before = h.summary()
    h.merge(Histogram())  # empty right operand changes nothing
    assert h.summary() == before
    empty = Histogram()
    empty.merge(h)  # empty left operand adopts the other sketch exactly
    assert empty.summary() == before
    assert Histogram().merge(Histogram()).quantile(0.5) is None


def test_percentile_summary_unknown_and_empty_return_none():
    reg = Registry()
    assert reg.percentile_summary("no.such.metric") is None
    reg.histogram("h", backend="a")  # registered but empty
    assert reg.percentile_summary("h") is None
    reg.histogram("h", backend="a").observe(0.1)
    assert reg.percentile_summary("h", backend="b") is None  # label mismatch
    s = reg.percentile_summary("h", backend="a")
    assert s is not None and s["count"] == 1


def test_count_above_errs_pessimistic_by_one_bucket():
    h = Histogram()
    for _ in range(10):
        h.observe(0.001)
    for _ in range(5):
        h.observe(1.0)
    assert h.count_above(0.1) == 5
    assert h.count_above(2.0) == 0
    # threshold inside a bucket attributes that bucket as above
    assert h.count_above(0.00099) >= 10


# ---------------------------------------------------------------------------
# trace merge: the chaos-export shape (two processes + harness markers)


def test_merge_traces_two_processes_with_markers():
    def doc(pid, name):
        return {"traceEvents": [
            {"name": "process_name", "ph": "M", "ts": 0, "pid": pid,
             "tid": 0, "args": {"name": name}},
            {"name": "update", "ph": "X", "ts": 10.0, "dur": 5.0,
             "pid": pid, "tid": 1, "cat": "obs", "args": {}},
        ]}

    merged = obs.merge_traces(
        [doc(100, "killed"), doc(100, "resumed")],
        markers=[{"name": "chaos/sigkill", "after_doc": 0,
                  "args": {"phase": "update_start#1"}},
                 {"name": "chaos/recovery", "after_doc": 0, "args": {}}])
    obs.validate_chrome_trace(merged)
    spans = [e for e in merged["traceEvents"] if e.get("ph") == "X"]
    span_pids = {e["pid"] for e in spans}
    assert len(span_pids) == 2  # same-pid docs still get distinct tracks
    markers = {e["name"]: e for e in merged["traceEvents"]
               if e.get("ph") == "i"}
    assert set(markers) == {"chaos/sigkill", "chaos/recovery"}
    for m in markers.values():
        assert m["s"] == "g"
        assert m["pid"] not in span_pids  # harness track, not a controller
    # markers land in the gap between the killed and the resumed doc
    doc1_start = min(e["ts"] for e in spans if e["pid"] != 100)
    doc0_end = max(e["ts"] + e["dur"] for e in spans if e["pid"] == 100)
    for m in markers.values():
        assert doc0_end < m["ts"] < doc1_start


# ---------------------------------------------------------------------------
# api: solver-discipline stats survive aggregation into the registry


def test_solver_stats_survive_fit_classes_aggregation():
    rng = np.random.default_rng(0)
    Xs = [rng.normal(size=(40 + 13 * i, 3)) for i in range(3)]
    models = api.fit_classes(Xs, method="oavi:bpcgavi", psi=0.1, max_degree=2)
    for m in models:
        assert "solver_schedule_len" in m.stats
        assert "solver_escalations" in m.stats
        assert "class_batch_padding" in m.stats
    agg = api.aggregate_fit_stats(models)
    assert isinstance(agg["solver_schedule_len"], int)
    assert agg["solver_escalations"] >= 0
    pad = agg["class_batch_padding"]
    assert pad["dispatched_rows"] >= sum(X.shape[0] for X in Xs)
    assert pad["padded_rows"] == pad["dispatched_rows"] - sum(
        X.shape[0] for X in Xs)
    assert 0.0 <= pad["waste"] < 1.0
    named = {(r["name"], tuple(sorted((r.get("labels") or {}).items())))
             for r in obs.registry().snapshot()}
    assert ("fit.solver_schedule_len", (("backend", "aggregate"),)) in named
    assert ("fit.class_batch_padding_waste", ()) in named
    # group dedup: per-class padding is counted once per batch group
    doubled = api.aggregate_fit_stats(list(models) + list(models))
    assert doubled["class_batch_padding"] == pad
