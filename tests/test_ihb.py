"""IHB (Theorem 4.9) tests: block-inverse and Cholesky appends vs numpy."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ihb


def _grow_sequence(seed, m=300, steps=6, Lcap=16):
    """Simulate OAVI's column appends; compare the maintained inverse and
    Cholesky factor against direct numpy computation at every step."""
    rng = np.random.default_rng(seed)
    cols = [np.ones(m, np.float64)]
    state = ihb.init_state(Lcap, jnp.asarray(1.0, jnp.float64), jnp.float64)
    for step in range(steps):
        # new column correlated with existing ones but independent
        b = rng.uniform(0, 1, m) * cols[0] + 0.1 * rng.standard_normal(m)
        A = np.stack(cols, axis=1)
        q = np.zeros(Lcap)
        q[: A.shape[1]] = A.T @ b / m
        btb = b @ b / m
        ell = A.shape[1]
        state = ihb.append_column(
            state, jnp.asarray(q), jnp.asarray(btb), jnp.asarray(ell)
        )
        cols.append(b)
        Afull = np.stack(cols, axis=1)
        G = Afull.T @ Afull / m
        Ninv = np.linalg.inv(G)
        got = np.asarray(state.N)[: ell + 1, : ell + 1]
        yield step, Ninv, got, np.asarray(state.R)[: ell + 1, : ell + 1], G


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_inverse_update_matches_numpy(seed):
    for step, Ninv, got, R, G in _grow_sequence(seed):
        # fp32 (x64 disabled in this container) with growing kappa(G):
        # compare against the conditioning-aware tolerance the paper's own
        # stability discussion implies (IHB is a warm start, not an oracle)
        kappa = np.linalg.cond(G)
        tol = max(1e-4, 1e-6 * kappa)
        np.testing.assert_allclose(got, Ninv, rtol=tol, atol=tol)


@pytest.mark.parametrize("seed", [0, 1])
def test_cholesky_update_matches_numpy(seed):
    for step, Ninv, got, R, G in _grow_sequence(seed):
        np.testing.assert_allclose(R.T @ R, G, rtol=1e-4, atol=1e-6)
        # R upper triangular
        assert np.allclose(R, np.triu(R))


def test_closed_form_solution_is_least_squares():
    rng = np.random.default_rng(3)
    m, ell, Lcap = 500, 5, 8
    A = rng.uniform(0, 1, (m, ell))
    b = rng.uniform(0, 1, m)
    A[:, 0] = 1.0  # col 0 is the constant column seeded into the state
    state = ihb.init_state(Lcap, jnp.asarray(float(A[:, 0] @ A[:, 0] / m)), jnp.float64)
    for j in range(1, ell):
        q = np.zeros(Lcap)
        q[:j] = A[:, :j].T @ A[:, j] / m
        state = ihb.append_column(
            state, jnp.asarray(q), jnp.asarray(float(A[:, j] @ A[:, j] / m)),
            jnp.asarray(j),
        )
    qb = np.zeros(Lcap)
    qb[:ell] = A.T @ b / m
    y = np.asarray(ihb.closed_form_inverse(state, jnp.asarray(qb)))[:ell]
    y_np = -np.linalg.lstsq(A, b, rcond=None)[0]
    # fp32 + ill-conditioned A: compare the *residuals*, the numerically
    # meaningful quantity (coefficients can differ by kappa * eps while the
    # fit is equally good — exactly why the paper refines IHB with a solver)
    res_opt = np.linalg.norm(A @ y_np + b) ** 2 / m
    res_ihb = np.linalg.norm(A @ y + b) ** 2 / m
    assert res_ihb <= res_opt * (1 + 1e-3) + 1e-6
    y_chol = np.asarray(ihb.closed_form_cholesky(state, jnp.asarray(qb)))[:ell]
    res_chol = np.linalg.norm(A @ y_chol + b) ** 2 / m
    assert res_chol <= res_opt * (1 + 1e-3) + 1e-6
    # Cholesky path is the better-conditioned engine (kappa vs kappa^2)
    assert res_chol <= res_ihb * (1 + 1e-3)


def test_schur_guard_detects_dependence():
    """(INF)/singularity guard (§4.4.3): a linearly dependent column gives a
    ~zero Schur complement."""
    rng = np.random.default_rng(4)
    m, Lcap = 200, 8
    ones = np.ones(m)
    x = rng.uniform(0, 1, m)
    state = ihb.init_state(Lcap, jnp.asarray(1.0, jnp.float64), jnp.float64)
    q = np.zeros(Lcap)
    q[0] = ones @ x / m
    state = ihb.append_column(state, jnp.asarray(q), jnp.asarray(x @ x / m), jnp.asarray(1))
    # dependent column: b = 2x - 0.5*ones
    b = 2 * x - 0.5 * ones
    qb = np.zeros(Lcap)
    qb[0] = ones @ b / m
    qb[1] = x @ b / m
    s = float(ihb.schur_complement(state, jnp.asarray(qb), jnp.asarray(b @ b / m)))
    assert abs(s) < 1e-5
    # independent column: clearly positive
    c = rng.uniform(0, 1, m)
    qc = np.zeros(Lcap)
    qc[0] = ones @ c / m
    qc[1] = x @ c / m
    s2 = float(ihb.schur_complement(state, jnp.asarray(qc), jnp.asarray(c @ c / m)))
    assert s2 > 1e-4


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 8))
def test_property_inverse_consistency(seed, steps):
    """Property: after any append sequence, N @ AtA == I on the active block."""
    rng = np.random.default_rng(seed)
    m, Lcap = 150, 12
    cols = [np.ones(m)]
    state = ihb.init_state(Lcap, jnp.asarray(1.0, jnp.float64), jnp.float64)
    for j in range(1, steps + 1):
        b = rng.uniform(0, 1, m)
        A = np.stack(cols, axis=1)
        q = np.zeros(Lcap)
        q[:j] = A.T @ b / m
        state = ihb.append_column(
            state, jnp.asarray(q), jnp.asarray(b @ b / m), jnp.asarray(j)
        )
        cols.append(b)
    ell = len(cols)
    prod = np.asarray(state.N @ state.AtA)[:ell, :ell]
    np.testing.assert_allclose(prod, np.eye(ell), atol=5e-4)
