"""Per-kernel validation: shape/dtype sweeps, interpret=True vs ref oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# gram_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,L,n,K,bm", [
    (256, 8, 4, 8, 128),
    (512, 32, 8, 16, 256),
    (1000, 16, 3, 32, 512),   # padded m
    (128, 64, 16, 8, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_gram_update_shapes(m, L, n, K, bm, dtype):
    rng = np.random.default_rng(m + L + K)
    A = jnp.asarray(rng.uniform(0, 1, (m, L)), dtype)
    X = jnp.asarray(rng.uniform(0, 1, (m, n)), dtype)
    parents = jnp.asarray(rng.integers(0, L, K), jnp.int32)
    vars_ = jnp.asarray(rng.integers(0, n, K), jnp.int32)
    QL_k, C_k = ops.gram_update(A, X, parents, vars_, bm=bm, interpret=True)
    Psel, Vsel = ops.selection_matrices(parents, vars_, L, n, dtype)
    QL_r, C_r = ref.gram_update_ref(A, X, Psel, Vsel)
    np.testing.assert_allclose(QL_k, QL_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(C_k, C_r, rtol=1e-5, atol=1e-5)


def test_gram_matches_direct_gather():
    """The one-hot-matmul formulation == direct gather semantics."""
    rng = np.random.default_rng(0)
    m, L, n, K = 300, 12, 5, 9
    A = jnp.asarray(rng.uniform(0, 1, (m, L)), jnp.float32)
    X = jnp.asarray(rng.uniform(0, 1, (m, n)), jnp.float32)
    parents = jnp.asarray(rng.integers(0, L, K), jnp.int32)
    vars_ = jnp.asarray(rng.integers(0, n, K), jnp.int32)
    B = ref.border_columns_ref(A, X, parents, vars_)
    QL, C = ops.gram_update(A, X, parents, vars_, bm=128, interpret=True)
    np.testing.assert_allclose(QL, np.asarray(A.T @ B), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(C, np.asarray(B.T @ B), rtol=1e-4, atol=1e-5)


def test_gram_gather_ref_bit_exact_vs_onehot_ref():
    """The fast gather fallback and the one-hot kernel spec are *bit*
    identical: a one-hot matmul row sums exactly one value plus hard zeros,
    so the candidate columns (and hence both Grams) match bit for bit."""
    rng = np.random.default_rng(7)
    m, L, n, K = 400, 24, 6, 17
    A = jnp.asarray(rng.uniform(0, 1, (m, L)), jnp.float32)
    X = jnp.asarray(rng.uniform(0, 1, (m, n)), jnp.float32)
    parents = jnp.asarray(rng.integers(0, L, K), jnp.int32)
    vars_ = jnp.asarray(rng.integers(0, n, K), jnp.int32)
    Psel, Vsel = ops.selection_matrices(parents, vars_, L, n, jnp.float32)
    g_gather = ref.gram_update_gather_ref(A, X, parents, vars_)
    g_onehot = ref.gram_update_ref(A, X, Psel, Vsel)
    for a, b in zip(g_gather, g_onehot):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # and the off-TPU ops dispatch routes to the gather formulation
    g_ops = ops.gram_update(A, X, parents, vars_, use_pallas=False)
    for a, b in zip(g_gather, g_ops):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_gram_property_symmetry_psd(seed):
    rng = np.random.default_rng(seed)
    m, L, n, K = 200, 8, 4, 8
    A = jnp.asarray(rng.uniform(0, 1, (m, L)), jnp.float32)
    X = jnp.asarray(rng.uniform(0, 1, (m, n)), jnp.float32)
    parents = jnp.asarray(rng.integers(0, L, K), jnp.int32)
    vars_ = jnp.asarray(rng.integers(0, n, K), jnp.int32)
    _, C = ops.gram_update(A, X, parents, vars_, bm=128, interpret=True)
    C = np.asarray(C)
    np.testing.assert_allclose(C, C.T, atol=1e-5)  # symmetric
    evals = np.linalg.eigvalsh(C)
    assert evals.min() > -1e-3  # PSD up to fp noise


# ---------------------------------------------------------------------------
# ihb_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("L,ell", [(8, 3), (16, 7), (32, 20), (64, 1)])
def test_ihb_update_vs_ref(L, ell):
    rng = np.random.default_rng(L * 31 + ell)
    m = 200
    Araw = rng.uniform(0, 1, (m, ell)).astype(np.float32)
    G = Araw.T @ Araw / m + 1e-3 * np.eye(ell, dtype=np.float32)
    N = np.eye(L, dtype=np.float32)
    N[:ell, :ell] = np.linalg.inv(G)
    b = rng.uniform(0, 1, m).astype(np.float32)
    q = np.zeros(L, np.float32)
    q[:ell] = Araw.T @ b / m
    btb = np.float32(b @ b / m)
    got = ops.ihb_update(jnp.asarray(N), jnp.asarray(q), btb, ell, interpret=True)
    want = ref.ihb_update_ref(jnp.asarray(N), jnp.asarray(q), btb, ell)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,S,d,bq,bk", [
    (1, 2, 2, 128, 32, 64, 64),
    (2, 4, 2, 256, 32, 64, 64),     # GQA group 2
    (2, 8, 1, 128, 16, 64, 32),     # MQA
    (1, 2, 2, 192, 32, 64, 64),     # padded seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(B, Hq, Hkv, S, d, bq, bk, causal):
    rng = np.random.default_rng(B * 100 + S)
    q = jnp.asarray(rng.standard_normal((B, Hq, S, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, S, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, S, d)), jnp.float32)
    got = ops.multihead_attention(q, k, v, causal=causal, bq=bq, bk=bk, interpret=True)
    want = ops.multihead_attention(q, k, v, causal=causal, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_mla_vdim():
    """v head dim != qk head dim (MLA layout)."""
    rng = np.random.default_rng(5)
    B, H, S, d, dv = 1, 2, 128, 24, 16
    q = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, dv)), jnp.float32)
    got = ops.multihead_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = ops.multihead_attention(q, k, v, causal=True, use_pallas=False)
    assert got.shape == (B, H, S, dv)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(9)
    B, H, S, d = 1, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.bfloat16)
    got = ops.multihead_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    want = ops.multihead_attention(q, k, v, causal=True, use_pallas=False)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )


def test_flash_attention_causality():
    """Changing future tokens must not change past outputs."""
    rng = np.random.default_rng(11)
    B, H, S, d = 1, 2, 128, 32
    q = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.float32)
    out1 = ops.multihead_attention(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    k2 = k.at[:, :, 100:].set(1000.0)
    v2 = v.at[:, :, 100:].set(-7.0)
    out2 = ops.multihead_attention(q, k2, v2, causal=True, bq=64, bk=64, interpret=True)
    np.testing.assert_allclose(out1[:, :, :100], out2[:, :, :100], rtol=1e-5, atol=1e-5)
