"""Launch-layer smoke tests: train/serve drivers on single-device CPU."""

import os
import subprocess
import sys
import tempfile

import pytest

from repro import configs
from repro.launch.serve import serve
from repro.launch.train import train
from repro.optim import AdamW


def test_train_driver_reduced_config(tmp_path):
    """Driver mechanics: steps run, losses finite, checkpoints commit.
    (Same-batch loss descent is covered by test_models.test_arch_smoke_train_step;
    20 distinct 2x32-token batches are too few to show cross-batch descent.)"""
    import numpy as np
    cfg = configs.get_reduced("phi4-mini-3.8b")
    opt = AdamW(peak_lr=1e-3, warmup_steps=5, total_steps=20)
    report = train(cfg, steps=20, global_batch=2, seq_len=32,
                   ckpt_dir=str(tmp_path / "ck"), ckpt_every=10, opt=opt)
    losses = report["losses"]
    assert len(losses) == 20
    assert np.isfinite(losses).all()
    # checkpoints were committed
    from repro.checkpoint import store
    assert store.latest_step(str(tmp_path / "ck")) == 20


def test_train_driver_resume(tmp_path):
    cfg = configs.get_reduced("qwen3-8b")
    opt = AdamW(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    d = str(tmp_path / "ck")
    train(cfg, steps=10, global_batch=2, seq_len=16, ckpt_dir=d,
          ckpt_every=5, opt=opt)
    from repro.checkpoint import store
    assert store.latest_step(d) == 10
    # second run resumes from step 10 and continues
    report = train(cfg, steps=5, global_batch=2, seq_len=16, ckpt_dir=d,
                   ckpt_every=5, opt=opt)
    assert report["final_step"] == 15
    assert store.latest_step(d) == 15


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "xlstm-1.3b"])
def test_serve_driver_generates(arch):
    cfg = configs.get_reduced(arch)
    out = serve(cfg, batch=2, prompt_len=8, gen_tokens=6, seed=0)
    assert out["generated"].shape == (2, 6)
    assert (out["generated"] >= 0).all()
    assert (out["generated"] < cfg.vocab_size).all()


def test_serve_rejects_encoder_only():
    cfg = configs.get_reduced("hubert-xlarge")
    with pytest.raises(ValueError, match="encoder-only"):
        serve(cfg, batch=1, prompt_len=4, gen_tokens=2)
