"""Layer-level unit tests: RoPE/M-RoPE, RMSNorm, hints, SSM primitives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import hints, layers, ssm


def test_rms_norm_unit_scale():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 3, 64)) * 7.0, jnp.float32)
    y = layers.rms_norm(x, jnp.ones(64))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-3)


def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(1)
    B, S, H, dh = 1, 8, 2, 32
    x = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y = layers.apply_rope(x, pos, 1e4)
    # rotation preserves norms
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # relative property: <R_m q, R_n k> depends only on (m - n)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, dh)), jnp.float32)
    def dot_at(m, n):
        qa = layers.apply_rope(q, jnp.asarray([[m]]), 1e4)
        ka = layers.apply_rope(k, jnp.asarray([[n]]), 1e4)
        return float(jnp.sum(qa * ka))
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(5, 0)) > 1e-4  # different offsets differ


def test_mrope_text_mode_equals_rope():
    """With t=h=w=index, M-RoPE must reduce to standard RoPE."""
    rng = np.random.default_rng(2)
    B, S, H, dh = 1, 6, 2, 32
    x = jnp.asarray(rng.standard_normal((B, S, H, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    ref = layers.apply_rope(x, pos, 1e4)
    got = layers.apply_mrope(x, layers.text_mrope_positions(pos), 1e4, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_mrope_distinct_axes_differ():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((1, 4, 1, 32)), jnp.float32)
    pos3 = jnp.zeros((1, 4, 3), jnp.int32).at[..., 1].set(jnp.arange(4)[None])
    pos3b = jnp.zeros((1, 4, 3), jnp.int32).at[..., 2].set(jnp.arange(4)[None])
    a = layers.apply_mrope(x, pos3, 1e4, (4, 6, 6))
    b = layers.apply_mrope(x, pos3b, 1e4, (4, 6, 6))
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_hints_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = hints.constrain(x, "data", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert hints.batch_axes() == ()


def test_hints_active_under_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        sizes = hints.axis_sizes()
        assert sizes == {"data": 1, "model": 1}
        x = jnp.ones((4, 8))
        y = hints.constrain(x, "data", ("model?", 8))
        assert y.shape == x.shape
        # unknown axes are dropped, not errors
        z = hints.constrain(x, "nonexistent", None)
        assert z.shape == x.shape


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_mamba_chunked_scan_matches_sequential(seed):
    """The chunked associative scan == naive sequential recurrence."""
    rng = np.random.default_rng(seed)
    B, T, di, ds = 1, 16, 4, 3
    dA = jnp.asarray(rng.uniform(0.5, 1.0, (B, T, di, ds)), jnp.float32)
    dBx = jnp.asarray(rng.standard_normal((B, T, di, ds)) * 0.1, jnp.float32)
    C = jnp.asarray(rng.standard_normal((B, T, ds)), jnp.float32)
    got = np.asarray(ssm._ssm_scan_chunked(dA, dBx, C, chunk=4))
    # naive reference
    h = np.zeros((B, di, ds), np.float32)
    want = np.zeros((B, T, di), np.float32)
    for t in range(T):
        h = np.asarray(dA)[:, t] * h + np.asarray(dBx)[:, t]
        want[:, t] = (h * np.asarray(C)[:, t][:, None, :]).sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_mlstm_chunked_matches_stepwise():
    """Chunked mLSTM == one-token recurrence applied sequentially."""
    rng = np.random.default_rng(7)
    B, H, T, dh = 1, 2, 8, 4
    q = jnp.asarray(rng.standard_normal((B, H, T, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, T, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, T, dh)), jnp.float32)
    li = jnp.asarray(rng.standard_normal((B, H, T)), jnp.float32)
    lf = jnp.asarray(np.log(rng.uniform(0.5, 0.99, (B, H, T))), jnp.float32)
    got = np.asarray(ssm._mlstm_chunk_scan(q, k, v, li, lf, chunk=4))
    # stepwise reference (stabilized recurrence)
    C = np.zeros((B, H, dh, dh)); n = np.zeros((B, H, dh)); m = np.full((B, H), -1e30)
    scale = 1 / np.sqrt(dh)
    want = np.zeros((B, H, T, dh))
    qn, kn, vn = np.asarray(q), np.asarray(k), np.asarray(v)
    lin, lfn = np.asarray(li), np.asarray(lf)
    for t in range(T):
        m_new = np.maximum(lfn[:, :, t] + m, lin[:, :, t])
        i_p = np.exp(lin[:, :, t] - m_new)
        f_p = np.exp(lfn[:, :, t] + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] * (
            kn[:, :, t][..., :, None] * vn[:, :, t][..., None, :])
        n = f_p[..., None] * n + i_p[..., None] * kn[:, :, t]
        num = np.einsum("bhd,bhde->bhe", qn[:, :, t] * scale, C)
        den = np.einsum("bhd,bhd->bh", qn[:, :, t] * scale, n)
        want[:, :, t] = num / np.maximum(np.abs(den), np.exp(-m_new))[..., None]
        m = m_new
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_swiglu_shapes():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8)), jnp.float32)
    w_in = jnp.asarray(rng.standard_normal((8, 12)), jnp.float32)
    w_out = jnp.asarray(rng.standard_normal((6, 8)), jnp.float32)
    y = layers.swiglu(x, w_in, w_out)
    assert y.shape == (2, 8)
