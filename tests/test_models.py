"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned architecture: instantiate the reduced config, run one forward
and one train step on CPU, assert output shapes and no NaNs (deliverable f).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.optim import AdamW

ARCH_IDS = sorted(configs.ARCHS)


def _batch_for(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "tokens":
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)}
    return {
        "frames": jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_forward_shapes_and_finite(arch_id):
    cfg = configs.get_reduced(arch_id)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    if cfg.frontend == "tokens":
        inputs = {"tokens": batch["tokens"][:, :-1]}
        B, S = inputs["tokens"].shape
    else:
        inputs = {"frames": batch["frames"]}
        B, S = batch["frames"].shape[:2]
    logits, aux = M.forward(params, inputs, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    cfg = configs.get_reduced(arch_id)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamW(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = opt.init(params)
    step = jax.jit(M.make_train_step(cfg, opt))
    batch = _batch_for(cfg)
    l0, params, opt_state = step(params, opt_state, batch)
    l1, params, opt_state = step(params, opt_state, batch)
    l2, _, _ = step(params, opt_state, batch)
    assert np.isfinite(float(l0)) and np.isfinite(float(l2))
    assert float(l2) < float(l0)  # optimizing the same batch must descend


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if configs.get_reduced(a).supports_decode])
def test_arch_decode_matches_forward(arch_id):
    """prefill(P) + decode(t) logits == forward(P+t) next-token logits."""
    cfg = configs.get_reduced(arch_id)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 17)), jnp.int32)
    lf, cache = M.prefill(params, {"tokens": toks[:, :16]}, cfg, S_max=18)
    full16, _ = M.forward(params, {"tokens": toks[:, :16]}, cfg)
    np.testing.assert_allclose(np.asarray(lf[:, 0]), np.asarray(full16[:, -1]),
                               rtol=5e-3, atol=5e-3)
    # attention/mla archs carry exact caches; ssm/hybrid prefill leaves a
    # fresh state (documented in model.prefill), so only check decode runs
    pos = jnp.full((2,), 16, jnp.int32)
    ld, cache2 = M.decode_step(params, cache, toks[:, 16], pos, cfg)
    assert ld.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(ld).all())
    if all(b in ("attn", "mla", "mlp", "moe") for b in cfg.period):
        full17, _ = M.forward(params, {"tokens": toks[:, :17]}, cfg)
        np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full17[:, -1]),
                                   rtol=5e-3, atol=5e-3)


def test_ssm_decode_matches_forward_stepwise():
    """For the recurrent families, decoding token-by-token from scratch must
    match the chunked/parallel forward pass (state correctness)."""
    cfg = configs.get_reduced("xlstm-1.3b")
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    T = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, T)), jnp.int32)
    logits_par, _ = M.forward(params, {"tokens": toks}, cfg)
    cache = M.init_cache(cfg, B=1, S_max=T)
    outs = []
    for t in range(T):
        ld, cache = M.decode_step(params, cache, toks[:, t], jnp.asarray([t], jnp.int32), cfg)
        outs.append(np.asarray(ld[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(logits_par), rtol=2e-2, atol=2e-2)


def test_mamba_decode_matches_forward_stepwise():
    from repro.models.ssm import MambaDims
    cfg = M.ModelConfig(
        name="mamba-test", family="ssm", n_periods=2, period=("mamba",),
        d_model=32, vocab_size=64, dtype="float32", ssm_chunk=4,
        mamba=MambaDims(d_inner=64, d_state=8), sub_quadratic=True,
    )
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    T = 8
    toks = jnp.asarray(rng.integers(0, 64, (1, T)), jnp.int32)
    logits_par, _ = M.forward(params, {"tokens": toks}, cfg)
    cache = M.init_cache(cfg, B=1, S_max=T)
    outs = []
    for t in range(T):
        ld, cache = M.decode_step(params, cache, toks[:, t], jnp.asarray([t], jnp.int32), cfg)
        outs.append(np.asarray(ld[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(logits_par), rtol=2e-3, atol=2e-3)


def test_cost_mode_preserves_loss_value():
    """unroll_scan (cost-extraction mode) must not change train-path numerics
    for non-slstm archs (slstm swaps in the FLOP-equivalent parallel form)."""
    import dataclasses
    cfg = configs.get_reduced("jamba-1.5-large-398b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    l_scan = float(M.loss_fn(params, batch, cfg))
    l_unroll = float(M.loss_fn(params, batch, dataclasses.replace(cfg, unroll_scan=True)))
    assert abs(l_scan - l_unroll) < 1e-4


def test_param_specs_cover_tree_and_divide():
    """Every param leaf gets a spec; sharded dims divide the mesh axes."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch_id in ARCH_IDS:
        cfg = configs.get_config(arch_id)
        ap = M.abstract_params(cfg)
        specs = M.param_specs(cfg, ap, mesh)
        flat_p = jax.tree.leaves(ap)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert len(flat_p) == len(flat_s)
