"""MoE dispatch property tests (capacity, gating, EP invariants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import layers, moe


def _params_and_input(E, k, d, f, T, seed, n_shared=0, dispatch="global"):
    dims = moe.MoEDims(num_experts=E, top_k=k, d_ff=f, n_shared=n_shared,
                       dispatch=dispatch)
    p = moe.init_params(jax.random.PRNGKey(seed), d, dims, jnp.float32)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, T // 2, d)), jnp.float32)
    return dims, p, x


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([4, 8]), st.sampled_from([1, 2]))
def test_moe_output_finite_and_residual(seed, E, k):
    dims, p, x = _params_and_input(E, k, 16, 32, 16, seed)
    out, aux = moe.forward(p, x, dims)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))
    # zero expert weights => output == residual input exactly
    p0 = dict(p)
    p0["w_out"] = jnp.zeros_like(p["w_out"])
    out0, _ = moe.forward(p0, x, dims)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(x), atol=1e-6)


def test_moe_capacity_formula():
    dims = moe.MoEDims(num_experts=8, top_k=2, d_ff=4, capacity_factor=1.25)
    C = moe.capacity(64, dims)
    assert C >= 64 * 2 / 8 * 1.25
    assert C % 8 == 0


def test_moe_capacity_drop_changes_output():
    """With capacity_factor tiny, tokens get dropped (less expert output)."""
    dims_full, p, x = _params_and_input(4, 2, 16, 32, 32, seed=0)
    dims_tight = dims_full._replace(capacity_factor=0.05)
    out_full, _ = moe.forward(p, x, dims_full)
    out_tight, _ = moe.forward(p, x, dims_tight)
    # dropped tokens fall back to the residual: outputs differ
    assert not np.allclose(np.asarray(out_full), np.asarray(out_tight))
    # and the tight version is closer to the input on average
    d_full = float(jnp.mean(jnp.abs(out_full - x)))
    d_tight = float(jnp.mean(jnp.abs(out_tight - x)))
    assert d_tight <= d_full + 1e-6


def test_moe_aux_loss_balanced_vs_collapsed():
    """Aux loss is ~1x aux_coef for uniform routing, larger when collapsed."""
    E, k, d, f, T = 8, 1, 16, 16, 512
    dims, p, x = _params_and_input(E, k, d, f, T, seed=3)
    # uniform router -> balanced
    p_bal = dict(p)
    p_bal["router"] = jnp.zeros_like(p["router"])
    _, aux_bal = moe.forward(p_bal, x, dims)
    # biased router -> collapse onto one expert
    p_col = dict(p)
    p_col["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(20.0)
    _, aux_col = moe.forward(p_col, x, dims)
    assert float(aux_col) > float(aux_bal) * 1.5


def test_rowwise_matches_global_exactly_single_row():
    dims, p, x = _params_and_input(8, 2, 16, 32, 64, seed=1)
    out_g, aux_g = moe.forward(p, x, dims)
    out_r, aux_r = moe.forward(p, x, dims._replace(dispatch="rowwise"))
    # single device -> rows=1 -> same capacity -> identical dispatch
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(aux_g), float(aux_r), rtol=1e-6)


def test_shared_expert_always_on():
    dims, p, x = _params_and_input(4, 1, 16, 8, 16, seed=2, n_shared=1)
    # zero the routed experts: output still differs from input (shared path)
    p2 = dict(p)
    p2["w_out"] = jnp.zeros_like(p["w_out"])
    out, _ = moe.forward(p2, x, dims)
    assert not np.allclose(np.asarray(out), np.asarray(x))
